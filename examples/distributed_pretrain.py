"""Distributed LM pretraining on scholarly text (8 placeholder devices).

Demonstrates the production path end-to-end at example scale: P3SAPP
pipeline → packed LM batches → (data, model) mesh → sharded params via
the logical-axis rule engine → microbatched train step → checkpointed
loop. MUST be launched directly (device count is locked at jax init):

    PYTHONPATH=src python examples/distributed_pretrain.py --steps 20
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_smoke
from repro.core.p3sapp import p3sapp_dataset
from repro.data.synthetic import write_corpus
from repro.distributed.sharding import tree_shardings
from repro.launch.mesh import make_host_mesh
from repro.models.lm import LM, MeshContext
from repro.optim.adamw import AdamW
from repro.runtime.train_loop import TrainStepConfig, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--arch", default="stablelm_3b")
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    args = ap.parse_args()

    corpus = tempfile.mkdtemp(prefix="p3sapp_corpus_")
    write_corpus(corpus, total_bytes=2_000_000, n_files=4, seed=7)
    ds = p3sapp_dataset([corpus])
    records, _ = ds.execute(optimize=True)
    tok = ds.fit_vocab(["abstract"], vocab_size=2000)

    cfg = get_smoke(args.arch)
    # pack abstracts into contiguous LM sequences
    stream = []
    for r in records:
        stream.extend(tok.stoi.get(w, 3) for w in r["abstract"].split())
    stream = np.asarray(stream[: (len(stream) // args.seq_len) * args.seq_len], np.int32)
    seqs = stream.reshape(-1, args.seq_len) % cfg.vocab_size

    mesh = make_host_mesh(model_parallel=2)
    print(f"mesh: {dict(mesh.shape)}")
    mctx = MeshContext(mesh, ("data",), "model")
    model = LM(cfg, mctx, remat=True, dtype=jnp.float32)
    opt = AdamW(learning_rate=3e-3)
    step = make_train_step(model.loss, opt, TrainStepConfig(n_microbatches=2))

    with jax.sharding.set_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        params = jax.tree.map(jax.device_put, params, tree_shardings(shapes, model.param_axes(), mesh))
        opt_state = opt.init(params)
        jstep = jax.jit(step, donate_argnums=(0, 1))
        bsh = NamedSharding(mesh, P("data", None))
        rng = np.random.default_rng(0)
        for i in range(args.steps):
            idx = rng.integers(0, len(seqs), size=args.batch)
            batch = {"tokens": jax.device_put(jnp.asarray(seqs[idx]), bsh)}
            params, opt_state, m = jstep(params, opt_state, batch)
            if i % 5 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss={float(m['loss']):.4f} gnorm={float(m['grad_norm']):.3f}")
    print("distributed pretrain example complete")


if __name__ == "__main__":
    main()
