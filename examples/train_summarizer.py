"""End-to-end driver (paper case study): title generation from abstracts.

Pipeline: synthetic CORE corpus → P3SAPP preprocessing → tokenizer →
async double-buffered loader → LSTM seq2seq with Bahdanau attention →
checkpointed training (resume-capable) → greedy inference samples.

Runs a few hundred steps on CPU by default:

    PYTHONPATH=src python examples/train_summarizer.py --steps 300
"""

import argparse
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.p3sapp_summarizer import CONFIG, SMOKE
from repro.core.async_loader import AsyncLoader
from repro.core.p3sapp import run_p3sapp
from repro.data.batching import batches, seq2seq_arrays, train_val_split
from repro.data.synthetic import write_corpus
from repro.data.tokenizer import WordTokenizer
from repro.models.seq2seq import Seq2Seq
from repro.optim.adamw import AdamW, warmup_cosine
from repro.runtime.fault_tolerance import TrainController


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--corpus-mb", type=float, default=4.0)
    ap.add_argument("--smoke", action="store_true", help="tiny model config")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = SMOKE if args.smoke else CONFIG
    corpus = tempfile.mkdtemp(prefix="p3sapp_corpus_")
    write_corpus(corpus, total_bytes=int(args.corpus_mb * 1e6), n_files=8, seed=1)

    t0 = time.perf_counter()
    records, timings = run_p3sapp([corpus], optimize=True)
    print(f"P3SAPP preprocessing: {timings.cumulative:.2f}s, {len(records)} records")

    tok = WordTokenizer.fit(
        (r["abstract"] + " " + r["title"] for r in records), vocab_size=cfg.vocab_size
    )
    arrs = seq2seq_arrays(records, tok, cfg.max_abstract_len, cfg.max_title_len)
    train, val = train_val_split(arrs, 0.1)
    print(f"train={len(train['encoder_tokens'])} val={len(val['encoder_tokens'])}")

    model = Seq2Seq(cfg)
    opt = AdamW(learning_rate=warmup_cosine(3e-3, 20, args.steps), weight_decay=1e-4)

    @jax.jit
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state, gnorm = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    def init_state():
        params = model.init(jax.random.PRNGKey(0))
        return params, opt.init(params)

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="p3sapp_ckpt_")
    controller = TrainController(ckpt_dir, train_step, init_state, save_every=100)
    if controller.resumed:
        print(f"resumed from step {controller.step}")

    def batch_stream():
        epoch = 0
        while True:
            yield from batches(train, args.batch_size, seed=epoch)
            epoch += 1

    loader = AsyncLoader(batch_stream(), prefetch=2)
    history = controller.run(iter(loader), n_steps=args.steps)
    if history:
        print(f"step {history[0]['step']}: loss={history[0]['loss']:.3f}")
        print(f"step {history[-1]['step']}: loss={history[-1]['loss']:.3f}")

    # validation loss + greedy samples (paper Algorithm 3)
    val_loss = float(model.loss(controller.params, {k: jnp.asarray(v[:64]) for k, v in val.items()}))
    print(f"val loss: {val_loss:.3f}")
    gen = model.generate(controller.params, val["encoder_tokens"][:3])
    for i in range(3):
        print(f"  gold: {tok.decode(val['decoder_tokens'][i])}")
        print(f"  pred: {tok.decode(np.asarray(gen[i]))}\n")
    print(f"total wall time: {time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    main()
