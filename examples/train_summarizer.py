"""End-to-end driver (paper case study): title generation from abstracts.

One declarative ``Dataset`` chain takes the synthetic CORE corpus all the
way to device-resident batches — ingestion, pre-cleaning, the Spark-ML-style
stage chain, tokenization, batching, and async prefetch are a single lazy
plan the planner fuses and overlaps with device compute. The model side is
an LSTM seq2seq with Bahdanau attention, checkpointed training
(resume-capable), and greedy inference samples.

Runs a few hundred steps on CPU by default:

    PYTHONPATH=src python examples/train_summarizer.py --steps 300
"""

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.p3sapp_summarizer import CONFIG, SMOKE
from repro.core.dataset import Dataset
from repro.core.expr import abstract_expr, col, title_expr
from repro.data.batching import seq2seq_specs
from repro.data.synthetic import write_corpus
from repro.models.seq2seq import Seq2Seq
from repro.optim.adamw import AdamW, warmup_cosine
from repro.runtime.fault_tolerance import TrainController


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--corpus-mb", type=float, default=4.0)
    ap.add_argument("--smoke", action="store_true", help="tiny model config")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = SMOKE if args.smoke else CONFIG
    corpus = tempfile.mkdtemp(prefix="p3sapp_corpus_")
    write_corpus(corpus, total_bytes=int(args.corpus_mb * 1e6), n_files=8, seed=1)

    t0 = time.perf_counter()
    # The full preprocessing flow is one lazy plan of column expressions;
    # nothing executes yet.
    keep = col("title").not_empty() & col("abstract").not_empty()
    clean = (
        Dataset.from_json_dirs([corpus])
        .where(keep)
        .drop_duplicates()
        .transform(abstract=abstract_expr(), title=title_expr())
        .where(keep)
    )
    records, timings = clean.execute(optimize=True)
    print(f"P3SAPP preprocessing: {timings.cumulative:.2f}s, {len(records)} records")

    # Vocabulary fitting is a plan verb: per-shard word counts merged on
    # the driver when streaming, the memoized frame here (one clean pass).
    tok = clean.fit_vocab(vocab_size=cfg.vocab_size)
    train_ds, val_ds = clean.split(val_fraction=0.1, seed=0)
    specs = seq2seq_specs(cfg.max_abstract_len, cfg.max_title_len)
    # ingest → where → transform → tokenize → batched → prefetch →
    # device_batches: the cleaned frame is memoized, so this reuses the
    # pass above; paired 2-D length-bucketed assembly trims encoder *and*
    # decoder padding to a small fixed grid (one jit compile per cell).
    loader = (
        train_ds.tokenize(tok, specs)
        .batched(
            args.batch_size, shuffle=True,
            bucket_by=("encoder_tokens", "decoder_tokens"),
        )
        .prefetch(2)
        .device_batches(epochs=None)
    )
    val = val_ds.tokenize(tok, specs).arrays()
    n_train = len(records) - len(next(iter(val.values())))
    print(f"train={n_train} val={len(next(iter(val.values())))}")

    model = Seq2Seq(cfg)
    opt = AdamW(learning_rate=warmup_cosine(3e-3, 20, args.steps), weight_decay=1e-4)

    @jax.jit
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state, gnorm = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    def init_state():
        params = model.init(jax.random.PRNGKey(0))
        return params, opt.init(params)

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="p3sapp_ckpt_")
    controller = TrainController(ckpt_dir, train_step, init_state, save_every=100)
    if controller.resumed:
        print(f"resumed from step {controller.step}")

    try:
        history = controller.run(iter(loader), n_steps=args.steps)
    finally:
        loader.close()  # endless epoch stream; stop the prefetch thread cleanly
    if history:
        print(f"step {history[0]['step']}: loss={history[0]['loss']:.3f}")
        print(f"step {history[-1]['step']}: loss={history[-1]['loss']:.3f}")

    # validation loss + greedy samples (paper Algorithm 3)
    val_loss = float(model.loss(controller.params, {k: jnp.asarray(v[:64]) for k, v in val.items()}))
    print(f"val loss: {val_loss:.3f}")
    gen = model.generate(controller.params, val["encoder_tokens"][:3])
    for i in range(3):
        print(f"  gold: {tok.decode(val['decoder_tokens'][i])}")
        print(f"  pred: {tok.decode(np.asarray(gen[i]))}\n")
    print(f"total wall time: {time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    main()
