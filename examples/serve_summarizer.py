"""Text-in/title-out serving example: the zero-skew request path.

Builds a tiny corpus, fits the preprocessing plan + vocabulary, lowers the
*same compiled plan* the training executors run into a per-request
``RowProgram`` (``dataset.row_program()``), and serves raw abstract text
through continuous batching (``serve_text``): bounded admission queue,
fixed decode slots with prefill refill, and a ring cache that answers a
repeated abstract without touching the model. The decoded titles come
back through the same tokenizer the plan was fitted with.

    PYTHONPATH=src python examples/serve_summarizer.py
"""

import argparse
import dataclasses
import json
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core.dataset import Dataset
from repro.core.expr import abstract_expr, col
from repro.data.batching import TokenSpec
from repro.models.lm import LM
from repro.runtime.serve_loop import RingCache, ServeStats, TextRequest, serve_text

CORPUS = [
    {"abstract": "Deep learning methods now drive scholarly data applications."},
    {"abstract": "A Spark ML pipeline cleans abstracts before model training."},
    {"abstract": "Continuous batching keeps decode slots busy between requests."},
    {"abstract": "Columnar byte kernels make text preprocessing vectorized."},
    {"abstract": "The ring cache answers repeated prompts without decoding."},
    {"abstract": "Shard executors stream token batches to the training loop."},
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    # 1. Fit the preprocessing plan + vocabulary on a tiny corpus, exactly
    # like training would, then lower it to a per-request row program.
    corpus_dir = Path(tempfile.mkdtemp(prefix="serve_corpus_")) / "shards"
    corpus_dir.mkdir()
    with open(corpus_dir / "shard-0.jsonl", "w", encoding="utf-8") as f:
        for rec in CORPUS:
            f.write(json.dumps(rec) + "\n")
    ds = (
        Dataset.from_json_dirs([corpus_dir], fields=("abstract",))
        .where(col("abstract").not_empty())
        .transform(abstract=abstract_expr())
    )
    tok = ds.fit_vocab(vocab_size=200)
    row_program = (
        ds.tokenize(tok, [TokenSpec("abstract", 32)])
        .batched(4)
        .prefetch(2)
        .row_program()
    )
    print(f"row program: fields={row_program.fields} backend={row_program.backend}")

    # 2. A tiny decoder LM (smoke config, vocab swapped for the fitted
    # tokenizer's) stands in for a trained summarizer — this example
    # exercises the serving runtime, not model quality.
    cfg = dataclasses.replace(get_smoke("stablelm_3b"), vocab_size=len(tok.itos))
    model = LM(cfg, remat=False, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))

    # 3. Serve raw text. The last request repeats the first abstract, so
    # it completes from the ring cache (watch cache_hits); the empty
    # request is filtered by the plan and answered with [].
    texts = [rec["abstract"] for rec in CORPUS] + ["", CORPUS[0]["abstract"]]
    reqs = [TextRequest(uid, t, max_new=args.max_new) for uid, t in enumerate(texts)]
    cache = RingCache(slots=32)
    stats = ServeStats()
    # Two waves so the repeat arrives after the original's answer is cached.
    results = dict(
        serve_text(model, params, row_program, reqs[:-1], slots=args.slots,
                   max_seq=64, cache=cache, stats=stats)
    )
    results.update(
        serve_text(model, params, row_program, reqs[-1:], slots=args.slots,
                   max_seq=64, cache=cache, stats=stats)
    )

    for uid in sorted(results):
        toks = results[uid]
        title = tok.decode(toks) if toks else "(filtered)"
        print(f"request {uid}: {texts[uid][:48]!r:50} -> {title!r}")
    print(
        f"served {stats.served}/{len(reqs)} through {args.slots} slots: "
        f"{stats.filtered} filtered, {stats.cache_hits} cache hit(s), "
        f"preprocess {stats.preprocess_s * 1e3:.1f} ms / "
        f"decode {stats.decode_s * 1e3:.1f} ms"
    )
    assert len(results) == len(reqs)
    assert stats.cache_hits >= 1 and results[len(texts) - 1] == results[0]


if __name__ == "__main__":
    main()
