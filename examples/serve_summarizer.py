"""Batched serving example: continuous-batching title generation.

Trains a tiny summarizer briefly (or restores a checkpoint), then serves
a queue of abstract-summarization requests through fixed decode slots
(repro.runtime.serve_loop).

    PYTHONPATH=src python examples/serve_summarizer.py
"""

import argparse

import jax
import numpy as np

from repro.models.lm import LM
from repro.configs import get_smoke
from repro.runtime.serve_loop import Request, serve_requests


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=3)
    args = ap.parse_args()

    # A tiny decoder LM (stablelm family smoke config) stands in for the
    # serving engine; the summarizer seq2seq has its own generate() (see
    # train_summarizer.py) — this example exercises the KV-cache serving
    # runtime: slots, prefill, continuous refill.
    cfg = get_smoke("stablelm_3b")
    model = LM(cfg, remat=False, dtype=jax.numpy.float32)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i, prompt=rng.integers(4, cfg.vocab_size, size=rng.integers(4, 10)).astype(np.int32),
                max_new=8)
        for i in range(args.requests)
    ]
    results = serve_requests(model, params, reqs, slots=args.slots, max_seq=64)
    for uid in sorted(results):
        print(f"request {uid}: {len(results[uid])} tokens -> {results[uid]}")
    assert len(results) == args.requests
    print(f"served {len(results)} requests through {args.slots} slots")


if __name__ == "__main__":
    main()
