"""Quickstart: the paper's pipeline as one lazy Dataset plan.

Generates a small synthetic CORE-style corpus, declares the P3SAPP flow
(ingest → pre-clean → stage chain → records) as a single declarative chain,
prints the optimized plan, compares against the conventional approach, and
prints the paper's headline numbers for this scale — then carries the same
plan into token space: ``fit_vocab`` (shard-merged word counts) →
``tokenize`` → length-bucketed ``batched``, all inside the planner.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

from repro.core.dataset import Dataset
from repro.core.p3sapp import case_study_stages, record_match_accuracy, run_conventional
from repro.data.batching import pad_token_fraction, seq2seq_specs
from repro.data.synthetic import write_corpus


def main() -> None:
    corpus = tempfile.mkdtemp(prefix="p3sapp_quickstart_")
    write_corpus(corpus, total_bytes=3_000_000, n_files=6, seed=42)
    print(f"corpus: {corpus}")

    # Nothing below executes until .execute(): the chain is a logical plan
    # the planner fuses (per-column op chains) and reorders (filter pushdown).
    ds = (
        Dataset.from_json_dirs([corpus])
        .dropna()
        .drop_duplicates()
        .apply(*case_study_stages())
        .dropna()
    )
    print(ds.explain())

    pa_records, t_pa = ds.execute(optimize=True)
    ca_records, t_ca = run_conventional([corpus])

    print(f"\nP3SAPP : {t_pa.as_dict()}")
    print(f"CA     : {t_ca.as_dict()}")
    print(f"\ningestion reduction    : {100 * (1 - t_pa.ingestion / t_ca.ingestion):.1f}%")
    print(f"preprocessing reduction: {100 * (1 - t_pa.preprocessing / t_ca.preprocessing):.1f}%")
    print(f"cumulative reduction   : {100 * (1 - t_pa.cumulative / t_ca.cumulative):.1f}%")
    for field in ("title", "abstract"):
        acc = record_match_accuracy(ca_records, pa_records, field)
        print(f"record match ({field:8s}): {acc['percentage']:.2f}%")

    print("\nsample cleaned record:")
    r = pa_records[0]
    print(f"  title   : {r['title'][:70]}")
    print(f"  abstract: {r['abstract'][:70]}...")

    # -- token space: the same plan, continued ------------------------------
    # fit_vocab is the Spark CountVectorizer-style fit half (per-shard
    # Counters, merged deterministically); tokenize/batched extend the
    # plan to int32 device-ready batches. The cleaned frame above is
    # memoized, so none of this re-reads or re-cleans the corpus.
    tok = ds.fit_vocab(vocab_size=4000)
    specs = seq2seq_specs(max_abstract_len=64, max_title_len=12)
    fixed = list(
        ds.tokenize(tok, specs).batch(32, shuffle=False).iter_batches()
    )
    bucketed = list(
        ds.tokenize(tok, specs)
        .batched(32, shuffle=False, bucket_by="encoder_tokens")
        .iter_batches()
    )
    print(f"\nvocab: {len(tok)} words, {len(bucketed)} batches")
    f_fixed = pad_token_fraction(fixed, "encoder_tokens")
    f_bucket = pad_token_fraction(bucketed, "encoder_tokens")
    print(f"pad fraction fixed max_len : {100 * f_fixed:.1f}%")
    print(f"pad fraction bucketed      : {100 * f_bucket:.1f}%")
    print(f"encoder shapes: {sorted({b['encoder_tokens'].shape for b in bucketed})}")


if __name__ == "__main__":
    main()
