"""Quickstart: the paper's pipeline as one lazy Dataset plan, declared
with composable column expressions.

Generates a small synthetic CORE-style corpus, declares the P3SAPP flow
(ingest → filter → per-column expressions → records) as a single
declarative chain — ``where`` predicates run on raw byte buffers and are
pushed toward the source, expression chains fuse per column — prints the
optimized plan, compares against the conventional approach, and prints
the paper's headline numbers for this scale. The same plan then carries
into token space: ``fit_vocab`` (shard-merged word counts) → ``tokenize``
→ length-bucketed ``batched`` (including the paired encoder/decoder 2-D
grid), all inside the planner.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

from repro.core.dataset import Dataset
from repro.core.expr import abstract_expr, col, title_expr
from repro.core.p3sapp import record_match_accuracy, run_conventional
from repro.data.batching import pad_token_fraction, seq2seq_specs
from repro.data.synthetic import write_corpus


def main() -> None:
    corpus = tempfile.mkdtemp(prefix="p3sapp_quickstart_")
    write_corpus(corpus, total_bytes=3_000_000, n_files=6, seed=42)
    print(f"corpus: {corpus}")

    # Nothing below executes until .execute(): the chain is a logical plan
    # the planner fuses (per-column expression chains) and reorders
    # (where-predicate pushdown). The expressions are the paper's Fig. 2/3
    # workflows — compose your own with col("x").lower().regex_replace(...)
    # / .where(col("x").word_count() >= n) for arbitrary scenarios.
    keep = col("title").not_empty() & col("abstract").not_empty()
    ds = (
        Dataset.from_json_dirs([corpus])
        .where(keep)
        .drop_duplicates()
        .transform(abstract=abstract_expr(), title=title_expr())
        .where(keep)
    )
    print(ds.explain())

    pa_records, t_pa = ds.execute(optimize=True)
    ca_records, t_ca = run_conventional([corpus])

    print(f"\nP3SAPP : {t_pa.as_dict()}")
    print(f"CA     : {t_ca.as_dict()}")
    print(f"\ningestion reduction    : {100 * (1 - t_pa.ingestion / t_ca.ingestion):.1f}%")
    print(f"preprocessing reduction: {100 * (1 - t_pa.preprocessing / t_ca.preprocessing):.1f}%")
    print(f"cumulative reduction   : {100 * (1 - t_pa.cumulative / t_ca.cumulative):.1f}%")
    for field in ("title", "abstract"):
        acc = record_match_accuracy(ca_records, pa_records, field)
        print(f"record match ({field:8s}): {acc['percentage']:.2f}%")

    print("\nsample cleaned record:")
    r = pa_records[0]
    print(f"  title   : {r['title'][:70]}")
    print(f"  abstract: {r['abstract'][:70]}...")

    # -- token space: the same plan, continued ------------------------------
    # fit_vocab is the Spark CountVectorizer-style fit half (per-shard
    # Counters, merged deterministically); tokenize/batched extend the
    # plan to int32 device-ready batches — the executors bulk-encode off
    # the flat byte buffers (VocabTable), no per-word Python loop. The
    # cleaned frame above is memoized, so none of this re-reads or
    # re-cleans the corpus.
    tok = ds.fit_vocab(vocab_size=4000)
    specs = seq2seq_specs(max_abstract_len=64, max_title_len=12)
    fixed = list(
        ds.tokenize(tok, specs).batch(32, shuffle=False).iter_batches()
    )
    bucketed = list(
        ds.tokenize(tok, specs)
        .batched(32, shuffle=False, bucket_by="encoder_tokens")
        .iter_batches()
    )
    paired = list(
        ds.tokenize(tok, specs)
        .batched(32, shuffle=False, bucket_by=("encoder_tokens", "decoder_tokens"))
        .iter_batches()
    )
    print(f"\nvocab: {len(tok)} words, {len(bucketed)} batches")
    for name, batches in (("fixed", fixed), ("bucketed", bucketed), ("paired 2-D", paired)):
        enc = pad_token_fraction(batches, "encoder_tokens")
        dec = pad_token_fraction(batches, "decoder_tokens")
        print(f"pad fraction {name:10s}: encoder {100 * enc:.1f}%  decoder {100 * dec:.1f}%")
    shapes = sorted(
        {
            (b["encoder_tokens"].shape[1], b["decoder_tokens"].shape[1])
            for b in paired
        }
    )
    print(f"paired (encoder, decoder) widths: {shapes}")


if __name__ == "__main__":
    main()
