"""Quickstart: the paper's pipeline in 40 lines.

Generates a small synthetic CORE-style corpus, runs the P3SAPP pipeline
(ingest → pre-clean → Spark-ML-style stage pipeline → records), compares
against the conventional approach, and prints the paper's headline
numbers for this scale.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

from repro.core.p3sapp import record_match_accuracy, run_conventional, run_p3sapp
from repro.data.synthetic import write_corpus


def main() -> None:
    corpus = tempfile.mkdtemp(prefix="p3sapp_quickstart_")
    write_corpus(corpus, total_bytes=3_000_000, n_files=6, seed=42)
    print(f"corpus: {corpus}")

    pa_records, t_pa = run_p3sapp([corpus], optimize=True)
    ca_records, t_ca = run_conventional([corpus])

    print(f"\nP3SAPP : {t_pa.as_dict()}")
    print(f"CA     : {t_ca.as_dict()}")
    print(f"\ningestion reduction    : {100 * (1 - t_pa.ingestion / t_ca.ingestion):.1f}%")
    print(f"preprocessing reduction: {100 * (1 - t_pa.preprocessing / t_ca.preprocessing):.1f}%")
    print(f"cumulative reduction   : {100 * (1 - t_pa.cumulative / t_ca.cumulative):.1f}%")
    for field in ("title", "abstract"):
        acc = record_match_accuracy(ca_records, pa_records, field)
        print(f"record match ({field:8s}): {acc['percentage']:.2f}%")

    print("\nsample cleaned record:")
    r = pa_records[0]
    print(f"  title   : {r['title'][:70]}")
    print(f"  abstract: {r['abstract'][:70]}...")


if __name__ == "__main__":
    main()
