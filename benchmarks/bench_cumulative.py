"""Paper Table 4 / Figs. 9-10: cumulative (ingestion+preprocessing) time
with trend-line slopes. P3SAPP runs as the lazy Dataset plan
(paper-faithful executor, ``optimize=False``).

``--workers N`` adds the shard-executor axis to the cumulative table: the
same chain streamed per shard through N workers (processes when N > 1),
optionally against the plan-fingerprint shard cache (``--cache``) — the
scaling curve the CA-vs-P3SAPP comparison predicts.

``--overlap`` closes the paper's headline claim at the device boundary:
it drives a synthetic jit'd device step against the *warm-cache* streaming
pipeline through :class:`repro.core.device_pipeline.DeviceFeed` and
reports the device-idle fraction per dataset — ~0% means host
preprocessing is fully hidden behind device compute. Each run appends its
rows to the committed ``results/BENCH_cumulative.json`` trajectory, which
``check_regression.py --mode overlap`` gates in CI (idle ceiling +
baseline row coverage)."""

from __future__ import annotations

import json
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core.p3sapp import p3sapp_dataset, run_conventional

from .common import RESULTS_DIR, dataset_dirs, emit

OVERLAP_JSON = RESULTS_DIR / "BENCH_cumulative.json"
TRAJECTORY_CAP = 20  # committed file keeps the last N runs


def run(
    quick: bool = False,
    workers: int | None = None,
    cache: bool = False,
    executor: str | None = None,
) -> list[dict]:
    rows = []
    xs, ca_ys, pa_ys = [], [], []
    for ds_id, d, gb in dataset_dirs(quick):
        _, tp = p3sapp_dataset([d]).execute(optimize=False)
        _, tc = run_conventional([d])
        xs.append(gb)
        ca_ys.append(tc.cumulative)
        pa_ys.append(tp.cumulative)
        row = {
            "name": "table4_cumulative",
            "dataset_id": ds_id,
            "paper_gb": gb,
            "ca_s": round(tc.cumulative, 4),
            "p3sapp_s": round(tp.cumulative, 4),
            "reduction_pct": round(100 * (1 - tp.cumulative / tc.cumulative), 3),
            "us_per_call": round(tp.cumulative * 1e6, 1),
        }
        rows.append(row)
    if workers is not None:
        from .bench_preprocessing import run_scaling

        for srow in run_scaling(quick, workers, cache, executor):
            srow["name"] = "table4_cumulative_workers"
            rows.append(srow)
    if len(xs) >= 2:
        ca_slope = float(np.polyfit(xs, ca_ys, 1)[0])
        pa_slope = float(np.polyfit(xs, pa_ys, 1)[0])
        rows.append({
            "name": "fig10_trendline",
            "dataset_id": "slope",
            "paper_gb": "-",
            "ca_s": round(ca_slope, 4),
            "p3sapp_s": round(pa_slope, 4),
            "reduction_pct": round(ca_slope / max(pa_slope, 1e-9), 2),
            "us_per_call": 0,
        })
    return rows


# ---------------------------------------------------------------------------
# --overlap: device-idle fraction of the warm-cache pipeline
# ---------------------------------------------------------------------------


def _make_device_step(vocab_size: int, dim: int, depth: int):
    """A jit'd synthetic step heavy enough to stand in for real training on
    CPU: embedding gather + ``depth`` square matmuls. Returns (step_fn,
    compile_counter) — the counter increments per trace, so the fixed
    bucket grid's compile-once-per-cell property is observable."""
    import jax
    import jax.numpy as jnp

    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    emb = jax.random.normal(k1, (vocab_size, dim), jnp.float32) * 0.02
    mat = jax.random.normal(k2, (dim, dim), jnp.float32) * 0.02
    compiles = [0]

    @jax.jit
    def step(enc, dec):
        compiles[0] += 1  # python side effect: runs once per trace
        h = emb[enc]
        for _ in range(depth):
            h = jnp.tanh(h @ mat)
        return h.sum() + jnp.sum(dec)

    return step, compiles


def run_overlap(
    quick: bool = False,
    max_steps: int = 200,
    prefetch: int = 4,
    batch_size: int = 32,
    step_dim: int = 192,
    step_depth: int = 2,
) -> list[dict]:
    import jax

    from repro.core.dataset import Dataset
    from repro.core.expr import abstract_expr, col, title_expr
    from repro.data.batching import seq2seq_specs
    from repro.runtime.train_loop import make_input_pipeline

    rows = []
    vocab_size = 4000
    specs = seq2seq_specs(max_abstract_len=64, max_title_len=12)
    for ds_id, d, _gb in dataset_dirs(quick):
        cache_dir = Path(tempfile.gettempdir()) / f"p3sapp_overlap_cache_ds{ds_id}"
        keep = col("title").not_empty() & col("abstract").not_empty()
        base = (
            Dataset.from_json_dirs([d])
            .where(keep)
            .drop_duplicates()
            .transform(abstract=abstract_expr(), title=title_expr())
            .where(keep)
            .cache(cache_dir)
        )
        tok = base.fit_vocab(vocab_size=vocab_size)
        pipe = (
            base.tokenize(tok, specs)
            .batched(
                batch_size,
                shuffle=False,
                bucket_by=("encoder_tokens", "decoder_tokens"),
                drop_remainder=False,
                pad_to=batch_size,
            )
            .prefetch(prefetch)
        )
        # Epoch 0 warms the shard cache (token arrays land on disk); the
        # measured epoch then exercises the paper's steady state: host work
        # is cache reads + bucket assembly, fully overlappable.
        for _ in pipe.iter_batches(epochs=1):
            pass
        step, compiles = _make_device_step(vocab_size, step_dim, step_depth)
        feed = make_input_pipeline(pipe, epochs=1, prefetch=prefetch, overlap=True)
        t0 = time.perf_counter()
        steps = 0
        try:
            for batch in feed:
                with feed.step(batch):
                    out = step(batch["encoder_tokens"], batch["decoder_tokens"])
                    jax.block_until_ready(out)
                steps += 1
                if steps >= max_steps:
                    break
        finally:
            feed.close()
        wall_s = time.perf_counter() - t0
        r = feed.report()
        ls = feed.loader_stats
        rows.append({
            "name": "overlap_device_idle",
            "dataset_id": ds_id,
            "steps": r.steps,
            "device_s": round(r.device_s, 4),
            "host_wait_s": round(r.host_wait_s, 4),
            "transfer_s": round(r.transfer_s, 4),
            "startup_s": round(r.startup_s, 4),
            "starved_steps": r.starved_steps,
            "queue_max_depth": ls.max_depth if ls else 0,
            "idle_pct": round(100 * r.device_idle_fraction, 3),
            "compiles": compiles[0],
            "batches_per_s": round(steps / wall_s, 2) if wall_s else 0.0,
            "us_per_call": round(wall_s / max(steps, 1) * 1e6, 1),
        })
    return rows


def append_trajectory(rows: list[dict], quick: bool, label: str, path: Path) -> None:
    """Append this run to the committed overlap trajectory (bounded)."""
    doc = {"name": "bench_cumulative_overlap", "trajectory": []}
    if path.exists():
        try:
            doc = json.loads(path.read_text())
        except (json.JSONDecodeError, OSError):
            pass  # corrupt local file: restart the trajectory
    doc.setdefault("trajectory", []).append(
        {"label": label, "quick": quick, "rows": rows}
    )
    doc["trajectory"] = doc["trajectory"][-TRAJECTORY_CAP:]
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(doc, indent=2) + "\n")


def main(
    quick: bool = False,
    workers: int | None = None,
    cache: bool = False,
    executor: str | None = None,
    overlap: bool = False,
    max_steps: int = 200,
    prefetch: int = 4,
    label: str = "local",
    out: Path = OVERLAP_JSON,
) -> None:
    if overlap:
        rows = run_overlap(quick, max_steps=max_steps, prefetch=prefetch)
        append_trajectory(rows, quick, label, out)
        emit("overlap_device_idle", rows)
        return
    emit("table4_cumulative", run(quick, workers, cache, executor))


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--workers", type=int, default=None,
                    help="add the shard-executor axis with N workers")
    ap.add_argument("--cache", action="store_true",
                    help="enable the plan-fingerprint shard cache")
    ap.add_argument("--executor", choices=["thread", "process", "remote"],
                    default=None)
    ap.add_argument("--overlap", action="store_true",
                    help="measure device-idle %% of the warm-cache pipeline "
                         "against a synthetic jit'd device step")
    ap.add_argument("--max-steps", type=int, default=200,
                    help="cap measured device steps per dataset (overlap)")
    ap.add_argument("--prefetch", type=int, default=4,
                    help="host prefetch depth of the device feed (overlap)")
    ap.add_argument("--label", default="local",
                    help="trajectory entry label (overlap)")
    ap.add_argument("--out", type=Path, default=OVERLAP_JSON,
                    help="overlap trajectory JSON path")
    args = ap.parse_args()
    main(
        args.quick, args.workers, args.cache, args.executor,
        overlap=args.overlap, max_steps=args.max_steps,
        prefetch=args.prefetch, label=args.label, out=args.out,
    )
