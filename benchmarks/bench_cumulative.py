"""Paper Table 4 / Figs. 9-10: cumulative (ingestion+preprocessing) time
with trend-line slopes. P3SAPP runs as the lazy Dataset plan
(paper-faithful executor, ``optimize=False``).

``--workers N`` adds the shard-executor axis to the cumulative table: the
same chain streamed per shard through N workers (processes when N > 1),
optionally against the plan-fingerprint shard cache (``--cache``) — the
scaling curve the CA-vs-P3SAPP comparison predicts."""

from __future__ import annotations

import numpy as np

from repro.core.p3sapp import p3sapp_dataset, run_conventional

from .common import dataset_dirs, emit


def run(
    quick: bool = False,
    workers: int | None = None,
    cache: bool = False,
    executor: str | None = None,
) -> list[dict]:
    rows = []
    xs, ca_ys, pa_ys = [], [], []
    for ds_id, d, gb in dataset_dirs(quick):
        _, tp = p3sapp_dataset([d]).execute(optimize=False)
        _, tc = run_conventional([d])
        xs.append(gb)
        ca_ys.append(tc.cumulative)
        pa_ys.append(tp.cumulative)
        row = {
            "name": "table4_cumulative",
            "dataset_id": ds_id,
            "paper_gb": gb,
            "ca_s": round(tc.cumulative, 4),
            "p3sapp_s": round(tp.cumulative, 4),
            "reduction_pct": round(100 * (1 - tp.cumulative / tc.cumulative), 3),
            "us_per_call": round(tp.cumulative * 1e6, 1),
        }
        rows.append(row)
    if workers is not None:
        from .bench_preprocessing import run_scaling

        for srow in run_scaling(quick, workers, cache, executor):
            srow["name"] = "table4_cumulative_workers"
            rows.append(srow)
    if len(xs) >= 2:
        ca_slope = float(np.polyfit(xs, ca_ys, 1)[0])
        pa_slope = float(np.polyfit(xs, pa_ys, 1)[0])
        rows.append({
            "name": "fig10_trendline",
            "dataset_id": "slope",
            "paper_gb": "-",
            "ca_s": round(ca_slope, 4),
            "p3sapp_s": round(pa_slope, 4),
            "reduction_pct": round(ca_slope / max(pa_slope, 1e-9), 2),
            "us_per_call": 0,
        })
    return rows


def main(
    quick: bool = False,
    workers: int | None = None,
    cache: bool = False,
    executor: str | None = None,
) -> None:
    emit("table4_cumulative", run(quick, workers, cache, executor))


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--workers", type=int, default=None,
                    help="add the shard-executor axis with N workers")
    ap.add_argument("--cache", action="store_true",
                    help="enable the plan-fingerprint shard cache")
    ap.add_argument("--executor", choices=["thread", "process"], default=None)
    args = ap.parse_args()
    main(args.quick, args.workers, args.cache, args.executor)
