"""Paper Table 2 / Fig. 7: ingestion time, CA vs P3SAPP."""

from __future__ import annotations

import time

from repro.core import conventional as ca
from repro.core import ingest as ing

from .common import dataset_dirs, emit

FIELDS = ("title", "abstract")


def run(quick: bool = False) -> list[dict]:
    rows = []
    for ds_id, d, gb in dataset_dirs(quick):
        t0 = time.perf_counter()
        frame = ing.ingest([d], FIELDS)
        t_pa = time.perf_counter() - t0

        t0 = time.perf_counter()
        rf = ca.ingest_conventional([d], FIELDS)
        t_ca = time.perf_counter() - t0

        assert len(frame) == len(rf)
        rows.append({
            "name": "table2_ingestion",
            "dataset_id": ds_id,
            "paper_gb": gb,
            "rows": len(frame),
            "ca_s": round(t_ca, 4),
            "p3sapp_s": round(t_pa, 4),
            "reduction_pct": round(100 * (1 - t_pa / t_ca), 3),
            "us_per_call": round(t_pa * 1e6, 1),
        })
    return rows


def main(quick: bool = False) -> None:
    emit("table2_ingestion", run(quick))


if __name__ == "__main__":
    main()
