"""Paper Tables 5-6: record-match accuracy between CA and P3SAPP frames."""

from __future__ import annotations

from repro.core.p3sapp import record_match_accuracy, run_conventional, run_p3sapp

from .common import dataset_dirs, emit


def run(quick: bool = False) -> list[dict]:
    rows = []
    for ds_id, d, gb in dataset_dirs(quick):
        pa, _ = run_p3sapp([d])
        ca, _ = run_conventional([d])
        for field, table in (("title", "table5"), ("abstract", "table6")):
            acc = record_match_accuracy(ca, pa, field)
            rows.append({
                "name": f"{table}_accuracy_{field}",
                "dataset_id": ds_id,
                "paper_gb": gb,
                "conventional": acc["conventional"],
                "proposed": acc["proposed"],
                "matching": acc["matching"],
                "percentage": round(acc["percentage"], 3),
                "us_per_call": 0,
            })
    return rows


def main(quick: bool = False) -> None:
    emit("tables56_accuracy", run(quick))


if __name__ == "__main__":
    main()
