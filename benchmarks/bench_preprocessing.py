"""Paper Table 3 / Fig. 8: preprocessing time (pre-clean/clean/post-clean),
CA vs P3SAPP, plus the beyond-paper planned/fused Dataset executor.

Both P3SAPP rows run through the lazy ``Dataset`` plan: ``optimize=False``
is the paper-faithful executor (no plan rewrites, per-stage ops), while
``optimize=True`` is the planner's merged + fused path."""

from __future__ import annotations

from repro.core.p3sapp import p3sapp_dataset, run_conventional

from .common import dataset_dirs, emit


def run(quick: bool = False) -> list[dict]:
    rows = []
    for ds_id, d, gb in dataset_dirs(quick):
        _, tp = p3sapp_dataset([d]).execute(optimize=False)  # paper-faithful
        _, tf = p3sapp_dataset([d]).execute(optimize=True)  # planned/fused
        _, tc = run_conventional([d])
        rows.append({
            "name": "table3_preprocessing",
            "dataset_id": ds_id,
            "paper_gb": gb,
            "ca_preclean_s": round(tc.pre_cleaning, 4),
            "pa_preclean_s": round(tp.pre_cleaning, 4),
            "ca_clean_s": round(tc.cleaning, 4),
            "pa_clean_s": round(tp.cleaning, 4),
            "ca_postclean_s": round(tc.post_cleaning, 4),
            "pa_postclean_s": round(tp.post_cleaning, 4),
            "ca_total_s": round(tc.preprocessing, 4),
            "pa_total_s": round(tp.preprocessing, 4),
            "pa_fused_total_s": round(tf.preprocessing, 4),
            "reduction_pct": round(100 * (1 - tp.preprocessing / tc.preprocessing), 3),
            "fused_reduction_pct": round(100 * (1 - tf.preprocessing / tc.preprocessing), 3),
            "us_per_call": round(tp.preprocessing * 1e6, 1),
        })
    return rows


def main(quick: bool = False) -> None:
    emit("table3_preprocessing", run(quick))


if __name__ == "__main__":
    main()
