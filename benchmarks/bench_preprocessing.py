"""Paper Table 3 / Fig. 8: preprocessing time (pre-clean/clean/post-clean),
CA vs P3SAPP, plus the beyond-paper planned/fused Dataset executor.

Both P3SAPP rows run through the lazy ``Dataset`` plan: ``optimize=False``
is the paper-faithful executor (no plan rewrites, per-stage ops), while
``optimize=True`` is the planner's merged + fused path.

``--workers N`` switches to the shard-executor scaling axis: the same
cleaning program runs per shard in the selected executor (worker processes
when N > 1) and the row reports end-to-end wall-clock. ``--cache`` enables
the plan-fingerprint shard cache; a second identical run then reports its
hit rate (the Spark ``persist()`` analogue). The cache persists across
invocations by design — compare ``--workers`` values *without* ``--cache``
(equal cold state), and use ``--cache`` for the cold/warm protocol; each
row's ``cache_hit_pct`` shows which state it measured.

``--tokenize`` measures the token-space tail of the same plan: vocabulary
fitting (per-shard counts merged on the driver) plus streaming
tokenization and batch assembly, fixed-``max_len`` vs length-bucketed.
Rows report tokens/sec (payload tokens delivered per wall second), the
pad-token fraction of the encoder column under each assembly, and the
token-cache hit rate (run twice with ``--cache`` for cold/warm)."""

from __future__ import annotations

import time

from repro.core import executor as EX
from repro.core import ingest as ing
from repro.core import plan as P
from repro.core.expr import abstract_expr, col, title_expr
from repro.core.p3sapp import p3sapp_dataset, run_conventional

from .common import dataset_dirs, emit

CACHE_DIR = EX.default_cache_dir() / "bench_preprocessing"


def _expr_chain(d):
    """The canonical cleaning chain in expression form, dedup-free so
    every executor (and the cache) applies; dedup is cross-shard state
    and thread-only."""
    from repro.core.dataset import Dataset

    keep = col("title").not_empty() & col("abstract").not_empty()
    return (
        Dataset.from_json_dirs([d])
        .where(keep)
        .transform(abstract=abstract_expr(), title=title_expr())
        .where(keep)
    )


def run_scaling(
    quick: bool = False,
    workers: int = 1,
    cache: bool = False,
    executor: str | None = None,
) -> list[dict]:
    rows = []
    for ds_id, d, gb in dataset_dirs(quick):
        ds = _expr_chain(d)
        frame_nodes, _ = P.split_plan(ds.plan)
        program = EX.compile_shard_program(
            P.optimize_plan(frame_nodes, ds.schema), optimize=True
        )
        shards = ing.list_shards([d])
        t0 = time.perf_counter()
        ex = EX.make_executor(
            shards,
            program,
            workers=workers,
            cache_dir=CACHE_DIR if cache else None,
            executor=executor,
        )
        n_rows = 0
        try:
            for res in ex:
                n_rows += len(res.frame)
        finally:
            ex.stop()
        wall = time.perf_counter() - t0
        lookups = ex.cache_hits + ex.cache_misses
        rows.append({
            "name": "executor_scaling",
            "dataset_id": ds_id,
            "paper_gb": gb,
            "workers": workers,
            "executor": ex.name,
            "cache": cache,
            "wall_s": round(wall, 4),
            "rows": n_rows,
            "shards": len(shards),
            "cache_hits": ex.cache_hits,
            "cache_misses": ex.cache_misses,
            "cache_hit_pct": round(100 * ex.cache_hits / lookups, 2) if lookups else 0.0,
            "us_per_call": round(wall * 1e6, 1),
        })
    return rows


def run_tokenize(
    quick: bool = False,
    workers: int = 2,
    cache: bool = False,
    executor: str | None = None,
) -> list[dict]:
    from repro.data.batching import (
        effective_lengths,
        pad_token_fraction,
        seq2seq_specs,
    )

    rows = []
    specs = seq2seq_specs(max_abstract_len=128, max_title_len=24)
    for ds_id, d, gb in dataset_dirs(quick):

        def chain():
            ds = _expr_chain(d)
            return ds.cache(CACHE_DIR / "tokens") if cache else ds

        t0 = time.perf_counter()
        fit_stats: dict = {}
        tok = chain().fit_vocab(
            vocab_size=8000, workers=workers, executor=executor, stats=fit_stats
        )
        fit_wall = time.perf_counter() - t0

        for mode in ("fixed", "bucketed", "paired"):
            pipe = chain().tokenize(tok, specs)
            if mode == "bucketed":
                pipe = pipe.batched(
                    32, shuffle=False, drop_remainder=False,
                    bucket_by="encoder_tokens",
                )
            elif mode == "paired":
                pipe = pipe.batched(
                    32, shuffle=False, drop_remainder=False,
                    bucket_by=("encoder_tokens", "decoder_tokens"),
                )
            else:
                pipe = pipe.batch(32, shuffle=False, drop_remainder=False)
            stats: dict = {}
            t0 = time.perf_counter()
            batches = list(
                pipe.prefetch(2).iter_batches(
                    workers=workers, executor=executor, stats=stats
                )
            )
            wall = time.perf_counter() - t0
            payload_tokens = sum(
                int(effective_lengths(b[k]).sum()) for b in batches for k in b
            )
            lookups = stats.get("token_cache_hits", 0) + stats.get(
                "token_cache_misses", 0
            )
            rows.append({
                "name": "tokenize",
                "dataset_id": ds_id,
                "paper_gb": gb,
                "mode": mode,
                "workers": workers,
                "executor": stats.get("executor"),
                "cache": cache,
                "fit_vocab_s": round(fit_wall, 4),
                "wall_s": round(wall, 4),
                "batches": len(batches),
                "payload_tokens": payload_tokens,
                "tokens_per_s": round(payload_tokens / wall, 1) if wall else 0.0,
                "pad_frac": round(
                    pad_token_fraction(batches, "encoder_tokens"), 4
                ),
                "pad_frac_decoder": round(
                    pad_token_fraction(batches, "decoder_tokens"), 4
                ),
                "token_cache_hits": stats.get("token_cache_hits", 0),
                "token_cache_misses": stats.get("token_cache_misses", 0),
                "token_cache_hit_pct": (
                    round(100 * stats.get("token_cache_hits", 0) / lookups, 2)
                    if lookups
                    else 0.0
                ),
                "us_per_call": round(wall * 1e6, 1),
            })
    return rows


def run(quick: bool = False) -> list[dict]:
    rows = []
    for ds_id, d, gb in dataset_dirs(quick):
        _, tp = p3sapp_dataset([d]).execute(optimize=False)  # paper-faithful
        _, tf = p3sapp_dataset([d]).execute(optimize=True)  # planned/fused
        _, tc = run_conventional([d])
        rows.append({
            "name": "table3_preprocessing",
            "dataset_id": ds_id,
            "paper_gb": gb,
            "ca_preclean_s": round(tc.pre_cleaning, 4),
            "pa_preclean_s": round(tp.pre_cleaning, 4),
            "ca_clean_s": round(tc.cleaning, 4),
            "pa_clean_s": round(tp.cleaning, 4),
            "ca_postclean_s": round(tc.post_cleaning, 4),
            "pa_postclean_s": round(tp.post_cleaning, 4),
            "ca_total_s": round(tc.preprocessing, 4),
            "pa_total_s": round(tp.preprocessing, 4),
            "pa_fused_total_s": round(tf.preprocessing, 4),
            "reduction_pct": round(100 * (1 - tp.preprocessing / tc.preprocessing), 3),
            "fused_reduction_pct": round(100 * (1 - tf.preprocessing / tc.preprocessing), 3),
            "us_per_call": round(tp.preprocessing * 1e6, 1),
        })
    return rows


def main(
    quick: bool = False,
    workers: int | None = None,
    cache: bool = False,
    executor: str | None = None,
    tokenize: bool = False,
) -> None:
    if tokenize:
        emit("tokenize", run_tokenize(quick, workers or 2, cache, executor))
    elif workers is not None:
        emit("executor_scaling", run_scaling(quick, workers, cache, executor))
    else:
        emit("table3_preprocessing", run(quick))


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--workers", type=int, default=None,
                    help="shard-executor scaling axis with N workers")
    ap.add_argument("--cache", action="store_true",
                    help="enable the plan-fingerprint shard cache")
    ap.add_argument(
        "--executor",
        choices=["thread", "process", "remote"],
        default=None,
        help="physical shard executor; 'remote' runs the distributed data "
        "plane with N localhost worker processes",
    )
    ap.add_argument("--tokenize", action="store_true",
                    help="token-space axis: fit_vocab + streaming "
                         "tokenization, fixed vs bucketed assembly")
    args = ap.parse_args()
    main(args.quick, args.workers, args.cache, args.executor, args.tokenize)
