"""Benchmark harness: one module per paper table/figure + kernels +
roofline. Prints ``name,us_per_call,derived`` CSV lines and writes
per-table CSVs to benchmarks/results/.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="2 datasets instead of 5")
    ap.add_argument("--only", default=None, help="comma-separated module names")
    args = ap.parse_args()

    from . import (
        bench_accuracy,
        bench_cost_benefit,
        bench_cumulative,
        bench_ingestion,
        bench_kernels,
        bench_preprocessing,
        roofline,
    )

    modules = {
        "ingestion": bench_ingestion,
        "preprocessing": bench_preprocessing,
        "cumulative": bench_cumulative,
        "accuracy": bench_accuracy,
        "cost_benefit": bench_cost_benefit,
        "kernels": bench_kernels,
        "roofline": roofline,
    }
    if args.only:
        keep = args.only.split(",")
        modules = {k: v for k, v in modules.items() if k in keep}

    failures = 0
    for name, mod in modules.items():
        print(f"# --- {name} ---", flush=True)
        try:
            mod.main(quick=args.quick)
        except Exception:
            failures += 1
            print(f"# {name} FAILED", file=sys.stderr)
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
