"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads benchmarks/results/dryrun/<mesh>/<arch>__<shape>.json (produced by
repro.launch.dryrun) and derives per cell:

    t_compute    = flops_per_device / 197 TFLOP/s          (bf16 MXU)
    t_memory     = bytes_per_device / 819 GB/s             (HBM)
    t_collective = coll_bytes_per_device / 50 GB/s         (ICI per link)

flops/bytes/collective bytes are the trip-count-aware per-device numbers
from repro.launch.hlo_cost (see its docstring for the byte model).
The usefulness ratio is MODEL_FLOPS / (flops_per_device × chips).
"""

from __future__ import annotations

import json
from pathlib import Path

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

DRYRUN_DIR = Path(__file__).parent / "results" / "dryrun"


def load_cells(mesh: str = "single") -> list[dict]:
    out = []
    for p in sorted((DRYRUN_DIR / mesh).glob("*.json")):
        out.append(json.loads(p.read_text()))
    return out


def roofline_row(rec: dict) -> dict:
    chips = rec["n_devices"]
    hc = rec.get("hlo_cost", {})
    flops = hc.get("flops", 0.0)
    bytes_ = hc.get("bytes", 0.0)
    coll = hc.get("collective_total", 0.0)
    t_c = flops / PEAK_FLOPS
    t_m = bytes_ / HBM_BW
    t_x = coll / ICI_BW
    dominant = max(("compute", t_c), ("memory", t_m), ("collective", t_x), key=lambda kv: kv[1])[0]
    useful = rec.get("model_flops", 0.0) / max(flops * chips, 1.0)
    bound_time = max(t_c, t_m, t_x)
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "ok": rec.get("ok", False),
        "t_compute_s": t_c,
        "t_memory_s": t_m,
        "t_collective_s": t_x,
        "dominant": dominant,
        "useful_ratio": useful,
        "roofline_fraction": (t_c / bound_time) if bound_time > 0 else 0.0,
        "flops_per_dev": flops,
        "bytes_per_dev": bytes_,
        "coll_per_dev": coll,
        "collective_breakdown": hc.get("collective_bytes", {}),
        "error": rec.get("error"),
    }


def table(mesh: str = "single") -> list[dict]:
    return [roofline_row(r) for r in load_cells(mesh)]


def render_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | t_compute | t_memory | t_collective | dominant | "
           "useful | roofline_frac |\n|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if not r["ok"]:
            body += f"| {r['arch']} | {r['shape']} | FAILED: {str(r['error'])[:60]} |  |  |  |  |  |\n"
            continue
        body += (
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.1f} ms | "
            f"{r['t_memory_s']*1e3:.1f} ms | {r['t_collective_s']*1e3:.1f} ms | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} |\n"
        )
    return hdr + body


def main(quick: bool = False) -> None:
    from .common import emit

    rows = []
    for mesh in ("single", "multi"):
        if not (DRYRUN_DIR / mesh).exists():
            continue
        for r in table(mesh):
            rows.append({
                "name": f"roofline_{mesh}",
                "arch": r["arch"], "shape": r["shape"], "ok": r["ok"],
                "t_compute_ms": round(r["t_compute_s"] * 1e3, 2),
                "t_memory_ms": round(r["t_memory_s"] * 1e3, 2),
                "t_collective_ms": round(r["t_collective_s"] * 1e3, 2),
                "dominant": r["dominant"],
                "useful_ratio": round(r["useful_ratio"], 3),
                "roofline_fraction": round(r["roofline_fraction"], 3),
                "us_per_call": 0,
            })
    emit("roofline", rows)


if __name__ == "__main__":
    main()
