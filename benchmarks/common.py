"""Shared benchmark scaffolding: scaled dataset series + result helpers.

The paper's five datasets are 4.18/8.54/13.34/18.23/23.58 GB. This CPU
container scales the series by 1000x (MB instead of GB) preserving the
ratios — the CA-vs-P3SAPP asymptotics (copy-on-append ingestion, row-loop
cleaning) are size-independent, so the qualitative claims reproduce at
container scale. Generated corpora are cached under /tmp.
"""

from __future__ import annotations

import csv
import json
from pathlib import Path

from repro.data.synthetic import write_corpus

# paper dataset sizes (GB) scaled to bytes at 1/1000
PAPER_SIZES_GB = [4.18, 8.54, 13.34, 18.23, 23.58]
SCALE = 1_000_000  # bytes per paper-GB => MB-scale series
RESULTS_DIR = Path(__file__).parent / "results"


def dataset_dirs(quick: bool = False) -> list[tuple[int, Path, float]]:
    """[(dataset_id, directory, paper_gb)]; generated once, cached."""
    base = Path("/tmp/p3sapp_corpora")
    out = []
    sizes = PAPER_SIZES_GB[:2] if quick else PAPER_SIZES_GB
    for i, gb in enumerate(sizes, start=1):
        d = base / f"ds{i}"
        marker = d / ".complete"
        if not marker.exists():
            write_corpus(d, total_bytes=int(gb * SCALE), n_files=6 + 2 * i, seed=100 + i)
            marker.write_text("ok")
        out.append((i, d, gb))
    return out


def emit(name: str, rows: list[dict]) -> None:
    """Write CSV to results/ and the required name,us_per_call,derived lines
    to stdout."""
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    if rows:
        fieldnames: list[str] = []
        for r in rows:  # union of keys, first-seen order (rows may vary)
            for k in r:
                if k not in fieldnames:
                    fieldnames.append(k)
        path = RESULTS_DIR / f"{name}.csv"
        with open(path, "w", newline="") as fh:
            w = csv.DictWriter(fh, fieldnames=fieldnames, restval="")
            w.writeheader()
            w.writerows(rows)
    for r in rows:
        us = r.get("us_per_call", r.get("p3sapp_s", 0) and r["p3sapp_s"] * 1e6)
        derived = {k: v for k, v in r.items() if k not in ("name",)}
        print(f"{name},{us},{json.dumps(derived, default=str)}")
