"""Kernel microbenchmarks: interpret-mode correctness timing + analytic
TPU roofline estimates per kernel (the container has no TPU; wall-clock
here measures the jnp reference path, the roofline numbers are the
model for the target hardware)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.lstm_cell.ref import lstm_cell_ref
from repro.kernels.rg_lru.ref import rg_lru_ref
from repro.kernels.text_clean.ref import text_clean_ref

from .common import dataset_dirs, emit

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def _time(fn, *args, iters=5) -> float:
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run() -> list[dict]:
    rows = []
    key = jax.random.PRNGKey(0)

    # flash attention: b=1, h=8, s=1024, d=64
    b, h, s, d = 1, 8, 1024, 64
    q = jax.random.normal(key, (b * h, s, d), jnp.float32)
    f = jax.jit(lambda q: flash_attention_ref(q, q, q, n_q_heads=h, n_kv_heads=h))
    t = _time(f, q)
    flops = 4 * b * h * s * s * d  # QK^T + PV
    bytes_ = 4 * (3 * b * h * s * d + b * h * s * d)
    rows.append({
        "name": "kernel_flash_attention", "us_per_call": round(t * 1e6, 1),
        "tpu_compute_us": round(flops / PEAK_FLOPS * 1e6, 2),
        "tpu_memory_us": round(bytes_ / HBM_BW * 1e6, 2),
        "arithmetic_intensity": round(flops / bytes_, 1),
        "bound": "compute" if flops / PEAK_FLOPS > bytes_ / HBM_BW else "memory",
    })

    # rg_lru: b=4, s=2048, d=1024 — memory bound by construction
    a = jax.nn.sigmoid(jax.random.normal(key, (4, 2048, 1024)))
    bb = jax.random.normal(key, (4, 2048, 1024)) * 0.1
    f = jax.jit(rg_lru_ref)
    t = _time(f, a, bb)
    n = a.size
    flops = 3 * n
    bytes_ = 4 * 3 * n
    rows.append({
        "name": "kernel_rg_lru", "us_per_call": round(t * 1e6, 1),
        "tpu_compute_us": round(flops / PEAK_FLOPS * 1e6, 2),
        "tpu_memory_us": round(bytes_ / HBM_BW * 1e6, 2),
        "arithmetic_intensity": round(flops / bytes_, 2),
        "bound": "memory",
    })

    # lstm_cell: b=256, d=512, h=512
    bsz, din, hid = 256, 512, 512
    x = jax.random.normal(key, (bsz, din))
    hh = jax.random.normal(key, (bsz, hid))
    cc = jax.random.normal(key, (bsz, hid))
    wx = jax.random.normal(key, (din, 4, hid)) * 0.05
    wh = jax.random.normal(key, (hid, 4, hid)) * 0.05
    bias = jnp.zeros((4, hid))
    f = jax.jit(lstm_cell_ref)
    t = _time(f, x, hh, cc, wx, wh, bias)
    flops = 2 * bsz * (din + hid) * 4 * hid
    bytes_ = 4 * (x.size + hh.size + cc.size + wx.size + wh.size + 2 * bsz * hid)
    rows.append({
        "name": "kernel_lstm_cell", "us_per_call": round(t * 1e6, 1),
        "tpu_compute_us": round(flops / PEAK_FLOPS * 1e6, 2),
        "tpu_memory_us": round(bytes_ / HBM_BW * 1e6, 2),
        "arithmetic_intensity": round(flops / bytes_, 1),
        "bound": "compute" if flops / PEAK_FLOPS > bytes_ / HBM_BW else "memory",
    })

    # mlstm_chunk: BH=8, s=1024, dh=64, chunk=64
    from repro.kernels.mlstm_chunk.ref import mlstm_chunk_ref

    bhx, sx, dhx = 8, 1024, 64
    qm = jax.random.normal(key, (bhx, sx, dhx)) * 0.5
    gm = jax.random.normal(key, (bhx, sx))
    f = jax.jit(mlstm_chunk_ref)
    t = _time(f, qm, qm, qm, gm, gm + 2.0)
    # per chunk: (L,dh)@(dh,dh) inter + (L,L)@(L,dh) intra (+ scores)
    L = 64
    n_chunks = sx // L
    flops = bhx * n_chunks * (2 * L * dhx * dhx + 2 * 2 * L * L * dhx)
    bytes_ = 4 * (4 * bhx * sx * dhx + 2 * bhx * sx)  # qkv+h streams + gates
    rows.append({
        "name": "kernel_mlstm_chunk", "us_per_call": round(t * 1e6, 1),
        "tpu_compute_us": round(flops / PEAK_FLOPS * 1e6, 2),
        "tpu_memory_us": round(bytes_ / HBM_BW * 1e6, 2),
        "arithmetic_intensity": round(flops / bytes_, 1),
        "bound": "compute" if flops / PEAK_FLOPS > bytes_ / HBM_BW else "memory",
    })

    # text_clean: 4096 rows x 512 bytes
    mat = jnp.asarray(np.random.randint(32, 127, (4096, 512), dtype=np.uint8))
    f = jax.jit(text_clean_ref)
    t = _time(f, mat)
    bytes_ = mat.size * 2
    rows.append({
        "name": "kernel_text_clean", "us_per_call": round(t * 1e6, 1),
        "tpu_compute_us": 0.0,
        "tpu_memory_us": round(bytes_ / HBM_BW * 1e6, 2),
        "arithmetic_intensity": 0.5,
        "bound": "memory",
        "host_mb_per_s": round(mat.size / t / 1e6, 1),
    })
    return rows


def backend_rows(quick: bool = False) -> list[dict]:
    """Bytes-backend comparison: the canonical Algorithm 1 cleaning chain
    over a real synthetic-corpus buffer, executed by every bytesops
    backend (``loops`` per-op passes vs the ``fused`` single-pass megapass
    vs ``pallas``). The gate metric is *relative* — fused speedup over
    loops measured on the same machine in the same process — so the
    committed baseline is portable across runner classes where absolute
    MB/s is not. Backends are byte-identical by contract; the bench
    asserts it on the measured buffer before timing."""
    from repro.core import bytesops as B
    from repro.core import ingest as ing
    from repro.core.p3sapp import case_study_stages
    from repro.core.pipeline import compile_column_plans
    from repro.kernels.pallas_compat import has_tpu

    _, d, _ = dataset_dirs(quick=True)[0]
    frame = ing.ingest([d], ("title", "abstract"))
    buf = frame.flat("abstract")
    plans = compile_column_plans(case_study_stages(), optimize=True)
    ops = next(o for in_col, _, o in plans if in_col == "abstract")

    def measure(backend: str, iters: int) -> tuple[float, np.ndarray]:
        out = B.execute_ops(buf, ops, backend)  # warm: memoized compile
        t0 = time.perf_counter()
        for _ in range(iters):
            out = B.execute_ops(buf, ops, backend)
        return (time.perf_counter() - t0) / iters, out

    iters = 3 if quick else 7
    t_loops, out_loops = measure("loops", iters)
    t_fused, out_fused = measure("fused", iters)
    np.testing.assert_array_equal(out_fused, out_loops)

    mb = buf.size / 1e6

    def row(backend: str, t: float) -> dict:
        return {
            "name": "bytes_backend",
            "backend": backend,
            "buffer_mb": round(mb, 2),
            "us_per_call": round(t * 1e6, 1),
            "mb_per_s": round(mb / t, 1),
            "speedup_vs_loops": round(t_loops / t, 3),
        }

    rows = [row("loops", t_loops), row("fused", t_fused)]
    if has_tpu():
        t_pallas, out_pallas = measure("pallas", iters)
        np.testing.assert_array_equal(out_pallas, out_loops)
        rows.append(row("pallas", t_pallas))
    else:
        # Interpret mode would bench the Pallas interpreter, not the
        # kernel; without a TPU the pallas backend falls back to the host
        # scan anyway, so emit an informational row with no gate metric.
        rows.append({
            "name": "bytes_backend", "backend": "pallas",
            "buffer_mb": round(mb, 2),
            "note": "skipped: no TPU (host-scan fallback == fused)",
        })
    return rows


def main(quick: bool = False) -> None:
    emit("kernel_bench", run())
    emit("kernel_backends", backend_rows(quick))


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(**vars(ap.parse_args()))
