"""Paper Tables 7-8 / Figs. 11,13: cost-benefit vs epochs and the
time-saving / MTT-per-epoch ratio.

MTT per epoch is MEASURED by training the case-study LSTM summarizer on
each dataset's cleaned output (one epoch, wall clock), exactly as the
paper couples preprocessing savings to training cost. Cost benefit uses
the paper's eq. 8/11: CB = (T_ca - T_pa) / T_ca with T = t_c + n * t_mt.
"""

from __future__ import annotations

import time

import jax

from repro.configs.p3sapp_summarizer import SMOKE as S2S_CFG
from repro.core.p3sapp import run_conventional, run_p3sapp
from repro.data.batching import batches, seq2seq_arrays, train_val_split
from repro.data.tokenizer import WordTokenizer
from repro.models.seq2seq import Seq2Seq
from repro.optim.adamw import AdamW

from .common import dataset_dirs, emit

EPOCH_GRID = (10, 25, 50)


def measure_mtt(records: list[dict]) -> tuple[float, int, int]:
    """Wall-clock one-epoch training time of the case-study model."""
    tok = WordTokenizer.fit(
        (r["abstract"] + " " + r["title"] for r in records[:2000]),
        vocab_size=S2S_CFG.vocab_size,
    )
    arrs = seq2seq_arrays(records, tok, S2S_CFG.max_abstract_len, S2S_CFG.max_title_len)
    train, val = train_val_split(arrs, 0.1)
    model = Seq2Seq(S2S_CFG)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(learning_rate=3e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, state, _ = opt.update(grads, state, params)
        return params, state, loss

    bs = list(batches(train, 32, seed=0))
    # warmup compile outside the timed epoch
    params, state, _ = step(params, state, bs[0])
    t0 = time.perf_counter()
    for b in bs:
        params, state, _ = step(params, state, b)
    jax.block_until_ready(params)
    mtt = time.perf_counter() - t0
    return mtt, len(train["encoder_tokens"]), len(val["encoder_tokens"])


def run(quick: bool = False) -> list[dict]:
    rows = []
    for ds_id, d, gb in dataset_dirs(quick):
        pa_records, tp = run_p3sapp([d])
        _, tc = run_conventional([d])
        mtt, n_train, n_val = measure_mtt(pa_records)
        saving = tc.cumulative - tp.cumulative
        # Table 8
        rows.append({
            "name": "table8_mtt_ratio",
            "dataset_id": ds_id,
            "paper_gb": gb,
            "n_train": n_train,
            "n_val": n_val,
            "mtt_per_epoch_s": round(mtt, 3),
            "time_saving_s": round(saving, 3),
            "ratio_saving_over_mtt": round(saving / mtt, 3),
            "us_per_call": round(mtt * 1e6, 1),
        })
        # Table 7
        for n_epochs in EPOCH_GRID:
            t_ca = tc.cumulative + n_epochs * mtt
            t_pa = tp.cumulative + n_epochs * mtt
            rows.append({
                "name": "table7_cost_benefit",
                "dataset_id": ds_id,
                "paper_gb": gb,
                "epochs": n_epochs,
                "t_ca_s": round(t_ca, 3),
                "t_pa_s": round(t_pa, 3),
                "cost_benefit_pct": round(100 * (t_ca - t_pa) / t_ca, 3),
                "us_per_call": 0,
            })
    return rows


def main(quick: bool = False) -> None:
    emit("tables78_cost_benefit", run(quick))


if __name__ == "__main__":
    main()
