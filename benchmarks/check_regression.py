"""Perf-regression gates over the committed benchmark baselines.

Default (tokenize) mode: CI runs ``python -m benchmarks.bench_preprocessing
--tokenize --quick`` (which rewrites ``benchmarks/results/tokenize.csv``)
after copying the committed CSV aside, then calls this script to compare
the fresh ``tokens_per_s`` of every ``(dataset_id, mode)`` row against the
baseline. A row slower than ``baseline * (1 - max_regression)`` fails the
gate; rows present in the baseline but missing from the fresh run fail too
(a silently skipped leg must not read as a pass).

``--mode overlap``: gates the device-overlap trajectory
(``benchmarks/results/BENCH_cumulative.json``, written by
``bench_cumulative --overlap``). The latest fresh entry must cover every
dataset row of the latest baseline entry, and every row's device-idle
fraction must stay at or below ``--max-idle`` — the paper's claim (host
preprocessing hidden behind device compute) as an absolute ceiling, which
is machine-portable where absolute seconds are not.

``--mode kernels``: gates the bytes-backend comparison
(``benchmarks/results/kernel_backends.csv``, written by ``bench_kernels``).
The gate metric is *relative* — the fused backend's speedup over loops
measured in the same process — so it is machine-portable: every baseline
row must be present in the fresh run, and every fresh ``fused`` row must
keep ``speedup_vs_loops >= --min-speedup``. Rows without a gate metric
(e.g. the pallas row on a TPU-less runner) are informational.

Refresh the committed baselines by re-running the benches on the reference
machine and committing the regenerated files. The tokenize baseline is
absolute throughput: regenerate it when the CI runner class changes, or
loosen ``--max-regression`` if the runner fleet is heterogeneous.
"""

import argparse
import csv
import json
import sys
from pathlib import Path

METRIC = "tokens_per_s"
KEY_FIELDS = ("dataset_id", "mode")


def load_rows(path):
    with open(path, newline="") as fh:
        return {
            tuple(row[k] for k in KEY_FIELDS): float(row[METRIC])
            for row in csv.DictReader(fh)
            if row.get(METRIC)
        }


def _latest_overlap_rows(path):
    """dataset_id -> row of the newest trajectory entry in an overlap JSON."""
    doc = json.loads(Path(path).read_text())
    trajectory = doc.get("trajectory") or []
    if not trajectory:
        return {}
    return {str(r["dataset_id"]): r for r in trajectory[-1].get("rows", [])}


def check_overlap(args):
    baseline = _latest_overlap_rows(args.baseline)
    fresh = _latest_overlap_rows(args.fresh)
    if not fresh:
        print(f"no overlap trajectory entries in {args.fresh}")
        return 1
    ceiling = 100.0 * args.max_idle
    failures = []
    for ds in sorted(baseline):
        if ds not in fresh:
            failures.append(f"ds{ds}: missing from fresh run")
    for ds in sorted(fresh):
        row = fresh[ds]
        idle = float(row["idle_pct"])
        steps = int(row.get("steps", 0))
        status = "OK" if idle <= ceiling and steps > 0 else "REGRESSION"
        print(
            f"ds{ds}: idle {idle:.2f}% (ceiling {ceiling:.2f}%), "
            f"{steps} steps, {row.get('starved_steps', '?')} starved, "
            f"{row.get('compiles', '?')} compiles {status}"
        )
        if steps <= 0:
            failures.append(f"ds{ds}: zero measured steps")
        if idle > ceiling:
            failures.append(f"ds{ds}: idle {idle:.2f}% > ceiling {ceiling:.2f}%")
    if failures:
        print()
        print(f"overlap gate failed ({len(failures)} row(s)):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"overlap gate passed: {len(fresh)} dataset(s) within the idle ceiling")
    return 0


def _load_backend_rows(path):
    with open(path, newline="") as fh:
        return {(row["name"], row["backend"]): row for row in csv.DictReader(fh)}


def check_kernels(args):
    baseline = _load_backend_rows(args.baseline)
    fresh = _load_backend_rows(args.fresh)
    if not baseline:
        print(f"no backend rows in {args.baseline}")
        return 1
    failures = []
    for key in sorted(baseline):
        label = "/".join(key)
        row = fresh.get(key)
        if row is None:
            failures.append(f"{label}: missing from fresh run")
            continue
        speedup = row.get("speedup_vs_loops") or ""
        if not speedup:
            print(f"{label}: informational ({row.get('note') or 'no metric'})")
            continue
        got = float(speedup)
        floor = args.min_speedup if key[1] != "loops" else 0.0
        status = "OK" if got >= floor else "REGRESSION"
        print(
            f"{label}: {got:.3f}x vs loops "
            f"({row.get('mb_per_s', '?')} MB/s, floor {floor:.2f}x) {status}"
        )
        if got < floor:
            failures.append(f"{label}: {got:.3f}x < floor {floor:.2f}x")
    if failures:
        print()
        print(f"kernel backend gate failed ({len(failures)} row(s)):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"kernel backend gate passed: {len(baseline)} row(s)")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", type=Path, required=True)
    ap.add_argument("--fresh", type=Path, required=True)
    ap.add_argument(
        "--mode",
        choices=["tokenize", "overlap", "kernels"],
        default="tokenize",
        help="tokenize: CSV throughput gate; overlap: device-idle JSON "
        "gate; kernels: relative bytes-backend speedup gate",
    )
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.30,
        help="fail when fresh tokens/sec drops more than this fraction",
    )
    ap.add_argument(
        "--max-idle",
        type=float,
        default=0.05,
        help="overlap mode: fail when device-idle fraction exceeds this",
    )
    ap.add_argument(
        "--min-speedup",
        type=float,
        default=1.05,
        help="kernels mode: fail when a non-loops backend's "
        "speedup_vs_loops falls below this",
    )
    args = ap.parse_args(argv)

    if args.mode == "overlap":
        return check_overlap(args)
    if args.mode == "kernels":
        return check_kernels(args)

    baseline = load_rows(args.baseline)
    fresh = load_rows(args.fresh)
    if not baseline:
        print(f"no baseline rows with {METRIC!r} in {args.baseline}")
        return 1

    failures = []
    for key in sorted(baseline):
        base = baseline[key]
        got = fresh.get(key)
        label = "/".join(key)
        if got is None:
            failures.append(f"{label}: missing from fresh run")
            continue
        floor = base * (1.0 - args.max_regression)
        delta = 100.0 * (got / base - 1.0)
        status = "OK" if got >= floor else "REGRESSION"
        print(
            f"{label}: baseline {base:,.0f} tok/s, "
            f"fresh {got:,.0f} tok/s ({delta:+.1f}%) {status}"
        )
        if got < floor:
            failures.append(
                f"{label}: {got:,.0f} < floor {floor:,.0f} tok/s "
                f"({delta:+.1f}% vs baseline)"
            )
    if failures:
        print()
        print(f"perf gate failed ({len(failures)} row(s)):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"perf gate passed: {len(baseline)} row(s) within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
