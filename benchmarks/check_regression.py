"""Perf-regression gates over the committed benchmark baselines.

Default (tokenize) mode: CI runs ``python -m benchmarks.bench_preprocessing
--tokenize --quick`` (which rewrites ``benchmarks/results/tokenize.csv``)
after copying the committed CSV aside, then calls this script to compare
the fresh ``tokens_per_s`` of every ``(dataset_id, mode)`` row against the
baseline. A row slower than ``baseline * (1 - max_regression)`` fails the
gate; rows present in the baseline but missing from the fresh run fail too
(a silently skipped leg must not read as a pass).

``--mode overlap``: gates the device-overlap trajectory
(``benchmarks/results/BENCH_cumulative.json``, written by
``bench_cumulative --overlap``). The latest fresh entry must cover every
dataset row of the latest baseline entry, and every row's device-idle
fraction must stay at or below ``--max-idle`` — the paper's claim (host
preprocessing hidden behind device compute) as an absolute ceiling, which
is machine-portable where absolute seconds are not.

``--mode kernels``: gates the bytes-backend comparison
(``benchmarks/results/kernel_backends.csv``, written by ``bench_kernels``).
The gate metric is *relative* — the fused backend's speedup over loops
measured in the same process — so it is machine-portable: every baseline
row must be present in the fresh run, and every fresh ``fused`` row must
keep ``speedup_vs_loops >= --min-speedup``. Rows without a gate metric
(e.g. the pallas row on a TPU-less runner) are informational.

``--mode serve``: gates the serving-latency snapshot
(``benchmarks/results/serve_latency.json``, written by ``bench_serve``).
Absolute latency is machine-specific, so the gate checks the
machine-portable invariants instead: the request ledger must close
(served + filtered == requests - rejected), p50/p99 must be finite and
ordered, preprocessing must stay under ``--max-preprocess-frac`` of host
wall time (the serving analogue of the overlap ceiling: the row program
must never dominate decode), and the ring cache must keep hitting when
the baseline run had hits.

Refresh the committed baselines by re-running the benches on the reference
machine and committing the regenerated files. The tokenize baseline is
absolute throughput: regenerate it when the CI runner class changes, or
loosen ``--max-regression`` if the runner fleet is heterogeneous.
"""

import argparse
import csv
import json
import sys
from pathlib import Path

METRIC = "tokens_per_s"
KEY_FIELDS = ("dataset_id", "mode")


def load_rows(path):
    with open(path, newline="") as fh:
        return {
            tuple(row[k] for k in KEY_FIELDS): float(row[METRIC])
            for row in csv.DictReader(fh)
            if row.get(METRIC)
        }


def _latest_overlap_rows(path):
    """dataset_id -> row of the newest trajectory entry in an overlap JSON."""
    doc = json.loads(Path(path).read_text())
    trajectory = doc.get("trajectory") or []
    if not trajectory:
        return {}
    return {str(r["dataset_id"]): r for r in trajectory[-1].get("rows", [])}


def check_overlap(args):
    baseline = _latest_overlap_rows(args.baseline)
    fresh = _latest_overlap_rows(args.fresh)
    if not fresh:
        print(f"no overlap trajectory entries in {args.fresh}")
        return 1
    ceiling = 100.0 * args.max_idle
    failures = []
    for ds in sorted(baseline):
        if ds not in fresh:
            failures.append(f"ds{ds}: missing from fresh run")
    for ds in sorted(fresh):
        row = fresh[ds]
        idle = float(row["idle_pct"])
        steps = int(row.get("steps", 0))
        status = "OK" if idle <= ceiling and steps > 0 else "REGRESSION"
        print(
            f"ds{ds}: idle {idle:.2f}% (ceiling {ceiling:.2f}%), "
            f"{steps} steps, {row.get('starved_steps', '?')} starved, "
            f"{row.get('compiles', '?')} compiles {status}"
        )
        if steps <= 0:
            failures.append(f"ds{ds}: zero measured steps")
        if idle > ceiling:
            failures.append(f"ds{ds}: idle {idle:.2f}% > ceiling {ceiling:.2f}%")
    if failures:
        print()
        print(f"overlap gate failed ({len(failures)} row(s)):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"overlap gate passed: {len(fresh)} dataset(s) within the idle ceiling")
    return 0


def check_serve(args):
    baseline = json.loads(Path(args.baseline).read_text())
    fresh = json.loads(Path(args.fresh).read_text())
    failures = []

    requests = int(fresh.get("requests", 0))
    served = int(fresh.get("served", 0))
    rejected = int(fresh.get("rejected", 0))
    filtered = int(fresh.get("filtered", 0))
    if served <= 0:
        failures.append("zero served requests")
    if served + filtered != requests - rejected:
        failures.append(
            f"request ledger does not close: served {served} + filtered "
            f"{filtered} != requests {requests} - rejected {rejected}"
        )
    if int(fresh.get("tokens_generated", 0)) <= 0:
        failures.append("zero tokens generated")

    p50 = float(fresh.get("p50_ms", 0.0))
    p99 = float(fresh.get("p99_ms", 0.0))
    if not (0.0 < p50 < float("inf")):
        failures.append(f"p50 {p50} ms is not finite/positive")
    if p99 < p50:
        failures.append(f"p99 {p99} ms < p50 {p50} ms")

    frac = float(fresh.get("preprocess_frac", 1.0))
    if frac > args.max_preprocess_frac:
        failures.append(
            f"preprocess fraction {frac:.4f} > ceiling "
            f"{args.max_preprocess_frac:.4f}"
        )
    if int(baseline.get("cache_hits", 0)) > 0 and int(fresh.get("cache_hits", 0)) <= 0:
        failures.append("ring cache stopped hitting (baseline run had hits)")

    print(
        f"serve: {served}/{requests} served ({rejected} rejected, "
        f"{filtered} filtered), p50 {p50:.1f} ms, p99 {p99:.1f} ms, "
        f"preprocess {100 * frac:.2f}% of host time "
        f"(ceiling {100 * args.max_preprocess_frac:.0f}%), "
        f"{fresh.get('cache_hits', 0)} cache hits"
    )
    if failures:
        print()
        print(f"serve gate failed ({len(failures)} check(s)):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("serve gate passed")
    return 0


def _load_backend_rows(path):
    with open(path, newline="") as fh:
        return {(row["name"], row["backend"]): row for row in csv.DictReader(fh)}


def check_kernels(args):
    baseline = _load_backend_rows(args.baseline)
    fresh = _load_backend_rows(args.fresh)
    if not baseline:
        print(f"no backend rows in {args.baseline}")
        return 1
    failures = []
    for key in sorted(baseline):
        label = "/".join(key)
        row = fresh.get(key)
        if row is None:
            failures.append(f"{label}: missing from fresh run")
            continue
        speedup = row.get("speedup_vs_loops") or ""
        if not speedup:
            print(f"{label}: informational ({row.get('note') or 'no metric'})")
            continue
        got = float(speedup)
        floor = args.min_speedup if key[1] != "loops" else 0.0
        status = "OK" if got >= floor else "REGRESSION"
        print(
            f"{label}: {got:.3f}x vs loops "
            f"({row.get('mb_per_s', '?')} MB/s, floor {floor:.2f}x) {status}"
        )
        if got < floor:
            failures.append(f"{label}: {got:.3f}x < floor {floor:.2f}x")
    if failures:
        print()
        print(f"kernel backend gate failed ({len(failures)} row(s)):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"kernel backend gate passed: {len(baseline)} row(s)")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", type=Path, required=True)
    ap.add_argument("--fresh", type=Path, required=True)
    ap.add_argument(
        "--mode",
        choices=["tokenize", "overlap", "kernels", "serve"],
        default="tokenize",
        help="tokenize: CSV throughput gate; overlap: device-idle JSON "
        "gate; kernels: relative bytes-backend speedup gate; serve: "
        "serving-latency invariant gate",
    )
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.30,
        help="fail when fresh tokens/sec drops more than this fraction",
    )
    ap.add_argument(
        "--max-idle",
        type=float,
        default=0.05,
        help="overlap mode: fail when device-idle fraction exceeds this",
    )
    ap.add_argument(
        "--min-speedup",
        type=float,
        default=1.05,
        help="kernels mode: fail when a non-loops backend's "
        "speedup_vs_loops falls below this",
    )
    ap.add_argument(
        "--max-preprocess-frac",
        type=float,
        default=0.5,
        help="serve mode: fail when preprocessing exceeds this fraction "
        "of host wall time",
    )
    args = ap.parse_args(argv)

    if args.mode == "overlap":
        return check_overlap(args)
    if args.mode == "kernels":
        return check_kernels(args)
    if args.mode == "serve":
        return check_serve(args)

    baseline = load_rows(args.baseline)
    fresh = load_rows(args.fresh)
    if not baseline:
        print(f"no baseline rows with {METRIC!r} in {args.baseline}")
        return 1

    failures = []
    for key in sorted(baseline):
        base = baseline[key]
        got = fresh.get(key)
        label = "/".join(key)
        if got is None:
            failures.append(f"{label}: missing from fresh run")
            continue
        floor = base * (1.0 - args.max_regression)
        delta = 100.0 * (got / base - 1.0)
        status = "OK" if got >= floor else "REGRESSION"
        print(
            f"{label}: baseline {base:,.0f} tok/s, "
            f"fresh {got:,.0f} tok/s ({delta:+.1f}%) {status}"
        )
        if got < floor:
            failures.append(
                f"{label}: {got:,.0f} < floor {floor:,.0f} tok/s "
                f"({delta:+.1f}% vs baseline)"
            )
    if failures:
        print()
        print(f"perf gate failed ({len(failures)} row(s)):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"perf gate passed: {len(baseline)} row(s) within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
