"""Perf-regression gate over the tokenize benchmark baseline.

CI runs ``python -m benchmarks.bench_preprocessing --tokenize --quick``
(which rewrites ``benchmarks/results/tokenize.csv``) after copying the
committed CSV aside, then calls this script to compare the fresh
``tokens_per_s`` of every ``(dataset_id, mode)`` row against the baseline.
A row slower than ``baseline * (1 - max_regression)`` fails the gate; rows
present in the baseline but missing from the fresh run fail too (a
silently skipped leg must not read as a pass).

Refresh the committed baseline by re-running the bench on the reference
machine and committing the regenerated CSV. The baseline is absolute
throughput: regenerate it when the CI runner class changes, or loosen
``--max-regression`` if the runner fleet is heterogeneous.
"""

import argparse
import csv
import sys
from pathlib import Path

METRIC = "tokens_per_s"
KEY_FIELDS = ("dataset_id", "mode")


def load_rows(path):
    with open(path, newline="") as fh:
        return {
            tuple(row[k] for k in KEY_FIELDS): float(row[METRIC])
            for row in csv.DictReader(fh)
            if row.get(METRIC)
        }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", type=Path, required=True)
    ap.add_argument("--fresh", type=Path, required=True)
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.30,
        help="fail when fresh tokens/sec drops more than this fraction",
    )
    args = ap.parse_args(argv)

    baseline = load_rows(args.baseline)
    fresh = load_rows(args.fresh)
    if not baseline:
        print(f"no baseline rows with {METRIC!r} in {args.baseline}")
        return 1

    failures = []
    for key in sorted(baseline):
        base = baseline[key]
        got = fresh.get(key)
        label = "/".join(key)
        if got is None:
            failures.append(f"{label}: missing from fresh run")
            continue
        floor = base * (1.0 - args.max_regression)
        delta = 100.0 * (got / base - 1.0)
        status = "OK" if got >= floor else "REGRESSION"
        print(
            f"{label}: baseline {base:,.0f} tok/s, "
            f"fresh {got:,.0f} tok/s ({delta:+.1f}%) {status}"
        )
        if got < floor:
            failures.append(
                f"{label}: {got:,.0f} < floor {floor:,.0f} tok/s "
                f"({delta:+.1f}% vs baseline)"
            )
    if failures:
        print()
        print(f"perf gate failed ({len(failures)} row(s)):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"perf gate passed: {len(baseline)} row(s) within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
