"""Serving latency: the per-request row-program path end to end.

Drives :func:`repro.runtime.serve_loop.serve_text` — raw abstract text in,
generated title tokens out — against a smoke-config LM, with requests
arriving in waves through the bounded admission queue and a shared
:class:`RingCache` (a fraction of prompts repeat across waves, so the
cache-hit path is exercised). Reports p50/p99 end-to-end latency and the
preprocess-vs-decode wall-time split; ``check_regression.py --mode serve``
gates the committed ``results/serve_latency.json`` in CI.
"""

from __future__ import annotations

import dataclasses
import json
import random
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core.dataset import Dataset
from repro.core.expr import abstract_expr, col
from repro.data.batching import TokenSpec
from repro.models.lm import LM
from repro.runtime.serve_loop import RingCache, ServeStats, TextRequest, serve_text

from .common import RESULTS_DIR, dataset_dirs

SERVE_JSON = RESULTS_DIR / "serve_latency.json"
REPEAT_FRAC = 0.25  # fraction of requests repeating an earlier prompt
MAX_SEQ = 96
MAX_NEW = 10


def build_row_program(directory: Path):
    # The serve-side plan encodes the request text only: at request time
    # there is no title (the model generates it), so the program reads the
    # abstract column alone — bare-string requests lower to it directly.
    base = (
        Dataset.from_json_dirs([directory], fields=("abstract",))
        .where(col("abstract").not_empty())
        .transform(abstract=abstract_expr())
    )
    tok = base.fit_vocab(vocab_size=2000)
    chain = base.tokenize(tok, [TokenSpec("abstract", 64)]).batched(8).prefetch(2)
    return chain.row_program(), tok


def sample_requests(directory: Path, n: int, seed: int = 7) -> list[TextRequest]:
    """``n`` raw-text requests: unique abstracts with ~REPEAT_FRAC repeats
    of earlier prompts mixed in (deterministic), so later waves hit the
    ring cache the way production repeat traffic would."""
    records = Dataset.from_json_dirs([directory]).dropna().collect().to_records()
    texts = [r["abstract"] for r in records if r.get("abstract")]
    rng = random.Random(seed)
    out: list[str] = []
    for i in range(n):
        if out and rng.random() < REPEAT_FRAC:
            out.append(out[rng.randrange(len(out))])
        else:
            out.append(texts[i % len(texts)])
    return [TextRequest(uid, text, max_new=MAX_NEW) for uid, text in enumerate(out)]


def run(quick: bool = False, requests: int | None = None, slots: int = 4) -> dict:
    n_requests = requests or (24 if quick else 64)
    _, directory, _ = dataset_dirs(quick=True)[0]
    row_program, tok = build_row_program(directory)

    cfg = dataclasses.replace(get_smoke("recurrentgemma_9b"), vocab_size=len(tok.itos))
    model = LM(cfg, remat=False, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))

    reqs = sample_requests(directory, n_requests)
    # Warmup: compile the prefill/step kernels outside the measured window.
    serve_text(model, params, row_program, reqs[:2], slots=slots, max_seq=MAX_SEQ)

    cache = RingCache(slots=128)
    stats = ServeStats()
    wave = max(slots * 4, 8)
    tokens_generated = 0
    t0 = time.perf_counter()
    for lo in range(0, len(reqs), wave):
        results = serve_text(
            model,
            params,
            row_program,
            reqs[lo : lo + wave],
            slots=slots,
            max_seq=MAX_SEQ,
            queue_size=wave,
            cache=cache,
            stats=stats,
        )
        tokens_generated += sum(len(v) for v in results.values())
    wall_s = time.perf_counter() - t0

    lat_ms = sorted(v * 1e3 for v in stats.latency_s.values())
    host_s = stats.preprocess_s + stats.decode_s
    return {
        "name": "serve_latency",
        "quick": quick,
        "requests": len(reqs),
        "slots": slots,
        "served": stats.served,
        "rejected": stats.rejected,
        "filtered": stats.filtered,
        "cache_hits": stats.cache_hits,
        "cache_misses": stats.cache_misses,
        "p50_ms": round(float(np.percentile(lat_ms, 50)), 3),
        "p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
        "preprocess_s": round(stats.preprocess_s, 4),
        "decode_s": round(stats.decode_s, 4),
        "preprocess_frac": round(stats.preprocess_s / host_s, 5) if host_s else 0.0,
        "tokens_generated": tokens_generated,
        "requests_per_s": round(len(reqs) / wall_s, 2) if wall_s else 0.0,
    }


def main(
    quick: bool = False,
    requests: int | None = None,
    slots: int = 4,
    out: Path = SERVE_JSON,
) -> None:
    row = run(quick=quick, requests=requests, slots=slots)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(row, indent=2) + "\n")
    print(f"serve_latency,{row['p50_ms'] * 1e3},{json.dumps(row)}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--requests", type=int, default=None,
                    help="total requests (default: 24 quick / 64 full)")
    ap.add_argument("--slots", type=int, default=4,
                    help="continuous-batching decode slots")
    ap.add_argument("--out", type=Path, default=SERVE_JSON,
                    help="output JSON path")
    args = ap.parse_args()
    main(args.quick, args.requests, args.slots, args.out)
