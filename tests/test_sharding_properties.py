"""Property tests for the sharding rule engine invariants + async ckpt."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.distributed.sharding import DEFAULT_RULES, FSDP_RULES, SP_RULES, spec_for


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESHES = [
    FakeMesh({"data": 16, "model": 16}),
    FakeMesh({"pod": 2, "data": 16, "model": 16}),
    FakeMesh({"data": 4, "model": 2}),
]

LOGICAL = [None, "batch", "seq", "vocab", "embed", "heads", "kv_heads",
           "head_dim", "mlp", "experts", "expert_ff", "rnn", "rnn_in", "frontend"]


@st.composite
def tensor_case(draw):
    rank = draw(st.integers(1, 4))
    dims = [draw(st.sampled_from([1, 2, 3, 8, 16, 40, 64, 128, 504, 512, 7168]))
            for _ in range(rank)]
    axes = [draw(st.sampled_from(LOGICAL)) for _ in range(rank)]
    mesh = draw(st.sampled_from(MESHES))
    rules = draw(st.sampled_from([DEFAULT_RULES, FSDP_RULES, SP_RULES]))
    return tuple(dims), tuple(axes), mesh, rules


def _flat_axes(entry):
    if entry is None:
        return []
    if isinstance(entry, tuple):
        return list(entry)
    return [entry]


@settings(max_examples=200, deadline=None)
@given(case=tensor_case())
def test_spec_invariants(case):
    dims, axes, mesh, rules = case
    spec = spec_for(dims, axes, mesh, rules)
    assert len(spec) <= len(dims)
    used = []
    for i, entry in enumerate(tuple(spec)):
        names = _flat_axes(entry)
        for n in names:
            # 1. every assigned axis exists in the mesh
            assert n in mesh.shape
            # 2. no mesh axis is used by two dims (PartitionSpec invariant)
            assert n not in used
            used.append(n)
        if names:
            # 3. the product of assigned axis sizes divides the dim
            total = int(np.prod([mesh.shape[n] for n in names]))
            assert dims[i] % total == 0


def test_spec_builds_valid_named_sharding():
    """Specs from the engine must be accepted by real NamedSharding."""
    from jax.sharding import NamedSharding
    from jax.sharding import AxisType

    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
    spec = spec_for((16, 8, 128), ("embed", "kv_heads", "head_dim"), mesh, DEFAULT_RULES)
    NamedSharding(mesh, spec)  # must not raise


def test_async_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint.checkpointer import Checkpointer

    ck = Checkpointer(tmp_path)
    tree = {"w": jnp.arange(8.0), "m": {"v": jnp.ones((3, 3))}}
    ck.save_async(5, tree, extra={"step": 5})
    ck.wait()
    restored, extra = ck.restore(tree)
    assert extra["step"] == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))


def test_async_checkpoint_snapshot_isolated(tmp_path):
    """Mutating (donating) the live tree after save_async must not corrupt
    the checkpoint — the snapshot is taken synchronously."""
    from repro.checkpoint.checkpointer import Checkpointer

    ck = Checkpointer(tmp_path)
    tree = {"w": jnp.arange(4.0)}
    ck.save_async(1, tree)
    tree["w"] = tree["w"] + 100.0  # simulates the next train step
    ck.wait()
    restored, _ = ck.restore(tree)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(4.0))
