"""Invalid-plan corpus + static-analysis self-tests (PR 9).

Three suites:

* **Invalid-plan corpus** — every known way to build a broken plan, each
  pinned to its diagnostic code and provenance. The companion
  spawn-counting test proves each one fails from ``Dataset.validate()``
  (auto-run at the head of every terminal) *before* any executor
  thread, worker process, or remote coordinator is constructed.
* **Rewrite-verifier unit tests** — :func:`verify_rewrite_pair` against
  deliberately tampered "optimized" plans (dropped filter, lost column,
  changed lineage, reordered dedup, broken scoping).
* **Contract-linter self-tests** — seeded R0xx violations planted in a
  tmp package tree, asserted caught; the real tree asserted clean; the
  ``python -m repro.analysis`` CLI exit codes.
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

import repro
from repro.analysis import PlanValidationError
from repro.analysis.contracts import lint_contracts
from repro.analysis.rewrites import verify_rewrite_pair
from repro.core import bytesops as B
from repro.core import executor as EX
from repro.core import expr as E
from repro.core import plan as P
from repro.core.dataset import Dataset
from repro.core.expr import col
from repro.data.batching import TokenSpec
from repro.data.tokenizer import WordTokenizer

FIELDS = ("title", "abstract")


def _tok():
    return WordTokenizer(["w"])


def _spec(column="title", max_len=8, out=None):
    return TokenSpec(column, max_len, out=out)


# -- invalid-plan corpus ----------------------------------------------------
#
# name -> (builder, expected code, provenance fragment, terminal, validate kw)
# ``terminal`` is how the plan would reach execution via the public API:
#   "iter"    Dataset.iter_batches()
#   "collect" Dataset.collect()
#   "stream"  direct plan.stream_batches() (shapes .iter_batches() routes
#             to whole-frame execution instead of streaming)


def _p001_non_json_source():
    return Dataset.from_records([{"title": "a", "abstract": "b"}], FIELDS)


def _p002_split_in_stream():
    train, _val = Dataset.from_json_dirs(["/x"], FIELDS).split(0.5)
    return train


def _p003_missing_tokenize():
    return Dataset.from_json_dirs(["/x"], FIELDS).prefetch(2)


def _p004_missing_batch():
    return (
        Dataset.from_json_dirs(["/x"], FIELDS)
        .tokenize(_tok(), (_spec(),))
        .prefetch(2)
    )


def _p005_stacked_partial_dedup():
    return (
        Dataset.from_json_dirs(["/x"], FIELDS)
        .drop_duplicates(["title"])
        .drop_duplicates(["abstract"])
        .tokenize(_tok(), (_spec(),))
        .batched(4)
        .prefetch(2)
    )


def _p006_select_unknown_column():
    # Hand-built: the Dataset builder verbs reject this at construction,
    # but deserialized/hand-assembled plans reach validate() directly.
    nodes = [P.SourceJsonDirs(("/x",), FIELDS), P.Select(("nope",))]
    return Dataset(nodes, ("nope",))


def _p007_frame_after_array():
    nodes = [
        P.SourceJsonDirs(("/x",), FIELDS),
        P.Tokenize(_tok(), (_spec(),)),
        P.DropNA(("title",)),
    ]
    return Dataset(nodes, FIELDS)


def _p008_batch_without_tokenize():
    nodes = [P.SourceJsonDirs(("/x",), FIELDS), P.Batch(4)]
    return Dataset(nodes, FIELDS)


def _p009_off_grid_buckets():
    nodes = [
        P.SourceJsonDirs(("/x",), FIELDS),
        P.Tokenize(_tok(), (_spec(out="title_tokens"),)),
        P.Batch(4, bucket_by="title_tokens", buckets=(8, 4)),
    ]
    return Dataset(nodes, FIELDS)


def _p014_no_source():
    return Dataset([P.Select(("title",))], ("title",))


def _e001_predicate_in_transform_position():
    nodes = [
        P.SourceJsonDirs(("/x",), FIELDS),
        P.Project((("flag", col("title").not_empty()),)),
    ]
    return Dataset(nodes, FIELDS)


def _e002_expression_in_predicate_position():
    nodes = [P.SourceJsonDirs(("/x",), FIELDS), P.Filter(col("title").lower())]
    return Dataset(nodes, FIELDS)


def _e003_regex_does_not_compile():
    # The builder verbs compile regexes at construction; a hand-built op
    # (deserialized plan) reaches the analyzer instead.
    bad = E.StrOp(
        col("title"), B.Op(kind="regex", regex=(b"(unclosed", b"x")), "bad_rx"
    )
    nodes = [P.SourceJsonDirs(("/x",), FIELDS), P.Project((("title", bad),))]
    return Dataset(nodes, FIELDS)


def _e005_expr_reads_unknown_column():
    nodes = [
        P.SourceJsonDirs(("/x",), FIELDS),
        P.Project((("x", col("nope").lower()),)),
    ]
    return Dataset(nodes, FIELDS)


CORPUS = {
    "p001_non_json_source": (
        _p001_non_json_source, "P001", "SourceFrame", "stream",
        {"streaming": True},
    ),
    "p002_split_in_stream": (
        _p002_split_in_stream, "P002", "Split", "stream", {"streaming": True},
    ),
    "p003_missing_tokenize": (
        _p003_missing_tokenize, "P003", "Prefetch", "iter", {},
    ),
    "p004_missing_batch": (
        _p004_missing_batch, "P004", "Prefetch", "iter", {},
    ),
    "p005_stacked_partial_dedup": (
        _p005_stacked_partial_dedup, "P005", "DropDuplicates", "iter", {},
    ),
    "p006_select_unknown_column": (
        _p006_select_unknown_column, "P006", "Select", "collect", {},
    ),
    "p007_frame_after_array": (
        _p007_frame_after_array, "P007", "DropNA", "iter", {},
    ),
    "p008_batch_without_tokenize": (
        _p008_batch_without_tokenize, "P008", "Batch", "iter", {},
    ),
    "p009_off_grid_buckets": (
        _p009_off_grid_buckets, "P009", "Batch", "iter", {},
    ),
    "p014_no_source": (_p014_no_source, "P014", "Select", "collect", {}),
    "e001_predicate_in_transform_position": (
        _e001_predicate_in_transform_position, "E001", "Project", "collect", {},
    ),
    "e002_expression_in_predicate_position": (
        _e002_expression_in_predicate_position, "E002", "Filter", "collect", {},
    ),
    "e003_regex_does_not_compile": (
        _e003_regex_does_not_compile, "E003", "Project", "collect", {},
    ),
    "e005_expr_reads_unknown_column": (
        _e005_expr_reads_unknown_column, "E005", "Project", "collect", {},
    ),
}


@pytest.mark.parametrize("name", sorted(CORPUS))
def test_corpus_fixture_yields_coded_diagnostic(name):
    build, code, prov_frag, _terminal, kwargs = CORPUS[name]
    diags = build().validate(**kwargs)
    hits = [d for d in diags if d.code == code]
    assert hits, (
        f"expected {code}, got "
        f"{[(d.code, d.message) for d in diags] or 'a clean plan'}"
    )
    diag = hits[0]
    assert diag.severity == "error"
    assert diag.provenance, f"{code} diagnostic carries no provenance"
    assert any(prov_frag in line for line in diag.provenance), (
        f"no provenance line mentions {prov_frag!r}: {diag.provenance}"
    )
    # Provenance renders like explain() node listings: "node <i>: <describe>"
    assert all(line.startswith("node ") for line in diag.provenance)


class _SpawnCounter:
    """Counts (and vetoes) every way execution machinery can start: the
    physical-executor factory, the executor classes themselves, and the
    whole-frame plan runners."""

    def __init__(self, monkeypatch):
        self.count = 0

        def bump(*_a, **_k):
            self.count += 1
            raise AssertionError(
                "executor/plan-runner spawned for an invalid plan"
            )

        monkeypatch.setattr(EX, "make_executor", bump)
        monkeypatch.setattr(EX.ThreadShardExecutor, "__init__", bump)
        monkeypatch.setattr(EX.ProcessShardExecutor, "__init__", bump)
        monkeypatch.setattr(P, "execute_frame_plan", bump)
        monkeypatch.setattr(P, "continue_frame_plan", bump)


def _run_terminal(ds, terminal):
    if terminal == "iter":
        return ds.iter_batches()
    if terminal == "collect":
        return ds.collect()
    if terminal == "stream":
        return next(P.stream_batches(ds.plan, final_schema=ds.schema))
    raise AssertionError(terminal)


@pytest.mark.parametrize("name", sorted(CORPUS))
def test_corpus_fails_before_any_executor_spawns(name, monkeypatch):
    build, code, _frag, terminal, _kwargs = CORPUS[name]
    ds = build()
    counter = _SpawnCounter(monkeypatch)
    with pytest.raises(PlanValidationError) as excinfo:
        _run_terminal(ds, terminal)
    assert any(d.code == code for d in excinfo.value.diagnostics)
    assert counter.count == 0, (
        f"{counter.count} executor(s) spawned before validation failed"
    )


def test_validation_error_renders_codes_and_provenance():
    with pytest.raises(PlanValidationError) as excinfo:
        _p005_stacked_partial_dedup().iter_batches()
    text = str(excinfo.value)
    assert "P005" in text
    assert "at node " in text  # provenance lines render like explain()
    # and the structured form is preserved for tools
    (diag,) = excinfo.value.diagnostics
    assert len(diag.provenance) == 2


def test_fit_vocab_validates_frame_prefix(monkeypatch):
    counter = _SpawnCounter(monkeypatch)
    with pytest.raises(PlanValidationError) as excinfo:
        _p006_select_unknown_column().fit_vocab(_tok())
    assert any(d.code == "P006" for d in excinfo.value.diagnostics)
    assert counter.count == 0


def test_warning_diagnostics_do_not_block_execution():
    """E004 (unfingerprintable lambda op) is a warning: validate() reports
    it, _require_valid lets the plan run."""
    lam = E.StrOp(
        col("title"),
        B.Op(kind="wordpred", pred=lambda _v, ln: ln > 2),
        "lambda_pred",
    )
    nodes = [P.SourceJsonDirs(("/x",), FIELDS), P.Project((("title", lam),))]
    ds = Dataset(nodes, FIELDS)
    diags = ds.validate()
    assert [(d.code, d.severity) for d in diags] == [("E004", "warning")]
    ds._require_valid()  # must not raise


def test_valid_plans_are_clean():
    ds = (
        Dataset.from_json_dirs(["/x"], FIELDS)
        .dropna()
        .where(col("title").not_empty())
        .with_column("title", col("title").lower())
        .tokenize(_tok(), (_spec(),))
        .batched(4)
        .prefetch(2)
    )
    assert ds.validate() == []


# -- backstop raises: unreachable via the public API ------------------------


def test_streaming_backstops_unreachable_via_public_api():
    """The four legacy mid-execution raises in stream_batches survive as
    backstops, but every public-API route now surfaces the analyzer's
    structured error instead: the exception always carries diagnostics."""
    for build, terminal in [
        (_p001_non_json_source, "stream"),
        (_p002_split_in_stream, "stream"),
        (_p003_missing_tokenize, "iter"),
        (_p004_missing_batch, "iter"),
        (_p005_stacked_partial_dedup, "iter"),
    ]:
        with pytest.raises(ValueError) as excinfo:
            _run_terminal(build(), terminal)
        err = excinfo.value
        assert isinstance(err, PlanValidationError), (
            f"legacy backstop ValueError leaked for {build.__name__}: {err}"
        )
        assert err.diagnostics


def test_streaming_backstop_still_fires_if_analyzer_bypassed(monkeypatch):
    """Defense in depth: with the analyzer stubbed out, the original
    raises still stop a malformed plan from executing."""
    from repro.analysis import plan_analyzer as PA

    monkeypatch.setattr(PA, "check_streaming_plan", lambda *_a, **_k: [])
    ds = _p003_missing_tokenize()
    with pytest.raises(ValueError) as excinfo:
        next(P.stream_batches(ds.plan, final_schema=ds.schema))
    assert not isinstance(excinfo.value, PlanValidationError)
    assert "streaming needs .tokenize" in str(excinfo.value)


# -- rewrite verifier -------------------------------------------------------


def _frame(ds):
    return P.split_plan(ds.plan)[0]


def test_rewrite_verifier_catches_dropped_filter():
    ds = Dataset.from_json_dirs(["/x"], FIELDS).where(col("title").not_empty())
    logical = _frame(ds)
    tampered = [n for n in logical if not isinstance(n, P.Filter)]
    diags = verify_rewrite_pair(logical, tampered, ds.schema)
    assert any(d.code == "P012" for d in diags)


def test_rewrite_verifier_catches_lost_final_column():
    ds = Dataset.from_json_dirs(["/x"], FIELDS)
    logical = _frame(ds)
    tampered = list(logical) + [P.Select(("title",))]
    diags = verify_rewrite_pair(logical, tampered, FIELDS)
    assert any(d.code == "P011" and "'abstract'" in d.message for d in diags)


def test_rewrite_verifier_catches_changed_value_lineage():
    ds = Dataset.from_json_dirs(["/x"], FIELDS).with_column(
        "title", col("title").lower()
    )
    logical = _frame(ds)
    tampered = [
        logical[0],
        P.Project((("title", col("title").collapse_spaces()),)),
    ]
    diags = verify_rewrite_pair(logical, tampered, FIELDS)
    assert any(d.code == "P013" and "'title'" in d.message for d in diags)


def test_rewrite_verifier_catches_dropped_dedup():
    ds = Dataset.from_json_dirs(["/x"], FIELDS).drop_duplicates(["title"])
    logical = _frame(ds)
    tampered = [n for n in logical if not isinstance(n, P.DropDuplicates)]
    diags = verify_rewrite_pair(logical, tampered, FIELDS)
    assert any(d.code == "P015" for d in diags)


def test_rewrite_verifier_catches_broken_scoping():
    ds = Dataset.from_json_dirs(["/x"], FIELDS)
    logical = _frame(ds)
    tampered = list(logical) + [P.Filter(col("nope").not_empty())]
    diags = verify_rewrite_pair(logical, tampered, FIELDS)
    assert any(d.code == "P010" for d in diags)


def test_rewrite_verifier_accepts_real_optimizer_output():
    """The real optimizer's CSE + pushdown on a shared cleaning chain must
    verify clean — validate() runs this on every terminal."""
    from repro.core.expr import clean_text

    ds = (
        Dataset.from_json_dirs(["/x"], FIELDS)
        .where(clean_text(col("abstract")).word_count() >= 5)
        .with_column("abstract", clean_text(col("abstract")))
    )
    assert [d for d in ds.validate() if d.severity == "error"] == []


# -- contract linter --------------------------------------------------------


def _write(root: Path, rel: str, text: str) -> None:
    path = root / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(text))


def _plant_clean_tree(tmp_path: Path) -> Path:
    pkg = tmp_path / "fakepkg"
    _write(tmp_path, "fakepkg/__init__.py", "")
    _write(tmp_path, "fakepkg/distributed/__init__.py", "")
    _write(tmp_path, "fakepkg/distributed/worker.py", "import os\n")
    _write(tmp_path, "fakepkg/distributed/transport.py", "import socket\n")
    _write(tmp_path, "fakepkg/core/__init__.py", "")
    _write(tmp_path, "fakepkg/core/bytesops.py", "import re\n")
    _write(tmp_path, "fakepkg/runtime/__init__.py", "")
    _write(
        tmp_path,
        "fakepkg/runtime/fault_tolerance.py",
        """\
        import os
        import tempfile

        def beat(path):
            fd, tmp = tempfile.mkstemp(dir=".")
            with os.fdopen(fd, "w") as f:
                f.write("x")
            os.replace(tmp, path)
        """,
    )
    return pkg


def test_linter_clean_on_planted_tree(tmp_path):
    assert lint_contracts(_plant_clean_tree(tmp_path)) == []


def test_linter_catches_seeded_r001_violation(tmp_path):
    """The acceptance-criterion self-test: a transitive module-level jax
    import planted under the worker tier is caught, with the import chain
    in the message and file:line provenance."""
    pkg = _plant_clean_tree(tmp_path)
    _write(tmp_path, "fakepkg/util.py", "import jax\n")
    _write(
        tmp_path,
        "fakepkg/distributed/worker.py",
        "from fakepkg import util\n",
    )
    diags = lint_contracts(pkg)
    r001 = [d for d in diags if d.code == "R001"]
    assert r001, f"seeded R001 violation not caught: {diags}"
    assert "fakepkg.distributed.worker -> fakepkg.util" in r001[0].message
    assert any("util.py:1" in line for line in r001[0].provenance)


def test_linter_exempts_function_level_jax_import(tmp_path):
    pkg = _plant_clean_tree(tmp_path)
    _write(
        tmp_path,
        "fakepkg/distributed/worker.py",
        """\
        def lazy():
            import jax
            return jax
        """,
    )
    assert lint_contracts(pkg) == []


def test_linter_catches_r002_fork_side_jax(tmp_path):
    pkg = _plant_clean_tree(tmp_path)
    _write(tmp_path, "fakepkg/core/bytesops.py", "import jax\n")
    diags = lint_contracts(pkg)
    assert any(d.code == "R002" for d in diags)


def test_linter_catches_r003_torn_write(tmp_path):
    pkg = _plant_clean_tree(tmp_path)
    _write(
        tmp_path,
        "fakepkg/runtime/fault_tolerance.py",
        """\
        def beat(path):
            with open(path, "w") as f:
                f.write("x")
        """,
    )
    diags = lint_contracts(pkg)
    r003 = [d for d in diags if d.code == "R003"]
    assert r003 and "beat()" in r003[0].message
    assert any("fault_tolerance.py:2" in line for line in r003[0].provenance)


def test_linter_catches_r004_bare_except(tmp_path):
    pkg = _plant_clean_tree(tmp_path)
    _write(
        tmp_path,
        "fakepkg/distributed/worker.py",
        """\
        def run():
            try:
                pass
            except:
                pass
        """,
    )
    diags = lint_contracts(pkg)
    assert any(d.code == "R004" for d in diags)


def test_linter_clean_on_real_tree():
    """The repo's own package must satisfy its own contracts — the same
    assertion CI's lint job makes via `python -m repro.analysis`."""
    root = Path(repro.__file__).parent
    diags = lint_contracts(root)
    assert [d for d in diags if d.severity == "error"] == [], "\n".join(
        d.render() for d in diags
    )


def _run_cli(*args):
    env = os.environ.copy()
    src = str(Path(repro.__file__).parents[1])
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True,
        text=True,
        env=env,
    )


def test_cli_exit_codes(tmp_path):
    pkg = _plant_clean_tree(tmp_path)
    clean = _run_cli("--contracts", str(pkg))
    assert clean.returncode == 0, clean.stdout + clean.stderr
    assert "0 error(s)" in clean.stdout

    _write(tmp_path, "fakepkg/util.py", "import jax\n")
    _write(
        tmp_path, "fakepkg/distributed/worker.py", "from fakepkg import util\n"
    )
    seeded = _run_cli("--contracts", str(pkg))
    assert seeded.returncode == 1
    assert "R001" in seeded.stdout


def test_cli_rule_subset(tmp_path):
    pkg = _plant_clean_tree(tmp_path)
    _write(tmp_path, "fakepkg/util.py", "import jax\n")
    _write(
        tmp_path, "fakepkg/distributed/worker.py", "from fakepkg import util\n"
    )
    # R001 excluded from the subset: the seeded violation must not fire.
    out = _run_cli("--contracts", str(pkg), "--rules", "R003,R004")
    assert out.returncode == 0, out.stdout + out.stderr
