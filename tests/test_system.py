"""End-to-end behaviour tests for the paper's system: corpus → P3SAPP
pipeline → tokenizer → case-study model training → inference, plus the
async loader and serving runtime."""

import jax
import numpy as np
import pytest

from repro.configs.p3sapp_summarizer import SMOKE as S2S
from repro.core.async_loader import AsyncLoader, ShardPool
from repro.core.p3sapp import run_p3sapp
from repro.data.batching import batches, seq2seq_arrays
from repro.data.synthetic import write_corpus
from repro.data.tokenizer import WordTokenizer
from repro.models.seq2seq import Seq2Seq
from repro.optim.adamw import AdamW


@pytest.fixture(scope="module")
def corpus(tmp_path_factory):
    d = tmp_path_factory.mktemp("e2e_corpus")
    write_corpus(d, total_bytes=400_000, n_files=4, seed=11)
    return d


@pytest.fixture(scope="module")
def cleaned(corpus):
    records, timings = run_p3sapp([corpus], optimize=True)
    assert timings.cumulative > 0
    return records


def test_pipeline_produces_clean_text(cleaned):
    assert len(cleaned) > 100
    for r in cleaned[:200]:
        for field in ("title", "abstract"):
            text = r[field]
            assert text, "post-clean must remove empty rows"
            assert text == text.lower()
            assert "<" not in text and ">" not in text
            assert not any(ch.isdigit() for ch in text)
            assert "  " not in text


def test_tokenizer_roundtrip(cleaned):
    tok = WordTokenizer.fit((r["abstract"] for r in cleaned), vocab_size=512)
    text = cleaned[0]["abstract"].split()[:10]
    enc = tok.encode(" ".join(text), max_len=16)
    dec = tok.decode(enc)
    # every in-vocab word must roundtrip
    for w, d in zip(text, dec.split()):
        if w in tok.stoi:
            assert w == d


def test_seq2seq_trains_and_generates(cleaned):
    tok = WordTokenizer.fit(
        (r["abstract"] + " " + r["title"] for r in cleaned), vocab_size=S2S.vocab_size
    )
    arrs = seq2seq_arrays(cleaned, tok, S2S.max_abstract_len, S2S.max_title_len)
    model = Seq2Seq(S2S)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(learning_rate=5e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, state, _ = opt.update(grads, state, params)
        return params, state, loss

    losses = []
    for i, b in enumerate(batches(arrs, 16, seed=0)):
        params, state, loss = step(params, state, b)
        losses.append(float(loss))
        if i >= 30:
            break
    assert losses[-1] < losses[0]

    gen = model.generate(params, arrs["encoder_tokens"][:4])
    assert gen.shape == (4, S2S.max_title_len)
    assert np.asarray(gen).min() >= 0


def test_async_loader_preserves_batches():
    bs = [{"x": np.full((2, 2), i)} for i in range(10)]
    out = list(AsyncLoader(iter(bs), prefetch=3))
    assert len(out) == 10
    got = sorted(int(np.asarray(b["x"])[0, 0]) for b in out)
    assert got == list(range(10))


def test_async_loader_propagates_errors():
    def gen():
        yield {"x": np.zeros(2)}
        raise RuntimeError("boom")

    with pytest.raises(RuntimeError, match="boom"):
        list(AsyncLoader(gen(), prefetch=1))


def test_shard_pool_work_stealing(corpus):
    from repro.core.ingest import list_shards

    shards = list_shards([corpus])

    def process(path):
        return path.name

    pool = ShardPool(shards, process, n_readers=3)
    results = list(pool)
    assert sorted(results) == sorted(p.name for p in shards)


def test_shard_pool_propagates_errors(corpus):
    from repro.core.ingest import list_shards

    def process(path):
        raise ValueError("bad shard")

    pool = ShardPool(list_shards([corpus]), process, n_readers=2)
    with pytest.raises(ValueError):
        list(pool)


def test_device_cleaner_end_to_end(cleaned, corpus):
    """On-device (interpret) cleaning path produces sane text."""
    from repro.core.device_pipeline import device_case_study_cleaner
    from repro.core.frame import ColumnarFrame

    frame = ColumnarFrame.from_records(
        [{"t": "Hello <b>World</b> 42 the a!"}, {"t": "MiXeD (x) CaSe"}], ["t"]
    )
    out = device_case_study_cleaner().transform(frame, ["t"])
    vals = list(out["t"])
    assert vals[0] == "hello world"  # lower+tags+digits+stopwords+short words
    assert "mixed" in vals[1]
