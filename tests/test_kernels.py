"""Pallas kernel validation: interpret-mode vs pure-jnp oracle, sweeping
shapes and dtypes.

Every parity test here runs under ``interpret=True`` so the kernel bodies
execute on CPU in plain CI — no blanket skip. The only genuinely-TPU-only
cases are the *compiled* (non-interpret) runs, and those are gated by a
capability check (``requires_tpu``) instead of skipping the module."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def tpu_available() -> bool:
    try:
        return len(jax.devices("tpu")) > 0
    except RuntimeError:
        return False


requires_tpu = pytest.mark.skipif(
    not tpu_available(),
    reason="compiled (non-interpret) Pallas kernels need a TPU backend",
)

from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.lstm_cell.ops import lstm_cell_op
from repro.kernels.lstm_cell.ref import lstm_cell_ref
from repro.kernels.rg_lru.ops import rg_lru_op
from repro.kernels.rg_lru.ref import rg_lru_ref
from repro.kernels.text_clean.ops import clean_rows, pack_rows, text_clean_op
from repro.kernels.text_clean.ref import text_clean_ref

KEY = jax.random.PRNGKey(0)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (b, sq, skv, nq, nkv, hd, causal, window, blk)
    (2, 128, 128, 4, 4, 64, True, 0, 64),
    (1, 256, 256, 8, 2, 32, True, 0, 128),
    (2, 128, 128, 4, 1, 64, True, 64, 64),   # MQA + sliding window
    (1, 96, 96, 4, 4, 64, False, 0, 64),     # encoder (non-divisible seq)
    (1, 200, 200, 2, 2, 128, True, 0, 128),  # padded seq
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=[str(c) for c in FLASH_CASES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(case, dtype):
    b, sq, skv, nq, nkv, hd, causal, window, blk = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, nq, hd), dtype)
    k = jax.random.normal(ks[1], (b, skv, nkv, hd), dtype)
    v = jax.random.normal(ks[2], (b, skv, nkv, hd), dtype)
    out = flash_attention_op(q, k, v, causal=causal, window=window,
                             blk_q=blk, blk_k=blk, interpret=True)

    def pack(x, h):
        return jnp.moveaxis(x, 2, 1).reshape(b * h, x.shape[1], hd)

    ref = flash_attention_ref(pack(q, nq), pack(k, nkv), pack(v, nkv),
                              n_q_heads=nq, n_kv_heads=nkv, causal=causal, window=window)
    ref = jnp.moveaxis(ref.reshape(b, nq, sq, hd), 1, 2)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype))


def test_flash_matches_model_sdpa():
    from repro.models.attention import sdpa

    q = jax.random.normal(KEY, (2, 64, 8, 32))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 64, 2, 32))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 64, 2, 32))
    out = flash_attention_op(q, k, v, causal=True, blk_q=32, blk_k=32, interpret=True)
    ref = sdpa(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# rg_lru
# ---------------------------------------------------------------------------

RG_CASES = [
    (1, 64, 32, 32, 32),
    (2, 128, 256, 64, 128),
    (3, 100, 48, 32, 16),  # non-divisible seq and d
]


@pytest.mark.parametrize("case", RG_CASES, ids=[str(c) for c in RG_CASES])
@pytest.mark.parametrize("with_h0", [False, True])
def test_rg_lru(case, with_h0):
    b, s, d, blk_s, blk_d = case
    ks = jax.random.split(KEY, 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (b, s, d))) * 0.98
    bb = jax.random.normal(ks[1], (b, s, d)) * 0.1
    h0 = jax.random.normal(ks[2], (b, d)) if with_h0 else None
    out = rg_lru_op(a, bb, h0, blk_s=blk_s, blk_d=blk_d, interpret=True)
    ref = rg_lru_ref(a, bb, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_rg_lru_matches_model_scan():
    """Kernel == the model's associative-scan training path."""
    from repro.configs import get_smoke
    from repro.models import rglru as RG

    cfg = get_smoke("recurrentgemma_9b")
    p = RG.init_rglru(KEY, cfg, jnp.float32)
    u = jax.random.normal(jax.random.fold_in(KEY, 7), (2, 32, cfg.resolved_d_rnn))
    a, b = RG._gates(p, u)
    href, _ = RG.rglru_scan(p, u)
    hker = rg_lru_op(a, b, blk_s=16, blk_d=32, interpret=True)
    np.testing.assert_allclose(np.asarray(hker), np.asarray(href, np.float32), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# lstm_cell
# ---------------------------------------------------------------------------

LSTM_CASES = [
    (4, 16, 32, 4, 16),
    (8, 64, 64, 8, 32),
    (5, 24, 48, 8, 48),  # non-divisible batch
]


@pytest.mark.parametrize("case", LSTM_CASES, ids=[str(c) for c in LSTM_CASES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lstm_cell(case, dtype):
    b, d_in, hidden, blk_b, blk_h = case
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (b, d_in), dtype)
    h = jax.random.normal(ks[1], (b, hidden), dtype)
    c = jax.random.normal(ks[2], (b, hidden), dtype)
    params = {
        "wx": jax.random.normal(ks[3], (d_in, 4 * hidden), dtype) * 0.1,
        "wh": jax.random.normal(ks[4], (hidden, 4 * hidden), dtype) * 0.1,
        "b": jax.random.normal(ks[5], (4 * hidden,), dtype) * 0.1,
    }
    ho, co = lstm_cell_op(x, h, c, params, blk_b=blk_b, blk_h=blk_h, interpret=True)
    hr, cr = lstm_cell_ref(x, h, c,
                           params["wx"].reshape(d_in, 4, hidden),
                           params["wh"].reshape(hidden, 4, hidden),
                           params["b"].reshape(4, hidden))
    np.testing.assert_allclose(np.asarray(ho, np.float32), np.asarray(hr, np.float32), **tol(dtype))
    np.testing.assert_allclose(np.asarray(co, np.float32), np.asarray(cr, np.float32), **tol(dtype))


def test_lstm_cell_matches_model_cell():
    from repro.models.seq2seq import LSTMState, init_lstm_layer, lstm_cell as model_cell

    p = init_lstm_layer(KEY, 16, 32, 0.1, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (4, 16))
    h = jax.random.normal(jax.random.fold_in(KEY, 2), (4, 32))
    c = jax.random.normal(jax.random.fold_in(KEY, 3), (4, 32))
    ho, co = lstm_cell_op(x, h, c, p, blk_b=4, blk_h=32, interpret=True)
    st = model_cell(p, x, LSTMState(h, c))
    np.testing.assert_allclose(np.asarray(ho), np.asarray(st.h), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(co), np.asarray(st.c), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# text_clean
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("blk", [4, 64])
def test_text_clean_vs_ref(blk):
    rows = [
        "Hello <b>World</b> 42!",
        "plain text only",
        "UPPER and (kept by kernel) 123",
        "",
    ] * 7
    mat = pack_rows(rows)
    out = text_clean_op(mat, blk_rows=blk, interpret=True)
    ref = text_clean_ref(mat)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@requires_tpu
@pytest.mark.parametrize("blk", [64])
def test_text_clean_compiled_on_tpu(blk):
    """Same parity as above but Mosaic-compiled — TPU capability gated."""
    rows = ["Hello <b>World</b> 42!", "plain text only", ""] * 11
    mat = pack_rows(rows)
    out = text_clean_op(mat, blk_rows=blk, interpret=False)
    ref = text_clean_ref(mat)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@requires_tpu
def test_flash_attention_compiled_on_tpu():
    b, s, h, hd = 1, 128, 4, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    out = flash_attention_op(q, k, v, causal=True, blk_q=64, blk_k=64, interpret=False)
    ref = flash_attention_op(q, k, v, causal=True, blk_q=64, blk_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# text_scan (the bytesops "pallas" backend kernel)
# ---------------------------------------------------------------------------

SCAN_ROWS = [
    "Hello <b>World</b> 42!",
    "plain text only",
    "(paren) and <tag> together",
    "<a(b>c)d adversarial nesting",
    "(a(b<c)d>e stray ) closer",
    "unclosed <span swallows",
    ">> leading closers ((",
    "",
] * 3


@pytest.mark.parametrize("flags", [
    dict(lower=True, strip_html=True, strip_parens=True),
    dict(lower=True, strip_html=True, strip_parens=False),
    dict(lower=False, strip_html=False, strip_parens=True),
    dict(lower=True, strip_html=False, strip_parens=False),
])
def test_text_scan_vs_ref(flags):
    from repro.kernels.text_clean.ops import text_scan_op
    from repro.kernels.text_clean.ref import text_scan_ref

    mat = pack_rows(SCAN_ROWS)
    out = text_scan_op(mat, blk_rows=8, interpret=True, **flags)
    ref = text_scan_ref(mat, **flags)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@requires_tpu
def test_text_scan_compiled_on_tpu():
    from repro.kernels.text_clean.ops import text_scan_op
    from repro.kernels.text_clean.ref import text_scan_ref

    mat = pack_rows(SCAN_ROWS)
    out = text_scan_op(mat, lower=True, strip_html=True, strip_parens=True,
                       interpret=False)
    ref = text_scan_ref(mat, lower=True, strip_html=True, strip_parens=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_scan_flat_matches_loops_ops(monkeypatch):
    """The flat-buffer bridge (pad → kernel → compact) must be
    byte-identical to the sequential loops ops it replaces — including
    non-ASCII bytes and the adversarial nesting rows."""
    from repro.core import bytesops as B
    from repro.kernels.text_clean.ops import scan_flat

    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    rows = SCAN_ROWS + ["naïve café 漢字 🙂 (ñé) <Ω>", "tab\there"]
    buf = B.flatten(rows)
    ops = [B.lut_op(B.LOWER_LUT), B.span_op("<", ">"), B.span_op("(", ")")]
    want = B.apply_ops(buf, ops)
    got = scan_flat(buf, lower=True, strip_html=True, strip_parens=True)
    assert got is not None, "bridge declined despite REPRO_PALLAS_INTERPRET"
    np.testing.assert_array_equal(got, want)


def test_scan_flat_declines_safely(monkeypatch):
    """Without a TPU or the interpret override the bridge must decline
    (return None) rather than run the interpreter in production."""
    from repro.core import bytesops as B
    from repro.kernels.pallas_compat import has_tpu
    from repro.kernels.text_clean.ops import scan_flat

    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    buf = B.flatten(["some text <b>here</b>"])
    out = scan_flat(buf, lower=True, strip_html=True)
    if not has_tpu():
        assert out is None


def test_text_clean_matches_host_stages():
    """Device kernel == host ConvertToLower+RemoveHTMLTags+char-class LUT."""
    from repro.core import bytesops as B

    rows = ["Hello <i>World</i>, 42 Things!", "MiXeD CaSe <p>tag</p> end"]
    out = clean_rows(rows, interpret=True)
    expect = []
    for r in rows:
        buf = B.flatten([r])
        buf = B.apply_lut(buf, B.LOWER_LUT)
        buf = B.span_strip(buf, ord("<"), ord(">"))
        buf = B.apply_lut(buf, B.UNWANTED_LUT)
        buf = B.collapse_spaces(buf)
        expect.append(B.unflatten(buf)[0])
    assert out == expect
