"""Checkpointing + fault tolerance: atomic save/restore, kill-resume,
elastic re-mesh."""

import os
import subprocess
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.runtime.fault_tolerance import Heartbeat, TrainController


def tree_eq(a, b):
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": [jnp.ones(4), {"c": jnp.zeros(2)}]}
    ck.save(10, tree, extra={"step": 10})
    restored, extra = ck.restore(tree)
    assert extra["step"] == 10
    assert tree_eq(tree, restored)


def test_latest_and_retention(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    tree = {"w": jnp.ones(3)}
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    assert ck.latest() == 4
    assert ck.steps() == [3, 4]  # older GC'd


def test_atomicity_partial_write_invisible(tmp_path):
    ck = Checkpointer(tmp_path)
    tree = {"w": jnp.ones(3)}
    ck.save(1, tree)
    # simulate a crash mid-write: stray .tmp dir must be ignored
    (tmp_path / "step_0000000002.tmp").mkdir()
    (tmp_path / "step_0000000002.tmp" / "leaf_00000.npy").write_bytes(b"garbage")
    assert ck.latest() == 1
    restored, _ = ck.restore(tree)
    assert tree_eq(tree, restored)


def test_controller_resumes(tmp_path):
    calls = {"n": 0}

    def init_state():
        calls["n"] += 1
        return {"w": jnp.zeros(2)}, {"m": jnp.zeros(2)}

    def step(params, opt, batch):
        return (
            jax.tree.map(lambda w: w + 1, params),
            opt,
            {"loss": jnp.asarray(1.0)},
        )

    c1 = TrainController(tmp_path, step, init_state, save_every=2)
    c1.run(iter([None] * 5), n_steps=5)
    assert c1.step == 5

    c2 = TrainController(tmp_path, step, init_state, save_every=2)
    assert c2.resumed and c2.step == 5
    assert float(c2.params["w"][0]) == 5.0
    c2.run(iter([None] * 3), n_steps=8)
    assert c2.step == 8


_KILL_SCRIPT = r"""
import sys, time
sys.path.insert(0, "SRC")
import jax, jax.numpy as jnp
from repro.runtime.fault_tolerance import TrainController

def init_state():
    return {"w": jnp.zeros(2)}, {"m": jnp.zeros(2)}

def step(params, opt, batch):
    time.sleep(0.05)
    return jax.tree.map(lambda w: w + 1, params), opt, {"loss": jnp.asarray(0.0)}

c = TrainController("CKPT", step, init_state, save_every=5)
print(f"START {c.step}", flush=True)
c.run(iter([None] * 1000), n_steps=1000)
"""


def test_kill_and_resume(tmp_path):
    """SIGKILL a training process mid-run; the restart must resume from the
    last committed checkpoint (the paper-scale failure model)."""
    script = _KILL_SCRIPT.replace("SRC", str(Path("src").resolve())).replace(
        "CKPT", str(tmp_path)
    )
    env = dict(os.environ)
    proc = subprocess.Popen([sys.executable, "-c", script], env=env,
                            stdout=subprocess.PIPE, text=True)
    time.sleep(12)  # let it commit a few checkpoints
    proc.kill()
    proc.wait()

    ck = Checkpointer(tmp_path)
    committed = ck.latest()
    assert committed is not None and committed >= 5

    # restart: must resume exactly at the committed step
    def init_state():
        return {"w": jnp.zeros(2)}, {"m": jnp.zeros(2)}

    def step(params, opt, batch):
        return jax.tree.map(lambda w: w + 1, params), opt, {"loss": jnp.asarray(0.0)}

    c = TrainController(tmp_path, step, init_state, save_every=5)
    assert c.resumed and c.step == committed
    assert float(c.params["w"][0]) == committed


def test_heartbeat(tmp_path):
    hb = Heartbeat(tmp_path / "hb", interval_s=0.0)
    hb.beat(3)
    assert Heartbeat.is_alive(tmp_path / "hb", timeout_s=10.0)
    assert not Heartbeat.is_alive(tmp_path / "missing", timeout_s=10.0)


def test_elastic_remesh_roundtrip(tmp_path):
    """Checkpoint from one topology restores onto another (here 1-device
    meshes of different shapes; the multi-device path is exercised in
    test_distributed.py)."""
    from repro.runtime.elastic import available_mesh, remesh

    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    axes = {"w": ("embed", "mlp")}
    mesh = available_mesh(model_parallel=1)
    out = remesh(tree, axes, mesh)
    assert tree_eq(tree, out)
