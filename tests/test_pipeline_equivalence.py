"""End-to-end CA vs P3SAPP equivalence (the paper's Tables 5-6 'accuracy')."""

import numpy as np
import pytest

from repro.core.frame import ColumnarFrame
from repro.core.p3sapp import (
    record_match_accuracy,
    run_conventional,
    run_p3sapp,
)
from repro.core.pipeline import Pipeline
from repro.core.stages import ConvertToLower, RemoveShortWords
from repro.data.synthetic import write_corpus


@pytest.fixture(scope="module")
def corpus(tmp_path_factory):
    d = tmp_path_factory.mktemp("corpus")
    write_corpus(d, total_bytes=300_000, n_files=3, seed=7)
    return d


def test_ca_vs_p3sapp_record_match(corpus):
    pa, _ = run_p3sapp([corpus])
    ca, _ = run_conventional([corpus])
    assert len(pa) == len(ca) > 50
    for field in ("title", "abstract"):
        acc = record_match_accuracy(ca, pa, field)
        # The paper reports 93-99%; our deterministic ingestion gives 100%.
        assert acc["percentage"] == 100.0


def test_fused_executor_is_exact(corpus):
    pa_plain, _ = run_p3sapp([corpus], optimize=False)
    pa_fused, _ = run_p3sapp([corpus], optimize=True)
    assert pa_plain == pa_fused


def test_worker_pool_is_exact(corpus):
    pa_serial, _ = run_p3sapp([corpus], workers=1)
    pa_pool, _ = run_p3sapp([corpus], workers=3)
    assert pa_serial == pa_pool


def test_pipeline_output_col_fork():
    frame = ColumnarFrame({"t": np.array(["AA bb", "C dd"], dtype=object)})
    pipe = Pipeline([
        ConvertToLower("t", "t_low"),
        RemoveShortWords("t", threshold=1),  # applies to original column
    ])
    out = pipe.fit(frame).transform(frame)
    assert list(out["t_low"]) == ["aa bb", "c dd"]
    assert list(out["t"]) == ["AA bb", "dd"]


def test_frame_ops():
    frame = ColumnarFrame.from_records(
        [
            {"title": "a", "abstract": "x"},
            {"title": None, "abstract": "y"},
            {"title": "a", "abstract": "x"},
            {"title": "b", "abstract": ""},
        ],
        ["title", "abstract"],
    )
    clean = frame.dropna(["title", "abstract"]).drop_duplicates(["title", "abstract"])
    assert len(clean) == 1
    assert clean.to_records() == [{"title": "a", "abstract": "x"}]


def test_union_and_concat():
    a = ColumnarFrame({"x": np.array(["1"], dtype=object)})
    b = ColumnarFrame({"x": np.array(["2", "3"], dtype=object)})
    assert len(a.union(b)) == 3
    assert len(ColumnarFrame.concat([a, b, a])) == 4
