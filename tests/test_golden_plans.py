"""Golden-plan regression tests.

The optimizer's rewrites (stage merge, dropna pullback, source projection)
are *exact* — they must never change what a plan computes — so their
output shape is part of the API. These snapshots pin the optimized plan
for four representative chains; an optimizer refactor that changes any of
them must update the snapshot deliberately, not silently.

The plan fingerprint (:func:`repro.core.plan.plan_fingerprint`) is pinned
structurally (stable across rebuilds, sensitive to every parameter) rather
than by literal value, since op fingerprints hash LUT/pattern contents.
"""

from repro.core import plan as P
from repro.core.dataset import Dataset
from repro.core.p3sapp import case_study_stages
from repro.core.stages import ConvertToLower, RemoveShortWords
from repro.data.batching import TokenSpec
from repro.data.tokenizer import WordTokenizer


def optimized_lines(ds: Dataset) -> list[str]:
    return [n.describe() for n in ds.optimized_plan()]


def test_golden_stage_and_filter_merge():
    ds = (
        Dataset.from_json_dirs(["/x"])
        .apply(ConvertToLower("title"))
        .apply(RemoveShortWords("title", threshold=2))
        .dropna(["title"])
        .dropna(["abstract"])
    )
    assert optimized_lines(ds) == [
        "SourceJsonDirs(dirs=1, fields=['title', 'abstract'])",
        "ApplyStages(ConvertToLower[title->title], RemoveShortWords[title->title])",
        "DropNA(['title', 'abstract'])",
    ]


def test_golden_dropna_pullback():
    ds = (
        Dataset.from_json_dirs(["/x"])
        .apply(ConvertToLower("abstract"))
        .dropna(["title"])
    )
    assert optimized_lines(ds) == [
        "SourceJsonDirs(dirs=1, fields=['title', 'abstract'])",
        "DropNA(['title'])",
        "ApplyStages(ConvertToLower[abstract->abstract])",
    ]


def test_golden_source_projection():
    tok = WordTokenizer(["w"])
    ds = (
        Dataset.from_json_dirs(["/x"], ("title", "abstract", "venue"))
        .dropna(["abstract"])
        .apply(ConvertToLower("abstract"))
        .tokenize(tok, (TokenSpec("abstract", 16),))
    )
    assert optimized_lines(ds) == [
        "SourceJsonDirs(dirs=1, fields=['abstract'])",
        "DropNA(['abstract'])",
        "ApplyStages(ConvertToLower[abstract->abstract])",
        "Tokenize(['abstract->abstract_tokens'])",
    ]


def test_golden_canonical_p3sapp_chain():
    ds = (
        Dataset.from_json_dirs(["/x"])
        .dropna()
        .drop_duplicates()
        .apply(*case_study_stages())
        .dropna()
    )
    assert optimized_lines(ds) == [
        "SourceJsonDirs(dirs=1, fields=['title', 'abstract'])",
        "DropNA(['title', 'abstract'])",
        "DropDuplicates(['title', 'abstract'])",
        "ApplyStages(ConvertToLower[abstract->abstract], "
        "RemoveHTMLTags[abstract->abstract], "
        "RemoveUnwantedCharacters[abstract->abstract], "
        "StopWordsRemover[abstract->abstract], "
        "RemoveShortWords[abstract->abstract], "
        "ConvertToLower[title->title], RemoveHTMLTags[title->title], "
        "RemoveUnwantedCharacters[title->title], "
        "RemoveShortWords[title->title])",
        "DropNA(['title', 'abstract'])",
    ]


def test_plan_fingerprint_stable_and_parameter_sensitive():
    def build(threshold=1, dirs=("/x",)):
        return (
            Dataset.from_json_dirs(list(dirs))
            .dropna()
            .apply(RemoveShortWords("title", threshold=threshold))
        )

    a = P.plan_fingerprint(build().plan, build().schema)
    b = P.plan_fingerprint(build().plan, build().schema)
    assert a == b  # stable across independent rebuilds of the same chain
    assert a != P.plan_fingerprint(build(threshold=2).plan, build().schema)
    # the optimized fingerprint sees through no-op plan re-orderings but
    # not through real structural change
    assert a != P.plan_fingerprint(build(dirs=("/y",)).plan, build().schema)
