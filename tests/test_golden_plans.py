"""Golden-plan regression tests.

The optimizer's rewrites (Project merge, filter pullback, dead-column
pruning, source projection) are *exact* — they must never change what a
plan computes — so their output shape is part of the API. These snapshots
pin the optimized plan for representative chains; an optimizer refactor
that changes any of them must update the snapshot deliberately, not
silently.

The plan fingerprint (:func:`repro.core.plan.plan_fingerprint`) is pinned
structurally (stable across rebuilds, sensitive to every parameter) rather
than by literal value, since expression fingerprints hash LUT/pattern
contents.
"""

from repro.core import plan as P
from repro.core.dataset import Dataset
from repro.core.expr import abstract_expr, col, concat, title_expr
from repro.core.p3sapp import case_study_stages
from repro.core.stages import ConvertToLower, RemoveShortWords
from repro.data.batching import TokenSpec
from repro.data.tokenizer import WordTokenizer

CLEAN_CHAIN = (
    ".strip_html().strip_parens().expand_contractions()"
    ".keep_letters().collapse_spaces()"
)


def optimized_lines(ds: Dataset) -> list[str]:
    return [n.describe() for n in ds.optimized_plan()]


def test_golden_project_and_filter_merge():
    ds = (
        Dataset.from_json_dirs(["/x"])
        .apply(ConvertToLower("title"))
        .apply(RemoveShortWords("title", threshold=2))
        .dropna(["title"])
        .dropna(["abstract"])
    )
    assert optimized_lines(ds) == [
        "SourceJsonDirs(dirs=1, fields=['title', 'abstract'])",
        "Project(title=col('title').lower(), title=col('title').min_word_len(3))",
        "DropNA(['title', 'abstract'])",
    ]


def test_golden_dropna_pullback():
    ds = (
        Dataset.from_json_dirs(["/x"])
        .apply(ConvertToLower("abstract"))
        .dropna(["title"])
    )
    assert optimized_lines(ds) == [
        "SourceJsonDirs(dirs=1, fields=['title', 'abstract'])",
        "DropNA(['title'])",
        "Project(abstract=col('abstract').lower())",
    ]


def test_golden_source_projection():
    tok = WordTokenizer(["w"])
    ds = (
        Dataset.from_json_dirs(["/x"], ("title", "abstract", "venue"))
        .dropna(["abstract"])
        .apply(ConvertToLower("abstract"))
        .tokenize(tok, (TokenSpec("abstract", 16),))
    )
    assert optimized_lines(ds) == [
        "SourceJsonDirs(dirs=1, fields=['abstract'])",
        "DropNA(['abstract'])",
        "Project(abstract=col('abstract').lower())",
        "Tokenize(abstract->abstract_tokens[max_len=16])",
    ]


def test_golden_canonical_p3sapp_chain():
    ds = (
        Dataset.from_json_dirs(["/x"])
        .dropna()
        .drop_duplicates()
        .apply(*case_study_stages())
        .dropna()
    )
    assert optimized_lines(ds) == [
        "SourceJsonDirs(dirs=1, fields=['title', 'abstract'])",
        "DropNA(['title', 'abstract'])",
        "DropDuplicates(['title', 'abstract'])",
        "Project(abstract=col('abstract').lower(), "
        "abstract=col('abstract').strip_html(), "
        "abstract=col('abstract').strip_parens().expand_contractions()"
        ".keep_letters().collapse_spaces(), "
        "abstract=col('abstract').remove_stopwords(127 words), "
        "abstract=col('abstract').min_word_len(2), "
        "title=col('title').lower(), title=col('title').strip_html(), "
        "title=col('title').strip_parens().expand_contractions()"
        ".keep_letters().collapse_spaces(), "
        "title=col('title').min_word_len(2))",
        "DropNA(['title', 'abstract'])",
    ]


def test_golden_expression_plan_filter_pushed_below_project():
    """Acceptance snapshot: a ``where`` on a *raw* column declared after a
    ``Project`` is pushed back below it, so the predicate runs on source
    byte buffers before any cleaning touches the dropped rows; the unused
    derived column is pruned; the merged predicate renders as a tree."""
    tok = WordTokenizer(["w"])
    ds = (
        Dataset.from_json_dirs(["/x"])
        .with_column("abstract", abstract_expr())
        .with_column("title_clean", title_expr())  # dead: nothing reads it
        .where(col("title").not_empty() & col("title").contains("a"))
        .tokenize(tok, (TokenSpec("abstract", 16),))
    )
    assert optimized_lines(ds) == [
        "SourceJsonDirs(dirs=1, fields=['title', 'abstract'])",
        "Filter((col('title').not_empty() & col('title').contains('a')))",
        "Project(abstract=col('abstract').lower()"
        + CLEAN_CHAIN
        + ".remove_stopwords(127 words).min_word_len(2))",
        "Tokenize(abstract->abstract_tokens[max_len=16])",
    ]


def test_golden_filter_on_derived_column_stays_put():
    """The dual snapshot: a predicate reading a column the Project writes
    must NOT move — pushing it down would filter on pre-cleaning bytes."""
    ds = (
        Dataset.from_json_dirs(["/x"])
        .with_column("abstract", abstract_expr())
        .where(col("abstract").word_count() >= 4)
    )
    assert optimized_lines(ds) == [
        "SourceJsonDirs(dirs=1, fields=['title', 'abstract'])",
        "Project(abstract=col('abstract').lower()"
        + CLEAN_CHAIN
        + ".remove_stopwords(127 words).min_word_len(2))",
        "Filter((col('abstract').word_count() >= 4))",
    ]


def test_golden_batch_options_rendered():
    """explain() must show batch/bucket parameters, not elide them."""
    tok = WordTokenizer(["w"])
    ds = (
        Dataset.from_json_dirs(["/x"])
        .tokenize(tok, (TokenSpec("abstract", 16), TokenSpec("title", 8)))
        .batched(32, shuffle=False, bucket_by="abstract_tokens", buckets=[4, 8])
    )
    line = ds.plan[-1].describe()
    assert "bucket_by=abstract_tokens" in line
    assert "buckets=[4, 8, 16]" in line
    assert "size=32" in line and "shuffle=False" in line


def test_plan_fingerprint_stable_and_parameter_sensitive():
    def build(threshold=1, dirs=("/x",)):
        return (
            Dataset.from_json_dirs(list(dirs))
            .dropna()
            .apply(RemoveShortWords("title", threshold=threshold))
        )

    a = P.plan_fingerprint(build().plan, build().schema)
    b = P.plan_fingerprint(build().plan, build().schema)
    assert a == b  # stable across independent rebuilds of the same chain
    assert a != P.plan_fingerprint(build(threshold=2).plan, build().schema)
    # the optimized fingerprint sees through no-op plan re-orderings but
    # not through real structural change
    assert a != P.plan_fingerprint(build(dirs=("/y",)).plan, build().schema)


def test_expression_fingerprints_stable_and_parameter_sensitive():
    def build(n=3, needle="x"):
        return (
            Dataset.from_json_dirs(["/x"])
            .with_column("both", concat(col("title"), col("abstract")))
            .where(col("both").word_count() >= n)
            .where(col("title").contains(needle))
        )

    a = P.plan_fingerprint(build().plan, build().schema)
    assert a == P.plan_fingerprint(build().plan, build().schema)
    assert a != P.plan_fingerprint(build(n=4).plan, build().schema)
    assert a != P.plan_fingerprint(build(needle="y").plan, build().schema)
