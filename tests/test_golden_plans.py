"""Golden-plan regression tests (file-based snapshots).

The optimizer's rewrites (Project merge, filter pullback, conjunct-split
pushdown, dead-column pruning, source projection, cross-node CSE) are
*exact* — they must never change what a plan computes — so their output
shape is part of the API. Each case under ``tests/golden_plans/`` pins the
full ``explain()`` rendering (logical plan + optimized plan) for a
representative chain; an optimizer refactor that changes any of them must
update the snapshot deliberately, not silently.

On drift the failure message is a unified diff of the plan rendering (the
CI golden-plan gate surfaces it verbatim). To accept intended changes::

    REPRO_UPDATE_GOLDENS=1 python -m pytest tests/test_golden_plans.py -q

The plan fingerprint (:func:`repro.core.plan.plan_fingerprint`) is pinned
structurally (stable across rebuilds, sensitive to every parameter) rather
than by literal value, since expression fingerprints hash LUT/pattern
contents.
"""

import difflib
import os
from pathlib import Path

import pytest

from repro.core import plan as P
from repro.core.dataset import Dataset
from repro.core.expr import (
    abstract_expr,
    clean_text,
    col,
    concat,
    title_expr,
)
from repro.core.p3sapp import case_study_stages
from repro.core.stages import ConvertToLower, RemoveShortWords
from repro.data.batching import TokenSpec
from repro.data.tokenizer import WordTokenizer

GOLDEN_DIR = Path(__file__).parent / "golden_plans"


def _case_project_and_filter_merge() -> Dataset:
    return (
        Dataset.from_json_dirs(["/x"])
        .apply(ConvertToLower("title"))
        .apply(RemoveShortWords("title", threshold=2))
        .dropna(["title"])
        .dropna(["abstract"])
    )


def _case_dropna_pullback() -> Dataset:
    return (
        Dataset.from_json_dirs(["/x"])
        .apply(ConvertToLower("abstract"))
        .dropna(["title"])
    )


def _case_source_projection() -> Dataset:
    tok = WordTokenizer(["w"])
    return (
        Dataset.from_json_dirs(["/x"], ("title", "abstract", "venue"))
        .dropna(["abstract"])
        .apply(ConvertToLower("abstract"))
        .tokenize(tok, (TokenSpec("abstract", 16),))
    )


def _case_canonical_p3sapp_chain() -> Dataset:
    return (
        Dataset.from_json_dirs(["/x"])
        .dropna()
        .drop_duplicates()
        .apply(*case_study_stages())
        .dropna()
    )


def _case_filter_pushed_below_project() -> Dataset:
    """A ``where`` on a *raw* column declared after a ``Project`` is pushed
    back below it, so the predicate runs on source byte buffers before any
    cleaning touches the dropped rows; the unused derived column is pruned;
    the merged predicate renders as a tree."""
    tok = WordTokenizer(["w"])
    return (
        Dataset.from_json_dirs(["/x"])
        .with_column("abstract", abstract_expr())
        .with_column("title_clean", title_expr())  # dead: nothing reads it
        .where(col("title").not_empty() & col("title").contains("a"))
        .tokenize(tok, (TokenSpec("abstract", 16),))
    )


def _case_filter_on_derived_column_stays_put() -> Dataset:
    """The dual snapshot: a predicate reading a column the Project writes
    must NOT move — pushing it down would filter on pre-cleaning bytes."""
    return (
        Dataset.from_json_dirs(["/x"])
        .with_column("abstract", abstract_expr())
        .where(col("abstract").word_count() >= 4)
    )


def _case_conjunct_split_mixed_filter() -> Dataset:
    """Conjunct-split pushdown: the raw-column conjunct of an ``&``
    predicate commutes below the Project (rows it rejects are never
    cleaned) while the derived-column conjunct stays behind it."""
    return (
        Dataset.from_json_dirs(["/x"])
        .with_column("abstract", abstract_expr())
        .where(
            (col("abstract").word_count() >= 4) & col("title").not_empty()
        )
    )


def _case_dropna_split_at_project() -> Dataset:
    """The DropNA analogue of conjunct splitting: the subset half the
    Project does not write commutes below it, the written half stays."""
    return (
        Dataset.from_json_dirs(["/x"])
        .apply(ConvertToLower("title"))
        .dropna(["title", "abstract"])
    )


def _case_cse_filter_project_shared_chain() -> Dataset:
    """Cross-node CSE: the cleaning chain shared by the ``where`` predicate
    and the projected column is hoisted into one ``__cse_*`` entry; both
    consumers read the memoized intermediate and a terminal Select keeps
    it out of the result schema."""
    return (
        Dataset.from_json_dirs(["/x"])
        .where(clean_text(col("abstract")).word_count() >= 5)
        .with_column("abstract", clean_text(col("abstract")))
    )


def _case_cse_shared_prefix_transform() -> Dataset:
    """CSE inside one ``transform``: two derived columns sharing a chain
    prefix compute it once."""
    return (
        Dataset.from_json_dirs(["/x"])
        .transform(
            abstract=clean_text(col("abstract")).remove_stopwords(),
            abstract_long=clean_text(col("abstract")).min_word_len(5),
        )
    )


def _case_cse_concat_shared() -> Dataset:
    """CSE of a shared ``concat`` root between a derived column and a
    later filter."""
    both = concat(col("title"), col("abstract")).lower().collapse_spaces()
    return (
        Dataset.from_json_dirs(["/x"])
        .with_column("both", both)
        .where(both.word_count() >= 3)
    )


CASES = {
    "project_and_filter_merge": _case_project_and_filter_merge,
    "dropna_pullback": _case_dropna_pullback,
    "source_projection": _case_source_projection,
    "canonical_p3sapp_chain": _case_canonical_p3sapp_chain,
    "filter_pushed_below_project": _case_filter_pushed_below_project,
    "filter_on_derived_column_stays_put": _case_filter_on_derived_column_stays_put,
    "conjunct_split_mixed_filter": _case_conjunct_split_mixed_filter,
    "dropna_split_at_project": _case_dropna_split_at_project,
    "cse_filter_project_shared_chain": _case_cse_filter_project_shared_chain,
    "cse_shared_prefix_transform": _case_cse_shared_prefix_transform,
    "cse_concat_shared": _case_cse_concat_shared,
}


@pytest.mark.parametrize("name", sorted(CASES))
def test_golden_plan(name):
    ds = CASES[name]()
    # Every committed golden plan must also be analyzer-clean: the static
    # rewrite verifier re-checks the exact optimizer output these
    # snapshots pin (warnings allowed, errors never).
    errors = [d for d in ds.validate() if d.severity == "error"]
    assert not errors, "analyzer rejected a golden plan:\n" + "\n".join(
        d.render() for d in errors
    )
    got = ds.explain() + "\n"
    path = GOLDEN_DIR / f"{name}.txt"
    if os.environ.get("REPRO_UPDATE_GOLDENS"):
        GOLDEN_DIR.mkdir(parents=True, exist_ok=True)
        path.write_text(got)
        return
    want = path.read_text() if path.exists() else ""
    if got != want:
        diff = "\n".join(
            difflib.unified_diff(
                want.splitlines(),
                got.splitlines(),
                fromfile=f"tests/golden_plans/{name}.txt (committed)",
                tofile="explain() (current optimizer)",
                lineterm="",
            )
        )
        pytest.fail(
            f"golden plan drift for {name!r}:\n{diff}\n\n"
            "If the optimizer change is intended, regenerate with\n"
            "  REPRO_UPDATE_GOLDENS=1 python -m pytest tests/test_golden_plans.py -q",
            pytrace=False,
        )


def test_golden_batch_options_rendered():
    """explain() must show batch/bucket parameters, not elide them."""
    tok = WordTokenizer(["w"])
    ds = (
        Dataset.from_json_dirs(["/x"])
        .tokenize(tok, (TokenSpec("abstract", 16), TokenSpec("title", 8)))
        .batched(32, shuffle=False, bucket_by="abstract_tokens", buckets=[4, 8])
    )
    line = ds.plan[-1].describe()
    assert "bucket_by=abstract_tokens" in line
    assert "buckets=[4, 8, 16]" in line
    assert "size=32" in line and "shuffle=False" in line


def test_plan_fingerprint_stable_and_parameter_sensitive():
    def build(threshold=1, dirs=("/x",)):
        return (
            Dataset.from_json_dirs(list(dirs))
            .dropna()
            .apply(RemoveShortWords("title", threshold=threshold))
        )

    a = P.plan_fingerprint(build().plan, build().schema)
    b = P.plan_fingerprint(build().plan, build().schema)
    assert a == b  # stable across independent rebuilds of the same chain
    assert a != P.plan_fingerprint(build(threshold=2).plan, build().schema)
    # the optimized fingerprint sees through no-op plan re-orderings but
    # not through real structural change
    assert a != P.plan_fingerprint(build(dirs=("/y",)).plan, build().schema)


def test_expression_fingerprints_stable_and_parameter_sensitive():
    def build(n=3, needle="x"):
        return (
            Dataset.from_json_dirs(["/x"])
            .with_column("both", concat(col("title"), col("abstract")))
            .where(col("both").word_count() >= n)
            .where(col("title").contains(needle))
        )

    a = P.plan_fingerprint(build().plan, build().schema)
    assert a == P.plan_fingerprint(build().plan, build().schema)
    assert a != P.plan_fingerprint(build(n=4).plan, build().schema)
    assert a != P.plan_fingerprint(build(needle="y").plan, build().schema)


def test_cse_plan_fingerprint_stable():
    """Synthetic ``__cse_*`` names derive from structural signatures, so
    independently rebuilt CSE plans fingerprint identically."""
    a = _case_cse_filter_project_shared_chain()
    b = _case_cse_filter_project_shared_chain()
    assert [n.describe() for n in a.optimized_plan()] == [
        n.describe() for n in b.optimized_plan()
    ]
