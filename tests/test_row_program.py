"""Train/serve skew: RowProgram output == shard-executor output, per row.

The serving contract is byte-identity *by construction*: both paths
compile the same plan through ``compile_shard_program``. These tests prove
the row evaluator keeps that promise empirically — every adversarial row
(non-ASCII, NUL bytes, balanced/malformed spans, None fields, rows that
clean to nothing) produces identical int32 token arrays through the
per-request :class:`RowProgram` and through a real shard executor, on all
three bytes backends, for both the cleaned/projected path (``encode_flat``)
and the raw-column path (``encode_rows``).
"""

import json
import random

import numpy as np
import pytest

from repro.analysis import PlanValidationError
from repro.core import executor as EX
from repro.core import plan as P
from repro.core.dataset import Dataset
from repro.core.expr import abstract_expr, col, title_expr
from repro.data.batching import TokenSpec, seq2seq_specs
from repro.runtime.row_program import RowProgram, RowProgramError

_FUZZ_CHARS = (
    "abcdefghijklmnopqrstuvwxyz ABCDEFGHIJ 0123456789 <>()'.,;:!?"
    "\t\x00ΩμέλΛñé漢字🙂"
)

EDGE_RECORDS = [
    {"title": "", "abstract": ""},
    {"title": None, "abstract": "an abstract whose title is null"},
    {"title": "nul\x00byte title", "abstract": "nul\x00inside abstract"},
    {"title": "Ωμέλ 漢字 ñé", "abstract": "Greek Ωμ and CJK 漢字 content é"},
    {"title": "A Plain Title", "abstract": "a perfectly plain abstract row"},
    {"title": "x", "abstract": "a b c i of"},  # cleans to nothing
    {"title": "<b>only tags</b>", "abstract": "(only parens)"},
    {"title": "It's span <open", "abstract": "stray ) close and isn't"},
]


def fuzz_records(seed: int, n: int) -> list[dict]:
    rng = random.Random(seed)
    records = []
    for _ in range(n):
        rec = {}
        for f in ("title", "abstract"):
            roll = rng.random()
            if roll < 0.1:
                rec[f] = None
            elif roll < 0.2:
                rec[f] = ""
            else:
                rec[f] = "".join(
                    rng.choice(_FUZZ_CHARS) for _ in range(rng.randrange(1, 80))
                )
        records.append(rec)
    return records


def write_shards(root, records, n_files=3):
    """Contiguous chunks (not round-robin): concatenating per-shard results
    in shard order then reproduces the original record order, which is what
    lets us compare executor outputs to encode_batch row-for-row."""
    root.mkdir(parents=True, exist_ok=True)
    per = -(-len(records) // n_files) or 1
    shards = []
    for i in range(n_files):
        chunk = records[i * per : (i + 1) * per]
        path = root / f"shard-{i}.jsonl"
        with open(path, "w", encoding="utf-8") as f:
            for r in chunk:
                f.write(json.dumps(r) + "\n")
        shards.append(path)
    return shards


def canonical_chain(d):
    keep = col("title").not_empty() & col("abstract").not_empty()
    return (
        Dataset.from_json_dirs([d])
        .where(keep)
        .transform(abstract=abstract_expr(), title=title_expr())
        .where(keep)
    )


def executor_outputs(chain, shards, backend):
    """Reference: the training path. One compiled program, a real thread
    shard executor, results reassembled in shard order."""
    tok_node = next(n for n in chain.plan if isinstance(n, P.Tokenize))
    frame_nodes, _ = P.split_plan(chain.plan)
    frame_nodes = P.optimize_plan(frame_nodes, chain._needed_columns())
    token_plan = EX.TokenPlan(
        specs=tuple(tok_node.specs),
        stoi=dict(tok_node.tokenizer.stoi),
        vocab_fp=tok_node.tokenizer.fingerprint,
    )
    spec_cols = tuple(dict.fromkeys(s.column for s in tok_node.specs))
    program = EX.compile_shard_program(
        frame_nodes,
        output_columns=spec_cols,
        tokens=token_plan,
        backend=backend,
    )
    results = sorted(
        EX.make_executor(shards, program, workers=2, executor="thread"),
        key=lambda r: r.shard_index,
    )
    names = [s.name for s in tok_node.specs]
    return {
        name: np.concatenate([r.tokens[name] for r in results]) for name in names
    }


BACKENDS = ["loops", "fused", "pallas"]


@pytest.fixture(params=BACKENDS)
def backend(request, monkeypatch):
    if request.param == "pallas":
        # Off-TPU the Pallas bridge declines unless interpret mode is
        # forced; force it so the kernel path is genuinely exercised.
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    return request.param


@pytest.mark.parametrize(
    "records",
    [EDGE_RECORDS, fuzz_records(7, 40), fuzz_records(11, 40)],
    ids=["edges", "fuzz7", "fuzz11"],
)
def test_row_program_matches_shard_executor(tmp_path, backend, records):
    shards = write_shards(tmp_path / "corpus", records)
    chain = canonical_chain(tmp_path / "corpus")
    tok = chain.fit_vocab(vocab_size=300)
    chain = chain.tokenize(tok, seq2seq_specs(32, 12)).batched(4).prefetch(2)
    chain = chain.backend(backend)

    rp = chain.row_program()
    assert rp.backend == backend
    assert rp.fingerprint

    ref = executor_outputs(chain, shards, backend)

    # Batch form: all rows at once.
    outs, keep = rp.encode_batch(records)
    for name, arr in ref.items():
        assert outs[name].dtype == np.int32
        np.testing.assert_array_equal(outs[name], arr, err_msg=name)
    assert int(keep.sum()) == ref["encoder_tokens"].shape[0]

    # Row form: one request at a time, matched against the executor's
    # kept-row stream in order.
    kept_i = 0
    for rec, kept in zip(records, keep):
        got = rp(rec)
        if not kept:
            assert got is None
        else:
            for name, arr in ref.items():
                np.testing.assert_array_equal(got[name][0], arr[kept_i])
            kept_i += 1
    assert kept_i == ref["encoder_tokens"].shape[0]


def test_row_program_raw_column_path_matches(tmp_path, backend):
    """A plan that tokenizes an *unprojected* column exercises the
    encode_rows parity leg (raw values, not flat buffers)."""
    records = EDGE_RECORDS + fuzz_records(3, 20)
    shards = write_shards(tmp_path / "corpus", records)
    ds = Dataset.from_json_dirs([tmp_path / "corpus"]).where(
        col("abstract").not_empty()
    )
    tok = ds.fit_vocab(vocab_size=200)
    chain = (
        ds.tokenize(tok, [TokenSpec("abstract", 24), TokenSpec("title", 16)])
        .batched(4)
        .prefetch(2)
        .backend(backend)
    )
    rp = chain.row_program()
    ref = executor_outputs(chain, shards, backend)
    outs, keep = rp.encode_batch(records)
    for name, arr in ref.items():
        np.testing.assert_array_equal(outs[name], arr, err_msg=name)


def test_row_program_single_field_accepts_bare_strings(tmp_path):
    records = [{"abstract": "Deep LEARNING for (scholarly) data!"}]
    write_shards(tmp_path / "corpus", records, n_files=1)
    ds = Dataset.from_json_dirs([tmp_path / "corpus"], fields=("abstract",)).transform(
        abstract=abstract_expr()
    )
    tok = ds.fit_vocab(vocab_size=100)
    rp = ds.tokenize(tok, [TokenSpec("abstract", 16)]).batched(2).prefetch(2).row_program()
    out = rp("Deep LEARNING for (scholarly) data!")
    assert out is not None and out["abstract_tokens"].shape == (1, 16)
    # dict spelling is identical
    out2 = rp({"abstract": "Deep LEARNING for (scholarly) data!"})
    np.testing.assert_array_equal(out["abstract_tokens"], out2["abstract_tokens"])


def test_row_program_rejects_cross_row_plans(tmp_path):
    records = [{"title": "t", "abstract": "a"}]
    write_shards(tmp_path / "corpus", records, n_files=1)
    ds = canonical_chain(tmp_path / "corpus").drop_duplicates()
    tok = ds.fit_vocab(vocab_size=50)
    chain = ds.tokenize(tok, seq2seq_specs(16, 8)).batched(2).prefetch(2)
    with pytest.raises(PlanValidationError) as ei:
        chain.row_program()
    assert any(d.code == "P016" for d in ei.value.diagnostics)


def test_row_program_requires_tokenize(tmp_path):
    records = [{"title": "t", "abstract": "a"}]
    write_shards(tmp_path / "corpus", records, n_files=1)
    ds = canonical_chain(tmp_path / "corpus")
    with pytest.raises(PlanValidationError) as ei:
        ds.row_program()
    assert any(d.code == "P016" for d in ei.value.diagnostics)


def test_row_program_constructor_rejects_stateful_steps():
    with pytest.raises(RowProgramError, match="cross-row"):
        RowProgram(
            fields=("a",),
            steps=(("dedup", ("a",)),),
            specs=(TokenSpec("a", 8),),
            stoi={},
            vocab_fp="x",
        )


def test_row_program_fingerprint_tracks_plan_and_vocab(tmp_path):
    records = [
        {
            "title": "alpha beta gamma delta",
            "abstract": "epsilon zeta eta theta iota kappa lambda nu omicron rho",
        }
    ] * 3
    write_shards(tmp_path / "corpus", records, n_files=1)
    base = canonical_chain(tmp_path / "corpus")
    tok = base.fit_vocab(vocab_size=100)
    rp1 = base.tokenize(tok, seq2seq_specs(16, 8)).batched(2).prefetch(2).row_program()
    rp2 = base.tokenize(tok, seq2seq_specs(16, 8)).batched(2).prefetch(2).row_program()
    assert rp1.fingerprint == rp2.fingerprint  # deterministic
    tok_small = base.fit_vocab(vocab_size=6)
    rp3 = (
        base.tokenize(tok_small, seq2seq_specs(16, 8))
        .batched(2)
        .prefetch(2)
        .row_program()
    )
    assert rp3.fingerprint != rp1.fingerprint  # vocab is part of the key
