"""Per-architecture smoke tests (reduced same-family configs, CPU):
one forward + one train step, asserting output shapes and finiteness, plus
decode==forward consistency and published-size parameter counts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, all_cells, get, get_smoke
from repro.models.lm import LM

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=16):
    batch = {"tokens": jax.random.randint(KEY, (b, s), 4, cfg.vocab_size)}
    if cfg.frontend == "audio":
        batch = {
            "frames": jax.random.normal(KEY, (b, s, cfg.frontend_dim)),
            "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
        }
    elif cfg.frontend == "vision":
        n_img = min(cfg.n_frontend_tokens, s)
        batch["patches"] = jax.random.normal(KEY, (b, n_img, cfg.frontend_dim))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke(arch)
    model = LM(cfg, remat=False, dtype=jnp.float32)
    params = model.init(KEY)
    b, s = 2, 16
    batch = make_batch(cfg, b, s)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_smoke(arch)
    model = LM(cfg, remat=True, dtype=jnp.float32)
    params = model.init(KEY)
    batch = make_batch(cfg)

    @jax.jit
    def step(p, b):
        loss, grads = jax.value_and_grad(model.loss)(p, b)
        p = jax.tree.map(lambda w, g: w - 1e-2 * g, p, grads)
        return p, loss

    p1, l1 = step(params, batch)
    p2, l2 = step(p1, batch)
    assert np.isfinite(float(l1)) and np.isfinite(float(l2))
    assert float(l2) < float(l1)  # same-batch loss must drop


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS if get_smoke(a).causal])
def test_decode_matches_forward(arch):
    cfg = get_smoke(arch)
    model = LM(cfg, remat=False, dtype=jnp.float32)
    params = model.init(KEY)
    b, s = 2, 10
    toks = jax.random.randint(KEY, (b, s), 4, cfg.vocab_size)
    full_logits, _ = model.forward(params, {"tokens": toks})
    state = model.init_decode_state(b, 16, cache_dtype=jnp.float32)
    step = jax.jit(model.decode_step)
    for t in range(s):
        lg, state = step(params, toks[:, t : t + 1], state, jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full_logits[:, t]), rtol=1e-4, atol=1e-4
        )


# Published sizes (billions), tolerance generous (embedding conventions vary)
_EXPECTED_B = {
    "hubert_xlarge": (0.9, 1.1),
    "deepseek_moe_16b": (15.0, 18.0),
    "kimi_k2_1t_a32b": (950.0, 1100.0),
    "stablelm_3b": (2.5, 3.2),
    "command_r_plus_104b": (95.0, 110.0),
    "granite_20b": (18.0, 22.0),
    "qwen2_5_32b": (30.0, 35.0),
    "recurrentgemma_9b": (8.5, 10.5),
    "xlstm_1_3b": (1.1, 1.7),
    "qwen2_vl_72b": (68.0, 76.0),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_matches_published(arch):
    lo, hi = _EXPECTED_B[arch]
    n = get(arch).param_count() / 1e9
    assert lo <= n <= hi, f"{arch}: {n:.2f}B not in [{lo},{hi}]"


def test_kimi_active_params():
    cfg = get("kimi_k2_1t_a32b")
    active = cfg.active_param_count() / 1e9
    assert 28.0 <= active <= 40.0  # a32b


def test_cell_grid():
    cells = all_cells()
    assert len(cells) == 31
    assert ("hubert_xlarge", "decode_32k") not in cells
    assert ("hubert_xlarge", "long_500k") not in cells
    assert ("recurrentgemma_9b", "long_500k") in cells
    assert ("xlstm_1_3b", "long_500k") in cells
    assert ("qwen2_5_32b", "long_500k") not in cells


def test_moe_local_matches_manual():
    """Routed-expert output == manual per-token dense computation."""
    from repro.models import moe as MOE

    cfg = get_smoke("deepseek_moe_16b")
    p = MOE.init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (2, 8, cfg.d_model)) * 0.5
    y, aux = MOE.moe_local(p, x, cfg)
    m = cfg.moe
    xf = x.reshape(-1, cfg.d_model)
    ids, probs, _ = MOE._route(xf, p["router"], m)
    expect = np.zeros_like(np.asarray(xf))
    for t in range(xf.shape[0]):
        for j in range(m.top_k):
            e = int(ids[t, j])
            h = jax.nn.silu(xf[t] @ p["w_gate"][e]) * (xf[t] @ p["w_up"][e])
            expect[t] += float(probs[t, j]) * np.asarray(h @ p["w_down"][e])
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)), expect, rtol=2e-4, atol=2e-4)
