"""Chunkwise mLSTM (hillclimb optimization) == sequential per-step scan."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import xlstm as XL


def _sequential(p, x, cfg, state):
    q, k, v, i_t, f_t, z = XL._mlstm_inputs(p, x, cfg)
    xs = jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0), (q, k, v, i_t, f_t))
    final, hs = jax.lax.scan(XL._mlstm_step, state, xs)
    hs = jnp.moveaxis(hs, 0, 1).reshape(x.shape[0], x.shape[1], -1).astype(x.dtype)
    y = (hs * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)) @ p["w_down"]
    return y, final


@pytest.mark.parametrize("seq,chunk", [(128, 32), (256, 64), (192, 64)])
def test_chunked_equals_sequential(seq, chunk):
    cfg = get_smoke("xlstm_1_3b")
    p = XL.init_mlstm(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, seq, cfg.d_model)) * 0.5
    st = XL.init_mlstm_state(2, cfg)
    y_ref, f_ref = _sequential(p, x, cfg, st)
    y_chk, f_chk = XL._mlstm_chunked(p, x, cfg, XL.init_mlstm_state(2, cfg), chunk)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_ref), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(f_chk.c), np.asarray(f_ref.c), rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(f_chk.n), np.asarray(f_ref.n), rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(f_chk.m), np.asarray(f_ref.m), rtol=1e-4, atol=1e-5)


def test_chunked_state_continues_decode():
    """State from a chunked prefill must continue correctly in per-step
    decode (prefill/decode consistency at the model level)."""
    cfg = get_smoke("xlstm_1_3b")
    p = XL.init_mlstm(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 160, cfg.d_model)) * 0.5
    # full sequential over 160
    y_all, f_all = _sequential(p, x, cfg, XL.init_mlstm_state(1, cfg))
    # chunked over first 128, then sequential for the remaining 32
    _, f_chunk = XL._mlstm_chunked(p, x[:, :128], cfg, XL.init_mlstm_state(1, cfg), 32)
    y_tail, f_tail = _sequential(p, x[:, 128:], cfg, f_chunk)
    np.testing.assert_allclose(np.asarray(y_tail), np.asarray(y_all[:, 128:]), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(f_tail.c), np.asarray(f_all.c), rtol=1e-4, atol=1e-6)


def test_moe_batched_matches_ragged():
    import dataclasses

    from repro.models import moe as MOE

    cfg = get_smoke("deepseek_moe_16b")
    big_cap = dataclasses.replace(cfg.moe, capacity_factor=8.0)
    cfg_r = dataclasses.replace(cfg, moe=big_cap)
    cfg_b = dataclasses.replace(cfg, moe=dataclasses.replace(big_cap, expert_impl="batched"))
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.5
    y_r, _ = MOE.moe_local(p, x, cfg_r)
    y_b, _ = MOE.moe_local(p, x, cfg_b)
    np.testing.assert_allclose(np.asarray(y_b), np.asarray(y_r), rtol=1e-4, atol=1e-5)
