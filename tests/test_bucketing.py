"""Length-bucketed batch assembly.

Bucketing must be a pure re-shaping: every row of the fixed-``max_len``
path appears exactly once, sliced to the smallest bucket wide enough for
its payload — so re-padding each bucketed batch back to ``max_len``
reconstructs the fixed-path rows byte-for-byte. Shapes stay inside the
small declared bucket set (jit compiles once per bucket, not per batch),
and the pad-token fraction can only go down.
"""

import json

import numpy as np
import pytest

from repro.core.dataset import Dataset
from repro.core.p3sapp import case_study_stages
from repro.data.batching import (
    assign_buckets,
    derive_buckets,
    effective_lengths,
    pad_token_fraction,
    seq2seq_specs,
)
from repro.data.tokenizer import PAD, WordTokenizer

WORDS = [f"w{i}" for i in range(30)]
TOK = WordTokenizer(WORDS)
MAX_LEN = 16


def records_with_varied_lengths(n=64, seed=5):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        k = int(rng.integers(1, MAX_LEN + 4))  # some rows overflow max_len
        out.append({"a": " ".join(rng.choice(WORDS, size=k))})
    return out


def repad(batch, col, width):
    arr = batch[col]
    if arr.shape[1] == width:
        return arr
    out = np.full((arr.shape[0], width), PAD, dtype=arr.dtype)
    out[:, : arr.shape[1]] = arr
    return out


def row_multiset(batches, col, width):
    return sorted(
        repad(b, col, width)[i].tobytes()
        for b in batches
        for i in range(len(b[col]))
    )


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


def test_derive_buckets_bounded_and_ends_at_max_len():
    assert derive_buckets(16, 4) == (4, 8, 12, 16)
    assert derive_buckets(5, 4)[-1] == 5
    assert derive_buckets(1, 4) == (1,)
    for b in (derive_buckets(128, 4), derive_buckets(7, 3)):
        assert all(x >= 1 for x in b) and list(b) == sorted(set(b))


def test_effective_lengths_counts_to_last_nonpad():
    arr = np.array(
        [
            [5, 6, 0, 0],  # plain padding
            [0, 0, 0, 0],  # all pad
            [5, 0, 6, 0],  # interior PAD (a literal "<pad>" word encodes to 0)
            [5, 6, 7, 8],  # full row
        ],
        dtype=np.int32,
    )
    assert list(effective_lengths(arr)) == [2, 0, 3, 4]


def test_assign_buckets_smallest_fit():
    buckets = (4, 8, 16)
    lengths = np.array([0, 1, 4, 5, 8, 9, 16, 99])
    assert list(assign_buckets(lengths, buckets)) == [0, 0, 0, 1, 1, 2, 2, 2]


# ---------------------------------------------------------------------------
# whole-frame bucketed batching through the Dataset verbs
# ---------------------------------------------------------------------------


def base_ds():
    return Dataset.from_records(records_with_varied_lengths(), ["a"]).tokenize(
        TOK, col="a", max_len=MAX_LEN
    )


def test_bucketed_batches_are_lossless_and_shape_bounded():
    fixed = list(base_ds().batch(8, shuffle=False, drop_remainder=False).iter_batches())
    bucketed = list(
        base_ds()
        .batched(8, shuffle=False, drop_remainder=False, bucket_by="a_tokens")
        .iter_batches()
    )
    buckets = derive_buckets(MAX_LEN)
    widths = {b["a_tokens"].shape[1] for b in bucketed}
    assert widths <= set(buckets)
    assert len(widths) > 1  # varied lengths actually exercise several buckets
    # bounded-shape contract holds for remainders too: never more than
    # batch_size rows, never a full-width catch-all batch
    assert all(len(b["a_tokens"]) <= 8 for b in bucketed)
    assert row_multiset(bucketed, "a_tokens", MAX_LEN) == row_multiset(
        fixed, "a_tokens", MAX_LEN
    )


def test_bucketed_pad_fraction_is_lower():
    fixed = list(base_ds().batch(8, shuffle=False, drop_remainder=False).iter_batches())
    bucketed = list(
        base_ds()
        .batched(8, shuffle=False, drop_remainder=False, bucket_by="a_tokens")
        .iter_batches()
    )
    assert pad_token_fraction(bucketed, "a_tokens") < pad_token_fraction(
        fixed, "a_tokens"
    )


def test_bucketed_remainder_policies():
    drop = list(base_ds().batched(8, shuffle=False, bucket_by="a_tokens").iter_batches())
    assert all(len(b["a_tokens"]) == 8 for b in drop)

    padded = list(
        base_ds()
        .batched(8, shuffle=False, pad_to=8, bucket_by="a_tokens")
        .iter_batches()
    )
    assert all(len(b["a_tokens"]) == 8 for b in padded)
    # pad_to keeps every real row
    n_real = sum(
        int((effective_lengths(b["a_tokens"]) > 0).sum()) for b in padded
    )
    records = records_with_varied_lengths()
    assert n_real == len(records)


def test_bucketed_shuffle_reshuffles_but_keeps_rows():
    a = list(base_ds().batched(8, seed=1, bucket_by="a_tokens").iter_batches())
    b = list(base_ds().batched(8, seed=2, bucket_by="a_tokens").iter_batches())
    # different order, same multiset of full batches' rows is not guaranteed
    # under drop_remainder (different rows may be dropped), so compare with
    # remainders kept:
    a = list(
        base_ds()
        .batched(8, seed=1, drop_remainder=False, bucket_by="a_tokens")
        .iter_batches()
    )
    b = list(
        base_ds()
        .batched(8, seed=2, drop_remainder=False, bucket_by="a_tokens")
        .iter_batches()
    )
    assert row_multiset(a, "a_tokens", MAX_LEN) == row_multiset(b, "a_tokens", MAX_LEN)


def test_explicit_buckets_are_extended_to_max_len():
    ds = base_ds().batched(4, bucket_by="a_tokens", buckets=[4])
    node = ds.plan[-1]
    assert node.buckets == (4, MAX_LEN)
    with pytest.raises(KeyError):
        base_ds().batched(4, bucket_by="nope")


# ---------------------------------------------------------------------------
# paired encoder/decoder bucketing (2-D grid)
# ---------------------------------------------------------------------------

MAX_B = 8


def records_two_cols(n=96, seed=7):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        ka = int(rng.integers(1, MAX_LEN + 4))
        kb = int(rng.integers(1, MAX_B + 2))
        out.append(
            {
                "a": " ".join(rng.choice(WORDS, size=ka)),
                "b": " ".join(rng.choice(WORDS, size=kb)),
            }
        )
    return out


def pair_ds():
    from repro.data.batching import TokenSpec

    return Dataset.from_records(records_two_cols(), ["a", "b"]).tokenize(
        TOK, (TokenSpec("a", MAX_LEN), TokenSpec("b", MAX_B))
    )


def pair_multiset(batches):
    return sorted(
        (
            repad(b, "a_tokens", MAX_LEN)[i].tobytes(),
            repad(b, "b_tokens", MAX_B)[i].tobytes(),
        )
        for b in batches
        for i in range(len(b["a_tokens"]))
    )


def test_paired_bucketing_lossless_and_grid_bounded():
    fixed = list(
        pair_ds().batch(8, shuffle=False, drop_remainder=False).iter_batches()
    )
    paired = list(
        pair_ds()
        .batched(
            8, shuffle=False, drop_remainder=False,
            bucket_by=("a_tokens", "b_tokens"),
        )
        .iter_batches()
    )
    grid_a, grid_b = derive_buckets(MAX_LEN), derive_buckets(MAX_B)
    shapes = {(b["a_tokens"].shape[1], b["b_tokens"].shape[1]) for b in paired}
    assert shapes <= {(wa, wb) for wa in grid_a for wb in grid_b}
    assert len({wa for wa, _ in shapes}) > 1 and len({wb for _, wb in shapes}) > 1
    assert all(len(b["a_tokens"]) <= 8 for b in paired)
    assert pair_multiset(paired) == pair_multiset(fixed)


def test_paired_bucketing_cuts_padding_on_both_columns():
    """The ROADMAP's point: 1-D bucketing only drops encoder padding;
    the 2-D grid drops decoder padding too."""
    fixed = list(
        pair_ds().batch(8, shuffle=False, drop_remainder=False).iter_batches()
    )
    one_d = list(
        pair_ds()
        .batched(8, shuffle=False, drop_remainder=False, bucket_by="a_tokens")
        .iter_batches()
    )
    paired = list(
        pair_ds()
        .batched(
            8, shuffle=False, drop_remainder=False,
            bucket_by=("a_tokens", "b_tokens"),
        )
        .iter_batches()
    )
    for col in ("a_tokens", "b_tokens"):
        assert pad_token_fraction(paired, col) < pad_token_fraction(fixed, col)
    # 1-D bucketing leaves the decoder column at full width; 2-D beats it
    assert pad_token_fraction(paired, "b_tokens") < pad_token_fraction(
        one_d, "b_tokens"
    )
    assert pad_token_fraction(one_d, "b_tokens") == pad_token_fraction(
        fixed, "b_tokens"
    )


def test_paired_bucketing_explicit_nested_buckets_and_validation():
    ds = pair_ds().batched(
        4, bucket_by=("a_tokens", "b_tokens"), buckets=[[4], [2]]
    )
    node = ds.plan[-1]
    assert node.bucket_by == ("a_tokens", "b_tokens")
    assert node.buckets == ((4, MAX_LEN), (2, MAX_B))
    assert "bucket_by=['a_tokens', 'b_tokens']" in node.describe()
    with pytest.raises(ValueError):
        pair_ds().batched(4, bucket_by=("a_tokens", "b_tokens"), buckets=[4, 8])
    with pytest.raises(ValueError):
        pair_ds().batched(4, bucket_by=("a_tokens", "b_tokens"), buckets=[[4]])
    with pytest.raises(KeyError):
        pair_ds().batched(4, bucket_by=("a_tokens", "nope"))


def test_paired_bucketing_remainder_policies():
    padded = list(
        pair_ds()
        .batched(
            8, shuffle=False, pad_to=8, bucket_by=("a_tokens", "b_tokens")
        )
        .iter_batches()
    )
    assert all(len(b["a_tokens"]) == 8 for b in padded)
    n_real = sum(
        int((effective_lengths(b["a_tokens"]) > 0).sum()) for b in padded
    )
    assert n_real == len(records_two_cols())


def test_streaming_paired_bucketing_matches_wholeframe(tmp_path):
    d = tmp_path / "corpus"
    d.mkdir()
    rng = np.random.default_rng(3)
    for i in range(4):
        with open(d / f"s{i}.jsonl", "w", encoding="utf-8") as fh:
            for _ in range(24):
                title = " ".join(rng.choice(WORDS, size=int(rng.integers(1, 7))))
                abstract = " ".join(rng.choice(WORDS, size=int(rng.integers(1, 30))))
                fh.write(json.dumps({"title": title, "abstract": abstract}) + "\n")

    specs = seq2seq_specs(max_abstract_len=24, max_title_len=8)

    def chain():
        return (
            Dataset.from_json_dirs([d])
            .dropna()
            .apply(*case_study_stages())
            .dropna()
            .tokenize(TOK, specs)
            .batched(
                8, shuffle=False, drop_remainder=False,
                bucket_by=("encoder_tokens", "decoder_tokens"),
            )
        )

    whole = list(chain().iter_batches())
    streamed = list(chain().prefetch(2).iter_batches(workers=2))
    cells = {
        (wa, wb)
        for wa in derive_buckets(24)
        for wb in derive_buckets(8)
    }
    for batches in (whole, streamed):
        assert {
            (b["encoder_tokens"].shape[1], b["decoder_tokens"].shape[1])
            for b in batches
        } <= cells

    def rows(batches):
        return sorted(
            (
                repad(b, "encoder_tokens", 24)[i].tobytes(),
                repad(b, "decoder_tokens", 8)[i].tobytes(),
            )
            for b in batches
            for i in range(len(b["encoder_tokens"]))
        )

    assert rows(streamed) == rows(whole)


# ---------------------------------------------------------------------------
# streaming bucketed assembly matches whole-frame
# ---------------------------------------------------------------------------


def test_streaming_bucketed_matches_wholeframe(tmp_path):
    d = tmp_path / "corpus"
    d.mkdir()
    rng = np.random.default_rng(11)
    for i in range(4):
        with open(d / f"s{i}.jsonl", "w", encoding="utf-8") as fh:
            for _ in range(20):
                title = " ".join(rng.choice(WORDS, size=int(rng.integers(1, 6))))
                abstract = " ".join(rng.choice(WORDS, size=int(rng.integers(1, 40))))
                fh.write(json.dumps({"title": title, "abstract": abstract}) + "\n")

    specs = seq2seq_specs(max_abstract_len=24, max_title_len=8)

    def chain():
        return (
            Dataset.from_json_dirs([d])
            .dropna()
            .apply(*case_study_stages())
            .dropna()
            .tokenize(TOK, specs)
            .batched(
                8, shuffle=False, drop_remainder=False, bucket_by="encoder_tokens"
            )
        )

    whole = list(chain().iter_batches())
    streamed = list(chain().prefetch(2).iter_batches(workers=2))
    for batches in (whole, streamed):
        assert {b["encoder_tokens"].shape[1] for b in batches} <= set(
            derive_buckets(24)
        )
        assert all(b["decoder_tokens"].shape[1] == 8 for b in batches)

    def rows(batches):
        return sorted(
            (
                repad(b, "encoder_tokens", 24)[i].tobytes(),
                b["decoder_tokens"][i].tobytes(),
            )
            for b in batches
            for i in range(len(b["encoder_tokens"]))
        )

    assert rows(streamed) == rows(whole)
