"""Plan-fingerprint shard cache: correctness under change and corruption.

The cache key is (shard bytes digest, column lineage fingerprint), so:
any op parameter change must change the fingerprint (never serve stale
results); an unchanged plan must hit without recomputing (the paper's
``persist()`` cost argument); a partially-changed plan must recompute only
the affected columns; and a corrupted cache file must degrade to a miss,
never an error.
"""

import json

import pytest

from repro.core import executor as EX
from repro.core import ingest as ing
from repro.core import plan as P
from repro.core.dataset import Dataset
from repro.core.frame import ColumnarFrame
from repro.core.p3sapp import case_study_stages
from repro.core.stages import RemoveShortWords, StopWordsRemover

FIELDS = ("title", "abstract")
RECORDS = [
    {"title": f"Title <b>{i}</b> Words", "abstract": f"The abstract (no {i}) isn't short."}
    for i in range(12)
]


@pytest.fixture
def corpus(tmp_path):
    d = tmp_path / "corpus"
    d.mkdir()
    for i in range(3):
        with open(d / f"s{i}.jsonl", "w", encoding="utf-8") as fh:
            for r in RECORDS[i::3]:
                fh.write(json.dumps(r) + "\n")
    return d


def program_for(ds):
    frame_nodes, _ = P.split_plan(ds.plan)
    return EX.compile_shard_program(
        P.optimize_plan(frame_nodes, ds.schema), optimize=True
    )


def run_thread(corpus, program, cache_dir, workers=2):
    ex = EX.ThreadShardExecutor(
        ing.list_shards([corpus]), program, workers=workers, cache_dir=cache_dir
    )
    frames = [r.frame for r in ex]
    ex.stop()
    records = ColumnarFrame.concat(frames).to_records()
    return sorted(tuple(sorted(r.items())) for r in records), ex


# ---------------------------------------------------------------------------
# fingerprints
# ---------------------------------------------------------------------------


def chain_with(stage):
    return Dataset.from_json_dirs(["/x"], FIELDS).dropna(FIELDS).apply(stage)


def test_fingerprint_changes_with_any_op_param():
    base = program_for(chain_with(RemoveShortWords("title", threshold=1)))
    fp = EX.column_fingerprints(base)
    assert fp is not None and set(fp) >= {"title", "abstract"}

    rethreshold = program_for(chain_with(RemoveShortWords("title", threshold=2)))
    assert EX.column_fingerprints(rethreshold)["title"] != fp["title"]
    # the untouched column keeps its fingerprint → stays cached
    assert EX.column_fingerprints(rethreshold)["abstract"] == fp["abstract"]

    restopped = program_for(chain_with(StopWordsRemover("title", stopwords=("the",))))
    assert EX.column_fingerprints(restopped)["title"] != fp["title"]

    # a row filter change invalidates every column (it changes the row set)
    unfiltered = program_for(
        Dataset.from_json_dirs(["/x"], FIELDS).apply(
            RemoveShortWords("title", threshold=1)
        )
    )
    ufp = EX.column_fingerprints(unfiltered)
    assert ufp["title"] != fp["title"] and ufp["abstract"] != fp["abstract"]


def test_fingerprints_disabled_for_dedup_plans():
    ds = Dataset.from_json_dirs(["/x"], FIELDS).drop_duplicates(FIELDS)
    assert EX.column_fingerprints(program_for(ds)) is None


# ---------------------------------------------------------------------------
# hit/miss behavior
# ---------------------------------------------------------------------------


def test_cache_hit_skips_recompute(corpus, tmp_path, monkeypatch):
    cache_dir = tmp_path / "cache"
    ds = Dataset.from_json_dirs([corpus], FIELDS).dropna(FIELDS).apply(
        *case_study_stages()
    )
    program = program_for(ds)

    cold, ex_cold = run_thread(corpus, program, cache_dir, workers=1)
    assert ex_cold.cache_hits == 0
    assert ex_cold.cache_misses == 6  # 3 shards x 2 columns

    # Count actual op-chain executions: a warm cache must not run any.
    calls = []
    real = EX.B.apply_ops

    def counting(buf, ops):
        calls.append(ops)
        return real(buf, ops)

    monkeypatch.setattr(EX.B, "apply_ops", counting)
    warm, ex_warm = run_thread(corpus, program, cache_dir, workers=1)
    assert warm == cold
    assert ex_warm.cache_hits == 6 and ex_warm.cache_misses == 0
    assert calls == []  # hit path never ran a single byte op


def test_partial_plan_change_recomputes_only_affected_column(corpus, tmp_path):
    cache_dir = tmp_path / "cache"
    base = Dataset.from_json_dirs([corpus], FIELDS).dropna(FIELDS)
    v1 = program_for(base.apply(*case_study_stages()))
    run_thread(corpus, v1, cache_dir)

    from repro.core.stages import abstract_stages, title_stages

    changed = program_for(
        base.apply(*(abstract_stages(threshold=3) + title_stages()))
    )
    _, ex = run_thread(corpus, changed, cache_dir)
    # abstract's threshold changed → misses; title's chain unchanged → hits
    assert ex.cache_hits == 3 and ex.cache_misses == 3


def test_corrupted_cache_falls_back_to_recompute(corpus, tmp_path):
    cache_dir = tmp_path / "cache"
    ds = Dataset.from_json_dirs([corpus], FIELDS).dropna(FIELDS).apply(
        *case_study_stages()
    )
    program = program_for(ds)
    cold, _ = run_thread(corpus, program, cache_dir)

    entries = sorted(cache_dir.glob("*.npy"))
    assert entries
    for p in entries[::2]:
        p.write_bytes(b"this is not a numpy file")
    entries[1].write_bytes(b"")  # truncated write

    again, ex = run_thread(corpus, program, cache_dir)
    assert again == cold  # corruption degrades to recompute, not to a crash
    assert ex.cache_misses > 0
    # corrupted entries were rewritten: a third run is fully warm
    final, ex3 = run_thread(corpus, program, cache_dir)
    assert final == cold and ex3.cache_misses == 0


def test_process_executor_shares_the_same_cache(corpus, tmp_path):
    cache_dir = tmp_path / "cache"
    ds = Dataset.from_json_dirs([corpus], FIELDS).dropna(FIELDS).apply(
        *case_study_stages()
    )
    program = program_for(ds)
    cold, _ = run_thread(corpus, program, cache_dir, workers=1)

    ex = EX.ProcessShardExecutor(
        ing.list_shards([corpus]), program, workers=2, cache_dir=cache_dir
    )
    frames = [r.frame for r in ex]
    ex.stop()
    got = sorted(
        tuple(sorted(r.items())) for r in ColumnarFrame.concat(frames).to_records()
    )
    assert got == cold
    assert ex.cache_hits == 6 and ex.cache_misses == 0


def test_two_project_steps_on_same_column_never_alias(corpus, tmp_path):
    """Regression: each project step keys the cache with its *own* lineage
    fingerprint. With final-only fingerprints, step 2 would hit the entry
    step 1 just stored and silently skip its ops."""
    from repro.core.stages import ConvertToLower, RemoveHTMLTags

    cache_dir = tmp_path / "cache"
    ds = (
        Dataset.from_json_dirs([corpus], FIELDS)
        .apply(ConvertToLower("title"))
        .select(["title"])  # keeps the two Project nodes from merging
        .apply(RemoveHTMLTags("title"))
    )
    program = program_for(ds)
    assert [k for k, _ in program.steps] == ["project", "select", "project"]
    fps = EX.step_column_fingerprints(program)
    step_ids = sorted(fps)
    assert fps[step_ids[0]]["title"] != fps[step_ids[1]]["title"]

    want, _ = run_thread(corpus, program, cache_dir=None)
    cold, _ = run_thread(corpus, program, cache_dir)
    warm, ex = run_thread(corpus, program, cache_dir)
    assert cold == want and warm == want
    assert ex.cache_misses == 0


def test_process_executor_preserves_non_string_values(tmp_path):
    """Regression: non-string JSON values (ints, …) must survive the
    shared-memory round trip with their types, as they do in the thread
    and whole-frame executors."""
    d = tmp_path / "corpus"
    d.mkdir()
    recs = [{"title": f"Paper {i}", "year": 1990 + i} for i in range(6)]
    recs.append({"title": "untyped", "year": None})
    with open(d / "s0.jsonl", "w") as fh:
        for r in recs:
            fh.write(json.dumps(r) + "\n")
    ds = Dataset.from_json_dirs([d], ("title", "year"))
    program = program_for(ds)
    shards = ing.list_shards([d])

    def typed_records(ex):
        frames = [r.frame for r in ex]
        ex.stop()
        return sorted(
            (r["title"], r["year"], type(r["year"]).__name__)
            for r in ColumnarFrame.concat(frames).to_records()
        )

    threaded = typed_records(EX.ThreadShardExecutor(shards, program, workers=2))
    processed = typed_records(EX.ProcessShardExecutor(shards, program, workers=2))
    assert processed == threaded
    assert ("Paper 0", 1990, "int") in processed


def test_lambda_predicate_is_uncacheable_not_wrong(corpus, tmp_path):
    """A predicate we cannot fingerprint (lambda) must disable caching for
    its column — never collide into another lambda's entry."""
    from repro.core import bytesops as B

    with pytest.raises(B.UnfingerprintableOpError):
        B.ops_fingerprint([B.wordpred_op(lambda v, ln: ln <= 1, False)])

    op = B.wordpred_op(lambda v, ln: ln <= 2, needs_hashes=False)
    program = EX.ShardProgram(
        FIELDS, (("project", (("title", ("chain", "title", (op,))),)),)
    )
    fps = EX.step_column_fingerprints(program)
    assert "title" not in fps[0]  # poisoned column: no cache key

    cache_dir = tmp_path / "cache"
    first, _ = run_thread(corpus, program, cache_dir)
    second, ex = run_thread(corpus, program, cache_dir)
    assert first == second
    assert ex.cache_hits == 0  # recomputed, not served from a colliding key


def test_options_after_terminal_reuse_memoized_frame(corpus):
    """Regression: .workers()/.cache() applied after a terminal must reuse
    the already-materialized frame instead of re-ingesting/cleaning."""
    ds = Dataset.from_json_dirs([corpus], FIELDS).dropna(FIELDS).apply(
        *case_study_stages()
    )
    first = ds.collect()
    reused = ds.workers(2).cache(False).collect()
    assert reused is first  # same memoized object, no re-execution


# ---------------------------------------------------------------------------
# expression-plan caching: per-column invalidation under the expression
# fingerprint
# ---------------------------------------------------------------------------


def expr_program(corpus, title_expr_, abstract_expr_, pred=None):
    ds = Dataset.from_json_dirs([corpus], FIELDS)
    if pred is not None:
        ds = ds.where(pred)
    ds = ds.transform(title=title_expr_, abstract=abstract_expr_)
    return program_for(ds)


def test_expression_cache_warm_run_hits_100_pct(corpus, tmp_path, monkeypatch):
    from repro.core.expr import col

    cache_dir = tmp_path / "cache"
    program = expr_program(
        corpus,
        col("title").lower().strip_html(),
        col("abstract").lower().keep_letters().collapse_spaces(),
        pred=col("title").not_empty(),
    )
    cold, ex_cold = run_thread(corpus, program, cache_dir, workers=1)
    assert ex_cold.cache_hits == 0 and ex_cold.cache_misses == 6

    calls = []
    real = EX.B.apply_ops
    monkeypatch.setattr(
        EX.B, "apply_ops", lambda buf, ops: calls.append(ops) or real(buf, ops)
    )
    warm, ex_warm = run_thread(corpus, program, cache_dir, workers=1)
    assert warm == cold
    # unchanged expression plan: 100% cache hits, zero expression ops run
    # (the where() predicate still evaluates — row sets are not cached —
    # but its raw-column reads carry empty op chains)
    assert ex_warm.cache_hits == 6 and ex_warm.cache_misses == 0
    assert all(len(ops) == 0 for ops in calls)


def test_changing_one_expression_recomputes_only_its_column(corpus, tmp_path):
    from repro.core.expr import col

    cache_dir = tmp_path / "cache"
    abstract = col("abstract").lower().keep_letters().collapse_spaces()
    v1 = expr_program(corpus, col("title").lower(), abstract)
    run_thread(corpus, v1, cache_dir)

    v2 = expr_program(corpus, col("title").lower().min_word_len(3), abstract)
    _, ex = run_thread(corpus, v2, cache_dir)
    # title's expression changed → 3 shard misses; abstract keeps hitting
    assert ex.cache_hits == 3 and ex.cache_misses == 3

    # a predicate change alters the row set → both columns recompute
    v3 = expr_program(
        corpus, col("title").lower().min_word_len(3), abstract,
        pred=col("abstract").word_count() >= 1,
    )
    _, ex3 = run_thread(corpus, v3, cache_dir)
    assert ex3.cache_hits == 0 and ex3.cache_misses == 6


def test_concat_expression_caches_and_invalidates(corpus, tmp_path):
    from repro.core.expr import col, concat

    cache_dir = tmp_path / "cache"

    def prog(sep):
        ds = Dataset.from_json_dirs([corpus], FIELDS).with_column(
            "both", concat(col("title"), col("abstract"), sep=sep)
        )
        return program_for(ds)

    first, ex1 = run_thread(corpus, prog(" | "), cache_dir)
    assert ex1.cache_misses == 3  # one derived column x 3 shards
    again, ex2 = run_thread(corpus, prog(" | "), cache_dir)
    assert again == first and ex2.cache_hits == 3 and ex2.cache_misses == 0
    _, ex3 = run_thread(corpus, prog(" # "), cache_dir)  # sep is a parameter
    assert ex3.cache_misses == 3 and ex3.cache_hits == 0


# ---------------------------------------------------------------------------
# token-space cache: vocab-fingerprint keying + per-spec invalidation
# ---------------------------------------------------------------------------


def token_program_for(ds, tok, specs):
    from repro.data.batching import TokenSpec  # noqa: F401  (doc pointer)

    frame_nodes, _ = P.split_plan(ds.plan)
    spec_cols = tuple(dict.fromkeys(s.column for s in specs))
    return EX.compile_shard_program(
        P.optimize_plan(frame_nodes, spec_cols),
        optimize=True,
        output_columns=spec_cols,
        tokens=EX.TokenPlan(tuple(specs), dict(tok.stoi), tok.fingerprint),
    )


def run_tokens(corpus, program, cache_dir, workers=1):
    ex = EX.ThreadShardExecutor(
        ing.list_shards([corpus]), program, workers=workers, cache_dir=cache_dir
    )
    rows = []
    for res in ex:
        keys = sorted(res.tokens)
        for i in range(len(res.tokens[keys[0]]) if keys else 0):
            rows.append(tuple(res.tokens[k][i].tobytes() for k in keys))
    ex.stop()
    return sorted(rows), ex


@pytest.fixture
def token_setup(corpus):
    from repro.data.batching import seq2seq_specs
    from repro.data.tokenizer import WordTokenizer

    ds = Dataset.from_json_dirs([corpus], FIELDS).dropna(FIELDS).apply(
        *case_study_stages()
    )
    tok = WordTokenizer.fit(
        (r["abstract"] + " " + r["title"] for r in RECORDS), vocab_size=64
    )
    specs = seq2seq_specs(max_abstract_len=16, max_title_len=8)
    return ds, tok, specs


def test_token_cache_warm_run_skips_everything(corpus, tmp_path, token_setup, monkeypatch):
    ds, tok, specs = token_setup
    cache_dir = tmp_path / "cache"
    program = token_program_for(ds, tok, specs)

    plain, _ = run_tokens(corpus, program, cache_dir=None)
    cold, ex_cold = run_tokens(corpus, program, cache_dir)
    assert cold == plain
    assert ex_cold.token_cache_hits == 0
    assert ex_cold.token_cache_misses == 6  # 3 shards x 2 specs

    # Warm: served straight from token entries — no byte op runs, no shard
    # is parsed, and the cleaned-text entries are never looked up.
    calls = []
    monkeypatch.setattr(EX.B, "apply_ops", lambda buf, ops: calls.append(ops))
    monkeypatch.setattr(
        EX.ing, "parse_shard_bytes", lambda *a, **k: pytest.fail("parsed on warm run")
    )
    warm, ex_warm = run_tokens(corpus, program, cache_dir)
    assert warm == cold
    assert ex_warm.token_cache_hits == 6 and ex_warm.token_cache_misses == 0
    assert ex_warm.cache_hits == 0 and ex_warm.cache_misses == 0
    assert calls == []


def test_token_cache_keys_include_vocab_fingerprint(corpus, tmp_path, token_setup):
    from repro.data.tokenizer import WordTokenizer

    ds, tok, specs = token_setup
    cache_dir = tmp_path / "cache"
    run_tokens(corpus, token_program_for(ds, tok, specs), cache_dir)

    refit = WordTokenizer.fit((r["abstract"] for r in RECORDS), vocab_size=32)
    assert refit.fingerprint != tok.fingerprint
    refit_program = token_program_for(ds, refit, specs)
    plain, _ = run_tokens(corpus, refit_program, cache_dir=None)
    got, ex = run_tokens(corpus, refit_program, cache_dir)
    assert got == plain
    # every token entry invalidated by the vocab fingerprint...
    assert ex.token_cache_hits == 0 and ex.token_cache_misses == 6
    # ...but the cleaned-text entries are untouched and keep hitting
    assert ex.cache_hits == 6 and ex.cache_misses == 0


def test_token_cache_partial_spec_invalidation(corpus, tmp_path, token_setup):
    from repro.data.batching import TokenSpec

    ds, tok, specs = token_setup
    cache_dir = tmp_path / "cache"
    run_tokens(corpus, token_program_for(ds, tok, specs), cache_dir)

    widened = (TokenSpec("abstract", 32, out="encoder_tokens"), specs[1])
    got, ex = run_tokens(corpus, token_program_for(ds, tok, widened), cache_dir)
    plain, _ = run_tokens(corpus, token_program_for(ds, tok, widened), cache_dir=None)
    assert got == plain
    # only the changed spec recomputes; the other spec's arrays keep hitting
    assert ex.token_cache_misses == 3 and ex.token_cache_hits == 3
    # the partial miss forces a real run, which reuses the cleaned text
    assert ex.cache_hits == 6 and ex.cache_misses == 0


def test_fit_vocab_counts_are_cached(corpus, tmp_path):
    cache_dir = tmp_path / "cache"

    def pipe():
        return (
            Dataset.from_json_dirs([corpus], FIELDS)
            .dropna(FIELDS)
            .apply(*case_study_stages())
            .cache(cache_dir)
        )

    s1: dict = {}
    tok1 = pipe().fit_vocab(vocab_size=64, workers=1, stats=s1)
    s2: dict = {}
    tok2 = pipe().fit_vocab(vocab_size=64, workers=1, stats=s2)
    assert tok1.itos == tok2.itos
    assert s1["token_cache_hits"] == 0 and s1["token_cache_misses"] == 3
    assert s2["token_cache_hits"] == 3 and s2["token_cache_misses"] == 0
    # a refit from cached counts still matches an uncached whole fit
    fresh = pipe().cache(False)
    fresh.collect()
    assert fresh.fit_vocab(vocab_size=64).itos == tok1.itos


# ---------------------------------------------------------------------------
# Dataset-level .cache() verb
# ---------------------------------------------------------------------------


def test_dataset_cache_verb_end_to_end(corpus, tmp_path):
    from repro.data.batching import seq2seq_specs
    from repro.data.tokenizer import WordTokenizer

    cache_dir = tmp_path / "ds_cache"
    tok = WordTokenizer.fit(r["abstract"] for r in RECORDS)

    def pipe():
        return (
            Dataset.from_json_dirs([corpus], FIELDS)
            .dropna(FIELDS)
            .apply(*case_study_stages())
            .cache(cache_dir)
            .workers(2)
            .tokenize(tok, seq2seq_specs(max_abstract_len=16, max_title_len=8))
            .batch(4, shuffle=False)
            .prefetch(2)
        )

    stats1: dict = {}
    batches1 = list(pipe().iter_batches(stats=stats1))
    stats2: dict = {}
    batches2 = list(pipe().iter_batches(stats=stats2))
    # Cold: every cleaned column (3 shards x 2 cols) and every token array
    # (3 shards x 2 specs) misses and is stored.
    assert stats1["cache_hits"] == 0 and stats1["cache_misses"] == 6
    assert stats1["token_cache_hits"] == 0 and stats1["token_cache_misses"] == 6
    # Warm: the token entries fully cover the plan's products, so shards
    # are served without parsing or cleaning — 100% token hits, and the
    # cleaned-text entries are never even looked up.
    assert stats2["token_cache_hits"] == 6 and stats2["token_cache_misses"] == 0
    assert stats2["cache_hits"] == 0 and stats2["cache_misses"] == 0
    assert len(batches1) == len(batches2)
