"""Column-expression IR: construction, structural hashing, compilation,
and flat-buffer evaluation semantics.

The expression layer is the single source of truth for every text
transform — the legacy Stage verbs are shims over it — so its signatures
must be stable-and-parameter-sensitive, its predicates must match Python
row semantics exactly, and its compiled programs must pickle (they ride
into worker processes).
"""

import pickle

import numpy as np
import pytest

from repro.core import bytesops as B
from repro.core import expr as E
from repro.core.dataset import Dataset
from repro.core.expr import col, concat, lit


def flat(rows):
    return B.flatten(rows)


def run_expr(e, columns):
    """Evaluate a string expression against dict-of-row-lists columns."""
    comp = E.fuse_compiled(E.compile_expr(e))
    n = len(next(iter(columns.values())))
    out = E.eval_str(comp, lambda c: flat(columns[c]), n)
    return B.unflatten(out)


def run_pred(p, columns):
    comp = E.fuse_compiled(E.compile_pred(p))
    n = len(next(iter(columns.values())))
    return E.eval_mask(comp, lambda c: flat(columns[c]), n)


# ---------------------------------------------------------------------------
# string expressions
# ---------------------------------------------------------------------------


def test_chained_string_ops():
    rows = ["The <b>QUICK</b> Fox (very fast)!", "", "won't stop"]
    got = run_expr(
        col("t").lower().strip_html().strip_parens()
        .expand_contractions().keep_letters().collapse_spaces(),
        {"t": rows},
    )
    assert got == ["the quick fox", "", "will not stop"]


def test_min_word_len_and_stopwords():
    rows = ["a bb ccc dddd", "the fox and hound"]
    assert run_expr(col("t").min_word_len(3), {"t": rows}) == [
        "ccc dddd", "the fox and hound"
    ]
    assert run_expr(col("t").remove_stopwords(), {"t": rows}) == [
        "bb ccc dddd", "fox hound"
    ]
    assert run_expr(
        col("t").remove_stopwords(("fox", "bb")), {"t": rows}
    ) == ["a ccc dddd", "the and hound"]


def test_regex_replace():
    rows = ["version 1.23 beta", "no digits here"]
    got = run_expr(col("t").regex_replace(r"[0-9]+", "#"), {"t": rows})
    assert got == ["version #.# beta", "no digits here"]
    with pytest.raises(Exception):
        col("t").regex_replace("(unbalanced")
    with pytest.raises(ValueError):
        col("t").regex_replace("\x00")


def test_regex_cannot_corrupt_row_structure():
    """Patterns that can match the row separator must be rejected at
    build time (common classes) or fail loudly at execution — never merge
    or split rows silently."""
    for pat in (r"[^a-z]", r".", r"\W", r"\D"):
        with pytest.raises(ValueError, match="separator"):
            col("t").regex_replace(pat, " ")
    with pytest.raises(ValueError):
        col("t").regex_replace("a", "x\x00y")
    # a pattern that slips past the build-time probes (NUL in a context
    # none of the probe strings exhibit) still trips the runtime row-count
    # check instead of silently merging rows
    op = B.regex_op("yz\x00", "_")
    with pytest.raises(ValueError, match="row"):
        B.apply_op(flat(["ab", "xyz"]), op)


def test_nul_rejected_in_literals_and_replacements():
    with pytest.raises(ValueError):
        lit("p\x00q")
    with pytest.raises(ValueError):
        col("t").replace([("b", "\x00")])
    with pytest.raises(ValueError):
        col("t").replace([("\x00", "b")])
    with pytest.raises(ValueError):
        concat(col("a"), col("b"), sep="\x00")
    with pytest.raises(ValueError):
        col("t").contains("\x00")


def test_concat_and_lit():
    cols = {"a": ["x", "y"], "b": ["1", "2"]}
    assert run_expr(concat(col("a"), col("b")), cols) == ["x 1", "y 2"]
    assert run_expr(concat(col("a"), col("b"), sep="|"), cols) == ["x|1", "y|2"]
    assert run_expr(
        concat(lit("<"), col("a"), lit(">"), sep=""), cols
    ) == ["<x>", "<y>"]
    # ops over a concat root
    assert run_expr(concat(col("a"), col("b")).lower(), {"a": ["X"], "b": ["Y"]}) == [
        "x y"
    ]
    with pytest.raises(ValueError):
        concat(lit("a"), lit("b"))  # literals only: no row count
    with pytest.raises(ValueError):
        concat()


# ---------------------------------------------------------------------------
# predicates
# ---------------------------------------------------------------------------


def test_predicates_match_python_semantics():
    rows = ["one two three", "", "single", "has needle here", "x " * 40]
    cols = {"t": rows}
    np.testing.assert_array_equal(
        run_pred(col("t").word_count() >= 2, cols),
        [len(r.split(" ")) - r.split(" ").count("") >= 2 for r in rows],
    )
    np.testing.assert_array_equal(
        run_pred(col("t").word_count() == 1, cols),
        [r != "" and len(r.split()) == 1 for r in rows],
    )
    np.testing.assert_array_equal(
        run_pred(col("t").contains("needle"), cols),
        ["needle" in r for r in rows],
    )
    np.testing.assert_array_equal(
        run_pred(col("t").not_empty(), cols), [r != "" for r in rows]
    )


def test_boolean_algebra():
    cols = {"t": ["aa bb", "aa", "", "cc dd ee"]}
    both = (col("t").word_count() >= 2) & col("t").contains("aa")
    np.testing.assert_array_equal(run_pred(both, cols), [True, False, False, False])
    either = (col("t").word_count() >= 3) | col("t").contains("aa")
    np.testing.assert_array_equal(run_pred(either, cols), [True, True, False, True])
    np.testing.assert_array_equal(run_pred(~either, cols), [False, False, True, False])


def test_contains_never_matches_across_rows():
    # "ab" split across two rows must not match
    mask = run_pred(col("t").contains("ab"), {"t": ["xa", "by"]})
    np.testing.assert_array_equal(mask, [False, False])


def test_word_count_compare_requires_int():
    with pytest.raises(TypeError):
        col("t").word_count() >= "three"
    with pytest.raises(TypeError):
        Dataset.from_records([{"t": "x"}], ["t"]).where(col("t").word_count())


# ---------------------------------------------------------------------------
# structural hashing
# ---------------------------------------------------------------------------


def test_signatures_stable_and_parameter_sensitive():
    def build(n=2, pat="a+"):
        return col("t").lower().regex_replace(pat, "_").min_word_len(n)

    assert build().fingerprint() == build().fingerprint()
    assert build().fingerprint() != build(n=3).fingerprint()
    assert build().fingerprint() != build(pat="b+").fingerprint()
    # different stopword lists differ; same list is stable
    a = col("t").remove_stopwords(("x", "y"))
    b = col("t").remove_stopwords(("x", "z"))
    assert a.fingerprint() == col("t").remove_stopwords(("x", "y")).fingerprint()
    assert a.fingerprint() != b.fingerprint()
    # predicates
    p = (col("t").word_count() >= 2) & col("u").contains("q")
    q = (col("t").word_count() >= 2) & col("u").contains("r")
    assert p.fingerprint() == ((col("t").word_count() >= 2) & col("u").contains("q")).fingerprint()
    assert p.fingerprint() != q.fingerprint()
    # concat sep is a parameter
    assert (
        concat(col("a"), col("b"), sep=" ").fingerprint()
        != concat(col("a"), col("b"), sep="|").fingerprint()
    )


def test_compiled_signature_matches_inputs():
    e = concat(col("a").lower(), col("b"))
    comp = E.compile_expr(e)
    assert E.compiled_inputs(comp) == {"a", "b"}
    assert e.inputs() == {"a", "b"}
    p = (col("x").word_count() >= 1) | col("y").not_empty()
    assert E.compiled_inputs(E.compile_pred(p)) == {"x", "y"}


def test_compiled_programs_pickle():
    e = concat(col("a").lower().remove_stopwords(), col("b").min_word_len(2))
    comp = E.fuse_compiled(E.compile_expr(e))
    again = pickle.loads(pickle.dumps(comp))
    got = E.eval_str(again, lambda c: flat({"a": ["The X"], "b": ["a bb"]}[c]), 1)
    assert B.unflatten(got) == ["x bb"]


def test_fusion_is_exact_and_shorter():
    e = col("t").lower().keep_letters().min_word_len(2).remove_stopwords()
    comp = E.compile_expr(e)
    fused = E.fuse_compiled(comp)
    assert len(fused[2]) < len(comp[2])  # LUT∘LUT + OR-ed word predicates
    cols = {"t": ["The QUICK5 fox a bb"]}
    n = 1
    a = E.eval_str(comp, lambda c: flat(cols[c]), n)
    b = E.eval_str(fused, lambda c: flat(cols[c]), n)
    assert B.unflatten(a) == B.unflatten(b)


# ---------------------------------------------------------------------------
# Dataset integration
# ---------------------------------------------------------------------------


def test_with_column_derives_and_overwrites():
    records = [{"t": "Hello World"}, {"t": "Bye"}]
    ds = Dataset.from_records(records, ["t"]).with_column("t_low", col("t").lower())
    assert ds.schema == ("t", "t_low")
    out = ds.collect().to_records()
    assert out == [
        {"t": "Hello World", "t_low": "hello world"},
        {"t": "Bye", "t_low": "bye"},
    ]
    # sequential transform: later entries see earlier outputs
    ds2 = Dataset.from_records(records, ["t"]).transform(
        a=col("t").lower(), b=col("a").min_word_len(4)
    )
    assert [r["b"] for r in ds2.collect().to_records()] == ["hello world", ""]


def test_where_filters_rows():
    records = [{"t": "one two"}, {"t": ""}, {"t": "solo"}]
    ds = Dataset.from_records(records, ["t"]).where(col("t").word_count() >= 2)
    assert [r["t"] for r in ds.collect().to_records()] == ["one two"]


def test_unknown_columns_rejected():
    ds = Dataset.from_records([{"t": "x"}], ["t"])
    with pytest.raises(KeyError):
        ds.with_column("y", col("missing").lower())
    with pytest.raises(KeyError):
        ds.where(col("missing").not_empty())
    with pytest.raises(TypeError):
        ds.with_column("y", "not an expression")


def test_stage_shims_are_byte_identical_to_expressions():
    """Every Stage is a shim over its expression: flat_ops derive from
    to_expr, and apply() == transform() byte for byte."""
    from repro.core.p3sapp import case_study_stages
    from repro.core.expr import abstract_expr, title_expr

    records = [
        {"title": "The <b>Title</b> (no 1)", "abstract": "Isn't ALL that? short"},
        {"title": "Another X", "abstract": "B c dd <i>eee</i>"},
    ]
    via_stages = (
        Dataset.from_records(records, ["title", "abstract"])
        .apply(*case_study_stages())
        .collect()
        .to_records()
    )
    via_exprs = (
        Dataset.from_records(records, ["title", "abstract"])
        .transform(abstract=abstract_expr(), title=title_expr())
        .collect()
        .to_records()
    )
    assert via_stages == via_exprs
