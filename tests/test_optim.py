"""Optimizer + gradient compression + train loop unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.optim.adamw import AdamW, warmup_cosine
from repro.optim.grad_compression import (
    compress_tree,
    decompress_tree,
    dequantize_int8,
    quantize_int8,
)
from repro.runtime.train_loop import TrainStepConfig, make_train_step, split_microbatches


def quadratic_loss(params, batch):
    return jnp.sum((params["w"] - 3.0) ** 2) + jnp.sum((params["b"] + 1.0) ** 2)


def test_adamw_converges_quadratic():
    opt = AdamW(learning_rate=0.1, weight_decay=0.0)
    params = {"w": jnp.zeros(4), "b": jnp.zeros(2)}
    state = opt.init(params)
    for _ in range(300):
        grads = jax.grad(quadratic_loss)(params, None)
        params, state, _ = opt.update(grads, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), 3.0, atol=1e-2)
    np.testing.assert_allclose(np.asarray(params["b"]), -1.0, atol=1e-2)


def test_grad_clipping():
    opt = AdamW(learning_rate=0.0, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, _, gnorm = opt.update({"w": jnp.full(3, 100.0)}, state, params)
    assert float(gnorm) > 1.0  # reported pre-clip norm


def test_warmup_cosine_shape():
    sched = warmup_cosine(1e-3, warmup_steps=10, total_steps=100)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert abs(float(sched(jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(sched(jnp.asarray(100))) < 1e-3


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1, max_size=40))
def test_int8_quantization_error_bound(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6  # half-ULP of the int8 grid


def test_error_feedback_reduces_bias():
    """With error feedback, the *accumulated* quantized sum tracks the true
    sum much better than independent quantization."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(64,)), jnp.float32) * 0.01
    true_sum = np.zeros(64)
    ef_sum = np.zeros(64)
    err = None
    for _ in range(50):
        true_sum += np.asarray(g)
        q, s, err = compress_tree(g, err)
        ef_sum += np.asarray(decompress_tree(q, s))
    # error feedback keeps the residual bounded by one quantization step
    assert np.abs(ef_sum - true_sum).max() <= float(jax.tree.leaves(s)[0]) + 1e-6


def test_split_microbatches():
    batch = {"x": jnp.arange(12).reshape(6, 2)}
    mb = split_microbatches(batch, 3)
    assert mb["x"].shape == (3, 2, 2)


@pytest.mark.parametrize("n_micro", [1, 4])
def test_train_step_microbatch_equivalence(n_micro):
    """Grad accumulation must match the full-batch gradient step."""

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2)

    rng = np.random.default_rng(1)
    params = {"w": jnp.asarray(rng.normal(size=(4, 1)), jnp.float32)}
    batch = {
        "x": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
        "y": jnp.asarray(rng.normal(size=(8, 1)), jnp.float32),
    }
    opt = AdamW(learning_rate=1e-2, weight_decay=0.0)
    step = make_train_step(loss_fn, opt, TrainStepConfig(n_microbatches=n_micro))
    p1, _, m = jax.jit(step)(params, opt.init(params), batch)
    # reference: plain full-batch
    ref_step = make_train_step(loss_fn, opt, TrainStepConfig(n_microbatches=1))
    p2, _, m2 = jax.jit(ref_step)(params, opt.init(params), batch)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]), rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(float(m["loss"]), float(m2["loss"]), rtol=2e-5)
