"""Unit tests for optimizer round two: cross-node CSE, conjunct-split
pushdown, and the two-pass dedup program split.

The differential harness (:mod:`tests.test_executor_equivalence`) proves
the rewrites are byte-exact; these tests prove they actually *eliminate*
work — an evaluation-count probe wraps ``bytesops.execute_ops`` (the
backend-independent chain entry point) and asserts the shared chain runs
once per frame/shard — and pin the unit-level
contracts (conjunct flattening, survivor-program compilation, dedup_take
guard rails).
"""

import json

import numpy as np
import pytest

from repro.core import bytesops as B
from repro.core import executor as EX
from repro.core import ingest as ing
from repro.core import plan as P
from repro.core.dataset import Dataset
from repro.core.expr import and_all, clean_text, col, split_conjuncts

FIELDS = ("title", "abstract")

RECORDS = [
    {"title": f"title {i}", "abstract": f"some abstract <b>text</b> number {i}"}
    for i in range(12)
]


def write_shards(root, records, n_files=3):
    d = root / "corpus"
    d.mkdir(parents=True, exist_ok=True)
    for i in range(n_files):
        with open(d / f"s{i}.jsonl", "w", encoding="utf-8") as fh:
            for r in records[i::n_files]:
                fh.write(json.dumps(r, ensure_ascii=False) + "\n")
    return d


@pytest.fixture
def op_chain_counter(monkeypatch):
    """Count non-trivial ``execute_ops`` invocations (the unit CSE saves).
    ``execute_ops`` is the one entry point every backend dispatches
    through, so the counts hold under REPRO_BYTES_BACKEND overrides."""
    calls = []
    real = B.execute_ops

    def counting(buf, ops, backend=None):
        if ops:
            calls.append(len(ops))
        return real(buf, ops, backend)

    monkeypatch.setattr(B, "execute_ops", counting)
    return calls


def shared_chain_ds(d):
    """The ROADMAP case: one cleaning chain consumed by both a ``where``
    predicate and a projected derived column."""
    shared = clean_text(col("abstract"))
    return (
        Dataset.from_json_dirs([d], FIELDS)
        .where(shared.word_count() >= 2)
        .with_column("abstract", shared)
    )


def test_cse_whole_frame_evaluates_shared_chain_once(tmp_path, op_chain_counter):
    d = write_shards(tmp_path, RECORDS)
    # workers=1 keeps evaluation in-process so the probe sees every call.
    frame = shared_chain_ds(d).collect(workers=1)
    # One chain execution for the hoisted chain; the filter reads the memoized
    # buffer and the projected column is a zero-op alias.
    assert len(op_chain_counter) == 1, op_chain_counter
    assert frame.field_names == ["title", "abstract"]  # no __cse_* leak


def test_without_cse_shared_chain_evaluates_twice(tmp_path, op_chain_counter):
    d = write_shards(tmp_path, RECORDS)
    shared_chain_ds(d).collect(optimize=False, workers=1)
    # The paper-faithful executor runs the chain once per consumer: once
    # for the predicate, once for the projected column.
    assert len(op_chain_counter) == 2, op_chain_counter


def test_cse_thread_executor_evaluates_shared_chain_once_per_shard(
    tmp_path, op_chain_counter
):
    d = write_shards(tmp_path, RECORDS, n_files=3)
    ds = shared_chain_ds(d)
    frame_nodes, _ = P.split_plan(ds.plan)
    program = EX.compile_shard_program(
        P.optimize_plan(frame_nodes, ds.schema), optimize=True
    )
    ex = EX.ThreadShardExecutor(ing.list_shards([d]), program, workers=1)
    rows = sum(len(res.frame) for res in ex)
    ex.stop()
    assert rows > 0
    assert len(op_chain_counter) == 3, op_chain_counter  # one per shard


def test_cse_skips_unfingerprintable_ops(tmp_path):
    """A lambda word predicate has no stable signature; CSE must not alias
    the full chain on an unsound key — only its fingerprintable prefix is
    hoisted, and each consumer keeps its own lambda op."""
    e = col("abstract").lower().remove_words(lambda w, h: False)
    ds = Dataset.from_json_dirs(["/x"], FIELDS).with_column("a", e).with_column("b", e)
    opt = ds.optimized_plan()
    entries = [
        (out, expr.describe())
        for n in opt
        if isinstance(n, P.Project)
        for out, expr in n.exprs
    ]
    # The `.lower()` prefix is shared once; the unfingerprintable tail is
    # re-evaluated per consumer (never collapsed into one alias).
    assert sum(1 for out, _ in entries if out.startswith("__cse_")) == 1
    lambda_entries = [d for out, d in entries if "remove_words" in d]
    assert len(lambda_entries) == 2
    assert all(d.count("remove_words") == 1 for d in lambda_entries)


def test_cse_distinguishes_column_versions():
    """``col('x')`` before and after an overwrite of ``x`` must never
    alias: the second entry reads the *new* version."""
    ds = (
        Dataset.from_json_dirs(["/x"], FIELDS)
        .with_column("abstract", col("abstract").lower())
        .with_column("abstract2", col("abstract").lower())
    )
    opt = ds.optimized_plan()
    # Same structural expression, different input versions → no CSE.
    assert not any(
        out.startswith("__cse_")
        for n in opt
        if isinstance(n, P.Project)
        for out, _ in n.exprs
    )


def test_cse_does_not_reuse_across_user_select(tmp_path):
    """A user ``select()`` between two consumers drops any synthetic
    column, so CSE must scope sharing to Select-free regions — the plan
    must still execute (no dangling ``__cse_*`` reference)."""
    d = write_shards(tmp_path, RECORDS)
    ds = (
        Dataset.from_json_dirs([d], FIELDS)
        .where(col("abstract").lower().not_empty())
        .select(["abstract"])
        .with_column("a2", col("abstract").lower())
    )
    frame = ds.collect(workers=1)
    assert sorted(frame.field_names) == ["a2", "abstract"]
    assert len(frame) > 0


def test_optimize_plan_idempotent_on_cse_output():
    shared = clean_text(col("abstract"))
    ds = (
        Dataset.from_json_dirs(["/x"], FIELDS)
        .where(shared.word_count() >= 2)
        .with_column("abstract", shared)
    )
    once = ds.optimized_plan()
    twice = P.optimize_plan(once, ds._needed_columns())
    assert [n.describe() for n in once] == [n.describe() for n in twice]


# ---------------------------------------------------------------------------
# conjunct splitting
# ---------------------------------------------------------------------------


def test_split_conjuncts_roundtrip():
    p = (col("a").word_count() >= 1) & col("b").not_empty() & ~col("c").contains("x")
    conjs = split_conjuncts(p)
    assert [c.describe() for c in conjs] == [
        "(col('a').word_count() >= 1)",
        "col('b').not_empty()",
        "~col('c').contains('x')",
    ]
    assert and_all(conjs).describe() == p.describe()
    single = col("a").not_empty()
    assert split_conjuncts(single) == [single]
    # `|` is not a conjunction: must stay whole
    assert len(split_conjuncts(col("a").not_empty() | col("b").not_empty())) == 1


def test_or_predicate_does_not_split():
    ds = (
        Dataset.from_json_dirs(["/x"], FIELDS)
        .with_column("abstract", col("abstract").lower())
        .where((col("abstract").word_count() >= 2) | col("title").not_empty())
    )
    opt = ds.optimized_plan()
    filters = [n for n in opt if isinstance(n, P.Filter)]
    assert len(filters) == 1  # disjunction is not separable: stays put
    assert opt.index(filters[0]) > [
        i for i, n in enumerate(opt) if isinstance(n, P.Project)
    ][0]


# ---------------------------------------------------------------------------
# two-pass dedup programs
# ---------------------------------------------------------------------------


def two_pass_nodes(d="/x"):
    ds = (
        Dataset.from_json_dirs([d], FIELDS)
        .dropna(FIELDS)
        .drop_duplicates(["title"])
        .with_column("abstract", clean_text(col("abstract")))
    )
    frame_nodes, _ = P.split_plan(ds.plan)
    return P.optimize_plan(frame_nodes, ds.schema), ds


def test_split_dedup_programs_shapes():
    nodes, ds = two_pass_nodes()
    p1, p2 = EX.split_dedup_programs(nodes, optimize=True, count_columns=ds.schema)
    assert p1.steps[-1] == ("dedup_emit", ("title",))
    # pass 1 must prune transforms that do not feed the dedup key
    assert not any(k == "project" for k, _ in p1.steps)
    assert ("dedup_take", ("title",)) in p2.steps
    assert not p1.has_dedup and not p2.has_dedup  # both process-capable
    # pass 1 keys are cacheable; pass 2 output depends on the whole corpus
    assert EX.dedup_keys_fingerprint(p1) is not None
    assert EX.column_fingerprints(p2) is None


def test_split_dedup_programs_rejects_multiple_dedups():
    from repro.analysis import PlanValidationError

    ds = (
        Dataset.from_json_dirs(["/x"], FIELDS)
        .drop_duplicates(["title"])
        .drop_duplicates(["abstract"])
    )
    frame_nodes, _ = P.split_plan(ds.plan)
    # Stacked dedups now fail at program build time with a structured
    # diagnostic naming both offending Dedup nodes.
    with pytest.raises(PlanValidationError) as excinfo:
        EX.split_dedup_programs(frame_nodes, count_columns=FIELDS)
    (diag,) = excinfo.value.diagnostics
    assert diag.code == "P005"
    assert len(diag.provenance) == 2
    assert any("DropDuplicates(['title'])" in p for p in diag.provenance)
    assert any("DropDuplicates(['abstract'])" in p for p in diag.provenance)


def test_dedup_take_requires_row_filters(tmp_path):
    d = write_shards(tmp_path, RECORDS)
    nodes, ds = two_pass_nodes(d)
    _, p2 = EX.split_dedup_programs(nodes, optimize=True, count_columns=ds.schema)
    shards = ing.list_shards([d])
    ex = EX.ThreadShardExecutor(shards, p2, workers=1)  # no row_filters
    with pytest.raises(EX.UnsupportedPlanError, match="survivor"):
        list(ex)
    ex.stop()


def test_dedup_key_digests_distinguish_values():
    a = EX._dedup_key_digests([["x", None, "", "x"], ["y", "y", "y", "y"]], 4)
    assert a.shape == (4, 4) and a.dtype == np.int32
    assert a[0].tobytes() == a[3].tobytes()  # equal value tuples agree
    assert len({a[i].tobytes() for i in range(3)}) == 3  # None != "" != "x"


def test_dedup_key_digests_match_python_equality_classes():
    """Whole-frame dedup keys on Python tuple equality: True == 1 == 1.0
    and 0.0 == -0.0 must collapse to one digest, while NaN (never equal
    to anything) must never merge."""
    a = EX._dedup_key_digests([[True, 1, 1.0, 0.0, -0.0, "1.0"]], 6)
    digests = [a[i].tobytes() for i in range(6)]
    assert digests[0] == digests[1] == digests[2]
    assert digests[3] == digests[4]
    assert digests[5] not in digests[:5]  # the *string* "1.0" stays apart
    nan = float("nan")
    b = EX._dedup_key_digests([[nan, nan]], 2)
    assert b[0].tobytes() != b[1].tobytes()
