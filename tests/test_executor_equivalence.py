"""Differential harness for the three physical executors.

Whole-frame (:func:`repro.core.plan.execute_frame_plan`), streaming-thread
(:class:`repro.core.executor.ThreadShardExecutor`) and multi-process
(:class:`repro.core.executor.ProcessShardExecutor`) execution of the same
plan must produce byte-identical record multisets (arrival order is
nondeterministic under work stealing) and attribute wall time to the same
set of paper stages. The same harness drives token space: executor-emitted
int32 token arrays must be byte-identical to the eager
``encode_frame_columns`` oracle, and shard-merged vocabulary fits must
equal the whole-frame fit exactly. Corpora are hypothesis-generated and
include the nasty cases: unicode, empty rows, NUL bytes, giant rows.
"""

import json
import random

import pytest

try:  # hypothesis drives the property search when installed (CI); the
    # deterministic + seeded-fuzz corpora below run everywhere regardless.
    from hypothesis import HealthCheck, example, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - bare container
    HAVE_HYPOTHESIS = False

from repro.core import executor as EX
from repro.core import ingest as ing
from repro.core import plan as P
from repro.core.dataset import Dataset
from repro.core.frame import ColumnarFrame
from repro.core.p3sapp import case_study_stages
from repro.data.batching import encode_frame_columns, seq2seq_specs
from repro.data.tokenizer import WordTokenizer

FIELDS = ("title", "abstract")

_FUZZ_CHARS = (
    "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789"
    " <>()'.,!?-\t\x00ΩμέλΛñé漢字🙂"
)


def fuzz_records(seed: int, n: int) -> list[dict]:
    """Seeded pseudo-random corpus over the same nasty alphabet the
    hypothesis strategy draws from."""
    rng = random.Random(seed)

    def text():
        roll = rng.random()
        if roll < 0.1:
            return None
        if roll < 0.2:
            return ""
        return "".join(rng.choice(_FUZZ_CHARS) for _ in range(rng.randrange(1, 60)))

    return [{"title": text(), "abstract": text()} for _ in range(n)]


EDGE_RECORDS = [
    {"title": "", "abstract": ""},  # empty row
    {"title": None, "abstract": "only abstract survives dropna? no"},  # null
    {"title": "NUL\x00inside", "abstract": "tab\there and CR"},  # NUL bytes
    {"title": "Ωμέγα ένα <b>δύο</b>", "abstract": "naïve café — résumé 漢字"},
    {"title": "plain Title 42", "abstract": "The QUICK brown fox isn't slow."},
]
GIANT_RECORDS = [
    {
        "title": "Giant <b>Row</b> " + "Lorem IPSUM (drop me) " * 2000,
        "abstract": "word " * 20_000 + "end",
    },
    {"title": "small", "abstract": "row"},
]


def write_shards(root, records, n_files=3):
    d = root / "corpus"
    d.mkdir(parents=True, exist_ok=True)
    for i in range(n_files):
        with open(d / f"s{i}.jsonl", "w", encoding="utf-8") as fh:
            for r in records[i::n_files]:
                fh.write(json.dumps(r, ensure_ascii=False) + "\n")
    return d


def chain(d):
    """The canonical Algorithm 1 chain (sans dedup, so every executor —
    including the process pool — can run it)."""
    return (
        Dataset.from_json_dirs([d], FIELDS)
        .dropna(FIELDS)
        .apply(*case_study_stages())
        .dropna(FIELDS)
    )


def optimized_program(ds):
    frame_nodes, _ = P.split_plan(ds.plan)
    opt = P.optimize_plan(frame_nodes, ds.schema)
    return EX.compile_shard_program(opt, optimize=True)


def record_multiset(records):
    return sorted(tuple(sorted(r.items(), key=lambda kv: kv[0])) for r in records)


def executor_records(executor):
    frames = [res.frame for res in executor]
    executor.stop()
    if not frames:
        return []
    return ColumnarFrame.concat(frames).to_records()


def nonzero_stages(timings):
    return {
        name
        for name in ("ingestion", "pre_cleaning", "cleaning", "post_cleaning")
        if getattr(timings, name) > 0.0
    }


# ---------------------------------------------------------------------------
# the differential property
# ---------------------------------------------------------------------------


def token_row_multiset(token_dicts):
    """Row-wise byte multiset over a list of per-shard token dicts."""
    rows = []
    for tokens in token_dicts:
        keys = sorted(tokens)
        n = len(tokens[keys[0]]) if keys else 0
        for i in range(n):
            rows.append(tuple(tokens[k][i].tobytes() for k in keys))
    return sorted(rows)


def executor_tokens(executor):
    out = [res.tokens for res in executor]
    executor.stop()
    return out


SPECS = seq2seq_specs(max_abstract_len=16, max_title_len=8)


def token_program(ds, tok, specs=SPECS):
    frame_nodes, _ = P.split_plan(ds.plan)
    spec_cols = tuple(dict.fromkeys(s.column for s in specs))
    return EX.compile_shard_program(
        P.optimize_plan(frame_nodes, spec_cols),
        optimize=True,
        output_columns=spec_cols,
        tokens=EX.TokenPlan(tuple(specs), dict(tok.stoi), tok.fingerprint),
    )


def check_token_executors(d, ds, frame):
    """Executor-emitted token arrays must be byte-identical to the eager
    encode_frame_columns oracle, and per-shard-counted vocabularies must
    equal the whole-frame fit."""
    tok = WordTokenizer.fit(
        [(v or "") for col in FIELDS for v in frame[col]], vocab_size=256
    )
    oracle = encode_frame_columns(
        {c: frame[c] for c in FIELDS}, tok, SPECS
    )
    want = token_row_multiset([oracle])
    shards = ing.list_shards([d])
    program = token_program(ds, tok)

    got_thread = token_row_multiset(
        executor_tokens(EX.ThreadShardExecutor(shards, program, workers=2))
    )
    assert got_thread == want
    got_proc = token_row_multiset(
        executor_tokens(EX.ProcessShardExecutor(shards, program, workers=2))
    )
    assert got_proc == want

    # vocabulary fitting: shard-merged Counters (thread and process) must
    # reproduce the whole-frame fit word for word
    whole_ds = chain(d)
    whole_ds.collect()  # materialize → fit_vocab counts the memoized frame
    vocab_whole = whole_ds.fit_vocab(vocab_size=64)
    vocab_thread = chain(d).fit_vocab(vocab_size=64, workers=2, executor="thread")
    vocab_proc = chain(d).fit_vocab(vocab_size=64, workers=2, executor="process")
    assert vocab_thread.itos == vocab_whole.itos
    assert vocab_proc.itos == vocab_whole.itos


def check_three_executors(root, records):
    d = write_shards(root, records)
    ds = chain(d)
    frame_nodes, _ = P.split_plan(ds.plan)
    frame, whole_t = P.execute_frame_plan(frame_nodes, final_schema=ds.schema)
    want = record_multiset(frame.to_records())

    program = optimized_program(ds)
    shards = ing.list_shards([d])

    thread_ex = EX.ThreadShardExecutor(shards, program, workers=2)
    got_thread = record_multiset(executor_records(thread_ex))
    assert got_thread == want

    proc_ex = EX.ProcessShardExecutor(shards, program, workers=2)
    got_proc = record_multiset(executor_records(proc_ex))
    assert got_proc == want

    # Identical timing attribution: all three executors charge the same
    # paper stages (values differ, the *stage set* must not).
    assert nonzero_stages(thread_ex.timings) == nonzero_stages(whole_t)
    assert nonzero_stages(proc_ex.timings) == nonzero_stages(whole_t)

    # Token space over the same corpus: arrays and vocabularies.
    check_token_executors(d, ds, frame)


@pytest.mark.parametrize(
    "records",
    [
        pytest.param([], id="empty-corpus"),
        pytest.param(EDGE_RECORDS, id="edge-cases"),
        pytest.param(GIANT_RECORDS, id="giant-rows"),
        pytest.param(fuzz_records(1, 40), id="fuzz-1"),
        pytest.param(fuzz_records(2, 40), id="fuzz-2"),
    ],
)
def test_three_executors_byte_identical(tmp_path, records):
    check_three_executors(tmp_path, records)


if HAVE_HYPOTHESIS:
    TEXT = st.text(
        alphabet=st.one_of(
            st.characters(min_codepoint=32, max_codepoint=126),
            st.sampled_from("ΩμέλΛñé漢字🙂\t\x00"),
        ),
        max_size=40,
    )
    RECORDS = st.lists(
        st.fixed_dictionaries(
            {
                "title": st.none() | st.just("") | TEXT,
                "abstract": st.none() | st.just("") | TEXT,
            }
        ),
        max_size=24,
    )

    @settings(
        max_examples=6,
        deadline=None,
        suppress_health_check=[HealthCheck.function_scoped_fixture],
    )
    @given(records=RECORDS)
    @example(records=EDGE_RECORDS)
    def test_three_executors_byte_identical_property(tmp_path, records):
        check_three_executors(tmp_path, records)


# ---------------------------------------------------------------------------
# expression pipelines vs the legacy Stage oracle
# ---------------------------------------------------------------------------


def _stage_oracle(d):
    """The eager Stage path (Pipeline over a ColumnarFrame + row filters),
    kept as the oracle the expression pipelines must reproduce byte for
    byte."""
    from repro.core.pipeline import Pipeline

    frame = ing.ingest([d], FIELDS)
    frame = frame.dropna(list(FIELDS))
    frame = Pipeline(case_study_stages()).fit(frame).transform(frame)
    frame = frame.dropna(list(FIELDS))
    return frame


def expr_chain(d):
    """The canonical chain rebuilt from composable expressions — no Stage
    verbs anywhere."""
    from repro.core.expr import abstract_expr, col, title_expr

    return (
        Dataset.from_json_dirs([d], FIELDS)
        .where(col("title").not_empty() & col("abstract").not_empty())
        .transform(abstract=abstract_expr(), title=title_expr())
        .where(col("title").not_empty() & col("abstract").not_empty())
    )


@pytest.mark.parametrize(
    "records",
    [
        pytest.param(EDGE_RECORDS, id="edge-cases"),
        pytest.param(fuzz_records(5, 40), id="fuzz-5"),
    ],
)
def test_expression_pipeline_matches_stage_oracle(tmp_path, records):
    d = write_shards(tmp_path, records)
    want = record_multiset(_stage_oracle(d).to_records())

    ds = expr_chain(d)
    frame_nodes, _ = P.split_plan(ds.plan)
    frame, _ = P.execute_frame_plan(frame_nodes, final_schema=ds.schema)
    assert record_multiset(frame.to_records()) == want

    program = EX.compile_shard_program(
        P.optimize_plan(frame_nodes, ds.schema), optimize=True
    )
    shards = ing.list_shards([d])
    got_thread = record_multiset(
        executor_records(EX.ThreadShardExecutor(shards, program, workers=2))
    )
    assert got_thread == want
    got_proc = record_multiset(
        executor_records(EX.ProcessShardExecutor(shards, program, workers=2))
    )
    assert got_proc == want

    # token space: executor-encoded arrays off the expression pipeline must
    # equal the eager oracle encoding of the oracle frame
    frame_o = _stage_oracle(d)
    tok = WordTokenizer.fit(
        [(v or "") for col_ in FIELDS for v in frame_o[col_]], vocab_size=256
    )
    oracle_tokens = encode_frame_columns(
        {c: frame_o[c] for c in FIELDS}, tok, SPECS
    )
    program_t = token_program(ds, tok)
    got = token_row_multiset(
        executor_tokens(EX.ProcessShardExecutor(shards, program_t, workers=2))
    )
    assert got == token_row_multiset([oracle_tokens])


def test_expression_predicates_match_python_semantics(tmp_path):
    """where() predicates (word_count / contains / boolean algebra) on
    byte buffers must agree with the same predicate evaluated row-wise in
    Python — across whole-frame and both shard executors."""
    from repro.core.expr import col

    records = fuzz_records(9, 60)
    d = write_shards(tmp_path, records)
    ds = Dataset.from_json_dirs([d], FIELDS).where(
        (col("abstract").word_count() >= 2)
        & ~col("title").contains("x")
        & col("title").not_empty()
    )

    def keep(r):
        t, a = r.get("title") or "", r.get("abstract") or ""
        return len(a.split(" ")) - a.split(" ").count("") >= 2 and "x" not in t and t != ""

    # NB: word_count counts space-separated words on the byte buffer; rows
    # are compared through the same normalization ingestion applies.
    frame = ing.ingest([d], FIELDS)
    want = record_multiset(
        r for r in frame.to_records()
        if keep({k: (v if v is None else str(v).replace("\x00", " ")) for k, v in r.items()})
    )

    frame_nodes, _ = P.split_plan(ds.plan)
    got_frame, _ = P.execute_frame_plan(frame_nodes, final_schema=ds.schema)
    assert record_multiset(got_frame.to_records()) == want

    program = EX.compile_shard_program(
        P.optimize_plan(frame_nodes, ds.schema), optimize=True
    )
    shards = ing.list_shards([d])
    for ex in (
        EX.ThreadShardExecutor(shards, program, workers=2),
        EX.ProcessShardExecutor(shards, program, workers=2),
    ):
        assert record_multiset(executor_records(ex)) == want


def _check_ds_three_executors(d, ds):
    """Whole-frame, thread, and process execution of an arbitrary
    frame-level dataset plan must produce byte-identical record
    multisets."""
    frame_nodes, _ = P.split_plan(ds.plan)
    frame, _ = P.execute_frame_plan(frame_nodes, final_schema=ds.schema)
    want = record_multiset(frame.to_records())
    program = EX.compile_shard_program(
        P.optimize_plan(frame_nodes, ds.schema), optimize=True
    )
    shards = ing.list_shards([d])
    for make in (
        lambda: EX.ThreadShardExecutor(shards, program, workers=2),
        lambda: EX.ProcessShardExecutor(shards, program, workers=2),
    ):
        assert record_multiset(executor_records(make())) == want
    return want


@pytest.mark.parametrize(
    "records",
    [
        pytest.param(EDGE_RECORDS, id="edge-cases"),
        pytest.param(fuzz_records(11, 50), id="fuzz-11"),
    ],
)
def test_cse_plan_byte_identical_across_executors(tmp_path, records):
    """A chain shared between a ``where`` predicate and a projected
    derived column (hoisted by cross-node CSE into a ``__cse_*``
    intermediate) must stay byte-identical to whole-frame on every
    executor, and the synthetic column must not leak into the results."""
    from repro.core.expr import clean_text, col

    d = write_shards(tmp_path, records)
    shared = clean_text(col("abstract"))
    ds = (
        Dataset.from_json_dirs([d], FIELDS)
        .where(shared.word_count() >= 2)
        .with_column("abstract", shared)
        .with_column("short", clean_text(col("abstract")))
    )
    opt = ds.optimized_plan()
    assert any(
        out.startswith("__cse_")
        for n in opt
        if isinstance(n, P.Project)
        for out, _ in n.exprs
    ), "expected a hoisted CSE intermediate in the optimized plan"
    want = _check_ds_three_executors(d, ds)
    for rec in want:
        assert not any(k.startswith("__cse_") for k, _ in rec)


@pytest.mark.parametrize(
    "records",
    [
        pytest.param(EDGE_RECORDS, id="edge-cases"),
        pytest.param(fuzz_records(12, 50), id="fuzz-12"),
    ],
)
def test_conjunct_split_byte_identical_across_executors(tmp_path, records):
    """A mixed raw/derived ``&`` predicate (split by the optimizer so the
    raw conjunct filters below the Project) must keep the exact row set of
    the unsplit plan on every executor."""
    from repro.core.expr import abstract_expr, col

    d = write_shards(tmp_path, records)
    ds = (
        Dataset.from_json_dirs([d], FIELDS)
        .with_column("abstract", abstract_expr())
        .where(
            (col("abstract").word_count() >= 1)
            & col("title").not_empty()
            & ~col("title").contains("x")
        )
    )
    opt = ds.optimized_plan()
    filters = [n for n in opt if isinstance(n, P.Filter)]
    assert len(filters) == 2, "expected the conjunction to split at the Project"
    _check_ds_three_executors(d, ds)


@pytest.mark.parametrize(
    "records",
    [
        pytest.param(EDGE_RECORDS * 3, id="edge-dups"),
        pytest.param(fuzz_records(13, 60) * 2, id="fuzz-dups"),
    ],
)
def test_two_pass_fit_vocab_matches_whole_frame(tmp_path, records):
    """fit_vocab on a partial-subset dedup plan must run the streaming
    two-pass canonical-survivor protocol (no whole-frame fallback) and
    produce the byte-identical vocabulary on thread and process
    executors."""

    def pipe():
        return (
            Dataset.from_json_dirs([d], FIELDS)
            .dropna(FIELDS)
            .drop_duplicates(["title"])  # partial subset
            .apply(*case_study_stages())
        )

    d = write_shards(tmp_path, records, n_files=4)
    whole_ds = pipe()
    whole_ds.collect()  # materialize → fit_vocab counts the memoized frame
    vocab_whole = whole_ds.fit_vocab(vocab_size=64)

    for executor in ("thread", "process"):
        stats: dict = {}
        vocab = pipe().fit_vocab(
            vocab_size=64, workers=2, executor=executor, stats=stats
        )
        assert stats["executor"] == executor, stats
        assert stats["two_pass"] is True
        assert vocab.itos == vocab_whole.itos


def test_dedup_plan_thread_matches_whole_frame(tmp_path):
    records = EDGE_RECORDS + EDGE_RECORDS  # every row duplicated across shards
    d = write_shards(tmp_path, records)
    ds = (
        Dataset.from_json_dirs([d], FIELDS)
        .dropna(FIELDS)
        .drop_duplicates(FIELDS)
        .apply(*case_study_stages())
    )
    frame_nodes, _ = P.split_plan(ds.plan)
    frame, _ = P.execute_frame_plan(frame_nodes, final_schema=ds.schema)
    want = record_multiset(frame.to_records())

    program = optimized_program(ds)
    assert program.has_dedup
    got = record_multiset(
        executor_records(
            EX.ThreadShardExecutor(ing.list_shards([d]), program, workers=3)
        )
    )
    assert got == want


# ---------------------------------------------------------------------------
# full streaming pipeline (tokenize + batch) across executors
# ---------------------------------------------------------------------------


def batch_rows(batches):
    rows = []
    for b in batches:
        keys = sorted(b)
        for i in range(len(b[keys[0]])):
            rows.append(tuple(bytes(b[k][i].tobytes()) for k in keys))
    return sorted(rows)


def test_streaming_batches_match_across_executors(tmp_path):
    d = write_shards(tmp_path, EDGE_RECORDS * 8, n_files=4)
    base = chain(d)
    tok = WordTokenizer.fit(
        [r["abstract"] or "" for r in base.collect().to_records()]
    )

    def pipe():
        return (
            chain(d)
            .tokenize(tok, seq2seq_specs(max_abstract_len=16, max_title_len=8))
            .batch(4, shuffle=False, drop_remainder=False)
            .prefetch(2)
        )

    whole = batch_rows(pipe().iter_batches(workers=1, executor="thread"))
    stats_t: dict = {}
    threaded = batch_rows(
        pipe().iter_batches(workers=2, executor="thread", stats=stats_t)
    )
    stats_p: dict = {}
    processed = batch_rows(
        pipe().iter_batches(workers=2, executor="process", stats=stats_p)
    )
    assert threaded == whole
    assert processed == whole
    assert stats_t["executor"] == "thread"
    assert stats_p["executor"] == "process"


# ---------------------------------------------------------------------------
# byte-kernel backends: fused / pallas(interpret) vs the loops oracle
# ---------------------------------------------------------------------------

# Adversarial span nesting: interleaved html/paren spans, stray closers,
# unclosed openers — the cases where a fused single-pass scan could diverge
# from the iterated row-wise semantics.
SPAN_RECORDS = [
    {"title": "<a(b>c)d mixed", "abstract": "(a(b<c)d>e stray ) closer"},
    {"title": "unclosed <span swallows to row end", "abstract": "(so does paren"},
    {"title": ">> leading closers ((", "abstract": "nested ((deep (er))) out"},
    {"title": "<<< (((", "abstract": ")))) >>>>"},
]

BACKEND_CORPUS = EDGE_RECORDS + SPAN_RECORDS + fuzz_records(21, 40)


@pytest.mark.parametrize("backend", ["fused", "pallas"])
def test_backend_three_executors_byte_identical(tmp_path, monkeypatch, backend):
    """The fused and pallas backends must reproduce the loops whole-frame
    records byte for byte — and the row-wise Stage oracle independently —
    on the whole-frame, thread, process, and remote executors, over
    non-ASCII, NUL-byte, and adversarial span-nesting rows."""
    if backend == "pallas":
        pytest.importorskip("jax")
        # No TPU in CI: force the kernel through the Pallas interpreter so
        # the kernel path itself is exercised, not the host fallback.
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    d = write_shards(tmp_path, BACKEND_CORPUS, n_files=4)
    ds = chain(d)
    frame_nodes, _ = P.split_plan(ds.plan)
    oracle, _ = P.execute_frame_plan(
        frame_nodes, final_schema=ds.schema, backend="loops"
    )
    want = record_multiset(oracle.to_records())
    # independent row-wise oracle (eager Stage path, no fused lowering)
    assert record_multiset(_stage_oracle(d).to_records()) == want

    got_frame, _ = P.execute_frame_plan(
        frame_nodes, final_schema=ds.schema, backend=backend
    )
    assert record_multiset(got_frame.to_records()) == want

    program = EX.compile_shard_program(
        P.optimize_plan(frame_nodes, ds.schema), optimize=True, backend=backend
    )
    assert program.backend == backend
    shards = ing.list_shards([d])
    for make in (
        lambda: EX.ThreadShardExecutor(shards, program, workers=2),
        lambda: EX.ProcessShardExecutor(shards, program, workers=2),
    ):
        assert record_multiset(executor_records(make())) == want

    from repro.distributed.coordinator import RemoteShardExecutor

    remote = RemoteShardExecutor(
        shards, program, workers=2,
        remote={"lease_s": 5.0, "heartbeat_timeout": 3.0,
                "heartbeat_interval_s": 0.1},
    )
    assert record_multiset(executor_records(remote)) == want


@pytest.mark.parametrize("backend", ["fused", "pallas"])
def test_backend_streaming_batches_match_loops(tmp_path, monkeypatch, backend):
    """End-to-end streamed token batches under a non-default backend must
    equal the loops stream on both in-host executors."""
    if backend == "pallas":
        pytest.importorskip("jax")
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    d = write_shards(tmp_path, BACKEND_CORPUS, n_files=4)
    tok = WordTokenizer.fit(
        [r["abstract"] or "" for r in chain(d).collect().to_records()]
    )

    def pipe(b=None):
        ds = chain(d)
        if b is not None:
            ds = ds.backend(b)
        return (
            ds.tokenize(tok, seq2seq_specs(max_abstract_len=16, max_title_len=8))
            .batch(4, shuffle=False, drop_remainder=False)
            .prefetch(2)
        )

    want = batch_rows(pipe().iter_batches(workers=1, executor="thread"))
    for executor in ("thread", "process"):
        got = batch_rows(
            pipe(backend).iter_batches(workers=2, executor=executor)
        )
        assert got == want, f"{backend}/{executor} diverged from loops"


def test_backend_resolution_and_validation(tmp_path, monkeypatch):
    """Explicit backend > REPRO_BYTES_BACKEND env > loops; unknown names
    are rejected at every entry point; the resolved backend is baked into
    the compiled program (it must travel to pickled workers, not re-read
    the worker's env)."""
    from repro.core import bytesops as B

    d = write_shards(tmp_path, EDGE_RECORDS)
    monkeypatch.delenv("REPRO_BYTES_BACKEND", raising=False)
    assert optimized_program(chain(d)).backend == "loops"
    monkeypatch.setenv("REPRO_BYTES_BACKEND", "fused")
    assert optimized_program(chain(d)).backend == "fused"
    frame_nodes, _ = P.split_plan(chain(d).plan)
    explicit = EX.compile_shard_program(
        P.optimize_plan(frame_nodes, chain(d).schema), backend="pallas"
    )
    assert explicit.backend == "pallas"  # explicit beats env

    assert B.resolve_backend(None) == "fused"  # env
    monkeypatch.delenv("REPRO_BYTES_BACKEND")
    assert B.resolve_backend(None) == "loops"
    with pytest.raises(ValueError, match="bogus"):
        B.resolve_backend("bogus")
    with pytest.raises(ValueError, match="bogus"):
        chain(d).backend("bogus")
    # the verb is a lazy option: it renders in explain() and does not
    # perturb the logical plan nodes
    ds = chain(d).backend("fused")
    assert ds.plan == chain(d).plan
    assert "bytes backend: fused" in ds.explain()


# ---------------------------------------------------------------------------
# executor selection and fallback
# ---------------------------------------------------------------------------


def test_make_executor_selection_and_fallback(tmp_path, monkeypatch):
    d = write_shards(tmp_path, EDGE_RECORDS)
    shards = ing.list_shards([d])
    plain = optimized_program(chain(d))
    dedup_ds = Dataset.from_json_dirs([d], FIELDS).drop_duplicates(FIELDS)
    dedup = optimized_program(dedup_ds)

    monkeypatch.delenv("REPRO_EXECUTOR", raising=False)
    # The default selection depends on the *effective* core count (one
    # effective worker → threads); pin it so the assertions below test the
    # selection rules, not the machine the suite happens to run on.
    monkeypatch.setattr(EX.os, "cpu_count", lambda: 4)
    picks = {
        "default-1": EX.make_executor(shards, plain, workers=1),
        "default-4": EX.make_executor(shards, plain, workers=4),
        "forced-thread": EX.make_executor(shards, plain, workers=4, executor="thread"),
        "dedup-falls-back": EX.make_executor(shards, dedup, workers=4),
    }
    try:
        assert picks["default-1"].name == "thread"
        assert picks["default-4"].name == "process"
        assert picks["forced-thread"].name == "thread"
        assert picks["dedup-falls-back"].name == "thread"
    finally:
        for ex in picks.values():
            ex.stop()

    monkeypatch.setenv("REPRO_EXECUTOR", "thread")
    ex = EX.make_executor(shards, plain, workers=4)
    try:
        assert ex.name == "thread"
    finally:
        ex.stop()

    monkeypatch.setenv("REPRO_EXECUTOR", "bogus")
    with pytest.raises(ValueError):
        EX.make_executor(shards, plain, workers=2)

    with pytest.raises(EX.UnsupportedPlanError):
        EX.ProcessShardExecutor(shards, dedup, workers=2)
