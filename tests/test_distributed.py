"""Distributed behaviour on 8 placeholder CPU devices.

jax locks the device count at first init, and the main pytest process
runs with 1 device — so every multi-device test executes in a fresh
subprocess with XLA_FLAGS set. The subprocess body asserts; the test
checks the exit code."""

import os
import subprocess
import sys
from pathlib import Path


from repro.distributed.sharding import FSDP_RULES, spec_for


def run_sub(body: str) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(Path("src").resolve())
    script = "import jax, jax.numpy as jnp\nimport numpy as np\n" + body
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-4000:]}"


# -- sharding rule engine (no devices needed) --------------------------------


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_spec_divisibility_fallback():
    mesh = FakeMesh({"data": 16, "model": 16})
    # kv_heads=8 not divisible by 16 -> falls to head_dim
    spec = spec_for((8192, 8, 128), ("embed", "kv_heads", "head_dim"), mesh)
    assert tuple(spec) == (None, None, "model")
    # vocab 504 indivisible -> replicated
    spec = spec_for((504, 1280), ("vocab", "embed"), mesh)
    assert tuple(spec) == ()
    # standard: vocab over model
    spec = spec_for((50304, 2560), ("vocab", "embed"), mesh)
    assert tuple(spec) == ("model",)
    # FSDP: embed over data too
    spec = spec_for((50304, 2560), ("vocab", "embed"), mesh, FSDP_RULES)
    assert tuple(spec) == ("model", "data")


def test_spec_axis_exclusivity():
    mesh = FakeMesh({"data": 16, "model": 16})
    # heads takes model; head_dim must NOT reuse it
    spec = spec_for((4096, 32, 128), ("embed", "heads", "head_dim"), mesh)
    assert tuple(spec) == (None, "model")


def test_batch_axes():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    spec = spec_for((256, 4096), ("batch", "seq"), mesh)
    assert tuple(spec) == (("pod", "data"),)
    # batch=1 (long_500k): replicated
    spec = spec_for((1, 4096), ("batch", "seq"), mesh)
    assert tuple(spec) == ()


# -- multi-device subprocess tests -------------------------------------------


def test_pjit_forward_matches_single_device():
    run_sub(r"""
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke
from repro.models.lm import LM, MeshContext
from repro.launch.mesh import make_host_mesh, set_mesh

cfg = get_smoke("stablelm_3b")
mesh = make_host_mesh(model_parallel=2)
mctx = MeshContext(mesh, ("data",), "model")
model = LM(cfg, mctx, remat=False, dtype=jnp.float32)
params = model.init(jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 4, cfg.vocab_size)

ref_model = LM(cfg, remat=False, dtype=jnp.float32)
ref_logits, _ = ref_model.forward(params, {"tokens": toks})

with set_mesh(mesh):
    sh = NamedSharding(mesh, P("data", None))
    toks_d = jax.device_put(toks, sh)
    logits, _ = jax.jit(model.forward)(params, {"tokens": toks_d})
np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits), rtol=2e-4, atol=2e-4)
print("pjit forward OK")
""")


def test_moe_ep_matches_local():
    """Expert-parallel all_to_all MoE == single-device local MoE."""
    run_sub(r"""
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke
from repro.models import moe as MOE
from repro.launch.mesh import make_host_mesh, set_mesh

cfg = get_smoke("deepseek_moe_16b")
# capacity high enough that nothing drops (so EP == local exactly)
object.__setattr__(cfg.moe, "capacity_factor", 8.0)
mesh = make_host_mesh(model_parallel=4)
p = MOE.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model)) * 0.5

y_local, aux_local = MOE.moe_local(p, x, cfg)
with set_mesh(mesh):
    xd = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    y_ep, aux_ep = jax.jit(lambda p, x: MOE.moe_ep(p, x, cfg, mesh, ("data",), "model"))(p, xd)
np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_local), rtol=2e-4, atol=2e-4)
# aux: EP averages per-shard load-balance terms (f_e * P_e is nonlinear in
# the shard mean), so a small deviation from the global statistic is inherent
np.testing.assert_allclose(float(aux_ep), float(aux_local), rtol=2e-2)
print("MoE EP OK")
""")


def test_psum_compressed_allreduce():
    run_sub(r"""
from functools import partial
from repro.optim.grad_compression import psum_compressed
from repro.launch.mesh import make_host_mesh, set_mesh, shard_map
from jax.sharding import PartitionSpec as P

mesh = make_host_mesh(model_parallel=1)
g = jax.random.normal(jax.random.PRNGKey(0), (8, 64)) * 0.01

@partial(shard_map, mesh=mesh, in_specs=P("data", None), out_specs=P("data", None), check_vma=False)
def reduce_fn(g_local):
    mean, err = psum_compressed({"g": g_local}, ("data",))
    return mean["g"] / 8.0

out = reduce_fn(g)
expect = np.broadcast_to(np.mean(np.asarray(g), axis=0, keepdims=True), (8, 64))
# int8 quantization: modest tolerance
np.testing.assert_allclose(np.asarray(out), expect, atol=2e-3)
print("compressed psum OK")
""")


def test_elastic_remesh_across_topologies():
    run_sub(r"""
from repro.runtime.elastic import available_mesh, remesh
from repro.distributed.sharding import tree_shardings
import jax

tree = {"w": jnp.arange(64.0).reshape(8, 8), "v": jnp.ones((8,))}
axes = {"w": ("embed", "mlp"), "v": ("embed",)}

mesh8 = available_mesh(model_parallel=4)  # 2x4
placed = remesh(tree, axes, mesh8)
# shrink to 4 devices (1x4)
mesh4 = available_mesh(model_parallel=4, devices=jax.devices()[:4])
replaced = remesh(placed, axes, mesh4)
np.testing.assert_array_equal(np.asarray(replaced["w"]), np.asarray(tree["w"]))
print("elastic OK")
""")


def test_train_step_sharded_end_to_end():
    run_sub(r"""
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke
from repro.models.lm import LM, MeshContext
from repro.launch.mesh import make_host_mesh, set_mesh
from repro.optim.adamw import AdamW
from repro.runtime.train_loop import TrainStepConfig, make_train_step
from repro.distributed.sharding import tree_shardings

cfg = get_smoke("qwen2_5_32b")
mesh = make_host_mesh(model_parallel=2)
mctx = MeshContext(mesh, ("data",), "model")
model = LM(cfg, mctx, remat=True, dtype=jnp.float32)
params = model.init(jax.random.PRNGKey(0))
opt = AdamW(learning_rate=1e-3)
step = make_train_step(model.loss, opt, TrainStepConfig(n_microbatches=2))

with set_mesh(mesh):
    sh = tree_shardings(jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params),
                        model.param_axes(), mesh)
    params = jax.tree.map(jax.device_put, params, sh)
    opt_state = opt.init(params)
    batch = {"tokens": jax.device_put(
        jax.random.randint(jax.random.PRNGKey(1), (8, 16), 4, cfg.vocab_size),
        NamedSharding(mesh, P("data", None)))}
    jstep = jax.jit(step)
    p, o, m1 = jstep(params, opt_state, batch)
    p, o, m2 = jstep(p, o, batch)
assert float(m2["loss"]) < float(m1["loss"])
print("sharded train OK", float(m1["loss"]), "->", float(m2["loss"]))
""")
