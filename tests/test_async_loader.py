"""Dedicated AsyncLoader suite: prefetch bound, shutdown, error
propagation/sentinel ordering, double-buffering, and queue stats.

The loader is the host half of the device-overlap story
(tests/test_device_feed.py covers the device half); everything here runs
with plain iterators and a stubbed ``device_put``, so the suite is
executor-independent — it passes unchanged under the thread, process, and
remote CI legs."""

import threading
import time

import numpy as np
import pytest

from repro.core.async_loader import AsyncLoader


def _batch(i, rows=2):
    return {"x": np.full((rows, 2), i, dtype=np.int32)}


def _wait_until(cond, timeout=5.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return True
        time.sleep(0.002)
    return False


def test_prefetch_bound_respected():
    """With no consumer, the fill thread runs at most ``prefetch`` batches
    ahead (queue full) plus the one batch blocked in put()."""
    produced = []

    def src():
        for i in range(100):
            produced.append(i)
            yield _batch(i)

    loader = AsyncLoader(src(), prefetch=3, device_put=lambda b: b)
    try:
        # the producer must stall at the bound, never race to 100
        assert _wait_until(lambda: len(produced) >= 4)
        time.sleep(0.05)  # any over-production would land in this window
        assert len(produced) <= 4  # 3 queued + 1 in the blocked put
        assert loader.stats.max_depth <= 3
    finally:
        loader.close()


def test_close_mid_epoch_joins_fill_thread():
    """close() after breaking out of an endless epoch stream unblocks the
    producer's put() and joins the thread — no deadlock, no leak."""
    source_closed = []

    class Endless:
        def __iter__(self):
            i = 0
            while True:
                yield _batch(i)
                i += 1

        def close(self):
            source_closed.append(True)

    loader = AsyncLoader(Endless(), prefetch=2, device_put=lambda b: b)
    it = iter(loader)
    for _ in range(3):
        next(it)
    loader.close()
    assert not loader.running
    # the fill thread's finally ran the source finalizer exactly once
    assert source_closed == [True]


def test_producer_exception_propagates():
    def src():
        yield _batch(0)
        raise RuntimeError("boom")

    with pytest.raises(RuntimeError, match="boom"):
        list(AsyncLoader(src(), prefetch=2, device_put=lambda b: b))


def test_sentinel_ordering_after_error():
    """Batches produced before the error are all yielded first; the error
    surfaces only at the end of iteration (the sentinel follows the last
    good batch, it never overtakes it)."""
    def src():
        for i in range(4):
            yield _batch(i)
        raise ValueError("late failure")

    loader = AsyncLoader(src(), prefetch=8, device_put=lambda b: b)
    got = []
    with pytest.raises(ValueError, match="late failure"):
        for b in loader:
            got.append(int(b["x"][0, 0]))
    assert got == [0, 1, 2, 3]


def test_error_before_first_batch_raises_promptly():
    def src():
        raise OSError("no data")
        yield  # pragma: no cover - makes src a generator

    with pytest.raises(OSError, match="no data"):
        list(AsyncLoader(src(), prefetch=1, device_put=lambda b: b))


def test_double_buffering_yields_k_while_k1_transfers():
    """The transfer of batch k+1 is issued before batch k is yielded —
    observed through a stubbed device_put that logs event order."""
    events = []

    def fake_device_put(batch):
        events.append(("put", int(batch["x"][0, 0])))
        return batch

    loader = AsyncLoader(
        (_batch(i) for i in range(5)), prefetch=2, device_put=fake_device_put
    )
    for b in loader:
        events.append(("yield", int(b["x"][0, 0])))
    puts = [i for kind, i in events if kind == "put"]
    assert puts == [0, 1, 2, 3, 4]
    for k in range(4):
        assert events.index(("put", k + 1)) < events.index(("yield", k)), (
            f"batch {k + 1} must be in flight before batch {k} is consumed"
        )


def test_starvation_counter_and_fake_clock_wait():
    """A producer gated on an event starves the consumer: the empty-queue
    get increments the counter and the (injectable) clock accounts the
    wait. The fake clock only advances when the producer runs, so the
    measured wait is exactly the producer's simulated delay."""
    class FakeClock:
        def __init__(self):
            self.t = 0.0
            self._lock = threading.Lock()

        def advance(self, dt):
            with self._lock:
                self.t += dt

        def __call__(self):
            with self._lock:
                return self.t

    clock = FakeClock()
    gate = threading.Event()

    def src():
        yield _batch(0)  # ungated pair: fills the queue before the
        yield _batch(1)  # consumer runs (no starvation on these)
        gate.wait(timeout=5.0)
        clock.advance(7.0)  # the slow batch "takes" 7 fake seconds
        yield _batch(2)

    loader = AsyncLoader(src(), prefetch=2, device_put=lambda b: b, clock=clock)
    it = iter(loader)
    assert _wait_until(lambda: loader.stats.produced >= 2)
    # first yield consumes batches 0 AND 1 (double buffering holds one
    # pending), both from a non-empty queue: no starvation yet
    assert int(next(it)["x"][0, 0]) == 0
    assert loader.stats.starvation == 0

    consumed = []
    t = threading.Thread(target=lambda: consumed.extend(it), daemon=True)
    t.start()
    # consumer is now blocked on an empty queue (producer gated)
    assert _wait_until(lambda: loader.stats.starvation == 1)
    gate.set()
    t.join(timeout=5.0)
    assert len(consumed) == 2  # batch 1 (pending) + batch 2
    assert loader.stats.starvation == 1
    assert loader.stats.wait_s == pytest.approx(7.0)
    assert loader.stats.consumed == 3


def test_queue_depth_gauges():
    """max_depth tracks how much of the prefetch budget the producer used."""
    loader = AsyncLoader(
        (_batch(i) for i in range(10)), prefetch=4, device_put=lambda b: b
    )
    assert _wait_until(lambda: loader.stats.max_depth >= 4)
    out = list(loader)
    assert len(out) == 10
    s = loader.stats
    assert s.prefetch == 4
    assert s.produced == 10 and s.consumed == 10
    assert 1 <= s.max_depth <= 4


def test_jax_device_put_default_path():
    """Without a stub, leaves come back as jax arrays (the seed behavior)."""
    import jax

    out = list(AsyncLoader(iter([_batch(3)]), prefetch=1))
    assert isinstance(out[0]["x"], jax.Array)
    np.testing.assert_array_equal(np.asarray(out[0]["x"]), _batch(3)["x"])
