"""Distributed data plane: coordinator/worker differential + fault tests.

The remote executor must be a byte-identical drop-in for the in-host
executors: the same compiled program rides a TCP frame instead of a
shared-memory segment, so records, token arrays, and fitted vocabularies
must match the whole-frame oracle exactly. On top of that come the
distribution-specific properties: lease expiry → work stealing
(fake-clock unit tests), worker death mid-epoch → restart-safe
reassignment with no duplicate or missing shard (SIGKILL integration
test), heartbeat liveness without torn reads, and warm-cache remote runs
reporting 100% token-cache hits.
"""

import os
import signal
import socket
import threading
import time
from pathlib import Path

import pytest

from repro.core import executor as EX
from repro.core import ingest as ing
from repro.core import plan as P
from repro.core.frame import ColumnarFrame
from repro.data.batching import encode_frame_columns
from repro.data.tokenizer import WordTokenizer
from repro.distributed.coordinator import (
    Coordinator,
    LeaseTable,
    RemoteShardExecutor,
)
from repro.distributed.transport import recv_frame, send_frame
from repro.distributed.worker import heartbeat_path
from repro.runtime.fault_tolerance import Heartbeat
from test_executor_equivalence import (
    FIELDS,
    SPECS,
    chain,
    executor_records,
    executor_tokens,
    fuzz_records,
    optimized_program,
    record_multiset,
    token_program,
    token_row_multiset,
    write_shards,
)

# Fast liveness so the fault tests finish in seconds, not lease_s defaults.
FAST = {"lease_s": 5.0, "heartbeat_timeout": 3.0, "heartbeat_interval_s": 0.1}


def remote_executor(shards, program, **kw):
    kw.setdefault("remote", dict(FAST))
    return RemoteShardExecutor(shards, program, workers=kw.pop("workers", 2), **kw)


# ---------------------------------------------------------------------------
# lease table: pure bookkeeping under a fake clock
# ---------------------------------------------------------------------------


class FakeClock:
    def __init__(self):
        self.now = 0.0

    def __call__(self):
        return self.now


def test_lease_acquire_complete_roundtrip():
    lt = LeaseTable(3, lease_s=10.0, clock=FakeClock())
    got = [lt.acquire("w1", timeout=0.01) for _ in range(3)]
    assert sorted(got) == [0, 1, 2]
    assert lt.acquire("w1", timeout=0.01) is None  # nothing pending
    assert not lt.all_done()
    for i in got:
        assert lt.complete(i, "w1")
    assert lt.all_done() and lt.remaining() == 0


def test_lease_expiry_requeues_for_survivor():
    clock = FakeClock()
    lt = LeaseTable(2, lease_s=10.0, clock=clock)
    assert lt.acquire("dead", timeout=0.01) == 0
    clock.now = 5.0
    assert lt.reap_expired() == []  # deadline not reached
    clock.now = 10.0
    assert lt.reap_expired() == [0]  # stolen back
    # the survivor picks up both the stolen shard and the untouched one
    got = [lt.acquire("live", timeout=0.01), lt.acquire("live", timeout=0.01)]
    assert sorted(got) == [0, 1]


def test_lease_duplicate_result_dropped():
    clock = FakeClock()
    lt = LeaseTable(1, lease_s=1.0, clock=clock)
    assert lt.acquire("slow", timeout=0.01) == 0
    clock.now = 2.0
    assert lt.reap_expired() == [0]
    assert lt.acquire("fast", timeout=0.01) == 0  # reassigned
    assert lt.complete(0, "fast")  # first result wins
    assert not lt.complete(0, "slow")  # late duplicate dropped
    assert lt.all_done()


def test_lease_release_on_worker_death():
    lt = LeaseTable(3, lease_s=100.0, clock=FakeClock())
    assert lt.acquire("w1", timeout=0.01) == 0
    assert lt.acquire("w2", timeout=0.01) == 1
    assert sorted(lt.release("w1")) == [0]  # w1 died: its lease requeues
    assert lt.leased_to("w2") == [1]  # w2 untouched
    got = [lt.acquire("w2", timeout=0.01), lt.acquire("w2", timeout=0.01)]
    assert sorted(got) == [0, 2]


def test_lease_close_wakes_waiters():
    lt = LeaseTable(1, lease_s=1.0)
    assert lt.acquire("w", timeout=0.01) == 0
    out = []
    t = threading.Thread(target=lambda: out.append(lt.acquire("w", timeout=30.0)))
    t.start()
    time.sleep(0.05)
    lt.close()
    t.join(timeout=5.0)
    assert not t.is_alive() and out == [None]


# ---------------------------------------------------------------------------
# heartbeat hardening: atomic beats, no torn reads
# ---------------------------------------------------------------------------


def test_heartbeat_beat_is_atomic(tmp_path):
    path = tmp_path / "w.beat"
    hb = Heartbeat(path, interval_s=0.0)
    hb.beat(7, force=True)
    assert Heartbeat.is_alive(path, timeout_s=60.0)
    # no temp residue: the tmp file was renamed into place
    assert [p.name for p in tmp_path.iterdir()] == ["w.beat"]


def test_heartbeat_tolerates_missing_and_garbage(tmp_path):
    assert Heartbeat.last_beat(tmp_path / "never.beat") is None
    garbage = tmp_path / "torn.beat"
    garbage.write_text("12 not-a-float")
    assert Heartbeat.last_beat(garbage) is None
    assert not Heartbeat.is_alive(garbage, timeout_s=60.0)
    garbage.write_text("")  # zero-length file (crash between create+write)
    assert Heartbeat.last_beat(garbage) is None


def test_heartbeat_interval_gate_and_force(tmp_path):
    hb = Heartbeat(tmp_path / "w.beat", interval_s=3600.0)
    hb.beat(1, force=True)
    first = Heartbeat.last_beat(hb.path)
    hb.beat(2)  # inside the interval: suppressed
    assert Heartbeat.last_beat(hb.path) == first
    hb.beat(3, force=True)  # force overrides the gate
    assert Heartbeat.last_beat(hb.path) >= first


# ---------------------------------------------------------------------------
# transport framing
# ---------------------------------------------------------------------------


def test_transport_frame_roundtrip():
    a, b = socket.socketpair()
    try:
        payload = os.urandom(70_001)
        send_frame(a, "task", {"shard_index": 3, "digest": "abc"}, payload)
        send_frame(a, "shutdown")
        kind, meta, view = recv_frame(b)
        assert kind == "task" and meta["shard_index"] == 3
        assert bytes(view) == payload
        kind, meta, view = recv_frame(b)
        assert kind == "shutdown" and meta == {} and len(view) == 0
        a.close()
        assert recv_frame(b) is None  # clean EOF between frames
    finally:
        for s in (a, b):
            try:
                s.close()
            except OSError:
                pass


# ---------------------------------------------------------------------------
# differential: remote == thread, byte for byte
# ---------------------------------------------------------------------------


def test_remote_records_match_thread(tmp_path):
    d = write_shards(tmp_path, fuzz_records(7, 48), n_files=4)
    ds = chain(d)
    program = optimized_program(ds)
    shards = ing.list_shards([d])
    want = record_multiset(
        executor_records(EX.ThreadShardExecutor(shards, program, workers=2))
    )
    got = record_multiset(executor_records(remote_executor(shards, program)))
    assert got == want


def test_remote_tokens_match_oracle_and_warm_cache_full_hits(tmp_path):
    d = write_shards(tmp_path, fuzz_records(8, 48), n_files=4)
    ds = chain(d)
    frame_nodes, _ = P.split_plan(ds.plan)
    frame, _ = P.execute_frame_plan(frame_nodes, final_schema=ds.schema)
    tok = WordTokenizer.fit(
        [(v or "") for col in FIELDS for v in frame[col]], vocab_size=256
    )
    want = token_row_multiset(
        [encode_frame_columns({c: frame[c] for c in FIELDS}, tok, SPECS)]
    )
    shards = ing.list_shards([d])
    program = token_program(ds, tok)

    cache = tmp_path / "shard-cache"
    cold = remote_executor(shards, program, cache_dir=cache)
    assert token_row_multiset(executor_tokens(cold)) == want
    assert cold.token_cache_misses > 0

    warm = remote_executor(shards, program, cache_dir=cache)
    assert token_row_multiset(executor_tokens(warm)) == want
    # acceptance criterion: warm remote runs report 100% ShardCache hits
    assert warm.token_cache_misses == 0
    assert warm.token_cache_hits == cold.token_cache_misses


def test_remote_fit_vocab_matches_whole_frame(tmp_path):
    d = write_shards(tmp_path, fuzz_records(9, 40), n_files=3)
    whole_ds = chain(d)
    whole_ds.collect()
    vocab_whole = whole_ds.fit_vocab(vocab_size=64)
    ds = chain(d).workers(2, remote=dict(FAST))
    vocab_remote = ds.fit_vocab(vocab_size=64)
    assert vocab_remote.itos == vocab_whole.itos


def test_remote_iter_batches_matches_thread(tmp_path):
    d = write_shards(tmp_path, fuzz_records(10, 40), n_files=3)

    def batches(ds):
        out = []
        for b in ds.iter_batches(epochs=1):
            out.append({k: v.copy() for k, v in b.items()})
        return out

    base = chain(d)
    tok = base.fit_vocab(vocab_size=128)
    # drop_remainder=False: with a partial final batch allowed, the row
    # multiset over the epoch is executor-invariant (drop_remainder would
    # discard rows chosen by nondeterministic shard arrival order)
    thread_ds = (
        chain(d)
        .tokenize(tok, SPECS)
        .batch(8, seed=3, drop_remainder=False)
        .prefetch(2)
        .workers(2, executor="thread")
    )
    remote_ds = (
        chain(d)
        .tokenize(tok, SPECS)
        .batch(8, seed=3, drop_remainder=False)
        .prefetch(2)
        .workers(2, remote=dict(FAST))
    )
    want, got = batches(thread_ds), batches(remote_ds)

    def flat(bs):
        # shard arrival order is nondeterministic under work stealing, so
        # compare the row multiset across the epoch
        return sorted(
            tuple(b[k][i].tobytes() for k in sorted(b))
            for b in bs
            for i in range(len(next(iter(b.values()))))
        )

    assert flat(got) == flat(want)


def test_make_executor_remote_selection_and_dedup_fallback(tmp_path):
    d = write_shards(tmp_path, fuzz_records(11, 12), n_files=2)
    ds = chain(d)
    program = optimized_program(ds)
    shards = ing.list_shards([d])
    ex = EX.make_executor(
        shards, program, workers=2, executor="remote", remote=dict(FAST)
    )
    try:
        assert ex.name == "remote"
    finally:
        ex.stop()
    # env-var selection
    os.environ["REPRO_EXECUTOR"] = "remote"
    try:
        ex = EX.make_executor(shards, program, workers=2, remote=dict(FAST))
        try:
            assert ex.name == "remote"
        finally:
            ex.stop()
    finally:
        del os.environ["REPRO_EXECUTOR"]
    # cross-shard dedup needs shared state: silently falls back to threads
    dedup_ds = chain(d).drop_duplicates(FIELDS)
    dedup_prog = optimized_program(dedup_ds)
    ex = EX.make_executor(shards, dedup_prog, workers=2, executor="remote")
    assert ex.name == "thread"
    ex.stop()


def test_remote_empty_corpus(tmp_path):
    d = write_shards(tmp_path, [], n_files=2)
    ds = chain(d)
    program = optimized_program(ds)
    shards = ing.list_shards([d])
    ex = remote_executor(shards, program)
    assert executor_records(ex) == []


# ---------------------------------------------------------------------------
# fault injection: death is a throughput event, never a correctness event
# ---------------------------------------------------------------------------


def test_kill_one_worker_mid_epoch_byte_identical(tmp_path):
    """ISSUE acceptance: SIGKILL one of two remote workers after the first
    result; the epoch still completes and the token batches are
    byte-identical to the thread executor's."""
    d = write_shards(tmp_path, fuzz_records(12, 60), n_files=6)
    ds = chain(d)
    frame_nodes, _ = P.split_plan(ds.plan)
    frame, _ = P.execute_frame_plan(frame_nodes, final_schema=ds.schema)
    tok = WordTokenizer.fit(
        [(v or "") for col in FIELDS for v in frame[col]], vocab_size=256
    )
    shards = ing.list_shards([d])
    program = token_program(ds, tok)
    want = token_row_multiset(
        executor_tokens(EX.ThreadShardExecutor(shards, program, workers=2))
    )

    ex = remote_executor(shards, program)
    assert len(ex.workers) == 2
    got = []
    it = iter(ex)
    got.append(next(it).tokens)  # first shard landed: both workers are up
    os.kill(ex.workers[0].pid, signal.SIGKILL)
    for res in it:
        got.append(res.tokens)
    ex.stop()
    assert token_row_multiset(got) == want
    assert ex.workers[0].poll() == -signal.SIGKILL  # it really died


def test_all_workers_dead_raises(tmp_path):
    d = write_shards(tmp_path, fuzz_records(13, 30), n_files=3)
    ds = chain(d)
    program = optimized_program(ds)
    shards = ing.list_shards([d])
    ex = remote_executor(shards, program, workers=2)
    for p in ex.workers:
        os.kill(p.pid, signal.SIGKILL)
    with pytest.raises(RuntimeError, match="remote shard workers exited"):
        list(ex)
    ex.stop()


def test_worker_exception_fails_fast(tmp_path):
    d = write_shards(tmp_path, fuzz_records(14, 12), n_files=2)
    ds = chain(d)
    program = optimized_program(ds)
    shards = [Path(s) for s in ing.list_shards([d])]
    shards[1].unlink()  # vanished shard: the coordinator's read raises
    ex = remote_executor(shards, program, workers=1)
    with pytest.raises(RuntimeError):
        list(ex)
    ex.stop()


def test_coordinator_reassigns_after_tcp_eof(tmp_path):
    """Protocol-level reassignment without real worker processes: a fake
    worker takes a task and drops the connection; a second fake worker
    must then be offered the same shard."""
    d = write_shards(tmp_path, fuzz_records(15, 8), n_files=1)
    ds = chain(d)
    program = optimized_program(ds)
    shards = ing.list_shards([d])
    coord = Coordinator(shards, program, lease_s=60.0)
    try:
        host, port = coord.address

        def dial(worker_id):
            s = socket.create_connection((host, port), timeout=5.0)
            send_frame(s, "hello", {"worker_id": worker_id})
            kind, meta, payload = recv_frame(s)
            assert kind == "program"
            return s

        flaky = dial("flaky")
        kind, meta, _ = recv_frame(flaky)  # the task frame
        assert kind == "task" and meta["shard_index"] == 0
        flaky.close()  # die mid-task: EOF → lease released

        steady = dial("steady")
        kind, meta, _ = recv_frame(steady)
        assert kind == "task" and meta["shard_index"] == 0  # stolen
        steady.close()
    finally:
        coord.stop()


def test_stale_heartbeat_triggers_reassignment(tmp_path):
    """A connected-but-wedged worker (beats once, then stops) must have
    its socket closed by the monitor so its lease requeues."""
    d = write_shards(tmp_path, fuzz_records(16, 8), n_files=1)
    ds = chain(d)
    program = optimized_program(ds)
    shards = ing.list_shards([d])
    hb_dir = tmp_path / "beats"
    hb_dir.mkdir()
    coord = Coordinator(
        shards,
        program,
        lease_s=60.0,  # lease alone won't expire within the test
        heartbeat_dir=hb_dir,
        heartbeat_timeout=0.3,
    )
    try:
        host, port = coord.address
        wedged = socket.create_connection((host, port), timeout=5.0)
        send_frame(wedged, "hello", {"worker_id": "wedged"})
        kind, _, _ = recv_frame(wedged)
        assert kind == "program"
        Heartbeat(heartbeat_path(hb_dir, "wedged"), interval_s=0.0).beat(
            0, force=True
        )
        recv_frame(wedged)  # take the task, then wedge (never beat again)
        deadline = time.time() + 10.0
        while coord.worker_count() and time.time() < deadline:
            time.sleep(0.05)
        assert coord.worker_count() == 0  # monitor evicted the wedged worker
        # and its shard is pending again for the next worker
        assert coord.leases.acquire("fresh", timeout=1.0) == 0
    finally:
        coord.stop()


def test_stop_terminates_workers_promptly(tmp_path):
    d = write_shards(tmp_path, fuzz_records(17, 30), n_files=3)
    ds = chain(d)
    program = optimized_program(ds)
    shards = ing.list_shards([d])
    ex = remote_executor(shards, program)
    next(iter(ex))  # abandon mid-epoch
    ex.stop()
    for p in ex.workers:
        assert p.poll() is not None  # no zombie worker processes
    ex.stop()  # idempotent
