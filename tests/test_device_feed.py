"""DeviceFeed / overlap-profiler suite: exact idle accounting under a fake
clock, fixed-grid compile-once behavior, donated-buffer safety, and the
end-to-end ``make_input_pipeline(overlap=True)`` wiring.

The integration test streams a real Dataset chain, so it runs through
whichever shard executor the CI leg selects (REPRO_EXECUTOR: thread,
process, or remote) — the feed is executor-agnostic by construction."""

import threading
import time

import jax
import numpy as np
import pytest

from repro.core.device_pipeline import BucketGrid, DeviceFeed
from repro.data.tokenizer import PAD


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def advance(self, dt):
        self.t += dt

    def __call__(self):
        return self.t


def _batch(i, rows=4, width=8):
    return {"x": np.full((rows, width), i + 1, dtype=np.int32)}


# ---------------------------------------------------------------------------
# Overlap accounting
# ---------------------------------------------------------------------------


def test_idle_fraction_math_exact_under_fake_clock():
    """Synchronous feed (prefetch=0) + fake clock: a producer that takes
    2s/batch against a 6s device step gives exactly known accounting.
    The first batch's wait is startup (pipeline fill), not idle."""
    clock = FakeClock()

    def slow_src(n=4):
        for i in range(n):
            clock.advance(2.0)  # host preprocessing time per batch
            yield _batch(i)

    feed = DeviceFeed(
        slow_src(), prefetch=0, device_put=lambda x: x, clock=clock
    )
    for batch in feed:
        with feed.step(batch):
            clock.advance(6.0)  # device compute time per step
    r = feed.report()
    assert r.steps == 4
    assert r.startup_s == pytest.approx(2.0)
    assert r.host_wait_s == pytest.approx(6.0)  # 3 post-startup waits
    assert r.device_s == pytest.approx(24.0)
    assert r.starved_steps == 3
    assert r.device_idle_fraction == pytest.approx(6.0 / 30.0)


def test_fast_producer_zero_idle():
    """When the host is instant on the fake clock, idle fraction is 0."""
    clock = FakeClock()
    feed = DeviceFeed(
        iter([_batch(i) for i in range(5)]),
        prefetch=0,
        device_put=lambda x: x,
        clock=clock,
    )
    for batch in feed:
        with feed.step(batch):
            clock.advance(3.0)
    r = feed.report()
    assert r.steps == 5
    assert r.host_wait_s == 0.0
    assert r.starved_steps == 0
    assert r.device_idle_fraction == 0.0


def test_slow_producer_increments_starvation_threaded():
    """Threaded mode: a producer gated on an event starves the feed; the
    starved step lands in the report and in the loader's queue stats."""
    gate = threading.Event()

    def src():
        # three ungated batches: the feed's first yield needs them (the
        # loader and the feed each hold one double-buffer pending)
        yield _batch(0)
        yield _batch(1)
        yield _batch(2)
        gate.wait(timeout=5.0)
        time.sleep(0.02)  # real stall, well over starvation_eps
        yield _batch(3)

    feed = DeviceFeed(src(), prefetch=2, device_put=lambda x: x)
    it = iter(feed)
    first = next(it)  # batch 0, no gated pull needed
    assert int(np.asarray(first["x"])[0, 0]) == 1
    done = []
    t = threading.Thread(target=lambda: done.extend(it), daemon=True)
    t.start()
    # the feed is now blocked pulling the gated batch 3
    deadline = time.monotonic() + 5.0
    while time.monotonic() < deadline and not feed.loader_stats.starvation:
        time.sleep(0.002)
    gate.set()
    t.join(timeout=5.0)
    assert len(done) == 3
    r = feed.report()
    assert r.starved_steps >= 1
    assert r.host_wait_s > 0.0
    assert feed.loader_stats.starvation >= 1


# ---------------------------------------------------------------------------
# Fixed bucket grid: snap + compile-once
# ---------------------------------------------------------------------------


def test_grid_snap_pads_rows_and_widths():
    grid = BucketGrid(4, {"x": (8, 16)})
    snapped = grid.snap({"x": np.ones((2, 5), np.int32), "y": np.arange(2)})
    assert snapped["x"].shape == (4, 8)
    assert snapped["y"].shape == (4,)
    # payload prefix preserved, PAD fill elsewhere
    assert (snapped["x"][:2, :5] == 1).all()
    assert (snapped["x"][2:] == PAD).all()
    assert (snapped["x"][:2, 5:] == PAD).all()
    assert grid.n_cells == 2


def test_grid_rejects_off_grid_width():
    grid = BucketGrid(4, {"x": (8, 16)})
    with pytest.raises(ValueError, match="beyond the top bucket"):
        grid.snap({"x": np.ones((4, 32), np.int32)})


def test_fixed_grid_jit_compiles_once_per_cell():
    """An epoch of ragged batches snapped onto a 2-rung grid triggers at
    most 2 traces of the jit'd step; without the grid every distinct width
    would compile separately."""
    traces = [0]

    @jax.jit
    def step(x):
        traces[0] += 1
        return x.sum()

    widths = [3, 5, 8, 9, 12, 16, 6, 14, 8, 11]
    rows = [4, 4, 4, 3, 4, 2, 4, 4, 1, 4]
    batches = [_batch(i, rows=r, width=w) for i, (r, w) in enumerate(zip(rows, widths))]
    assert len({(r, w) for r, w in zip(rows, widths)}) > 2  # ragged input

    feed = DeviceFeed(
        iter(batches), grid=BucketGrid(4, {"x": (8, 16)}), prefetch=2
    )
    n = 0
    for batch in feed:
        with feed.step(batch):
            jax.block_until_ready(step(batch["x"]))
        n += 1
    assert n == len(batches)
    assert traces[0] == 2, "one compilation per grid cell, not per batch"
    assert feed.report().steps == n


def test_snapped_batches_preserve_payload():
    grid = BucketGrid(3, {"x": (4,)})
    feed = DeviceFeed(
        iter([{"x": np.array([[7, 8]], np.int32)}]),
        grid=grid,
        prefetch=0,
        device_put=lambda x: x,
    )
    [batch] = list(feed)
    np.testing.assert_array_equal(
        batch["x"],
        np.array([[7, 8, PAD, PAD], [PAD] * 4, [PAD] * 4], np.int32),
    )


# ---------------------------------------------------------------------------
# Donation safety
# ---------------------------------------------------------------------------


def test_reuse_after_donate_raises():
    feed = DeviceFeed(
        iter([_batch(0), _batch(1)]), prefetch=0, device_put=lambda x: x
    )
    seen = []
    for batch in feed:
        _ = batch["x"]  # reads inside the step window are fine
        with feed.step(batch):
            seen.append(batch["x"].sum())
        with pytest.raises(RuntimeError, match="reuse after donate"):
            batch["x"]
        with pytest.raises(RuntimeError, match="reuse after donate"):
            batch.arrays
    assert len(seen) == 2


def test_donate_false_allows_rereads():
    feed = DeviceFeed(
        iter([_batch(0)]), prefetch=0, device_put=lambda x: x, donate=False
    )
    [batch] = list(feed)
    with feed.step(batch):
        pass
    assert batch["x"].shape == (4, 8)  # no donation: re-read is legal


# ---------------------------------------------------------------------------
# Double buffering at the device boundary
# ---------------------------------------------------------------------------


def test_transfer_of_next_batch_precedes_yield():
    events = []

    def fake_put(x):
        events.append(("put", int(x[0, 0]) - 1))
        return x

    feed = DeviceFeed(
        iter([_batch(i) for i in range(4)]), prefetch=2, device_put=fake_put
    )
    for b in feed:
        events.append(("yield", int(np.asarray(b["x"])[0, 0]) - 1))
    for k in range(3):
        assert events.index(("put", k + 1)) < events.index(("yield", k))


def test_close_joins_pipeline():
    def endless():
        i = 0
        while True:
            yield _batch(i)
            i += 1

    feed = DeviceFeed(endless(), prefetch=2, device_put=lambda x: x)
    it = iter(feed)
    next(it)
    feed.close()
    assert not feed._loader.running


# ---------------------------------------------------------------------------
# End-to-end: plan → bucketed batches → DeviceFeed (executor-agnostic)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def corpus(tmp_path_factory):
    from repro.data.synthetic import write_corpus

    d = tmp_path_factory.mktemp("overlap_corpus")
    write_corpus(d, total_bytes=300_000, n_files=4, seed=21)
    return d


def test_make_input_pipeline_overlap_end_to_end(corpus):
    from repro.core.dataset import Dataset
    from repro.core.expr import abstract_expr, col, title_expr
    from repro.data.batching import seq2seq_specs
    from repro.runtime.train_loop import make_input_pipeline

    keep = col("title").not_empty() & col("abstract").not_empty()
    base = (
        Dataset.from_json_dirs([corpus])
        .where(keep)
        .drop_duplicates()
        .transform(abstract=abstract_expr(), title=title_expr())
        .where(keep)
    )
    tok = base.fit_vocab(vocab_size=500)
    pipe = (
        base.tokenize(tok, seq2seq_specs(max_abstract_len=32, max_title_len=8))
        .batched(
            8,
            shuffle=False,
            bucket_by="encoder_tokens",
            drop_remainder=False,
            pad_to=8,
        )
        .prefetch(2)
    )
    grid = pipe.bucket_grid_spec()
    assert grid is not None and grid.batch_size == 8

    feed = make_input_pipeline(pipe, epochs=1, prefetch=2, overlap=True)
    try:
        steps = 0
        cells = set()
        for batch in feed:
            assert isinstance(batch["encoder_tokens"], jax.Array)
            assert batch["encoder_tokens"].shape[0] == 8
            assert batch["encoder_tokens"].shape[1] in grid.widths["encoder_tokens"]
            cells.add(batch.cell)
            with feed.step(batch):
                jax.block_until_ready(batch["encoder_tokens"].sum())
            steps += 1
    finally:
        feed.close()
    assert steps > 0
    assert len(cells) <= grid.n_cells
    r = feed.report()
    assert r.steps == steps
    assert r.device_s > 0.0


def test_dataset_device_batches_overlap_terminal(corpus):
    from repro.core.dataset import Dataset
    from repro.core.device_pipeline import DeviceFeed as DF
    from repro.core.expr import abstract_expr, col, title_expr

    keep = col("title").not_empty() & col("abstract").not_empty()
    base = (
        Dataset.from_json_dirs([corpus])
        .where(keep)
        .transform(abstract=abstract_expr(), title=title_expr())
        .where(keep)
    )
    tok = base.fit_vocab(vocab_size=300)
    feed = base.tokenize(tok, col="abstract", max_len=16).batch(
        4, shuffle=False, drop_remainder=False, pad_to=4
    ).prefetch(2).device_batches(overlap=True)
    assert isinstance(feed, DF)
    try:
        n = sum(1 for _ in feed)
    finally:
        feed.close()
    assert n > 0
