"""Chunked mLSTM Pallas kernel vs sequential oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.mlstm_chunk.ops import mlstm_chunk_op
from repro.kernels.mlstm_chunk.ref import mlstm_chunk_ref

KEY = jax.random.PRNGKey(0)

CASES = [
    # (b, s, H, dh, chunk)
    (1, 128, 2, 32, 32),
    (2, 128, 4, 16, 64),
    (1, 96, 2, 32, 32),   # padded seq (96 % 32 == 0 but != chunk mult of 64)
    (2, 100, 2, 16, 32),  # non-divisible seq -> padding path
]


@pytest.mark.parametrize("case", CASES, ids=[str(c) for c in CASES])
def test_kernel_matches_sequential_oracle(case):
    b, s, H, dh, chunk = case
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, s, H, dh)) * 0.5
    k = jax.random.normal(ks[1], (b, s, H, dh)) * 0.5
    v = jax.random.normal(ks[2], (b, s, H, dh)) * 0.5
    ig = jax.random.normal(ks[3], (b, s, H))
    fg = jax.random.normal(ks[4], (b, s, H)) + 2.0

    out = mlstm_chunk_op(q, k, v, ig, fg, chunk=chunk, interpret=True)

    def pack(x):
        return jnp.moveaxis(x, 2, 1).reshape(b * H, s, *x.shape[3:])

    ref = mlstm_chunk_ref(pack(q), pack(k), pack(v), pack(ig), pack(fg))
    ref = jnp.moveaxis(ref.reshape(b, H, s, dh), 1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_kernel_matches_model_chunked_path():
    """Kernel == the model's jnp chunked formulation on model-derived
    q/k/v/gates (end-to-end consistency of the three implementations)."""
    from repro.configs import get_smoke
    from repro.models import xlstm as XL

    cfg = get_smoke("xlstm_1_3b")
    p = XL.init_mlstm(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 9), (2, 128, cfg.d_model)) * 0.5
    q, k, v, i_t, f_t, z = XL._mlstm_inputs(p, x, cfg)
    out = mlstm_chunk_op(q, k, v, i_t, f_t, chunk=64, interpret=True)

    b, s = x.shape[:2]
    H = cfg.n_heads
    dh = q.shape[-1]

    def pack(a):
        return jnp.moveaxis(a, 2, 1).reshape(b * H, s, *a.shape[3:])

    ref = mlstm_chunk_ref(pack(q), pack(k), pack(v), pack(i_t), pack(f_t))
    ref = jnp.moveaxis(ref.reshape(b, H, s, dh), 1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
