"""Serving path: admission backpressure, ring-cache accounting, end-to-end
text-in/tokens-out over a row program, and the R005 hot-path contract.

The decode-level tests run against a deterministic echo model (argmax of a
one-hot is the input token) so slot/refill/admission mechanics are checked
without paying for a real LM; one smoke test drives the full stack with a
real smoke-config LM.
"""

import dataclasses
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.contracts import ALL_RULES, lint_contracts
from repro.configs import get_smoke
from repro.core.dataset import Dataset
from repro.core.expr import abstract_expr, col
from repro.data.batching import TokenSpec
from repro.models.lm import LM
from repro.runtime.serve_loop import (
    AdmissionQueue,
    RingCache,
    ServeStats,
    TextRequest,
    serve_text,
)

# -- fixtures ---------------------------------------------------------------

CORPUS = [
    {"abstract": "deep learning methods for scholarly metadata extraction"},
    {"abstract": "spark pipelines accelerate large corpus preprocessing work"},
    {"abstract": "attention models summarize scientific abstracts neatly"},
    {"abstract": "tokenization vocabulary coverage affects downstream quality"},
    {"abstract": "distributed executors shard the cleaning workload evenly"},
    {"abstract": "ring buffers bound the decode cache memory footprint"},
]


@pytest.fixture(scope="module")
def row_program(tmp_path_factory):
    d = tmp_path_factory.mktemp("serve_corpus")
    with open(d / "shard-0.jsonl", "w", encoding="utf-8") as f:
        for r in CORPUS:
            f.write(json.dumps(r) + "\n")
    ds = (
        Dataset.from_json_dirs([d], fields=("abstract",))
        .where(col("abstract").not_empty())
        .transform(abstract=abstract_expr())
    )
    tok = ds.fit_vocab(vocab_size=200)
    rp = (
        ds.tokenize(tok, [TokenSpec("abstract", 16)])
        .batched(2)
        .prefetch(2)
        .row_program()
    )
    return rp, tok


class _EchoModel:
    """argmax(one_hot(t)) == t: prefill emits the prompt's last token and
    decode repeats it, making every serve run deterministic and instant."""

    def init_decode_state(self, b, max_seq, cache_dtype=jnp.float32):
        return jnp.zeros((b,), jnp.int32)

    def decode_step(self, params, tokens, state, pos):
        return jax.nn.one_hot(tokens, 512, dtype=jnp.float32), state


# -- unit: admission queue --------------------------------------------------


def test_admission_queue_sheds_on_arrival():
    q = AdmissionQueue(maxsize=2)
    assert q.offer("a") and q.offer("b")
    assert not q.offer("c")  # full: shed, not queued
    assert (q.admitted, q.rejected, len(q)) == (2, 1, 2)
    assert q.pop() == "a"  # FIFO
    assert q.offer("d")  # slot freed
    assert q.pop() == "b" and q.pop() == "d" and q.pop() is None
    with pytest.raises(ValueError):
        AdmissionQueue(maxsize=0)


# -- unit: ring cache -------------------------------------------------------


def test_ring_cache_fifo_eviction_and_accounting():
    c = RingCache(slots=2)
    assert c.get("k1") is None  # miss
    c.put("k1", [1, 2])
    c.put("k2", [3])
    assert c.get("k1") == [1, 2]  # hit
    c.put("k3", [4])  # evicts k1 (oldest inserted)
    assert len(c) == 2
    assert c.get("k1") is None
    assert c.get("k3") == [4]
    assert (c.hits, c.misses, c.evictions) == (2, 2, 1)
    # updating an existing key neither grows nor evicts
    c.put("k2", [5, 6])
    assert (len(c), c.evictions) == (2, 1)
    assert c.get("k2") == [5, 6]
    # returned lists are copies: mutating one can't poison the cache
    c.get("k2").append(99)
    assert c.get("k2") == [5, 6]
    with pytest.raises(ValueError):
        RingCache(slots=0)


# -- serve_text over the echo model ----------------------------------------


def test_serve_text_backpressure_rejects_overflow(row_program):
    rp, _ = row_program
    reqs = [TextRequest(i, CORPUS[i]["abstract"], max_new=3) for i in range(6)]
    stats = ServeStats()
    results = serve_text(
        _EchoModel(), None, rp, reqs, slots=2, max_seq=32, queue_size=2, stats=stats
    )
    assert stats.admitted == 2
    assert stats.rejected == 4
    assert stats.served == 2
    assert sorted(results) == [0, 1]  # shed requests get no entry at all
    assert all(len(v) == 3 for v in results.values())
    assert sorted(stats.latency_s) == [0, 1]
    assert stats.preprocess_s > 0.0


def test_serve_text_slots_refill_until_drained(row_program):
    rp, _ = row_program
    reqs = [TextRequest(i, CORPUS[i % len(CORPUS)]["abstract"]) for i in range(6)]
    results = serve_text(_EchoModel(), None, rp, reqs, slots=2, max_seq=32)
    assert sorted(results) == list(range(6))  # 2 slots still serve all 6


def test_serve_text_filtered_request_answers_empty(row_program):
    rp, _ = row_program
    reqs = [
        TextRequest(0, CORPUS[0]["abstract"], max_new=2),
        TextRequest(1, ""),  # dropped by where(not_empty)
        TextRequest(2, "a i x !"),  # cleans to an empty prompt
    ]
    stats = ServeStats()
    results = serve_text(_EchoModel(), None, rp, reqs, slots=2, max_seq=32, stats=stats)
    assert results[1] == [] and results[2] == []
    assert stats.filtered == 2
    assert stats.served == 1 and len(results[0]) == 2


def test_serve_text_ring_cache_round_trip(row_program):
    rp, _ = row_program
    cache = RingCache(slots=8)
    stats = ServeStats()
    first = serve_text(
        _EchoModel(),
        None,
        rp,
        [TextRequest(0, CORPUS[0]["abstract"]), TextRequest(1, CORPUS[1]["abstract"])],
        slots=2,
        max_seq=32,
        cache=cache,
        stats=stats,
    )
    assert (stats.cache_hits, stats.cache_misses) == (0, 2)
    # repeat one prompt: completes from the cache, byte-identical answer
    again = serve_text(
        _EchoModel(),
        None,
        rp,
        [TextRequest(7, CORPUS[0]["abstract"])],
        slots=2,
        max_seq=32,
        cache=cache,
        stats=stats,
    )
    assert again[7] == first[0]
    assert (stats.cache_hits, stats.cache_misses) == (1, 2)
    assert cache.hits == 1 and cache.misses == 2
    # cache keys bind the program fingerprint: a different program misses
    rp2 = dataclasses.replace(rp, fingerprint="other")
    miss = serve_text(
        _EchoModel(),
        None,
        rp2,
        [TextRequest(9, CORPUS[0]["abstract"])],
        slots=2,
        max_seq=32,
        cache=cache,
        stats=stats,
    )
    assert stats.cache_misses == 3 and miss[9] == first[0]


# -- end-to-end with a real smoke LM ---------------------------------------


def test_serve_text_end_to_end_smoke(row_program):
    rp, tok = row_program
    cfg = dataclasses.replace(get_smoke("recurrentgemma_9b"), vocab_size=len(tok.itos))
    model = LM(cfg, remat=False, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    reqs = [TextRequest(i, CORPUS[i]["abstract"], max_new=4) for i in range(3)]
    stats = ServeStats()
    results = serve_text(
        model, params, rp, reqs, slots=2, max_seq=32, stats=stats
    )
    assert sorted(results) == [0, 1, 2]
    for out in results.values():
        assert 1 <= len(out) <= 4
        assert all(0 <= t < cfg.vocab_size for t in out)
    assert stats.served == 3
    assert stats.decode_s > 0.0
    # greedy decode is deterministic: a re-serve reproduces every token
    rerun = serve_text(model, params, rp, reqs, slots=2, max_seq=32)
    assert rerun == results


# -- R005: the serve hot path stays free of shard machinery -----------------

_PKG_ROOT = Path(__file__).resolve().parents[1] / "src" / "repro"


def test_serve_hot_path_contract_is_clean():
    assert "R005" in ALL_RULES
    diags = lint_contracts(_PKG_ROOT, rules=["R005"])
    assert diags == [], [d.message for d in diags]


def test_r005_flags_shard_machinery_imports(tmp_path):
    pkg = tmp_path / "repro"
    (pkg / "runtime").mkdir(parents=True)
    (pkg / "core").mkdir()
    (pkg / "runtime" / "serve_loop.py").write_text(
        "import multiprocessing\nfrom repro.core import executor\n"
    )
    (pkg / "runtime" / "row_program.py").write_text("x = 1\n")
    (pkg / "core" / "executor.py").write_text("POOL = None\n")
    diags = lint_contracts(pkg, rules=["R005"])
    codes = [d.code for d in diags]
    assert codes and set(codes) == {"R005"}
    msgs = " ".join(d.message for d in diags)
    assert "multiprocessing" in msgs
    assert "core.executor" in msgs
