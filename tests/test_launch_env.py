"""Unit tests for the tuned launch environment (repro.launch.env)."""

import os

import pytest

from repro.launch import env as launch_env


def test_tuned_env_baseline_flags():
    e = launch_env.tuned_env(tcmalloc=False, base={})
    assert e["TF_CPP_MIN_LOG_LEVEL"] == "4"
    assert e["JAX_DEFAULT_DTYPE_BITS"] == "32"
    assert "LD_PRELOAD" not in e
    assert "XLA_FLAGS" not in e  # no device pin requested


def test_host_device_count_pins_xla_flag():
    e = launch_env.tuned_env(8, tcmalloc=False, base={})
    assert e["XLA_FLAGS"] == "--xla_force_host_platform_device_count=8"
    with pytest.raises(ValueError, match="host_device_count"):
        launch_env.tuned_env(0, tcmalloc=False, base={})


def test_xla_flags_merge_preserves_and_overrides():
    merged = launch_env.merge_xla_flags(
        "--xla_step_marker_location=1 --xla_force_host_platform_device_count=2",
        "--xla_force_host_platform_device_count=48",
    )
    toks = merged.split()
    assert "--xla_step_marker_location=1" in toks
    assert "--xla_force_host_platform_device_count=48" in toks
    assert "--xla_force_host_platform_device_count=2" not in toks


def test_tuned_env_merges_existing_xla_flags():
    base = {"XLA_FLAGS": "--xla_step_marker_location=1"}
    e = launch_env.tuned_env(4, tcmalloc=False, base=base)
    assert e["XLA_FLAGS"] == (
        "--xla_step_marker_location=1 --xla_force_host_platform_device_count=4"
    )


def test_tcmalloc_preload_when_present(tmp_path, monkeypatch):
    lib = tmp_path / "libtcmalloc.so.4"
    lib.write_bytes(b"")
    monkeypatch.setattr(
        launch_env, "TCMALLOC_CANDIDATES", (str(tmp_path / "missing"), str(lib))
    )
    e = launch_env.tuned_env(base={})
    assert e["LD_PRELOAD"] == str(lib)
    assert (
        e["TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD"]
        == launch_env.TCMALLOC_REPORT_THRESHOLD
    )


def test_tcmalloc_absent_no_preload(monkeypatch):
    monkeypatch.setattr(launch_env, "TCMALLOC_CANDIDATES", ("/nonexistent/lib.so",))
    e = launch_env.tuned_env(base={})
    assert "LD_PRELOAD" not in e
    assert "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD" not in e


def test_apply_respects_user_values(monkeypatch):
    monkeypatch.setenv("TF_CPP_MIN_LOG_LEVEL", "0")
    monkeypatch.delenv("JAX_DEFAULT_DTYPE_BITS", raising=False)
    applied = launch_env.apply({"TF_CPP_MIN_LOG_LEVEL": "4", "JAX_DEFAULT_DTYPE_BITS": "32"})
    assert "TF_CPP_MIN_LOG_LEVEL" not in applied  # user export wins
    assert os.environ["TF_CPP_MIN_LOG_LEVEL"] == "0"
    assert applied["JAX_DEFAULT_DTYPE_BITS"] == "32"
    assert os.environ["JAX_DEFAULT_DTYPE_BITS"] == "32"


def test_apply_overwrite(monkeypatch):
    monkeypatch.setenv("TF_CPP_MIN_LOG_LEVEL", "0")
    applied = launch_env.apply({"TF_CPP_MIN_LOG_LEVEL": "4"}, overwrite=True)
    assert applied == {"TF_CPP_MIN_LOG_LEVEL": "4"}
    assert os.environ["TF_CPP_MIN_LOG_LEVEL"] == "4"


def test_render_exports_quoted_and_sorted():
    out = launch_env.render_exports(
        {"B_FLAG": "a b", "A_FLAG": "plain"}
    )
    assert out.splitlines() == ["export A_FLAG=plain", "export B_FLAG='a b'"]


def test_main_prints_exports(capsys, monkeypatch):
    monkeypatch.setattr(launch_env, "TCMALLOC_CANDIDATES", ("/nonexistent/lib.so",))
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    assert launch_env.main(["--devices", "16"]) == 0
    out = capsys.readouterr().out
    # shlex.quote leaves the flag bare (no shell-special characters)
    assert "export XLA_FLAGS=--xla_force_host_platform_device_count=16" in out
    assert "export TF_CPP_MIN_LOG_LEVEL=4" in out
