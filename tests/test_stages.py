"""Unit + property tests: every ``col()`` expression verb == its row oracle.

Migrated from the deprecated ``Stage`` shims (PR-4): the expression IR is
the engine's native verb set, so the vectorized-vs-oracle contract is
pinned directly on ``col()`` chains; the shims are covered only by the
deprecation tests at the bottom.
"""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # property tests skip; example/deprecation tests run
    HAVE_HYPOTHESIS = False

from repro.core import bytesops as B
from repro.core import expr as E
from repro.core.expr import ENGLISH_STOPWORDS, abstract_expr, col

# -- row-wise oracles (semantics of each verb, one row at a time) -----------

_ASCII_LOWER_TABLE = {c: c + 32 for c in range(ord("A"), ord("Z") + 1)}


def _lower_row(row):
    # ASCII-only lowering to match the byte LUT exactly.
    return row.translate(_ASCII_LOWER_TABLE)


def _strip_spans_row(row, open_c, close_c):
    out = []
    depth = 0
    for ch in row:
        if ch == open_c:
            depth += 1
        elif ch == close_c:
            depth = max(depth - 1, 0)
        elif depth == 0:
            out.append(ch)
    return "".join(out)


def _strip_html_row(row):
    return _strip_spans_row(row, "<", ">")


def _unwanted_row(row):
    row = _strip_spans_row(row, "(", ")")
    for pat, rep in B.CONTRACTIONS:
        row = row.replace(pat.decode(), rep.decode())
    row = "".join(ch if ("a" <= ch <= "z" or ch == " ") else " " for ch in row)
    return " ".join(w for w in row.split(" ") if w)


def _min_word_len_row(n):
    return lambda row: " ".join(w for w in row.split(" ") if len(w) >= n)


def _collapse_row(row):
    return " ".join(w for w in row.split(" ") if w)


_STOPSET = frozenset(ENGLISH_STOPWORDS)


def _stopwords_row(row):
    return " ".join(w for w in row.split(" ") if w and w not in _STOPSET)


# (name, expression chain on column "c", row oracle)
VERBS = [
    ("lower", col("c").lower(), _lower_row),
    ("strip_html", col("c").strip_html(), _strip_html_row),
    (
        "unwanted",
        col("c").strip_parens().expand_contractions().keep_letters().collapse_spaces(),
        _unwanted_row,
    ),
    ("min_word_len-2", col("c").min_word_len(2), _min_word_len_row(2)),
    ("min_word_len-4", col("c").min_word_len(4), _min_word_len_row(4)),
    ("collapse_spaces", col("c").collapse_spaces(), _collapse_row),
    ("remove_stopwords", col("c").remove_stopwords(), _stopwords_row),
]


def chain_ops(expr):
    comp = E.compile_expr(expr)
    assert comp[0] == "chain" and comp[1] == "c"
    return list(comp[2])


def apply_flat(expr, rows):
    return B.unflatten(B.apply_ops(B.flatten(rows), chain_ops(expr)))


EXAMPLES = [
    [],
    [""],
    ["", "", ""],
    ["Hello World"],
    ["a <b>bold</b> move", "no tags here"],
    ["nested (paren (not)) ok" , "x (y) z"],
    ["It's CAN'T won't they've", "she'd we're he's"],
    ["UPPER lower MiXeD 123 !!!", "digits 42 and, punct; here."],
    ["  leading and trailing  ", "multi   spaces    inside"],
    ["a ab abc abcd abcde", "i of the and an it"],
    ["the quick brown fox is over a lazy dog", "will not be removed maybe"],
    ["<p>tag at start</p> mid <i>x</i> end", "(paren at start) mid (y) end"],
    ["word", " ", "  ", "x"],
]


@pytest.mark.parametrize("name,expr,oracle", VERBS, ids=[v[0] for v in VERBS])
@pytest.mark.parametrize("rows", EXAMPLES, ids=range(len(EXAMPLES)))
def test_expr_matches_oracle(name, expr, oracle, rows):
    assert apply_flat(expr, rows) == [oracle(r) for r in rows]


# The canonical abstract-cleaning chain, oracle-composed row by row.
_ABSTRACT_ORACLE = [
    _lower_row,
    _strip_html_row,
    _unwanted_row,
    _stopwords_row,
    _min_word_len_row(2),
]


def test_full_chain_matches_oracle_and_fusion_is_exact_examples():
    for rows in EXAMPLES:
        ops = chain_ops(abstract_expr("c"))
        buf = B.flatten(rows)
        unfused = B.unflatten(B.apply_ops(buf.copy(), ops))
        fused = B.unflatten(B.apply_ops(buf.copy(), B.fuse_ops(ops)))
        oracle = rows
        for fn in _ABSTRACT_ORACLE:
            oracle = [fn(r) for r in oracle]
        assert unfused == oracle
        assert fused == oracle


# -- property tests (hypothesis) --------------------------------------------

if HAVE_HYPOTHESIS:
    # Contract alphabet: no <>() (span delimiters exercised separately with
    # balanced construction), no NUL.
    _plain = st.text(
        alphabet=st.sampled_from("abcdefghij XYZ'.,;:!?0123456789-_/"), max_size=60
    )

    @st.composite
    def _balanced_rows(draw):
        """Rows with balanced, non-nested tag and paren spans around plain
        text."""
        n = draw(st.integers(0, 6))
        rows = []
        for _ in range(n):
            parts = []
            for _ in range(draw(st.integers(0, 4))):
                kind = draw(st.integers(0, 2))
                body = draw(_plain)
                if kind == 0:
                    parts.append(body)
                elif kind == 1:
                    parts.append(f"<{draw(_plain)}>")
                else:
                    parts.append(f"({body})")
            rows.append(" ".join(parts))
        return rows

    @pytest.mark.parametrize(
        "name,expr,oracle", VERBS, ids=[v[0] for v in VERBS]
    )
    @settings(max_examples=60, deadline=None)
    @given(rows=_balanced_rows())
    def test_expr_matches_oracle_property(name, expr, oracle, rows):
        assert apply_flat(expr, rows) == [oracle(r) for r in rows]

    @settings(max_examples=40, deadline=None)
    @given(rows=_balanced_rows())
    def test_full_chain_matches_oracle_and_fusion_is_exact(rows):
        ops = chain_ops(abstract_expr("c"))
        buf = B.flatten(rows)
        unfused = B.unflatten(B.apply_ops(buf.copy(), ops))
        fused = B.unflatten(B.apply_ops(buf.copy(), B.fuse_ops(ops)))
        oracle = rows
        for fn in _ABSTRACT_ORACLE:
            oracle = [fn(r) for r in oracle]
        assert unfused == oracle
        assert fused == oracle


def test_row_count_invariant_on_malformed_spans():
    # malformed rows must never swallow the row separator
    rows = ["open < never closed", "stray > here", "((", "))", "<<>", "fine"]
    for expr in (col("c").strip_html(), col("c").strip_parens().keep_letters()):
        out = apply_flat(expr, rows)
        assert len(out) == len(rows)


def test_wordset_exactness():
    ws = B.WordSet(["the", "a", "themselves", "yourselves", "yourself"])
    rows = ["the them themselves themselvesx a ab yourselves yourself yourselfs"]
    buf = B.remove_stopwords(B.flatten(rows), ws)
    assert B.unflatten(buf) == ["them themselvesx ab yourselfs"]


# -- deprecated Stage shims -------------------------------------------------


def test_stage_construction_warns_deprecation():
    from repro.core.stages import ConvertToLower

    with pytest.warns(DeprecationWarning, match="col\\(\\) expressions"):
        st_ = ConvertToLower("c")
    assert st_.fit(None) is st_  # Spark Transformer protocol still intact


def test_stage_shim_still_matches_expression_path():
    from repro.core.stages import abstract_stages

    rows = ["It's a <b>Deep</b> (hidden) LEARNING Story!", "", "tiny a i"]
    with pytest.warns(DeprecationWarning):
        stages = abstract_stages("c")
    ops = [op for s in stages for op in s.flat_ops()]
    via_stages = B.unflatten(B.apply_ops(B.flatten(rows), ops))
    via_expr = apply_flat(abstract_expr("c"), rows)
    assert via_stages == via_expr
