"""Unit + property tests: every vectorized stage == its row-wise oracle."""

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import bytesops as B
from repro.core.stages import (
    ConvertToLower,
    RemoveHTMLTags,
    RemoveShortWords,
    RemoveUnwantedCharacters,
    StopWordsRemover,
    Tokenizer,
    abstract_stages,
)

ALL_STAGES = [
    ConvertToLower("c"),
    RemoveHTMLTags("c"),
    RemoveUnwantedCharacters("c"),
    RemoveShortWords("c", threshold=1),
    RemoveShortWords("c", threshold=3),
    Tokenizer("c"),
    StopWordsRemover("c"),
]


def apply_flat(stage, rows):
    return B.unflatten(stage.transform_flat(B.flatten(rows)))


def apply_oracle(stage, rows):
    return [stage.transform_row(r) for r in rows]


EXAMPLES = [
    [],
    [""],
    ["", "", ""],
    ["Hello World"],
    ["a <b>bold</b> move", "no tags here"],
    ["nested (paren (not)) ok" , "x (y) z"],
    ["It's CAN'T won't they've", "she'd we're he's"],
    ["UPPER lower MiXeD 123 !!!", "digits 42 and, punct; here."],
    ["  leading and trailing  ", "multi   spaces    inside"],
    ["a ab abc abcd abcde", "i of the and an it"],
    ["the quick brown fox is over a lazy dog", "will not be removed maybe"],
    ["<p>tag at start</p> mid <i>x</i> end", "(paren at start) mid (y) end"],
    ["word", " ", "  ", "x"],
]


@pytest.mark.parametrize("stage", ALL_STAGES, ids=lambda s: f"{type(s).__name__}-{getattr(s,'threshold','')}")
@pytest.mark.parametrize("rows", EXAMPLES, ids=range(len(EXAMPLES)))
def test_stage_matches_oracle(stage, rows):
    assert apply_flat(stage, rows) == apply_oracle(stage, rows)


# -- property tests ---------------------------------------------------------

# Contract alphabet: no <>() (span delimiters exercised separately with
# balanced construction), no NUL.
_plain = st.text(
    alphabet=st.sampled_from("abcdefghij XYZ'.,;:!?0123456789-_/"), max_size=60
)


@st.composite
def _balanced_rows(draw):
    """Rows with balanced, non-nested tag and paren spans around plain text."""
    n = draw(st.integers(0, 6))
    rows = []
    for _ in range(n):
        parts = []
        for _ in range(draw(st.integers(0, 4))):
            kind = draw(st.integers(0, 2))
            body = draw(_plain)
            if kind == 0:
                parts.append(body)
            elif kind == 1:
                parts.append(f"<{draw(_plain)}>")
            else:
                parts.append(f"({body})")
        rows.append(" ".join(parts))
    return rows


@pytest.mark.parametrize("stage", ALL_STAGES, ids=lambda s: f"{type(s).__name__}-{getattr(s,'threshold','')}")
@settings(max_examples=60, deadline=None)
@given(rows=_balanced_rows())
def test_stage_matches_oracle_property(stage, rows):
    assert apply_flat(stage, rows) == apply_oracle(stage, rows)


@settings(max_examples=40, deadline=None)
@given(rows=_balanced_rows())
def test_full_chain_matches_oracle_and_fusion_is_exact(rows):
    stages = abstract_stages("c") + []
    buf = B.flatten(rows)
    ops = [op for s in stages for op in s.flat_ops()]
    unfused = B.unflatten(B.apply_ops(buf.copy(), ops))
    fused = B.unflatten(B.apply_ops(buf.copy(), B.fuse_ops(ops)))
    oracle = rows
    for s in stages:
        oracle = [s.transform_row(r) for r in oracle]
    assert unfused == oracle
    assert fused == oracle


def test_row_count_invariant_on_malformed_spans():
    # malformed rows must never swallow the row separator
    rows = ["open < never closed", "stray > here", "((", "))", "<<>", "fine"]
    for stage in (RemoveHTMLTags("c"), RemoveUnwantedCharacters("c")):
        out = apply_flat(stage, rows)
        assert len(out) == len(rows)


def test_wordset_exactness():
    ws = B.WordSet(["the", "a", "themselves", "yourselves", "yourself"])
    rows = ["the them themselves themselvesx a ab yourselves yourself yourselfs"]
    buf = B.remove_stopwords(B.flatten(rows), ws)
    assert B.unflatten(buf) == ["them themselvesx ab yourselfs"]


def test_stage_fit_returns_self():
    st_ = ConvertToLower("c")
    assert st_.fit(None) is st_
