"""Ring-buffer KV cache (sliding-window attention): exactness incl. wrap."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models.lm import LM


def test_ring_cache_wraps_exactly():
    """Decode far past the window: ring cache logits == full forward."""
    cfg = get_smoke("recurrentgemma_9b")  # window = 16
    assert cfg.window == 16
    model = LM(cfg, remat=False, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 40  # 2.5x window -> multiple wraps
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 4, cfg.vocab_size)
    full_logits, _ = model.forward(params, {"tokens": toks})

    state = model.init_decode_state(b, s, cache_dtype=jnp.float32)
    # verify the cache really is ring-sized
    kv_leaves = [x for x in jax.tree.leaves(state) if x.ndim == 5]  # stacked KV
    assert all(x.shape[2] == cfg.window for x in kv_leaves)

    step = jax.jit(model.decode_step)
    for t in range(s):
        lg, state = step(params, toks[:, t : t + 1], state, jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full_logits[:, t]), rtol=2e-4, atol=2e-4,
            err_msg=f"position {t}",
        )


def test_ring_cache_block_prefill_then_decode():
    """Block prefill (s > window) into the ring, then incremental decode."""
    cfg = get_smoke("recurrentgemma_9b")
    model = LM(cfg, remat=False, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    b, s_prompt, s_total = 2, 24, 32
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s_total), 4, cfg.vocab_size)
    full_logits, _ = model.forward(params, {"tokens": toks})

    state = model.init_decode_state(b, s_total, cache_dtype=jnp.float32)
    step = jax.jit(model.decode_step)
    lg, state = step(params, toks[:, :s_prompt], state, jnp.int32(0))
    np.testing.assert_allclose(
        np.asarray(lg[:, -1]), np.asarray(full_logits[:, s_prompt - 1]), rtol=2e-4, atol=2e-4
    )
    for t in range(s_prompt, s_total):
        lg, state = step(params, toks[:, t : t + 1], state, jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full_logits[:, t]), rtol=2e-4, atol=2e-4,
            err_msg=f"position {t}",
        )
