"""Vocabulary fitting determinism.

``Counter.most_common`` breaks frequency ties by insertion order, so a
whole-frame fit and a shard-merged fit of the same corpus used to produce
*different* vocabularies (the merge visits words in shard order). The fix
is a total order — count descending, then word ascending — applied in both
the legacy ``fit`` path and the distributed ``from_counts`` path; these
tests pin it.
"""

import random
from collections import Counter

from repro.data.tokenizer import SPECIALS, WordTokenizer, top_words


def test_fit_is_insertion_order_independent():
    # both words tie at count 2; insertion order differs between the texts
    a = WordTokenizer.fit(["b a", "a b"], vocab_size=8)
    b = WordTokenizer.fit(["a b", "b a"], vocab_size=8)
    assert a.itos == b.itos
    assert a.itos[: len(SPECIALS)] == list(SPECIALS)


def test_tie_at_truncation_boundary_is_deterministic():
    # vocab_size leaves room for exactly one of the two tied words: the
    # lexicographically smaller one must win regardless of encounter order
    a = WordTokenizer.fit(["zz aa"], vocab_size=len(SPECIALS) + 1)
    b = WordTokenizer.fit(["aa zz"], vocab_size=len(SPECIALS) + 1)
    assert a.itos == b.itos == list(SPECIALS) + ["aa"]


def test_top_words_orders_by_count_then_word():
    counts = {"late": 2, "apple": 2, "zebra": 5, "mid": 3}
    assert top_words(counts, 10) == ["zebra", "mid", "apple", "late"]
    assert top_words(counts, 2) == ["zebra", "mid"]
    assert top_words(counts, 0) == []


def test_shard_merged_fit_matches_whole_fit():
    rng = random.Random(7)
    words = [f"w{i}" for i in range(40)]
    texts = [
        " ".join(rng.choice(words) for _ in range(rng.randrange(1, 12)))
        for _ in range(120)
    ]
    whole = WordTokenizer.fit(texts, vocab_size=32)
    # shard-by-shard counting in a different visit order, merged on the
    # driver — the CountVectorizer-style distributed fit
    merged: Counter = Counter()
    for shard_start in (2, 1, 0):
        shard_counts: Counter = Counter()
        for t in texts[shard_start::3]:
            shard_counts.update(t.split())
        merged.update(shard_counts)
    sharded = WordTokenizer.from_counts(merged, vocab_size=32)
    assert whole.itos == sharded.itos
    assert whole.stoi == sharded.stoi


def test_fingerprint_tracks_vocabulary():
    a = WordTokenizer(["alpha", "beta"])
    b = WordTokenizer(["alpha", "beta"])
    c = WordTokenizer(["beta", "alpha"])  # order matters: different ids
    d = WordTokenizer(["alpha"])
    assert a.fingerprint == b.fingerprint
    assert a.fingerprint != c.fingerprint
    assert a.fingerprint != d.fingerprint


def test_roundtrip_preserves_fingerprint(tmp_path):
    tok = WordTokenizer.fit(["the quick brown fox", "the slow fox"], 16)
    path = tmp_path / "vocab.json"
    tok.save(path)
    assert WordTokenizer.load(path).fingerprint == tok.fingerprint
