"""Lazy Dataset API: plan construction, optimizer rewrites, executor
equivalence with the legacy eager flow, and streaming/batching semantics."""

import json

import numpy as np
import pytest

from repro.core import ingest as ing
from repro.core import plan as P
from repro.core.dataset import Dataset
from repro.core.frame import ColumnarFrame
from repro.core.p3sapp import case_study_stages, run_conventional, run_p3sapp
from repro.core.pipeline import Pipeline, compile_column_plans
from repro.core.stages import ConvertToLower, RemoveShortWords, StopWordsRemover
from repro.data.batching import seq2seq_arrays, seq2seq_specs
from repro.data.synthetic import write_corpus
from repro.data.tokenizer import WordTokenizer


@pytest.fixture(scope="module")
def corpus(tmp_path_factory):
    d = tmp_path_factory.mktemp("ds_corpus")
    write_corpus(d, total_bytes=250_000, n_files=4, seed=21)
    return d


def _legacy_p3sapp(directories, fields=("title", "abstract")):
    """The seed's hand-wired eager flow (ingest → pre_clean → Pipeline →
    to_records → filter), kept here as the equivalence oracle."""
    frame = ing.ingest(directories, fields)
    frame = ing.pre_clean(frame, fields)
    model = Pipeline(case_study_stages()).fit(frame)
    frame = model.transform(frame, optimize=True)
    records = frame.to_records()
    return [r for r in records if all(r.get(f) for f in fields)]


# ---------------------------------------------------------------------------
# plan construction
# ---------------------------------------------------------------------------


def test_builders_are_lazy():
    # nonexistent directory: building the whole chain must not touch disk
    ds = (
        Dataset.from_json_dirs(["/nonexistent/nowhere"])
        .dropna()
        .drop_duplicates()
        .apply(*case_study_stages())
        .dropna()
    )
    kinds = [type(n) for n in ds.plan]
    assert kinds == [P.SourceJsonDirs, P.DropNA, P.DropDuplicates, P.Project, P.DropNA]
    # executing an empty source is fine too (no such files -> empty frame)
    assert ds.collect().to_records() == []


def test_schema_tracking_and_validation():
    ds = Dataset.from_records([{"a": "x", "b": "y"}], ["a", "b"])
    assert ds.schema == ("a", "b")
    assert ds.apply(ConvertToLower("a", "a_low")).schema == ("a", "b", "a_low")
    with pytest.raises(KeyError):
        ds.dropna(["missing"])
    with pytest.raises(KeyError):
        ds.apply(ConvertToLower("missing"))
    tok = WordTokenizer(["x"])
    tokenized = ds.tokenize(tok, col="a", max_len=4)
    with pytest.raises(ValueError):
        tokenized.dropna()  # frame-level op after tokenize
    with pytest.raises(ValueError):
        ds.batch(4)  # batch before tokenize
    with pytest.raises(ValueError):
        tokenized.to_records()  # record terminals refuse tokenized plans too


def test_explain_mentions_plan_nodes():
    ds = Dataset.from_json_dirs(["/tmp/x"]).dropna().apply(ConvertToLower("title"))
    text = ds.explain()
    assert "SourceJsonDirs" in text and "DropNA" in text and "optimized plan" in text


# ---------------------------------------------------------------------------
# optimizer rewrites
# ---------------------------------------------------------------------------


def test_adjacent_apply_and_dropna_merge():
    ds = (
        Dataset.from_json_dirs(["/tmp/x"])
        .apply(ConvertToLower("title"))
        .apply(RemoveShortWords("title"))
        .dropna(["title"])
        .dropna(["abstract"])
    )
    opt = ds.optimized_plan()
    projects = [n for n in opt if isinstance(n, P.Project)]
    assert len(projects) == 1 and len(projects[0].exprs) == 2
    # The two dropnas merge, then the merged subset splits at the Project:
    # the ``abstract`` half (untouched by the stages) commutes below it,
    # the ``title`` half (written by the stages) stays behind.
    assert [n.describe() for n in opt] == [
        "SourceJsonDirs(dirs=1, fields=['title', 'abstract'])",
        "DropNA(['abstract'])",
        projects[0].describe(),
        "DropNA(['title'])",
    ]


def test_dropna_pullback_past_disjoint_apply():
    # dropna(title) after stages writing only `abstract` moves before them,
    # so dropped rows are never flattened/cleaned — and results are identical.
    records = [
        {"title": "Keep Me", "abstract": "Some <b>Text</b> here"},
        {"title": None, "abstract": "Dropped <i>Row</i>"},
        {"title": "Also Kept", "abstract": "More (text) 42"},
    ]
    ds = (
        Dataset.from_records(records, ["title", "abstract"])
        .apply(ConvertToLower("abstract"))
        .dropna(["title"])
    )
    opt = ds.optimized_plan()
    assert isinstance(opt[1], P.DropNA) and isinstance(opt[2], P.Project)
    # pulled-back plan produces the same records as the unoptimized order
    plain = ds.collect(optimize=False).to_records()
    fused = ds.collect(optimize=True).to_records()
    assert plain == fused
    assert all(r["title"] for r in fused) and len(fused) == 2


def test_dropna_stays_after_apply_that_writes_it():
    ds = (
        Dataset.from_json_dirs(["/tmp/x"])
        .apply(ConvertToLower("title"))
        .dropna(["title"])
    )
    opt = ds.optimized_plan()
    assert isinstance(opt[1], P.Project) and isinstance(opt[2], P.DropNA)


def test_projection_pushdown_narrows_source():
    ds = (
        Dataset.from_json_dirs(["/tmp/x"], fields=("title", "abstract", "year"))
        .dropna(["abstract"])
        .apply(ConvertToLower("abstract"))
        .tokenize(WordTokenizer(["x"]), col="abstract", max_len=8)
    )
    src = ds.optimized_plan()[0]
    assert isinstance(src, P.SourceJsonDirs)
    assert src.fields == ("abstract",)  # title/year are dead downstream


# ---------------------------------------------------------------------------
# column_plans fork/seal semantics
# ---------------------------------------------------------------------------


def test_column_plans_fork_and_seal_structure():
    stages = [
        ConvertToLower("t", "t_low"),  # fork: t -> t_low
        RemoveShortWords("t", threshold=1),  # must NOT feed the fork above
        StopWordsRemover("t_low"),  # merges into the forked plan
    ]
    plans = compile_column_plans(stages, optimize=False)
    assert [(i, o) for i, o, _ in plans] == [
        ("t", "t"),  # live plan for t, sealed by the fork
        ("t", "t_low"),  # the fork reads the sealed state of t
        ("t", "t"),  # later mutation of t starts a FRESH plan
    ]
    assert len(plans[1][2]) == len(ConvertToLower("t").flat_ops()) + len(
        StopWordsRemover("t_low").flat_ops()
    )  # the t_low continuation merged into the forked plan


def test_fork_does_not_see_later_input_mutation():
    frame = ColumnarFrame({"t": np.array(["AA bb", "C dd"], dtype=object)})
    pipe = Pipeline([
        ConvertToLower("t", "t_low"),
        RemoveShortWords("t", threshold=1),  # mutates t AFTER the fork read it
    ])
    for optimize in (False, True):
        out = pipe.fit(frame).transform(frame, optimize=optimize)
        assert list(out["t_low"]) == ["aa bb", "c dd"]
        assert list(out["t"]) == ["AA bb", "dd"]


def test_fused_plans_are_shorter():
    stages = case_study_stages()
    plain = compile_column_plans(stages, optimize=False)
    fused = compile_column_plans(stages, optimize=True)
    assert sum(len(ops) for _, _, ops in fused) < sum(len(ops) for _, _, ops in plain)


# ---------------------------------------------------------------------------
# executor equivalence (property-style over seeds)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [3, 13])
def test_collect_matches_legacy_run_p3sapp(tmp_path_factory, seed):
    d = tmp_path_factory.mktemp(f"eq_{seed}")
    write_corpus(d, total_bytes=120_000, n_files=3, seed=seed)
    legacy = _legacy_p3sapp([d])
    fields = ("title", "abstract")
    ds = (
        Dataset.from_json_dirs([d], fields)
        .dropna(fields)
        .drop_duplicates(fields)
        .apply(*case_study_stages())
        .dropna(fields)
    )
    assert ds.collect(optimize=True).to_records() == legacy
    assert ds.to_records(optimize=False) == legacy
    via_driver, timings = run_p3sapp([d], optimize=True)
    assert via_driver == legacy
    assert timings.cumulative > 0


def test_streaming_matches_wholeframe(corpus):
    tok_records, _ = run_p3sapp([corpus], optimize=True)
    tok = WordTokenizer.fit((r["abstract"] for r in tok_records), vocab_size=256)

    def chain():
        return (
            Dataset.from_json_dirs([corpus])
            .dropna()
            .drop_duplicates()
            .apply(*case_study_stages())
            .dropna()
            .tokenize(tok, seq2seq_specs(32, 8))
            .batch(16, shuffle=False, drop_remainder=False)
        )

    whole = list(chain().iter_batches())
    streamed = list(chain().prefetch(2).iter_batches(workers=3))

    def row_set(batches):
        return sorted(
            (b["encoder_tokens"][i].tobytes(), b["decoder_tokens"][i].tobytes())
            for b in batches
            for i in range(len(b["encoder_tokens"]))
        )

    assert sum(len(b["encoder_tokens"]) for b in streamed) == sum(
        len(b["encoder_tokens"]) for b in whole
    )
    assert row_set(streamed) == row_set(whole)


def test_streaming_partial_subset_dedup_matches_wholeframe(corpus):
    # partial-subset dedup streams via the two-pass canonical-survivor
    # protocol: the streamed rows must equal whole-frame execution as a
    # multiset (the election pins each key's whole-frame keep-first row)
    tok_records, _ = run_p3sapp([corpus], optimize=True)
    tok = WordTokenizer.fit((r["abstract"] for r in tok_records), vocab_size=256)

    def chain():
        return (
            Dataset.from_json_dirs([corpus])
            .dropna()
            .drop_duplicates(["title"])  # partial subset
            .apply(*case_study_stages())
            .dropna()
            .tokenize(tok, seq2seq_specs(32, 8))
            .batch(8, shuffle=False, drop_remainder=False)
        )

    whole = list(chain().iter_batches())
    streamed = list(chain().prefetch(2).iter_batches(workers=3))

    def row_set(batches):
        return sorted(
            (b["encoder_tokens"][i].tobytes(), b["decoder_tokens"][i].tobytes())
            for b in batches
            for i in range(len(b["encoder_tokens"]))
        )

    assert row_set(streamed) == row_set(whole)


def test_streaming_rejects_stacked_partial_dedup(corpus):
    # a partial-subset dedup stacked with another dedup: the election pass
    # itself would run under scheduling-dependent cross-shard state
    tok = WordTokenizer(["w"])
    ds = (
        Dataset.from_json_dirs([corpus])
        .drop_duplicates(["title"])
        .drop_duplicates()
        .apply(*case_study_stages())
        .tokenize(tok, seq2seq_specs(16, 4))
        .batch(4, shuffle=False)
        .prefetch(2)
    )
    with pytest.raises(ValueError, match="cannot stack"):
        next(ds.iter_batches())


def test_tokenize_arrays_match_legacy_encoding(corpus):
    records, _ = run_p3sapp([corpus], optimize=True)
    tok = WordTokenizer.fit((r["abstract"] + " " + r["title"] for r in records), 512)
    ds = (
        Dataset.from_json_dirs([corpus])
        .dropna()
        .drop_duplicates()
        .apply(*case_study_stages())
        .dropna()
        .tokenize(tok, seq2seq_specs(48, 12))
    )
    arrs = ds.arrays(optimize=True)
    legacy = seq2seq_arrays(records, tok, 48, 12)
    np.testing.assert_array_equal(arrs["encoder_tokens"], legacy["encoder_tokens"])
    np.testing.assert_array_equal(arrs["decoder_tokens"], legacy["decoder_tokens"])


# ---------------------------------------------------------------------------
# batching / split / device terminals
# ---------------------------------------------------------------------------


def test_batch_shapes_pad_and_remainder():
    records = [{"a": f"word{i}"} for i in range(10)]
    tok = WordTokenizer([f"word{i}" for i in range(10)])
    base = Dataset.from_records(records, ["a"]).tokenize(tok, col="a", max_len=4)

    dropped = list(base.batch(4, shuffle=False).iter_batches())
    assert [len(b["a_tokens"]) for b in dropped] == [4, 4]

    kept = list(base.batch(4, shuffle=False, drop_remainder=False).iter_batches())
    assert [len(b["a_tokens"]) for b in kept] == [4, 4, 2]

    padded = list(base.batch(4, shuffle=False, pad_to=4).iter_batches())
    assert [len(b["a_tokens"]) for b in padded] == [4, 4, 4]
    assert (padded[-1]["a_tokens"][2:] == 0).all()  # PAD rows


def test_epochs_reshuffle():
    records = [{"a": f"word{i}"} for i in range(8)]
    tok = WordTokenizer([f"word{i}" for i in range(8)])
    ds = Dataset.from_records(records, ["a"]).tokenize(tok, col="a", max_len=2).batch(
        4, shuffle=True, seed=0
    )
    batches = list(ds.iter_batches(epochs=2))
    assert len(batches) == 4
    e0 = np.concatenate([b["a_tokens"] for b in batches[:2]])
    e1 = np.concatenate([b["a_tokens"] for b in batches[2:]])
    assert sorted(map(tuple, e0)) == sorted(map(tuple, e1))  # same rows
    assert not (e0 == e1).all()  # different order across epochs


def test_split_partitions_rows(corpus):
    ds = (
        Dataset.from_json_dirs([corpus])
        .dropna()
        .drop_duplicates()
        .apply(*case_study_stages())
        .dropna()
    )
    all_records = ds.to_records()
    train, val = ds.split(val_fraction=0.2, seed=1)
    tr, va = train.to_records(), val.to_records()
    assert len(tr) + len(va) == len(all_records)
    def key(r):
        return (r["title"], r["abstract"])

    assert sorted(map(key, tr + va)) == sorted(map(key, all_records))


def test_device_batches_smoke_and_close():
    records = [{"a": f"word{i}"} for i in range(32)]
    tok = WordTokenizer([f"word{i}" for i in range(32)])
    ds = Dataset.from_records(records, ["a"]).tokenize(tok, col="a", max_len=2).batch(8)
    loader = ds.device_batches(epochs=None, prefetch=2)  # endless stream
    taken = []
    for b in loader:
        taken.append(b)
        if len(taken) >= 6:
            break
    loader.close()  # must not hang on the blocked fill thread
    assert all(b["a_tokens"].shape == (8, 2) for b in taken)


def test_endless_epochs_terminate_when_empty():
    # regression: epochs=None over a dataset too small to fill one batch
    # must terminate instead of busy-spinning forever
    records = [{"a": "word0"}, {"a": "word1"}]
    tok = WordTokenizer(["word0", "word1"])
    ds = Dataset.from_records(records, ["a"]).tokenize(tok, col="a", max_len=2).batch(
        8, shuffle=False  # drop_remainder=True -> zero batches per epoch
    )
    assert list(ds.iter_batches(epochs=None)) == []


def test_async_loader_close_with_prefetch_one():
    # regression: the fill thread's sentinel put must not deadlock when
    # close() races a full 1-slot queue
    import threading
    import time

    from repro.core.async_loader import AsyncLoader

    src = ({"x": np.full((2,), i)} for i in range(100_000))
    loader = AsyncLoader(src, prefetch=1)
    next(iter(loader))
    t0 = time.time()
    loader.close()
    assert time.time() - t0 < 2.0


def test_streaming_abandon_stops_shard_pool(corpus):
    # regression: breaking out of a streaming loader must stop the ShardPool
    # readers instead of preprocessing the rest of the corpus
    import threading
    import time

    tok = WordTokenizer(["w"])
    ds = (
        Dataset.from_json_dirs([corpus])
        .dropna()
        .drop_duplicates()
        .apply(*case_study_stages())
        .tokenize(tok, seq2seq_specs(16, 4))
        .batch(4, shuffle=False)
        .prefetch(2)
    )
    before = threading.active_count()
    loader = ds.device_batches(epochs=None, workers=3)
    it = iter(loader)
    next(it)
    loader.close()
    time.sleep(0.5)
    assert threading.active_count() <= before + 1


def test_materialization_is_memoized(corpus, monkeypatch):
    ds = Dataset.from_json_dirs([corpus]).dropna().drop_duplicates()
    first = ds.collect()
    calls = []
    monkeypatch.setattr(
        P, "execute_frame_plan", lambda *a, **k: calls.append(1) or (_ for _ in ()).throw(
            AssertionError("re-executed a memoized plan")
        )
    )
    assert ds.collect() is first  # cache hit, no re-execution
    # a derived split resumes from the memoized frame instead of re-ingesting
    train, val = ds.split(0.25, seed=0)
    assert len(train.collect()) + len(val.collect()) == len(first)


# ---------------------------------------------------------------------------
# NUL normalization (CA/P3SAPP equivalence regression)
# ---------------------------------------------------------------------------


def test_nul_bytes_normalized_identically_in_both_paths(tmp_path):
    shard = tmp_path / "shard_0000.jsonl"
    rows = [
        {"title": "Null\x00Byte Title", "abstract": "Some\x00 <b>Marked</b> abstract text"},
        {"title": "Plain Title", "abstract": "Plain abstract text with words"},
    ]
    with open(shard, "w", encoding="utf-8") as fh:
        for r in rows:
            fh.write(json.dumps(r) + "\n")
    pa, _ = run_p3sapp([tmp_path])
    ca, _ = run_conventional([tmp_path])
    assert pa == ca  # byte-identical records, not just set overlap
    assert len(pa) == 2
    assert "null byte title" == pa[0]["title"]
