"""Deterministic streaming order: heap reassembly makes ``iter_batches``
emit the exact same batch sequence run to run and across executor kinds.

The multiset guarantee (streamed rows == whole-frame rows) lives in
``test_dataset_plan.py``; this suite pins the stronger ordering leg added
with the serving PR — shard results are reassembled in shard order, so
scheduling jitter between workers can never reorder the stream.
"""

import pytest

from repro.core.dataset import Dataset
from repro.core.p3sapp import case_study_stages
from repro.data.batching import seq2seq_specs
from repro.data.synthetic import write_corpus
from repro.data.tokenizer import WordTokenizer


@pytest.fixture(scope="module")
def corpus(tmp_path_factory):
    d = tmp_path_factory.mktemp("order_corpus")
    write_corpus(d, total_bytes=200_000, n_files=5, seed=33)
    return d


@pytest.fixture(scope="module")
def tok(corpus):
    records = Dataset.from_json_dirs([corpus]).dropna().collect().to_records()
    return WordTokenizer.fit((r["abstract"] for r in records), vocab_size=256)


def chain(corpus, tok):
    return (
        Dataset.from_json_dirs([corpus])
        .dropna()
        .apply(*case_study_stages())
        .dropna()
        .tokenize(tok, seq2seq_specs(32, 8))
        .batch(16, shuffle=False, drop_remainder=False)
        .prefetch(2)
    )


def materialize(ds, **kw):
    return [
        {k: v.copy() for k, v in batch.items()} for batch in ds.iter_batches(**kw)
    ]


def assert_same_sequence(a, b):
    assert len(a) == len(b)
    for i, (ba, bb) in enumerate(zip(a, b)):
        assert sorted(ba) == sorted(bb), f"batch {i} keys differ"
        for k in ba:
            assert (ba[k] == bb[k]).all(), f"batch {i} column {k} differs"


def test_streaming_order_is_deterministic_run_to_run(corpus, tok):
    first = materialize(chain(corpus, tok), workers=3)
    second = materialize(chain(corpus, tok), workers=3)
    assert_same_sequence(first, second)


def test_streaming_order_matches_across_worker_counts(corpus, tok):
    # shard-order reassembly means the schedule (1 worker vs many) is
    # invisible in the output sequence
    serial = materialize(chain(corpus, tok), workers=1)
    threaded = materialize(chain(corpus, tok), workers=4)
    assert_same_sequence(serial, threaded)


def test_streaming_order_matches_across_executors(corpus, tok):
    threaded = materialize(chain(corpus, tok), workers=2, executor="thread")
    process = materialize(chain(corpus, tok), workers=2, executor="process")
    assert_same_sequence(threaded, process)
