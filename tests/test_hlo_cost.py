"""Unit tests for the trip-count-aware HLO cost parser (the §Roofline
foundation): dots, while-loop trip resolution, collectives, byte model."""

import textwrap

import pytest

from repro.launch.hlo_cost import analyze, parse_module

SIMPLE = textwrap.dedent("""
    HloModule test

    ENTRY %main.1 (p0: f32[128,64], p1: f32[64,32]) -> f32[128,32] {
      %p0 = f32[128,64]{1,0} parameter(0)
      %p1 = f32[64,32]{1,0} parameter(1)
      ROOT %dot.1 = f32[128,32]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
    }
""")


def test_simple_dot_flops_and_bytes():
    r = analyze(SIMPLE)
    assert r["flops"] == 2 * 128 * 32 * 64
    # dot bytes: result + operands
    assert r["bytes"] == 4 * (128 * 32 + 128 * 64 + 64 * 32)
    assert r["unresolved_whiles"] == 0


WHILE = textwrap.dedent("""
    HloModule loop

    %body.1 (arg: (s32[], f32[16,16])) -> (s32[], f32[16,16]) {
      %arg = (s32[], f32[16,16]) parameter(0)
      %i = s32[] get-tuple-element(%arg), index=0
      %x = f32[16,16]{1,0} get-tuple-element(%arg), index=1
      %one = s32[] constant(1)
      %ip = s32[] add(%i, %one)
      %d = f32[16,16]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      ROOT %out = (s32[], f32[16,16]) tuple(%ip, %d)
    }

    %cond.1 (arg: (s32[], f32[16,16])) -> pred[] {
      %arg = (s32[], f32[16,16]) parameter(0)
      %i = s32[] get-tuple-element(%arg), index=0
      %n = s32[] constant(7)
      ROOT %cmp = pred[] compare(%i, %n), direction=LT
    }

    ENTRY %main.2 (p: f32[16,16]) -> (s32[], f32[16,16]) {
      %p = f32[16,16]{1,0} parameter(0)
      %z = s32[] constant(0)
      %t = (s32[], f32[16,16]) tuple(%z, %p)
      ROOT %w = (s32[], f32[16,16]) while(%t), condition=%cond.1, body=%body.1
    }
""")


def test_while_trip_count_multiplies():
    r = analyze(WHILE)
    # body dot: 2*16*16*16 flops, executed 7 times
    assert r["flops"] == 7 * 2 * 16 * 16 * 16
    assert r["unresolved_whiles"] == 0


COLLECTIVE = textwrap.dedent("""
    HloModule coll

    ENTRY %main.3 (p: bf16[1024,512]) -> bf16[1024,512] {
      %p = bf16[1024,512]{1,0} parameter(0)
      %ar = bf16[1024,512]{1,0} all-reduce(%p), replica_groups={}, to_apply=%add.1
      ROOT %ag = bf16[1024,512]{1,0} all-gather(%ar), dimensions={0}
    }

    %add.1 (a: bf16[], b: bf16[]) -> bf16[] {
      %a = bf16[] parameter(0)
      %b = bf16[] parameter(1)
      ROOT %s = bf16[] add(%a, %b)
    }
""")


def test_collective_bytes():
    r = analyze(COLLECTIVE)
    n = 1024 * 512 * 2  # bf16
    assert r["collective_bytes"]["all-reduce"] == 2 * n  # ring wire 2x
    assert r["collective_bytes"]["all-gather"] == n
    assert r["collective_total"] == 3 * n


def test_parse_module_structure():
    comps, entry = parse_module(WHILE)
    assert entry == "%main.2"
    assert "%body.1" in comps and "%cond.1" in comps
    assert comps["%cond.1"].root == "%cmp"


def test_real_artifact_consistency():
    """Parse a real saved dry-run HLO and check basic invariants."""
    import json
    from pathlib import Path

    zstandard = pytest.importorskip("zstandard")

    p = Path("benchmarks/results/dryrun/single/stablelm_3b__train_4k.hlo.zst")
    if not p.exists():
        pytest.skip("dry-run artifacts not present")
    txt = zstandard.ZstdDecompressor().decompress(p.read_bytes()).decode()
    r = analyze(txt)
    rec = json.loads(p.with_suffix("").with_suffix(".json").read_text())
    assert r["unresolved_whiles"] == 0
    # parsed flops must exceed XLA's body-once count and be within 3x of
    # the analytic 6·N·D (remat + attention overhead band)
    per_dev_model = rec["model_flops"] / rec["n_devices"]
    assert per_dev_model < r["flops"] < 3 * per_dev_model
