"""Shared-memory lifecycle regression tests for ProcessShardExecutor.

The failure this guards against: a worker creates its output segment,
then dies (SIGKILL/OOM) before the driver learns the segment's name — the
block outlives the run in /dev/shm until reboot. The fix names output
segments deterministically from (run id, task id), so the driver's
``stop()`` sweep (plus an atexit last resort) can unlink orphans it was
never told about. These tests assert zero ``repro_<run_id>_*`` residue
after a clean run, after SIGKILLing a worker mid-run, and after
abandoning an executor mid-iteration.
"""

import os
import signal
import time
from pathlib import Path

import pytest

from repro.core import executor as EX
from repro.core import ingest as ing
from test_executor_equivalence import (
    chain,
    fuzz_records,
    optimized_program,
    write_shards,
)

SHM_DIR = Path("/dev/shm")

pytestmark = pytest.mark.skipif(
    not EX.shared_memory_available() or not SHM_DIR.is_dir(),
    reason="POSIX shared memory not available",
)


def run_segments(run_id: str) -> list[str]:
    return sorted(p.name for p in SHM_DIR.glob(f"repro_{run_id}_*"))


def make_proc_executor(tmp_path, seed=21, n=40, files=4, workers=2):
    d = write_shards(tmp_path, fuzz_records(seed, n), n_files=files)
    ds = chain(d)
    program = optimized_program(ds)
    shards = ing.list_shards([d])
    return EX.ProcessShardExecutor(shards, program, workers=workers)


def test_clean_run_leaves_no_segments(tmp_path):
    ex = make_proc_executor(tmp_path)
    list(ex)
    ex.stop()
    assert run_segments(ex.run_id) == []


def test_abandoned_run_leaves_no_segments(tmp_path):
    ex = make_proc_executor(tmp_path)
    next(iter(ex))  # consume one shard, abandon the rest in flight
    ex.stop()
    assert run_segments(ex.run_id) == []


def test_sigkilled_worker_leaves_no_segments(tmp_path):
    """Kill a worker process mid-run: whatever segments the run created —
    including an output block the worker allocated but never reported —
    must be gone after stop()."""
    ex = make_proc_executor(tmp_path, seed=22, n=60, files=6)
    it = iter(ex)
    next(it)  # workers are up and processing
    for p in ex._procs:
        os.kill(p.pid, signal.SIGKILL)
    # The iterator surfaces the dead pool as a RuntimeError (or, if every
    # remaining result already sat in the queue, finishes); either way the
    # executor must sweep its blocks.
    try:
        for _ in it:
            pass
    except RuntimeError:
        pass
    ex.stop()
    deadline = time.time() + 5.0
    while run_segments(ex.run_id) and time.time() < deadline:
        time.sleep(0.05)  # resource tracker may unlink asynchronously
    assert run_segments(ex.run_id) == []


def test_output_segment_names_are_deterministic():
    assert EX._out_seg_name("abc", 7) == "repro_abc_7"
