"""Fault-tolerant training orchestration.

At thousand-node scale the failure model is: a worker dies (hardware,
preemption), the SPMD step cannot proceed, the job restarts on the
surviving/replacement topology and must resume from the last committed
checkpoint with zero manual intervention. This module provides that
control plane at single-process scale with the same interfaces:

* ``TrainController`` — wraps the step loop: periodic atomic checkpoints,
  resume-from-latest on construction, crash-equivalent kill points in
  tests (the integration test SIGKILLs a child mid-run and verifies the
  restarted run continues from the committed step, not from scratch).
* ``Heartbeat`` — liveness file the launcher can monitor (a real cluster
  would use the coordination service; the artifact is the same: detect a
  dead worker, trigger restart).
* Elastic restarts go through ``repro.runtime.elastic``: the checkpoint
  is topology-independent (host arrays + current-mesh shardings).
"""

from __future__ import annotations

import os
import time
from pathlib import Path
from typing import Any, Callable, Iterator

# NOTE: Checkpointer (and through it jax) is imported lazily inside
# TrainController.__init__. The distributed preprocessing workers import
# this module for Heartbeat, and the worker tier must stay jax-free at
# module level (contract R001, enforced by `python -m repro.analysis`).


class Heartbeat:
    """Liveness beacon file: ``<step> <unix-time>``.

    Writes go to a temp file in the same directory and are atomically
    renamed into place, so a monitor (``is_alive``) can never observe a
    torn, partially-written beat — a reader sees either the previous beat
    or the new one. The remote preprocessing coordinator
    (:mod:`repro.distributed.coordinator`) monitors these files to decide
    worker liveness alongside TCP connection state.
    """

    def __init__(self, path: str | Path, interval_s: float = 5.0):
        self.path = Path(path)
        self.interval_s = interval_s
        self._last = 0.0

    def beat(self, step: int, *, force: bool = False) -> None:
        now = time.time()
        if not force and now - self._last < self.interval_s:
            return
        tmp = self.path.with_name(self.path.name + f".tmp{os.getpid()}")
        tmp.write_text(f"{step} {now}")
        os.replace(tmp, self.path)
        self._last = now

    @staticmethod
    def last_beat(path: str | Path) -> float | None:
        """Unix time of the last committed beat, or None when the file is
        missing or unreadable (never raises: a vanished/garbage file just
        means "no beat")."""
        try:
            _, ts = Path(path).read_text().split()
            return float(ts)
        except (OSError, ValueError):
            # OSError: file missing / unreadable. ValueError: garbage
            # content (wrong field count or a non-float timestamp) — with
            # atomic beats that means corruption, not a torn write.
            return None

    @staticmethod
    def is_alive(path: str | Path, timeout_s: float) -> bool:
        ts = Heartbeat.last_beat(path)
        return ts is not None and (time.time() - ts) < timeout_s


class TrainController:
    """Checkpointed step loop: resumes from the latest committed step."""

    def __init__(
        self,
        ckpt_dir: str | Path,
        train_step: Callable,  # (params, opt_state, batch) -> (params, opt, metrics)
        init_state: Callable[[], tuple[Any, Any]],  # () -> (params, opt_state)
        *,
        save_every: int = 50,
        keep: int = 3,
        shardings: Any | None = None,
        heartbeat: Heartbeat | None = None,
    ):
        from ..checkpoint.checkpointer import Checkpointer

        self.ckpt = Checkpointer(ckpt_dir, keep=keep)
        self.train_step = train_step
        self.save_every = save_every
        self.heartbeat = heartbeat
        self.shardings = shardings

        latest = self.ckpt.latest()
        if latest is None:
            self.params, self.opt_state = init_state()
            self.step = 0
            self.resumed = False
        else:
            params, opt_state = init_state()  # structure donor
            (self.params, self.opt_state), extra = self.ckpt.restore(
                (params, opt_state), latest, shardings=self.shardings
            )
            self.step = int(extra.get("step", latest))
            self.resumed = True

    def run(self, batches: Iterator, n_steps: int) -> list[dict]:
        history = []
        for batch in batches:
            if self.step >= n_steps:
                break
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch
            )
            self.step += 1
            if self.heartbeat is not None:
                self.heartbeat.beat(self.step)
            history.append({"step": self.step, **{k: float(v) for k, v in metrics.items()}})
            if self.step % self.save_every == 0:
                self.save()
        self.save()
        return history

    def save(self) -> None:
        self.ckpt.save(self.step, (self.params, self.opt_state), extra={"step": self.step})
