"""Per-request lowering of a compiled shard program: zero train/serve skew.

A :class:`RowProgram` is the serving-side twin of
:class:`repro.core.executor.ShardProgram`: the same optimized step chain
(select / dropna / filter / compiled column expressions) followed by the
same frozen token plan (specs + vocabulary, pinned by the vocab
fingerprint), but with every shard-sized assumption removed — no shard
pool, no shared memory, no worker processes, no cache. Input is a single
raw string (or a field dict), output is the int32 token arrays the
training executors would have produced for that row, byte-identical by
construction: both paths are compiled by ``compile_shard_program`` from
the same plan with the same optimizer, and the evaluator here mirrors
``execute_program``'s flat-buffer semantics op for op (differentially
tested row-by-row in ``tests/test_row_program.py`` across all bytes
backends).

Built via :meth:`repro.core.dataset.Dataset.row_program` — the analyzer
first proves the plan row-executable (diagnostic ``P016``: cross-row
steps like ``drop_duplicates`` or whole-frame ``split`` cannot run per
request).

Contract (linter rule R005): this module and :mod:`repro.runtime.serve_loop`
form the serve hot path and must never import the shard/shm/pool machinery
(``core.executor``, ``core.async_loader``, ``repro.distributed``,
``multiprocessing``). Only the pure compute layers are allowed:
:mod:`repro.core.bytesops`, :mod:`repro.core.expr`, and the encoders in
:mod:`repro.data.batching`.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Mapping, Sequence

import numpy as np

from ..core import bytesops as B
from ..core import expr as E
from ..data.batching import TokenSpec, VocabTable, encode_flat, encode_rows

# Step kinds a single row can execute: everything row-local. Cross-row
# steps (dedup and its two-pass split) hold state over the whole corpus
# and are rejected at construction (and earlier, by analyzer code P016).
ROW_EXECUTABLE_STEPS = ("select", "dropna", "filter", "project")


class RowProgramError(ValueError):
    """The program cannot be lowered to per-row execution."""


def _flatten_raw(values: Sequence[Any]) -> np.ndarray:
    """Flatten raw column values exactly like ``ColumnarFrame.flat``:
    None -> "", str() conversion, NUL bytes (the row separator) -> space."""
    rows = ["" if v is None else str(v).replace("\x00", " ") for v in values]
    return B.flatten(rows)


def _flat_take(buf: np.ndarray, keep: np.ndarray) -> np.ndarray:
    # Mirror of executor._flat_take (kept local: R005 bans that import).
    if buf.size == 0 or keep.all():
        return buf
    return buf[np.repeat(keep, B.row_lengths(buf))]


@dataclass(frozen=True)
class RowProgram:
    """A precompiled request-to-tokens program.

    ``fields``/``steps``/``backend`` are lifted verbatim from the compiled
    :class:`ShardProgram`; ``specs``/``stoi``/``vocab_fp`` are its frozen
    :class:`TokenPlan`. ``fingerprint`` is the shard program's structural
    fingerprint — cache keys derived from it (e.g. the serve-loop ring
    cache) are therefore shared with nothing but this exact plan + vocab.
    """

    fields: tuple[str, ...]
    steps: tuple[tuple[str, Any], ...]
    specs: tuple[TokenSpec, ...]
    stoi: Mapping[str, int]
    vocab_fp: str
    backend: str = "loops"
    fingerprint: str = ""
    _table: list = field(default_factory=list, repr=False, compare=False)

    def __post_init__(self):
        for kind, _ in self.steps:
            if kind not in ROW_EXECUTABLE_STEPS:
                raise RowProgramError(
                    f"step {kind!r} holds cross-row state; not row-executable"
                )
        if not self.specs:
            raise RowProgramError("row programs require a token plan (tokenize())")

    @property
    def output_names(self) -> tuple[str, ...]:
        return tuple(spec.name for spec in self.specs)

    @property
    def table(self) -> VocabTable:
        if not self._table:  # lazy: built once, ~O(vocab) to sort
            self._table.append(VocabTable(dict(self.stoi)))
        return self._table[0]

    # -- input normalization ----------------------------------------------
    @staticmethod
    def _normalize(value: Any) -> Any:
        # Ingest-time invariant (mirror of ingest._normalize, kept local
        # per R005): NUL is the flat-buffer row separator and never
        # survives into the engine, so a served request's text must be
        # sanitized exactly like a parsed shard record.
        if isinstance(value, str) and "\x00" in value:
            return value.replace("\x00", " ")
        return value

    def _columns(self, rows: Sequence[Any]) -> dict[str, list]:
        """Column-major raw values for ``rows`` of strings (single-field
        programs) or field dicts (missing fields -> None, like a JSON
        record that lacks the key)."""
        cols: dict[str, list] = {f: [] for f in self.fields}
        for row in rows:
            if isinstance(row, str) or row is None:
                if len(self.fields) != 1:
                    raise RowProgramError(
                        f"program reads fields {self.fields}; pass a dict, "
                        "not a bare string"
                    )
                cols[self.fields[0]].append(self._normalize(row))
            elif isinstance(row, Mapping):
                for f in self.fields:
                    cols[f].append(self._normalize(row.get(f)))
            else:
                raise RowProgramError(f"unsupported request row {type(row).__name__}")
        return cols

    # -- evaluation --------------------------------------------------------
    def encode_batch(
        self, rows: Sequence[Any]
    ) -> tuple[dict[str, np.ndarray], np.ndarray]:
        """Run the program over a micro-batch of raw request rows.

        Returns ``(outputs, keep)``: one ``(n_kept, max_len)`` int32 array
        per token spec, and a boolean mask over the *input* rows marking
        which survived the plan's filters (a served request whose row is
        filtered out gets an empty response, it does not shift its
        neighbors' outputs).

        The evaluator mirrors ``execute_program``: projected columns live
        as flat byte buffers (``flat``), raw source columns flatten lazily
        and memoize (``src_flat``), and row-dropping steps compact both via
        the same repeat-by-row-length take.
        """
        live = self._columns(rows)
        n = len(rows)
        orig = np.arange(n)
        flat: dict[str, np.ndarray] = {}
        src_flat: dict[str, np.ndarray] = {}

        def lookup(c: str) -> np.ndarray:
            if c in flat:
                return flat[c]
            if c not in src_flat:
                src_flat[c] = _flatten_raw(live[c])
            return src_flat[c]

        def take_rows(keep: np.ndarray) -> None:
            nonlocal orig
            if keep.all():
                return
            for d in (flat, src_flat):
                for c in d:
                    d[c] = _flat_take(d[c], keep)
            for c in live:
                live[c] = [v for v, k in zip(live[c], keep) if k]
            orig = orig[keep]

        for kind, arg in self.steps:
            if kind == "select":
                for d in (flat, src_flat, live):
                    for c in [c for c in d if c not in arg]:
                        del d[c]
            elif kind == "dropna":
                cur = len(orig)
                keep = np.ones(cur, dtype=bool)
                for c in arg:
                    if c in flat:
                        keep &= B.row_nonempty(flat[c])
                    else:
                        keep &= np.fromiter(
                            (v is not None and v != "" for v in live[c]),
                            dtype=bool,
                            count=cur,
                        )
                take_rows(keep)
            elif kind == "filter":
                take_rows(E.eval_mask(arg, lookup, len(orig), self.backend))
            else:  # project
                cur = len(orig)
                for out_col, comp in arg:
                    if comp[0] == "chain" and not comp[2]:  # pure alias
                        flat[out_col] = lookup(comp[1])
                    else:
                        flat[out_col] = E.eval_str(comp, lookup, cur, self.backend)

        outputs: dict[str, np.ndarray] = {}
        for spec in self.specs:
            col = spec.column
            if col in flat:
                outputs[spec.name] = encode_flat(
                    flat[col], self.table, spec.max_len, spec.add_start_end
                )
            else:
                outputs[spec.name] = encode_rows(
                    list(live[col]),
                    self.stoi,
                    spec.max_len,
                    spec.add_start_end,
                    table=self.table,
                )
        keep_mask = np.zeros(n, dtype=bool)
        keep_mask[orig] = True
        return outputs, keep_mask

    def __call__(self, row: Any) -> dict[str, np.ndarray] | None:
        """Encode one request row; ``None`` when the plan filters it out."""
        outputs, keep = self.encode_batch([row])
        if not keep[0]:
            return None
        return outputs
