"""Elastic scaling: restore a checkpoint onto a different topology.

The checkpoint format is topology-independent (host-side arrays keyed by
tree path); elasticity is therefore a *placement* problem: rebuild the
mesh from the currently-available device count, re-derive every leaf's
sharding with the same logical-axis rules, and device_put accordingly.
``remesh`` is the entry point the launcher calls after a failure shrinks
(or an allocation grows) the slice.

Divisibility: the sharding rule engine already falls back per-tensor when
a dimension stops dividing the new axis size, so shrinking 16→8→4 devices
needs no per-arch handling. Global batch is rebalanced by the data
pipeline (batch axis = whatever the new mesh provides).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np

try:  # jax >= 0.4.38; older versions predate explicit axis types
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None

from ..distributed.sharding import DEFAULT_RULES, tree_shardings


def available_mesh(model_parallel: int = 1, devices=None):
    """Largest (data, model) mesh over the devices that are still alive."""
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    mp = model_parallel
    while n % mp:
        mp -= 1
    kwargs = {}
    if AxisType is not None:
        kwargs["axis_types"] = (AxisType.Auto, AxisType.Auto)
    return jax.make_mesh(
        (n // mp, mp), ("data", "model"), devices=devices, **kwargs
    )


def remesh(
    tree: Any,
    axes_tree: Any,
    new_mesh,
    rules=DEFAULT_RULES,
) -> Any:
    """Re-place every leaf of ``tree`` for ``new_mesh`` (host round-trip —
    on a real pod this is the post-restart restore path, so arrays are on
    host already)."""
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    sh = tree_shardings(shapes, axes_tree, new_mesh, rules)
    return jax.tree.map(lambda x, s: jax.device_put(np.asarray(jax.device_get(x)), s), tree, sh)
