"""Distributed train-step factory: microbatched gradient accumulation,
sharded AdamW update, donated buffers.

Gradient accumulation serves three purposes at pod scale:
* activation memory (micro-rows sized per arch),
* MoE dispatch-buffer memory (capacity buffers scale with micro tokens),
* compute/comm overlap: per-microbatch grads are accumulated locally and
  the cross-replica reduction happens ONCE per step, overlapped by XLA
  with the last microbatch's backward (the sharded-update reduce-scatter
  pattern falls out of pjit output shardings).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..optim.adamw import AdamW, AdamWState


@dataclass(frozen=True)
class TrainStepConfig:
    n_microbatches: int = 1
    loss_scale: float = 1.0  # static loss scaling for bf16 grads


def split_microbatches(batch: dict, n: int) -> dict:
    """(B, ...) -> (n, B/n, ...) on every leaf."""
    def sp(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by microbatches {n}"
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree.map(sp, batch)


def make_input_pipeline(
    dataset,
    *,
    epochs: int | None = None,
    prefetch: int = 2,
    sharding: Any = None,
    stats: dict | None = None,
    overlap: bool = False,
    donate: bool = True,
    profiler: Any = None,
):
    """Wire a streaming :class:`~repro.core.dataset.Dataset` into the
    learner: batches stream out of the dataset's shard executor — reader
    threads, local worker processes, or the distributed data plane when
    the chain carries ``.workers(n, remote=...)`` — through an
    :class:`~repro.core.async_loader.AsyncLoader` that device-puts ahead
    of compute.

    This is the actor/learner split at pipeline level: preprocessing
    actors (possibly on other hosts) feed the device step loop, and a
    dead actor costs throughput, never correctness — its leased shards
    are reassigned and the batch stream is unchanged. Returns the loader;
    call ``.close()`` (or let a ``finally`` do it) when training stops
    mid-epoch so remote workers shut down instead of preprocessing into a
    queue nobody drains. ``stats`` (a dict) receives executor and cache
    counters after each epoch.

    ``overlap=True`` (or passing a ``profiler``) upgrades the tail to a
    :class:`~repro.core.device_pipeline.DeviceFeed`: batches snap onto the
    plan's fixed bucket grid (the jit'd step compiles once per grid cell),
    transfers double-buffer one batch ahead, the consuming step donates
    its input buffers (``donate``), and the feed's
    :class:`~repro.core.device_pipeline.OverlapProfiler` accounts
    host-wait vs device-compute time into a device-idle fraction — wrap
    each step in ``feed.step(batch)`` to attribute its compute segment.
    """
    from ..core.async_loader import AsyncLoader

    batches = dataset.iter_batches(epochs=epochs, stats=stats)
    if overlap or profiler is not None:
        from ..core.device_pipeline import DeviceFeed

        return DeviceFeed(
            batches,
            grid=dataset.bucket_grid_spec(),
            prefetch=prefetch,
            sharding=sharding,
            donate=donate,
            profiler=profiler,
        )
    return AsyncLoader(batches, prefetch=prefetch, sharding=sharding)


def make_train_step(
    loss_fn: Callable[[Any, dict], jax.Array],
    optimizer: AdamW,
    cfg: TrainStepConfig = TrainStepConfig(),
):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def grads_of(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return loss, grads

    def train_step(params, opt_state: AdamWState, batch: dict):
        n = cfg.n_microbatches
        if n <= 1:
            loss, grads = grads_of(params, batch)
        else:
            micro = split_microbatches(batch, n)

            def body(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = grads_of(params, mb)
                grad_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), grad_acc, grads
                )
                return (loss_acc + loss, grad_acc), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grads), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zero), micro)
            loss = loss_sum / n
            grads = jax.tree.map(lambda g: g / n, grads)
        new_params, new_opt, gnorm = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm}
        return new_params, new_opt, metrics

    return train_step
