"""Serving: batched incremental decoding against sharded KV/recurrent state.

``make_serve_step`` produces the one-token step the decode dry-run cells
lower; ``serve_requests`` is the host-side batched-request driver used by
examples/serve_summarizer.py and the serving integration test (continuous
batching in its simplest correct form: fixed slots, refill on completion).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def make_serve_step(model):
    """serve_step(params, tokens (b,1), state, pos) -> (next_tokens, logits, state).

    Greedy sampling on-device: the returned tokens feed the next step
    directly, keeping decode a device-side loop with O(1) host traffic.
    """

    def serve_step(params, tokens, state, pos):
        logits, state = model.decode_step(params, tokens, state, pos)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, state

    return serve_step


@dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (prompt_len,) int32
    max_new: int = 16


def serve_requests(
    model,
    params,
    requests: Sequence[Request],
    *,
    slots: int = 4,
    max_seq: int = 128,
    eos_id: int = 2,
    cache_dtype=jnp.float32,
) -> dict[int, list[int]]:
    """Continuous-batching driver: fixed decode slots; finished slots are
    refilled from the queue. Per-slot position tracking; prompts are
    prefilled one slot at a time (block prefill)."""
    step = jax.jit(make_serve_step(model))
    prefill = jax.jit(model.decode_step)

    queue = list(requests)
    results: dict[int, list[int]] = {}
    # one independent state per slot (batch=1) so refills don't disturb others
    states = [model.init_decode_state(1, max_seq, cache_dtype) for _ in range(slots)]
    active: list[dict | None] = [None] * slots
    last_tok = [None] * slots

    def fill(slot: int) -> None:
        if not queue:
            active[slot] = None
            return
        req = queue.pop(0)
        states[slot] = model.init_decode_state(1, max_seq, cache_dtype)
        logits, states[slot] = prefill(
            params, jnp.asarray(req.prompt[None]), states[slot], jnp.int32(0)
        )
        nxt = int(jnp.argmax(logits[0, -1]))
        active[slot] = {"req": req, "pos": len(req.prompt), "out": [nxt]}
        last_tok[slot] = nxt

    for s in range(slots):
        fill(s)

    while any(a is not None for a in active):
        for s in range(slots):
            a = active[s]
            if a is None:
                continue
            done = (
                last_tok[s] == eos_id
                or len(a["out"]) >= a["req"].max_new
                or a["pos"] + 1 >= max_seq
            )
            if done:
                results[a["req"].uid] = a["out"]
                fill(s)
                continue
            toks = jnp.full((1, 1), last_tok[s], jnp.int32)
            nxt, _, states[s] = step(params, toks, states[s], jnp.int32(a["pos"]))
            last_tok[s] = int(nxt[0, 0])
            a["out"].append(last_tok[s])
            a["pos"] += 1
    return results
