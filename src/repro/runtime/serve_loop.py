"""Serving: text-in/tokens-out continuous batching over a row program.

The serving path closes the train/serve loop: requests arrive as raw
abstract text, are encoded by the *same* compiled plan the training
executors run (a :class:`~repro.runtime.row_program.RowProgram`, passed in
by the caller), and flow into micro-batched continuous batching — fixed
decode slots with block-prefill refill, fed by a bounded admission queue
that sheds load on arrival, with a fixed-slot :class:`RingCache` fronting
repeated prompts.

Layers, bottom up:

* ``make_serve_step`` — the one-token greedy decode step (jit'd).
* ``_continuous_decode`` — the slot driver: fixed decode slots, refill on
  completion from a ``next_item`` callback (continuous batching in its
  simplest correct form, unchanged from the original loop).
* ``serve_requests`` — the legacy pre-tokenized entry point
  (:class:`Request` carries an int32 prompt array), kept for
  ``launch/serve.py`` and direct callers.
* ``serve_text`` — the end-to-end entry point: :class:`TextRequest` in,
  token lists out, with an :class:`AdmissionQueue`, per-request
  preprocessing through the row program, ring-cache hits, and a
  :class:`ServeStats` ledger (admission/shed/filter counters, cache
  accounting, preprocess-vs-decode time split, per-request latency).

Contract (linter rule R005): this module is the serve hot path — it must
never import the shard/shm/pool machinery (``core.executor``,
``core.async_loader``, ``repro.distributed``, ``multiprocessing``). The
row program arrives as an argument; anything it needs it carries.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

PAD_ID = 0


def make_serve_step(model):
    """serve_step(params, tokens (b,1), state, pos) -> (next_tokens, logits, state).

    Greedy sampling on-device: the returned tokens feed the next step
    directly, keeping decode a device-side loop with O(1) host traffic.
    """

    def serve_step(params, tokens, state, pos):
        logits, state = model.decode_step(params, tokens, state, pos)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, state

    return serve_step


@dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (prompt_len,) int32
    max_new: int = 16


@dataclass
class TextRequest:
    """A raw serving request: abstract text (or a field dict for multi-field
    plans), encoded through the row program at admission time."""

    uid: int
    text: str | Mapping[str, Any]
    max_new: int = 16


class AdmissionQueue:
    """Bounded FIFO admission queue: load is shed on *arrival* (``offer``
    returns False and counts a rejection when full), so an overloaded
    server degrades by refusing new work deterministically instead of
    queueing unboundedly. Thread-safe: producers may offer from request
    threads while the decode loop pops."""

    def __init__(self, maxsize: int = 16):
        if maxsize < 1:
            raise ValueError(f"queue size must be >= 1, got {maxsize}")
        self.maxsize = maxsize
        self.admitted = 0
        self.rejected = 0
        self._items: deque = deque()
        self._lock = threading.Lock()

    def offer(self, item: Any) -> bool:
        with self._lock:
            if len(self._items) >= self.maxsize:
                self.rejected += 1
                return False
            self._items.append(item)
            self.admitted += 1
            return True

    def pop(self) -> Any | None:
        with self._lock:
            return self._items.popleft() if self._items else None

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)


class RingCache:
    """Fixed-slot FIFO response cache fronting repeated prompts.

    The decode path already reuses state through the model's sliding-window
    ring buffer (``test_ring_cache.py``); this is the request-level analogue
    — a fixed number of slots, overwrite-oldest on overflow — so a repeated
    prompt skips preprocessing *and* decoding entirely. Keys should bind
    the row-program fingerprint (see :func:`serve_text`), making a stale
    hit across plan or vocab changes structurally impossible."""

    def __init__(self, slots: int = 64):
        if slots < 1:
            raise ValueError(f"cache slots must be >= 1, got {slots}")
        self.slots = slots
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._data: OrderedDict = OrderedDict()

    def get(self, key: Any) -> list[int] | None:
        hit = self._data.get(key)
        if hit is None:
            self.misses += 1
            return None
        self.hits += 1
        return list(hit)

    def put(self, key: Any, value: Sequence[int]) -> None:
        if key in self._data:
            self._data[key] = list(value)
            return
        if len(self._data) >= self.slots:
            self._data.popitem(last=False)  # FIFO: overwrite-oldest
            self.evictions += 1
        self._data[key] = list(value)

    def __len__(self) -> int:
        return len(self._data)


@dataclass
class ServeStats:
    """One serve run's ledger: admission/shed/filter counters, ring-cache
    accounting, the preprocess-vs-decode wall-time split, and per-request
    end-to-end latency (admission offer -> final token)."""

    admitted: int = 0
    rejected: int = 0
    filtered: int = 0
    served: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    preprocess_s: float = 0.0
    decode_s: float = 0.0
    latency_s: dict[int, float] = field(default_factory=dict)


def _continuous_decode(
    model,
    params,
    next_item: Callable[[], tuple[int, np.ndarray, int] | None],
    on_done: Callable[[int, list[int]], None],
    *,
    slots: int = 4,
    max_seq: int = 128,
    eos_id: int = 2,
    cache_dtype=jnp.float32,
) -> None:
    """The continuous-batching slot driver: fixed decode slots; a finished
    slot refills immediately from ``next_item`` (block prefill, one slot at
    a time, per-slot position tracking). ``next_item`` returns
    ``(uid, prompt, max_new)`` or None when drained; ``on_done`` receives
    each request's generated tokens."""
    step = jax.jit(make_serve_step(model))
    prefill = jax.jit(model.decode_step)

    # one independent state per slot (batch=1) so refills don't disturb others
    states = [model.init_decode_state(1, max_seq, cache_dtype) for _ in range(slots)]
    active: list[dict | None] = [None] * slots
    last_tok = [None] * slots

    def fill(slot: int) -> None:
        item = next_item()
        if item is None:
            active[slot] = None
            return
        uid, prompt, max_new = item
        states[slot] = model.init_decode_state(1, max_seq, cache_dtype)
        logits, states[slot] = prefill(
            params, jnp.asarray(prompt[None]), states[slot], jnp.int32(0)
        )
        nxt = int(jnp.argmax(logits[0, -1]))
        active[slot] = {"uid": uid, "max_new": max_new, "pos": len(prompt), "out": [nxt]}
        last_tok[slot] = nxt

    for s in range(slots):
        fill(s)

    while any(a is not None for a in active):
        for s in range(slots):
            a = active[s]
            if a is None:
                continue
            done = (
                last_tok[s] == eos_id
                or len(a["out"]) >= a["max_new"]
                or a["pos"] + 1 >= max_seq
            )
            if done:
                on_done(a["uid"], a["out"])
                fill(s)
                continue
            toks = jnp.full((1, 1), last_tok[s], jnp.int32)
            nxt, _, states[s] = step(params, toks, states[s], jnp.int32(a["pos"]))
            last_tok[s] = int(nxt[0, 0])
            a["out"].append(last_tok[s])
            a["pos"] += 1


def serve_requests(
    model,
    params,
    requests: Sequence[Request],
    *,
    slots: int = 4,
    max_seq: int = 128,
    eos_id: int = 2,
    cache_dtype=jnp.float32,
) -> dict[int, list[int]]:
    """Continuous-batching driver over pre-tokenized prompts (the legacy
    entry point; ``serve_text`` is the raw-text path)."""
    queue = deque(requests)
    results: dict[int, list[int]] = {}

    def next_item():
        if not queue:
            return None
        req = queue.popleft()
        return req.uid, req.prompt, req.max_new

    def on_done(uid: int, out: list[int]) -> None:
        results[uid] = out

    _continuous_decode(
        model,
        params,
        next_item,
        on_done,
        slots=slots,
        max_seq=max_seq,
        eos_id=eos_id,
        cache_dtype=cache_dtype,
    )
    return results


def _cache_key(row_program, text: str | Mapping[str, Any]) -> tuple:
    """Bind the response cache to this exact plan + vocabulary: any change
    to the compiled steps or the fitted tokenizer changes the fingerprint,
    so a redeploy can never serve stale cached completions."""
    if isinstance(text, Mapping):
        text_key: Any = tuple(sorted((str(k), str(v)) for k, v in text.items()))
    else:
        text_key = text
    return (row_program.fingerprint, text_key)


def serve_text(
    model,
    params,
    row_program,
    requests: Sequence[TextRequest],
    *,
    slots: int = 4,
    max_seq: int = 128,
    queue_size: int = 16,
    eos_id: int = 2,
    prompt_output: str | None = None,
    cache: RingCache | None = None,
    cache_dtype=jnp.float32,
    stats: ServeStats | None = None,
) -> dict[int, list[int]]:
    """End-to-end serving: raw text in, generated token lists out.

    Each request is checked against the ring cache at admission (key =
    row-program fingerprint + text; a hit completes immediately), then
    offered to the bounded admission queue — a full queue sheds the
    request on arrival (no entry in the result dict; counted in
    ``stats.rejected``). Admitted requests are preprocessed through the
    row program when a decode slot picks them up: the prompt is
    ``prompt_output``'s non-pad prefix (default: the program's first token
    output), clamped to ``max_seq - 1``. A request whose row the plan
    filters out — or that encodes to an empty prompt — is answered with
    ``[]`` and counted in ``stats.filtered``; it never occupies a slot.

    ``stats`` (a :class:`ServeStats`) receives counters, the
    preprocess-vs-decode time split, and per-uid end-to-end latency.
    """
    st = stats if stats is not None else ServeStats()
    out_name = prompt_output or row_program.output_names[0]
    queue = AdmissionQueue(queue_size)
    results: dict[int, list[int]] = {}
    offered_at: dict[int, float] = {}
    keys: dict[int, tuple] = {}
    t_start = time.perf_counter()

    for req in requests:
        key = _cache_key(row_program, req.text)
        now = time.perf_counter()
        if cache is not None:
            hit = cache.get(key)
            if hit is not None:
                results[req.uid] = hit
                st.cache_hits += 1
                st.served += 1
                st.latency_s[req.uid] = time.perf_counter() - now
                continue
            st.cache_misses += 1
        if queue.offer(req):
            offered_at[req.uid] = now
            keys[req.uid] = key
        else:
            st.rejected += 1
    st.admitted += queue.admitted  # += so one ledger can span serve waves

    def next_item():
        while True:
            req = queue.pop()
            if req is None:
                return None
            t0 = time.perf_counter()
            encoded = row_program(req.text)
            st.preprocess_s += time.perf_counter() - t0
            prompt = None if encoded is None else encoded[out_name][0]
            if prompt is not None:
                prompt = prompt[prompt != PAD_ID][: max_seq - 1]
            if prompt is None or prompt.size == 0:
                # Filtered by the plan (or cleaned to nothing): answer
                # empty immediately, don't burn a decode slot.
                results[req.uid] = []
                st.filtered += 1
                st.latency_s[req.uid] = time.perf_counter() - offered_at[req.uid]
                continue
            return req.uid, np.asarray(prompt, dtype=np.int32), req.max_new

    def on_done(uid: int, out: list[int]) -> None:
        results[uid] = out
        st.served += 1
        st.latency_s[uid] = time.perf_counter() - offered_at[uid]
        if cache is not None:
            cache.put(keys[uid], out)

    _continuous_decode(
        model,
        params,
        next_item,
        on_done,
        slots=slots,
        max_seq=max_seq,
        eos_id=eos_id,
        cache_dtype=cache_dtype,
    )
    st.decode_s += (time.perf_counter() - t_start) - st.preprocess_s
    return results
