"""Trip-count-aware cost model over post-partitioning HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE, which
undercounts scanned models (layers × microbatches × attention chunks) by
orders of magnitude. This module parses the compiled HLO, resolves each
while loop's static trip count from its condition (jax scans lower to
``while i < constant``), and accumulates:

* ``flops``       — dot-product FLOPs (2·M·N·K from result shape ×
  contraction size); matmul-dominated models ⇒ ≥95% of real FLOPs.
* ``bytes``       — per-instruction operand+result bytes over
  data-moving ops (the same accounting model XLA's bytes_accessed uses),
  i.e. an HBM-traffic upper bound.
* ``collectives`` — per-op-kind payload bytes (all-reduce counted 2× for
  ring wire traffic), trip-multiplied like everything else.

All numbers are PER DEVICE (the post-SPMD module is the per-device
program).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from functools import reduce
from operator import mul

SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
NAME_RE = re.compile(r"%[\w.\-]+")
CALL_ATTR_RE = re.compile(r"(?:calls|body|condition|to_apply)=(%[\w.\-]+)")
BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
CONST_RE = re.compile(r"constant\((\d+)\)")
LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
METADATA_RE = re.compile(r",?\s*metadata=\{[^}]*\}")
COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->")

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "token": 0, "s4": 1, "u4": 1,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")

# ops whose operand+result traffic we count toward HBM bytes
BYTE_OPS = {
    "dot", "fusion", "convolution", "reduce", "transpose", "copy", "convert",
    "gather", "scatter", "dynamic-slice", "dynamic-update-slice", "slice",
    "concatenate", "reverse", "pad", "select-and-scatter", "reduce-window",
    "sort", "iota", "broadcast", "cholesky", "triangular-solve",
} | set(COLLECTIVE_OPS) | {c + "-start" for c in COLLECTIVE_OPS}


def _prod(xs) -> int:
    return reduce(mul, xs, 1)


def _shapes_of(text: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dt, dims in SHAPE_RE.findall(text):
        dims_t = tuple(int(d) for d in dims.split(",")) if dims else ()
        out.append((dt, dims_t))
    return out


def _shape_bytes(text: str) -> int:
    return sum(DTYPE_BYTES.get(dt, 4) * _prod(dims) for dt, dims in _shapes_of(text))


@dataclass
class Instr:
    name: str
    op: str
    result_txt: str
    operands: list[str]
    calls: list[str]
    attrs_txt: str


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)
    shape_of: dict[str, str] = field(default_factory=dict)  # name -> result text
    const_of: dict[str, int] = field(default_factory=dict)
    root: str | None = None


_OP_TOKEN_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")


def parse_module(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = ""
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" "):  # computation header or module line
            m = COMP_HEADER_RE.match(line)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
            continue
        if cur is None or line.strip() == "}":
            continue
        clean = METADATA_RE.sub("", line)
        m = _OP_TOKEN_RE.match(clean)
        if not m:
            continue
        name, result_txt, op = m.group(1), m.group(2), m.group(3)
        rest = clean[m.end():]
        # operand segment: up to matching close paren (approx: first ')')
        operand_seg = rest.split(")", 1)[0]
        operands = NAME_RE.findall(operand_seg)
        calls = CALL_ATTR_RE.findall(clean)
        calls += [c.strip() for c in
                  (BRANCH_RE.search(clean).group(1).split(",") if BRANCH_RE.search(clean) else [])]
        ins = Instr(name, op, result_txt, operands, calls, attrs_txt=clean)
        cur.instrs.append(ins)
        cur.shape_of[name] = result_txt
        if op == "constant":
            cm = CONST_RE.search(clean)
            if cm:
                cur.const_of[name] = int(cm.group(1))
        if clean.lstrip().startswith("ROOT"):
            cur.root = name
    return comps, entry


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = field(default_factory=lambda: {k: 0.0 for k in COLLECTIVE_OPS})
    coll_counts: dict = field(default_factory=lambda: {k: 0 for k in COLLECTIVE_OPS})
    unresolved_whiles: int = 0

    def add(self, other: "Cost", times: float = 1.0) -> None:
        self.flops += other.flops * times
        self.bytes += other.bytes * times
        for k in self.coll:
            self.coll[k] += other.coll[k] * times
            self.coll_counts[k] += int(other.coll_counts[k] * times)
        self.unresolved_whiles += other.unresolved_whiles


def _instr_bytes(ins: Instr, comp: Computation) -> float:
    """Op-aware HBM traffic model (upper bound, XLA bytes_accessed style):

    * dynamic-slice / slice / gather: result + indices (NOT the full operand)
    * dynamic-update-slice: 2x update size (read update, write region)
    * broadcast / iota: result only
    * everything else: operands + result
    """
    res = _shape_bytes(ins.result_txt)
    if ins.op in ("dynamic-slice", "slice", "gather"):
        return 2.0 * res
    if ins.op == "dynamic-update-slice":
        upd = _shape_bytes(comp.shape_of.get(ins.operands[1], "")) if len(ins.operands) > 1 else res
        return 2.0 * upd
    if ins.op in ("broadcast", "iota"):
        return res
    return res + sum(_shape_bytes(comp.shape_of.get(o, "")) for o in ins.operands)


def _dot_flops(ins: Instr, comp: Computation, comps: dict[str, Computation]) -> float:
    out_elems = sum(_prod(d) for _, d in _shapes_of(ins.result_txt))
    cm = LHS_CDIMS_RE.search(ins.attrs_txt)
    k = 1
    if cm and ins.operands:
        lhs_txt = comp.shape_of.get(ins.operands[0])
        if lhs_txt:
            shapes = _shapes_of(lhs_txt)
            if shapes:
                dims = shapes[0][1]
                for d in cm.group(1).split(","):
                    if d and int(d) < len(dims):
                        k *= dims[int(d)]
    return 2.0 * out_elems * k


def _trip_count(while_ins: Instr, comps: dict[str, Computation]) -> int | None:
    """Resolve static trip count from the while condition computation:
    look for a constant operand of the root compare (possibly wrapped in a
    fusion)."""
    cond_name = None
    for c in while_ins.calls:
        if c in comps and comps[c].root is not None:
            # heuristics: condition computations return pred[]
            root = comps[c].shape_of.get(comps[c].root, "")
            if root.startswith("pred"):
                cond_name = c
                break
    if cond_name is None:
        return None
    comp = comps[cond_name]
    root_ins = next((i for i in comp.instrs if i.name == comp.root), None)
    if root_ins is None:
        return None

    def const_from(ins: Instr, depth: int = 0) -> int | None:
        for opnd in ins.operands:
            if opnd in comp.const_of:
                return comp.const_of[opnd]
        # wrapped compare: fusion calls a tiny computation; constants are
        # operands of the fusion itself (handled above) or inside
        for c in ins.calls:
            sub = comps.get(c)
            if sub:
                for i2 in sub.instrs:
                    if i2.op == "constant" and i2.name in sub.const_of:
                        return sub.const_of[i2.name]
        return None

    return const_from(root_ins)


def _comp_cost(name: str, comps: dict[str, Computation], memo: dict[str, Cost]) -> Cost:
    if name in memo:
        return memo[name]
    memo[name] = Cost()  # cycle guard
    comp = comps.get(name)
    total = Cost()
    if comp is None:
        memo[name] = total
        return total
    for ins in comp.instrs:
        if ins.op == "dot":
            total.flops += _dot_flops(ins, comp, comps)
            total.bytes += _instr_bytes(ins, comp)
        elif ins.op == "while":
            body_cost = Cost()
            for c in ins.calls:
                body_cost.add(_comp_cost(c, comps, memo))
            trips = _trip_count(ins, comps)
            if trips is None:
                trips = 1
                total.unresolved_whiles += 1
            total.add(body_cost, times=trips)
        elif ins.op in ("call", "conditional", "fusion", "reduce", "map", "scatter",
                        "select-and-scatter", "sort", "custom-call"):
            for c in ins.calls:
                total.add(_comp_cost(c, comps, memo))
            if ins.op in BYTE_OPS:
                total.bytes += _instr_bytes(ins, comp)
        else:
            base_op = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if base_op in COLLECTIVE_OPS:
                b = _shape_bytes(ins.result_txt)
                if base_op == "all-reduce":
                    b *= 2
                total.coll[base_op] += b
                total.coll_counts[base_op] += 1
                total.bytes += _shape_bytes(ins.result_txt)
            elif ins.op in BYTE_OPS:
                total.bytes += _instr_bytes(ins, comp)
    memo[name] = total
    return total


def analyze(hlo_text: str) -> dict:
    comps, entry = parse_module(hlo_text)
    if not entry:
        # fall back: biggest computation
        entry = max(comps, key=lambda c: len(comps[c].instrs)) if comps else ""
    memo: dict[str, Cost] = {}
    cost = _comp_cost(entry, comps, memo)
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "collective_bytes": dict(cost.coll),
        "collective_counts": dict(cost.coll_counts),
        "collective_total": sum(cost.coll.values()),
        "unresolved_whiles": cost.unresolved_whiles,
        "n_computations": len(comps),
    }
