"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm_3b --smoke \
        --steps 30 --batch 8 --seq-len 64 --ckpt /tmp/run1

Wires the full stack: P3SAPP preprocessing -> packed LM batches -> mesh ->
logical-axis shardings -> microbatched train step -> fault-tolerant
checkpointed loop (resume-from-latest on restart). On CPU containers use
--smoke (reduced config); on a real pod the same flags drive the full
config with `make_production_mesh`.
"""

from __future__ import annotations

import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCH_IDS, get, get_smoke
from ..core.dataset import Dataset
from ..core.expr import abstract_expr, col, title_expr
from ..data.synthetic import write_corpus
from ..distributed.sharding import DEFAULT_RULES, data_axis_names, tree_shardings
from ..models.lm import LM, MeshContext
from ..optim.adamw import AdamW, warmup_cosine
from ..runtime.fault_tolerance import TrainController
from ..runtime.train_loop import TrainStepConfig, make_train_step
from .mesh import make_host_mesh, make_production_mesh, set_mesh


def build_dataset(cfg, seq_len: int, corpus_mb: float, seed: int) -> np.ndarray:
    corpus = tempfile.mkdtemp(prefix="p3sapp_train_")
    write_corpus(corpus, total_bytes=int(corpus_mb * 1e6), n_files=6, seed=seed)
    # The canonical chain in expression form (see repro.core.expr):
    # where() predicates filter on raw byte buffers before any cleaning,
    # transform() fuses the per-column expression chains.
    keep = col("title").not_empty() & col("abstract").not_empty()
    ds = (
        Dataset.from_json_dirs([corpus])
        .where(keep)
        .drop_duplicates()
        .transform(abstract=abstract_expr(), title=title_expr())
        .where(keep)
    )
    records, timings = ds.execute(optimize=True)
    print(f"P3SAPP: {len(records)} records in {timings.cumulative:.2f}s")
    # vocabulary fitting as a plan verb (shard-merged counts when the
    # frame is not yet materialized; here it reuses the memoized frame)
    tok = ds.fit_vocab(["abstract"], vocab_size=cfg.vocab_size)
    stream: list[int] = []
    for r in records:
        stream.extend(tok.stoi.get(w, 3) for w in r["abstract"].split())
    n = (len(stream) // seq_len) * seq_len
    return np.asarray(stream[:n], np.int32).reshape(-1, seq_len) % cfg.vocab_size


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="stablelm_3b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--corpus-mb", type=float, default=2.0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 mesh (requires 256 devices)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    # Tuned env for everything forked from here (shard-executor workers,
    # remote worker spawns). LD_PRELOAD/XLA pinning for *this* process must
    # come from the wrapper: python -m repro.launch.env -- python -m ...
    from .env import apply as apply_tuned_env

    apply_tuned_env()

    cfg = get_smoke(args.arch) if args.smoke else get(args.arch)
    mesh = (
        make_production_mesh() if args.production_mesh
        else make_host_mesh(model_parallel=args.model_parallel)
    )
    print(f"arch={cfg.name} mesh={dict(mesh.shape)} params~{cfg.param_count()/1e6:.1f}M")

    seqs = build_dataset(cfg, args.seq_len, args.corpus_mb, seed=0)
    mctx = MeshContext(mesh, data_axis_names(mesh), "model")
    model = LM(cfg, mctx, remat=True, dtype=jnp.float32)
    opt = AdamW(learning_rate=warmup_cosine(args.lr, 10, args.steps))
    step = make_train_step(model.loss, opt, TrainStepConfig(args.microbatches))

    with set_mesh(mesh):
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        shardings = tree_shardings(shapes, model.param_axes(), mesh, DEFAULT_RULES)

        def init_state():
            params = jax.tree.map(
                jax.device_put, model.init(jax.random.PRNGKey(0)), shardings
            )
            return params, opt.init(params)

        jstep = jax.jit(step, donate_argnums=(0, 1))
        ckpt = args.ckpt or tempfile.mkdtemp(prefix="p3sapp_ckpt_")
        controller = TrainController(
            ckpt, jstep, init_state, save_every=args.save_every
        )
        if controller.resumed:
            print(f"resumed from step {controller.step}")

        bsh = NamedSharding(mesh, P(data_axis_names(mesh) if len(data_axis_names(mesh)) > 1 else "data", None))
        rng = np.random.default_rng(controller.step)

        def stream():
            while True:
                idx = rng.integers(0, len(seqs), size=args.batch)
                yield {"tokens": jax.device_put(jnp.asarray(seqs[idx]), bsh)}

        history = controller.run(stream(), n_steps=args.steps)
    for h in history[:: max(len(history) // 6, 1)]:
        print(f"step {h['step']:5d} loss={h['loss']:.4f} gnorm={h['grad_norm']:.3f}")
    print(f"final checkpoint at step {controller.step} in {ckpt}")


if __name__ == "__main__":
    main()
