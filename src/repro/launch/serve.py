"""Serving launcher: batched continuous-batching decode.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm_3b --smoke \
        --requests 8 --slots 4
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get, get_smoke
from ..models.lm import LM
from ..runtime.serve_loop import Request, serve_requests


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="stablelm_3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get(args.arch)
    if not cfg.causal:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")
    model = LM(cfg, remat=False, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    reqs = [
        Request(
            uid=i,
            prompt=rng.integers(4, cfg.vocab_size, size=int(rng.integers(4, 16))).astype(np.int32),
            max_new=args.max_new,
        )
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    results = serve_requests(
        model, params, reqs, slots=args.slots, max_seq=args.max_seq
    )
    dt = time.perf_counter() - t0
    n_tokens = sum(len(v) for v in results.values())
    print(f"served {len(results)} requests / {n_tokens} tokens in {dt:.2f}s "
          f"({n_tokens/dt:.1f} tok/s through {args.slots} slots)")
    for uid in sorted(results)[:4]:
        print(f"  req {uid}: {results[uid]}")


if __name__ == "__main__":
    main()
