"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (required so smoke tests see 1 device while the
dry-run sees 512 placeholder host devices).
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model). Multi-pod: 2 pods of
    256 = 512 chips (pod, data, model); the ``pod`` axis is an extra
    data-parallel dimension whose collectives cross the inter-pod links."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (CPU tests, examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh(
        (n // model_parallel, model_parallel), ("data", "model"),
        axis_types=(AxisType.Auto, AxisType.Auto),
    )
