"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (required so smoke tests see 1 device while the
dry-run sees 512 placeholder host devices).
"""

from __future__ import annotations

import jax

try:  # jax >= 0.4.38; older versions predate explicit axis types
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None


def _axis_kwargs(n_axes: int) -> dict:
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def shard_map(fn, **kwargs):
    """Version-portable ``shard_map``: top-level ``jax.shard_map`` (jax >=
    0.6, replication check spelled ``check_vma``) or the experimental home
    (``check_rep``) on older versions."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map

    if "check_vma" in kwargs:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    return _shard_map(fn, **kwargs)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.sharding.set_mesh`` where it exists; on older jax the mesh object
    itself is the context manager."""
    if hasattr(jax.sharding, "set_mesh"):
        return jax.sharding.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model). Multi-pod: 2 pods of
    256 = 512 chips (pod, data, model); the ``pod`` axis is an extra
    data-parallel dimension whose collectives cross the inter-pod links."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (CPU tests, examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh(
        (n // model_parallel, model_parallel), ("data", "model"),
        **_axis_kwargs(2),
    )
