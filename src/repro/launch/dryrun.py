import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) cell and extract the roofline inputs.

The two lines above MUST precede every other import (jax locks the device
count at first init). Do NOT import this module from tests — it is a CLI:

    PYTHONPATH=src python -m repro.launch.dryrun --mesh single --cells all
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi  --cells kimi_k2_1t_a32b:train_4k

Per cell this produces benchmarks/results/dryrun/<mesh>/<arch>__<shape>.json
with: compiled FLOPs / bytes (cost_analysis), per-collective byte totals
parsed from the post-SPMD HLO, memory analysis when the backend provides
it, and analytic MODEL_FLOPS for the §Roofline usefulness ratio.
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import SHAPES, all_cells, get
from ..distributed.sharding import (
    DEFAULT_RULES,
    FSDP_RULES,
    batch_spec,
    data_axis_names,
    tree_shardings,
    with_shardings,
)
from ..models.lm import LM, MeshContext
from ..optim.adamw import AdamW
from ..runtime.train_loop import TrainStepConfig, make_train_step
from .mesh import make_production_mesh

# Per-arch training microbatch rows per device-shard (activation memory).
MICRO_ROWS = {
    "hubert_xlarge": 4,
    "deepseek_moe_16b": 2,
    "kimi_k2_1t_a32b": 1,
    "stablelm_3b": 8,
    "command_r_plus_104b": 1,
    "granite_20b": 2,
    "qwen2_5_32b": 2,
    "recurrentgemma_9b": 4,
    "xlstm_1_3b": 8,
    "qwen2_vl_72b": 1,
}

# FSDP (params sharded over the data axis) for archs that cannot replicate.
FSDP_ARCHS = {
    "kimi_k2_1t_a32b", "command_r_plus_104b", "qwen2_vl_72b",
    "qwen2_5_32b", "granite_20b", "deepseek_moe_16b",
}

# --plan optimized: the per-arch hillclimb configurations (EXPERIMENTS.md
# §Perf). Baseline cells stay as recorded under results/dryrun/.
from ..distributed.sharding import SP_RULES  # noqa: E402

_KIMI_RULES = dict(FSDP_RULES)
_KIMI_RULES["expert_ff"] = (("data",),)  # TP-in-expert: weights stay resident
# 64 q-heads shard cleanly over the 16-way model axis; keep K/V replicated
# instead of falling back to head_dim sharding (which put an all-reduce in
# every attention chunk step — measured in iteration 1)
_KIMI_RULES["kv_heads"] = ()
_KIMI_RULES["head_dim"] = ()

OPTIMIZED_PLANS: dict[str, dict] = {
    # worst roofline fraction: chunkwise mLSTM (model-code change) — no
    # sharding overrides needed, recompilation picks it up
    "xlstm_1_3b": {},
    # most collective-bound: sequence-parallel + ZeRO-3, kv-only attention
    # streaming, single macrobatch
    "qwen2_5_32b": {
        "rules": SP_RULES,
        "micro_rows": 16,
        "seq_parallel": True,
        "cfg_updates": {"attn_q_chunk": 0},
    },
    # 1T-scale MoE: batched expert GEMMs + expert weights resident
    # (expert_ff over data) + fewer microbatches + Q-head TP with
    # replicated KV (iteration 2)
    "kimi_k2_1t_a32b": {
        "rules": _KIMI_RULES,
        "micro_rows": 4,
        "moe_impl": "batched",
    },
    # bonus: deepseek with batched experts (same family as kimi)
    "deepseek_moe_16b": {"moe_impl": "batched"},
}

COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+\[[^\]]*\]))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device collective payload bytes from post-partitioning HLO.

    Methodology: result-shape bytes per op; all-reduce counted 2x (ring =
    reduce-scatter + all-gather wire traffic)."""
    out = {k: 0 for k in
           ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")}
    counts = dict(out)
    for m in COLLECTIVE_RE.finditer(hlo_text):
        shape_txt, op = m.group(1), m.group(2)
        b = _shape_bytes(shape_txt)
        if op == "all-reduce":
            b *= 2
        out[op] += b
        counts[op] += 1
    return {"bytes": out, "counts": counts, "total_bytes": sum(out.values())}


def input_specs(arch: str, shape_name: str, mesh):
    """ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no device
    allocation) for every model input of this cell."""
    cfg = get(arch)
    shp = SHAPES[shape_name]
    from jax.sharding import NamedSharding

    b, s = shp.global_batch, shp.seq_len
    bspec = batch_spec(mesh, b)

    def sh(spec):
        return NamedSharding(mesh, spec)

    from jax.sharding import PartitionSpec as P

    def bsp(*rest):
        return sh(P(*((bspec[0] if len(bspec) else None,) + rest)))

    if shp.kind == "train":
        if cfg.frontend == "audio":
            return {
                "frames": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim), jnp.bfloat16, sharding=bsp(None, None)),
                "labels": jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=bsp(None)),
            }
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=bsp(None))}
        if cfg.frontend == "vision":
            batch["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.bfloat16, sharding=bsp(None, None)
            )
        return batch
    if shp.kind == "prefill":
        if cfg.frontend == "audio":
            return {
                "frames": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim), jnp.bfloat16, sharding=bsp(None, None)),
            }
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=bsp(None))}
        if cfg.frontend == "vision":
            batch["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.bfloat16, sharding=bsp(None, None)
            )
        return batch
    # decode: one new token against a seq_len cache
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32, sharding=bsp(None))}


def build_cell(arch: str, shape_name: str, mesh, *, param_dtype=jnp.bfloat16,
               plan: dict | None = None):
    import dataclasses

    cfg = get(arch)
    shp = SHAPES[shape_name]
    plan = plan or {}
    if plan.get("cfg_updates"):
        cfg = dataclasses.replace(cfg, **plan["cfg_updates"])
    if plan.get("moe_impl") and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, expert_impl=plan["moe_impl"])
        )
    mctx = MeshContext(
        mesh, data_axis_names(mesh), "model",
        seq_axis="model" if plan.get("seq_parallel") else "",
    )
    model = LM(cfg, mctx, remat=True, dtype=param_dtype)
    rules = plan.get("rules") or (FSDP_RULES if arch in FSDP_ARCHS else DEFAULT_RULES)

    param_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    param_sh = tree_shardings(param_sds, model.param_axes(), mesh, rules)
    params_in = with_shardings(param_sds, param_sh)
    batch = input_specs(arch, shape_name, mesh)

    from jax.sharding import NamedSharding, PartitionSpec as P

    repl = NamedSharding(mesh, P())

    if shp.kind == "train":
        micro_rows = plan.get("micro_rows", MICRO_ROWS.get(arch, 4))
        dp = int(np.prod([mesh.shape[a] for a in data_axis_names(mesh)]))
        n_micro = max(1, shp.global_batch // (micro_rows * dp))
        opt = AdamW(learning_rate=1e-4)
        step = make_train_step(model.loss, opt, TrainStepConfig(n_microbatches=n_micro))
        opt_sds = jax.eval_shape(opt.init, param_sds)
        opt_sh = type(opt_sds)(
            count=repl,
            m=tree_shardings(opt_sds.m, model.param_axes(), mesh, rules),
            v=tree_shardings(opt_sds.v, model.param_axes(), mesh, rules),
        )
        opt_in = with_shardings(opt_sds, opt_sh)
        metrics_sh = {"loss": repl, "grad_norm": repl}
        jitted = jax.jit(
            step,
            in_shardings=(param_sh, opt_sh, jax.tree.map(lambda s: s.sharding, batch)),
            out_shardings=(param_sh, opt_sh, metrics_sh),
            donate_argnums=(0, 1),
        )
        args = (params_in, opt_in, batch)
        extra = {"n_microbatches": n_micro}
    elif shp.kind == "prefill":
        if not cfg.causal:
            def fwd(params, batch):
                logits, _ = model.forward(params, batch)
                return logits
            logits_sh = NamedSharding(
                mesh, P(batch_spec(mesh, shp.global_batch)[0] if len(batch_spec(mesh, shp.global_batch)) else None, None, None)
            )
            jitted = jax.jit(fwd, in_shardings=(param_sh, jax.tree.map(lambda s: s.sharding, batch)),
                             out_shardings=logits_sh)
            args = (params_in, batch)
            extra = {}
        else:
            state_sds = jax.eval_shape(
                lambda: model.init_decode_state(shp.global_batch, shp.seq_len, jnp.bfloat16)
            )
            state_sh = tree_shardings(state_sds, model.decode_state_axes(), mesh, rules)
            state_in = with_shardings(state_sds, state_sh)

            def prefill(params, tokens_batch, state):
                logits, state = model.decode_step(params, tokens_batch["tokens"], state, jnp.int32(0))
                return logits, state

            bs = batch_spec(mesh, shp.global_batch)
            logits_sh = NamedSharding(mesh, P(bs[0] if len(bs) else None))
            jitted = jax.jit(
                prefill,
                in_shardings=(param_sh, jax.tree.map(lambda s: s.sharding, batch), state_sh),
                out_shardings=(logits_sh, state_sh),
                donate_argnums=(2,),
            )
            args = (params_in, batch, state_in)
            extra = {}
    else:  # decode
        state_sds = jax.eval_shape(
            lambda: model.init_decode_state(shp.global_batch, shp.seq_len, jnp.bfloat16)
        )
        state_sh = tree_shardings(state_sds, model.decode_state_axes(), mesh, rules)
        state_in = with_shardings(state_sds, state_sh)

        def decode(params, tokens_batch, state, pos):
            logits, state = model.decode_step(params, tokens_batch["tokens"], state, pos)
            return logits, state

        bs = batch_spec(mesh, shp.global_batch)
        jitted = jax.jit(
            decode,
            in_shardings=(param_sh, jax.tree.map(lambda s: s.sharding, batch), state_sh, repl),
            out_shardings=(NamedSharding(mesh, P(bs[0] if len(bs) else None)), state_sh),
            donate_argnums=(2,),
        )
        pos = jax.ShapeDtypeStruct((), jnp.int32, sharding=repl)
        args = (params_in, batch, state_in, pos)
        extra = {}
    return jitted, args, extra


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic useful FLOPs: 6·N_active·tokens (train) / 2·N_active·tokens
    (inference forward), attention KV term excluded (recorded separately)."""
    cfg = get(arch)
    shp = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shp.kind == "train":
        tokens = shp.global_batch * shp.seq_len
        return 6.0 * n_active * tokens
    if shp.kind == "prefill":
        tokens = shp.global_batch * shp.seq_len
        return 2.0 * n_active * tokens
    tokens = shp.global_batch  # one token per request
    return 2.0 * n_active * tokens


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             plan: dict | None = None) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    out_path = out_dir / mesh_name / f"{arch}__{shape_name}.json"
    out_path.parent.mkdir(parents=True, exist_ok=True)
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if plan:
        rec["plan"] = {k: str(v)[:200] for k, v in plan.items()}
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec["mesh_shape"] = dict(mesh.shape)
    rec["n_devices"] = int(np.prod(list(mesh.shape.values())))
    try:
        t0 = time.time()
        jitted, args, extra = build_cell(arch, shape_name, mesh, plan=plan)
        with mesh:
            lowered = jitted.lower(*args)
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)
        try:
            ca = compiled.cost_analysis()
            ca = ca[0] if isinstance(ca, (list, tuple)) else ca
            rec["cost_analysis"] = {
                "flops": float(ca.get("flops", -1)),
                "bytes_accessed": float(ca.get("bytes accessed", -1)),
            }
        except Exception as e:  # pragma: no cover
            rec["cost_analysis"] = {"error": str(e)}
        try:
            ma = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: int(getattr(ma, k))
                for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes",
                )
                if hasattr(ma, k)
            } if ma is not None else None
        except Exception as e:  # pragma: no cover
            rec["memory_analysis"] = {"error": str(e)}
        hlo = compiled.as_text()
        rec["collectives"] = collective_bytes(hlo)  # flat (body-once) view
        from .hlo_cost import analyze

        rec["hlo_cost"] = analyze(hlo)  # trip-count-aware per-device costs
        rec["hlo_chars"] = len(hlo)
        try:
            import zstandard

            comp_path = out_path.with_suffix(".hlo.zst")
            comp_path.write_bytes(zstandard.ZstdCompressor(level=6).compress(hlo.encode()))
        except Exception:
            pass
        rec["model_flops"] = model_flops(arch, shape_name)
        rec["params"] = get(arch).param_count()
        rec["active_params"] = get(arch).active_param_count()
        rec.update(extra)
        rec["ok"] = True
    except Exception as e:
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-3000:]
    out_path.write_text(json.dumps(rec, indent=1))
    status = "OK" if rec["ok"] else f"FAIL ({rec['error'][:120]})"
    print(f"[{mesh_name}] {arch} x {shape_name}: {status}", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--cells", default="all", help="all | arch:shape[,arch:shape...]")
    ap.add_argument("--arch", default=None, help="restrict to one architecture")
    ap.add_argument("--plan", choices=["baseline", "optimized"], default="baseline")
    ap.add_argument("--out", default=None)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    if args.out is None:
        args.out = (
            "benchmarks/results/dryrun"
            if args.plan == "baseline"
            else "benchmarks/results/dryrun_opt"
        )

    cells = all_cells()
    if args.cells != "all":
        want = [tuple(c.split(":")) for c in args.cells.split(",")]
        cells = [c for c in cells if c in want]
    if args.arch:
        cells = [c for c in cells if c[0] == args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    out_dir = Path(args.out)

    n_fail = 0
    for multi in meshes:
        for arch, shape in cells:
            mesh_name = "multi" if multi else "single"
            p = out_dir / mesh_name / f"{arch}__{shape}.json"
            if args.skip_existing and p.exists() and json.loads(p.read_text()).get("ok"):
                print(f"[{mesh_name}] {arch} x {shape}: cached OK", flush=True)
                continue
            if args.plan == "optimized":
                plan = OPTIMIZED_PLANS.get(arch, {})
            else:
                # baseline = the recorded paper-faithful state: full-size
                # (non-ring) KV caches, ragged experts, TP rules
                plan = {"cfg_updates": {"ring_kv": False}}
            rec = run_cell(arch, shape, multi, out_dir, plan=plan)
            n_fail += 0 if rec["ok"] else 1
    print(f"dry-run complete, {n_fail} failures", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
