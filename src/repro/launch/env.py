"""Tuned launch environment: the process-level half of device overlap.

The input pipeline hides preprocessing behind device compute only if the
host side is not sabotaged by its own runtime: glibc malloc serializes the
multi-threaded byte-buffer churn (tcmalloc fixes it), TensorFlow's logging
taxes every worker fork, and on CPU containers jax presents one device
unless XLA is told to pin a host device count. This module derives the
production environment (the ``run.sh`` idiom of large-scale JAX trainers)
as data, so it is unit-testable and composes with an existing
environment instead of clobbering it:

    # print eval-able exports
    PYTHONPATH=src python -m repro.launch.env --devices 8

    # re-exec a training command under the tuned env (LD_PRELOAD needs to
    # be set before the process starts, so exec is the honest wiring)
    PYTHONPATH=src python -m repro.launch.env --devices 8 -- \
        python -m repro.launch.train --arch stablelm_3b --smoke
"""

from __future__ import annotations

import argparse
import os
import shlex
from typing import Mapping, Sequence

# Preload candidates, most specific first: full tcmalloc, then the
# minimal build Debian/Ubuntu ship by default.
TCMALLOC_CANDIDATES: tuple[str, ...] = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
    "/usr/lib/libtcmalloc_minimal.so.4",
)

# Keep numpy's large transient buffers (flat byte buffers, token arrays)
# below tcmalloc's large-alloc report chatter.
TCMALLOC_REPORT_THRESHOLD = "60000000000"


def find_tcmalloc(candidates: Sequence[str] | None = None) -> str | None:
    """First present tcmalloc shared object, or None (then no preload)."""
    if candidates is None:
        candidates = TCMALLOC_CANDIDATES  # read at call time: patchable
    for path in candidates:
        if os.path.exists(path):
            return path
    return None


def merge_xla_flags(existing: str, *flags: str) -> str:
    """Append ``flags`` to an ``XLA_FLAGS`` string, letting the new value
    win when the same ``--flag=`` is already present (re-launching with a
    different device count must not silently keep the old pin)."""
    merged: list[str] = []
    names = {f.split("=", 1)[0] for f in flags}
    for tok in existing.split():
        if tok.split("=", 1)[0] not in names:
            merged.append(tok)
    merged.extend(flags)
    return " ".join(merged)


def tuned_env(
    host_device_count: int | None = None,
    *,
    tcmalloc: bool = True,
    base: Mapping[str, str] | None = None,
) -> dict[str, str]:
    """The tuned launch variables as a plain dict.

    ``base`` (default ``os.environ``) supplies existing values to merge
    with — notably ``XLA_FLAGS``, which is extended, not replaced. Only
    variables this helper owns are returned; apply them with
    :func:`apply` or export them from a wrapper shell.
    """
    base = os.environ if base is None else base
    env: dict[str, str] = {
        # silence TF/absl banner spam in every worker process
        "TF_CPP_MIN_LOG_LEVEL": "4",
        # fp32 default without forcing x64 everywhere
        "JAX_DEFAULT_DTYPE_BITS": "32",
    }
    if tcmalloc:
        lib = find_tcmalloc()
        if lib is not None:
            env["LD_PRELOAD"] = lib
            env["TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD"] = TCMALLOC_REPORT_THRESHOLD
    if host_device_count is not None:
        if host_device_count < 1:
            raise ValueError(f"host_device_count must be >= 1, got {host_device_count}")
        env["XLA_FLAGS"] = merge_xla_flags(
            base.get("XLA_FLAGS", ""),
            f"--xla_force_host_platform_device_count={host_device_count}",
        )
    return env


def apply(
    env: Mapping[str, str] | None = None,
    *,
    host_device_count: int | None = None,
    overwrite: bool = False,
) -> dict[str, str]:
    """Set the tuned variables on ``os.environ`` and return what was set.

    Values the user already exported win unless ``overwrite=True``
    (``XLA_FLAGS`` from :func:`tuned_env` already merged them). Note
    ``LD_PRELOAD`` only affects *future* processes (worker forks, an
    ``exec``'d trainer) — preloading the current process is the wrapper
    shell's job (see module docstring).
    """
    env = tuned_env(host_device_count) if env is None else dict(env)
    applied: dict[str, str] = {}
    for k, v in env.items():
        if overwrite or k not in os.environ:
            os.environ[k] = v
            applied[k] = v
    return applied


def render_exports(env: Mapping[str, str]) -> str:
    """Eval-able ``export K=V`` lines for a wrapper shell."""
    return "\n".join(f"export {k}={shlex.quote(v)}" for k, v in sorted(env.items()))


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument(
        "--devices", type=int, default=None,
        help="pin --xla_force_host_platform_device_count",
    )
    ap.add_argument(
        "--no-tcmalloc", action="store_true", help="skip the LD_PRELOAD probe"
    )
    ap.add_argument(
        "command", nargs="*",
        help="after '--': command to exec under the tuned environment",
    )
    args = ap.parse_args(argv)
    env = tuned_env(args.devices, tcmalloc=not args.no_tcmalloc)
    if args.command:
        os.environ.update(env)
        os.execvpe(args.command[0], list(args.command), os.environ)
    print(render_exports(env))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
