"""Sharded, atomic checkpointing with resume-after-failure semantics.

Design (orbax is not available offline; this is a self-contained
equivalent for the features the runtime needs):

* **Atomicity** — a checkpoint is written to ``step_N.tmp/`` and renamed to
  ``step_N/`` only after the manifest fsync; a crash mid-write can never
  produce a loadable-but-corrupt checkpoint. ``latest()`` only ever sees
  committed steps.
* **Sharded layout** — every array leaf is saved as its own ``.npy``
  (addressable shards would map 1:1 onto per-host files on a real pod;
  here one process owns all shards). The manifest records the tree
  structure, dtypes, shapes and the step.
* **Resharding restore** — arrays are loaded to host then ``device_put``
  with whatever sharding the *current* mesh dictates, so a checkpoint
  taken on one topology restores onto another (elastic scaling).
* **Retention** — keep the last K checkpoints (garbage-collect older).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    def path_str(p):
        parts = []
        for k in p:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            elif hasattr(k, "name"):
                parts.append(str(k.name))
            else:
                parts.append(str(k))
        return "/".join(parts)
    return [(path_str(p), leaf) for p, leaf in flat], treedef


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._async_thread: threading.Thread | None = None
        self._async_error: list[BaseException] = []

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: dict | None = None) -> Path:
        tmp = self.dir / f"step_{step:010d}.tmp"
        final = self.dir / f"step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaves, _ = _flatten_with_paths(tree)
        index = []
        for i, (path, leaf) in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            fname = f"leaf_{i:05d}.npy"
            np.save(tmp / fname, arr)
            index.append({"path": path, "file": fname,
                          "shape": list(arr.shape), "dtype": str(arr.dtype)})
        manifest = {
            "step": step,
            "time": time.time(),
            "leaves": index,
            "extra": extra or {},
        }
        mpath = tmp / _MANIFEST
        with open(mpath, "w") as fh:
            json.dump(manifest, fh)
            fh.flush()
            os.fsync(fh.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # commit point
        self._gc()
        return final

    # -- async save ----------------------------------------------------------
    def save_async(self, step: int, tree: Any, extra: dict | None = None) -> None:
        """Orbax-style async save: the device→host snapshot happens now
        (cheap, and consistent — later step updates can't corrupt it),
        file I/O runs in a background thread so the train loop never
        blocks on disk. ``wait()`` joins + re-raises."""
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work() -> None:
            try:
                self.save(step, host_tree, extra)
            except BaseException as e:  # surfaced by wait()
                self._async_error.append(e)

        self._async_thread = threading.Thread(target=work, daemon=True)
        self._async_thread.start()

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None
        if self._async_error:
            raise self._async_error.pop()

    # -- load ---------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / _MANIFEST).exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, tree_like: Any, step: int | None = None,
                shardings: Any | None = None) -> tuple[Any, dict]:
        """Restore into the structure of ``tree_like``. With ``shardings``
        (same structure), leaves are placed with those shardings —
        topology-independent restore."""
        step = step if step is not None else self.latest()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:010d}"
        manifest = json.loads((d / _MANIFEST).read_text())
        leaves, treedef = _flatten_with_paths(tree_like)
        by_path = {e["path"]: e for e in manifest["leaves"]}
        new_leaves = []
        flat_shardings = None
        if shardings is not None:
            flat_shardings = [s for _, s in _flatten_with_paths(shardings)[0]]
        for i, (path, like) in enumerate(leaves):
            entry = by_path.get(path)
            if entry is None:
                raise KeyError(f"checkpoint missing leaf {path!r}")
            arr = np.load(d / entry["file"])
            if flat_shardings is not None:
                arr = jax.device_put(arr, flat_shardings[i])
            new_leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, new_leaves), manifest["extra"]

    # -- retention ----------------------------------------------------------
    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)
