"""Remote shard-executor worker: the actor half of the actor/learner split.

A worker is a long-lived process (``python -m repro.distributed.worker
--connect HOST:PORT``) that dials the coordinator, receives one serialized
:class:`~repro.core.executor.ShardProgram`, then loops: take a leased
shard task, execute it through the exact same
``ProgramContext``/``execute_program``/``ShardCache`` path the in-host
executors use, and stream the packed token/column buffers back as one
``result`` frame. Liveness rides the seed
:class:`~repro.runtime.fault_tolerance.Heartbeat`: a daemon thread beats a
per-worker file the coordinator monitors alongside TCP connection state,
so a wedged-but-connected worker and a SIGKILLed one both surface.

Workers never import jax — preprocessing is a pure host tier, so worker
startup stays cheap and the pool scales independently of the device mesh.
"""

from __future__ import annotations

import argparse
import os
import pickle
import socket
import sys
import threading
import traceback
from pathlib import Path

import numpy as np

from ..core import executor as EX
from ..runtime.fault_tolerance import Heartbeat
from .transport import recv_frame, send_frame


def heartbeat_path(heartbeat_dir: str | Path, worker_id: str) -> Path:
    return Path(heartbeat_dir) / f"{worker_id}.beat"


def run_worker(
    host: str,
    port: int,
    worker_id: str | None = None,
    *,
    connect_timeout: float = 10.0,
) -> int:
    """Serve one coordinator session; returns the number of shards done.

    Heartbeat configuration (directory + interval) arrives in the
    ``program`` frame, so the launch command needs nothing but the
    coordinator address.
    """
    worker_id = worker_id or f"worker-{socket.gethostname()}-{os.getpid()}"
    sock = socket.create_connection((host, port), timeout=connect_timeout)
    sock.settimeout(None)
    try:
        return _serve(sock, worker_id)
    finally:
        try:
            sock.close()
        except OSError:
            pass


def _serve(sock: socket.socket, worker_id: str) -> int:
    send_frame(sock, "hello", {"worker_id": worker_id})
    frame = recv_frame(sock)
    if frame is None:
        return 0
    kind, meta, payload = frame
    if kind != "program":
        raise RuntimeError(f"expected program frame, got {kind!r}")
    program = pickle.loads(bytes(payload))
    ctx = EX.ProgramContext(program, meta.get("cache_dir"))
    program_fp = meta["program_fp"]

    stop_beating = threading.Event()
    done = 0
    if meta.get("heartbeat_dir"):
        hb = Heartbeat(
            heartbeat_path(meta["heartbeat_dir"], worker_id),
            interval_s=float(meta.get("heartbeat_interval_s", 1.0)),
        )

        def beat_loop() -> None:
            while not stop_beating.is_set():
                try:
                    hb.beat(done, force=True)
                except OSError:
                    pass  # beat dir vanished: the TCP channel still covers us
                stop_beating.wait(hb.interval_s)

        threading.Thread(target=beat_loop, daemon=True).start()

    try:
        while True:
            frame = recv_frame(sock)
            if frame is None:
                break
            kind, meta, payload = frame
            if kind == "shutdown":
                break
            if kind != "task":
                raise RuntimeError(f"unexpected frame kind {kind!r}")
            idx = meta["shard_index"]
            row_take = meta.get("row_take")
            if row_take is not None:
                row_take = np.asarray(row_take, dtype=np.int64)
            try:
                res = ctx.run(
                    bytes(payload) if len(payload) else None,
                    meta.get("path"),
                    meta.get("digest"),
                    row_take,
                )
                body, out = EX.pack_shard_result(res, token_space=ctx.token_space)
                body["shard_index"] = idx
                body["program_fp"] = program_fp
                send_frame(sock, "result", body, out)
                done += 1
            except (OSError, ConnectionError):
                raise  # the coordinator is gone; no point reporting to it
            except BaseException:
                send_frame(
                    sock,
                    "error",
                    {"shard_index": idx, "traceback": traceback.format_exc()},
                )
    finally:
        stop_beating.set()
    return done


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="repro remote shard-executor worker"
    )
    ap.add_argument(
        "--connect",
        required=True,
        metavar="HOST:PORT",
        help="coordinator address to dial",
    )
    ap.add_argument(
        "--worker-id",
        default=None,
        help="stable identity for heartbeat/lease bookkeeping "
        "(default: worker-<host>-<pid>)",
    )
    args = ap.parse_args(argv)
    host, _, port = args.connect.rpartition(":")
    run_worker(host or "127.0.0.1", int(port), args.worker_id)
    return 0


if __name__ == "__main__":
    sys.exit(main())
