"""Logical-axis sharding rule engine.

Params and activations are annotated with *logical* axis names (see
``LM.param_axes``). Rules map logical names to an ordered list of candidate
mesh axes; ``spec_for`` picks the first candidate that (a) exists in the
mesh, (b) divides the dimension, and (c) is not already taken by another
dim of the same tensor. This divisibility-aware fallback is what lets all
31 heterogeneous (arch × shape) cells compile on the same mesh without
hand-written specs (e.g. kv_heads=8 on a 16-way model axis falls back to
sharding head_dim; vocab=504 falls back to replication).
"""

from __future__ import annotations

from typing import Any, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Default rules: TP over "model", DP/FSDP over ("pod","data").
# Entries are candidate lists; special entry "data_axes"/"model_axis" name
# the mesh roles.
DEFAULT_RULES: dict[str, tuple] = {
    "batch": (("pod", "data"), ("data",)),
    "seq": (),
    "vocab": (("model",),),
    "embed": (),  # replicated by default; FSDP rule overrides
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "head_dim": (("model",),),  # fallback target when kv_heads indivisible
    "mlp": (("model",),),
    "experts": (("model",),),
    "expert_ff": (),
    "rnn": (("model",),),
    "rnn_in": (),
    "frontend": (),
}

FSDP_RULES = dict(DEFAULT_RULES)
# ZeRO-3-style parameter sharding: span BOTH data-parallel axes on the
# multi-pod mesh (halves per-chip parameter+optimizer bytes vs data-only
# FSDP — measured on kimi-k2, EXPERIMENTS.md §Dry-run); falls back to
# ("data",) on the single-pod mesh automatically.
FSDP_RULES["embed"] = (("pod", "data"), ("data",))

# Sequence-parallel + ZeRO-3 plan (hillclimb, EXPERIMENTS.md §Perf):
# no tensor-parallel compute — the model axis shards the SEQUENCE of the
# activations (see MeshContext.constrain_batch) and stores parameters
# ZeRO-3-style over (data, model); weights are gathered at use (one
# all-gather per layer per microbatch) instead of per-matmul activation
# all-reduces. lm_head keeps vocab over model so logits shard 2D.
SP_RULES = dict(DEFAULT_RULES)
SP_RULES.update({
    "seq": (("model",),),
    "embed": (("data", "model"), ("data",)),
    "vocab": (("model",),),
    "heads": (),
    "kv_heads": (),
    "head_dim": (),
    "mlp": (),
    "rnn": (),
})

RULE_SETS = {"tp": DEFAULT_RULES, "fsdp": FSDP_RULES, "sp_zero3": SP_RULES}


def _axes_in_mesh(mesh: Mesh, cand: Sequence[str]) -> bool:
    return all(a in mesh.shape for a in cand)


def _mesh_size(mesh: Mesh, cand: Sequence[str]) -> int:
    return int(np.prod([mesh.shape[a] for a in cand]))


def spec_for(
    shape: Sequence[int],
    axes: Sequence[str | None],
    mesh: Mesh,
    rules: Mapping[str, tuple] = DEFAULT_RULES,
) -> P:
    """Build a PartitionSpec for one tensor.

    Per-tensor exclusivity: once a mesh axis is used by a dim, later dims
    cannot reuse it (PartitionSpec invariant). ``kv_heads``+``head_dim``
    cooperate: if kv_heads takes "model", head_dim's fallback is skipped.
    """
    assert len(shape) == len(axes), (shape, axes)
    used: set[str] = set()
    entries: list = []
    for dim, name in zip(shape, axes):
        placed = None
        if name is not None:
            for cand in rules.get(name, ()):
                cand = tuple(cand)
                if not cand or not _axes_in_mesh(mesh, cand):
                    continue
                if any(a in used for a in cand):
                    continue
                if dim % _mesh_size(mesh, cand) != 0:
                    continue
                placed = cand if len(cand) > 1 else cand[0]
                used.update(cand)
                break
        entries.append(placed)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def tree_shardings(
    shapes_tree: Any,
    axes_tree: Any,
    mesh: Mesh,
    rules: Mapping[str, tuple] = DEFAULT_RULES,
) -> Any:
    """Map (ShapeDtypeStruct tree, logical-axes tree) -> NamedSharding tree."""

    def one(sds, axes):
        return NamedSharding(mesh, spec_for(sds.shape, axes, mesh, rules))

    return jax.tree.map(
        one, shapes_tree, axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )


def with_shardings(shapes_tree: Any, shardings_tree: Any) -> Any:
    """Attach shardings to a ShapeDtypeStruct tree (for AOT .lower())."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes_tree, shardings_tree,
    )


def batch_spec(mesh: Mesh, batch_size: int, rank: int = 2) -> P:
    """Sharding spec for a (batch, ...) activation/input tensor."""
    for cand in DEFAULT_RULES["batch"]:
        if _axes_in_mesh(mesh, cand) and batch_size % _mesh_size(mesh, cand) == 0:
            first = tuple(cand) if len(cand) > 1 else cand[0]
            return P(first)
    return P()


def data_axis_names(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
