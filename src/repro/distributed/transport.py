"""Framed socket protocol for the distributed data plane.

One frame = a fixed header (magic + header length + payload length), a
pickled ``(kind, meta)`` tuple, and an opaque binary payload. The payload
is the executor wire format of :mod:`repro.core.executor` —
``_pack_columns`` flat column sections followed by 8-byte-aligned
``_pack_tokens`` int32 sections — so the exact bytes that ride a
shared-memory segment under :class:`~repro.core.executor.ProcessShardExecutor`
ride a TCP stream here, and both sides reuse
``pack_shard_result``/``unpack_shard_result`` unchanged.

Frame kinds (coordinator ↔ worker):

* ``hello``    worker → coordinator: ``{"worker_id": ...}``
* ``program``  coordinator → worker: run metadata in ``meta`` (cache dir,
  program fingerprint, heartbeat config); payload = pickled
  :class:`~repro.core.executor.ShardProgram`
* ``task``     coordinator → worker: ``{"shard_index", "digest",
  "row_take", "path"}``; payload = raw shard bytes
* ``result``   worker → coordinator: ``pack_shard_result`` meta +
  ``{"shard_index", "program_fp"}``; payload = packed buffers
* ``error``    worker → coordinator: ``{"shard_index", "traceback"}``
* ``shutdown`` coordinator → worker: no body

Security model matches ``multiprocessing``'s queues (which also pickle):
the protocol is for preprocessing workers you launched on hosts you
control, bound to loopback by default — not for untrusted peers.
"""

from __future__ import annotations

import pickle
import socket
import struct
import threading
from typing import Any

MAGIC = b"RSX1"
_HEAD = struct.Struct("!4sQQ")  # magic, pickled-meta length, payload length

# A frame above this size is a protocol error (corrupt or hostile stream),
# not a real shard: refuse instead of trying to allocate it.
MAX_FRAME = 16 << 30


class TransportError(ConnectionError):
    """Malformed frame or broken stream."""


def send_frame(
    sock: socket.socket,
    kind: str,
    meta: dict[str, Any] | None = None,
    payload: bytes | memoryview = b"",
    lock: threading.Lock | None = None,
) -> None:
    """Write one frame; ``lock`` serializes concurrent senders on a shared
    socket (frames must never interleave)."""
    head = pickle.dumps((kind, meta or {}), protocol=4)
    prefix = _HEAD.pack(MAGIC, len(head), len(payload))
    if lock is None:
        sock.sendall(prefix + head)
        if len(payload):
            sock.sendall(payload)
    else:
        with lock:
            sock.sendall(prefix + head)
            if len(payload):
                sock.sendall(payload)


def _recv_exact(sock: socket.socket, n: int) -> bytearray | None:
    """Read exactly n bytes; None on clean EOF at a frame boundary."""
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        k = sock.recv_into(view[got:], n - got)
        if k == 0:
            if got == 0:
                return None
            raise TransportError(f"stream truncated mid-frame ({got}/{n} bytes)")
        got += k
    return buf


def recv_frame(
    sock: socket.socket,
) -> tuple[str, dict[str, Any], memoryview] | None:
    """Read one frame → ``(kind, meta, payload)``; None on clean EOF (the
    peer closed between frames)."""
    head = _recv_exact(sock, _HEAD.size)
    if head is None:
        return None
    magic, head_len, payload_len = _HEAD.unpack(bytes(head))
    if magic != MAGIC:
        raise TransportError(f"bad frame magic {magic!r}")
    if head_len > MAX_FRAME or payload_len > MAX_FRAME:
        raise TransportError(
            f"oversized frame (meta={head_len}, payload={payload_len})"
        )
    meta_raw = _recv_exact(sock, head_len)
    if meta_raw is None:
        raise TransportError("stream truncated before frame meta")
    kind, meta = pickle.loads(bytes(meta_raw))
    if payload_len:
        payload = _recv_exact(sock, payload_len)
        if payload is None:
            raise TransportError("stream truncated before frame payload")
    else:
        payload = bytearray()
    return kind, meta, memoryview(payload)
