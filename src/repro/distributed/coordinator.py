"""Coordinator for the distributed data plane: shard leases, worker
liveness, restart-safe reassignment.

The topology is the actor/learner split the related apex-style systems
use: a pool of remote preprocessing workers (:mod:`.worker`) dials the
coordinator, which leases shards to whichever worker asks next
(self-scheduling == work stealing), ships the raw shard bytes in the task
frame, and collects packed token/column buffers back over the same
socket. Because plan/lineage/token fingerprints already make shard work
idempotent — a shard's products are a pure function of (shard bytes,
program) — fault tolerance is lease bookkeeping, not protocol:

* every leased shard carries a deadline (:class:`LeaseTable`); an expired
  lease simply re-enters the pending queue, so a wedged worker's shards
  are stolen by survivors while the original may still finish;
* a dead worker (TCP EOF, or a stale
  :class:`~repro.runtime.fault_tolerance.Heartbeat` file) has its
  in-flight leases released immediately;
* results dedup by ``(shard_index, program fingerprint)`` — the first
  result under the pair wins and late duplicates from a slow original are
  dropped, so reassignment can never double-deliver or tear an epoch.

Restart-safety is therefore by construction: killing a worker mid-epoch
yields the byte-identical batch stream, just slower.
"""

from __future__ import annotations

import os
import queue
import shutil
import socket
import subprocess
import sys
import tempfile
import threading
import time
from collections import deque
from pathlib import Path
from typing import Any, Callable, Iterator, Sequence

import numpy as np

from ..core import executor as EX
from ..core import ingest as ing
from ..core.async_loader import drain, put_cancellable
from ..runtime.fault_tolerance import Heartbeat
from .transport import TransportError, recv_frame, send_frame
from .worker import heartbeat_path


def _teardown(sock: socket.socket) -> None:
    """Wake any thread blocked on this socket, then close it. A bare
    ``close()`` does not interrupt a concurrent ``recv`` — ``shutdown``
    does."""
    try:
        sock.shutdown(socket.SHUT_RDWR)
    except OSError:
        pass
    try:
        sock.close()
    except OSError:
        pass


class LeaseTable:
    """Shard assignment state: pending queue + per-task leases + done set.

    Pure bookkeeping behind one lock, with an injectable ``clock`` so
    lease expiry is unit-testable against a fake clock. A task may hold
    several live leases at once (an expired lease re-enters pending while
    the original worker may still be computing); :meth:`complete` keeps
    exactly the first result.
    """

    def __init__(
        self,
        n_tasks: int,
        *,
        lease_s: float = 30.0,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.n_tasks = n_tasks
        self.lease_s = lease_s
        self._clock = clock
        self._pending: deque[int] = deque(range(n_tasks))
        self._leases: dict[int, dict[str, float]] = {}
        self._done: set[int] = set()
        self._closed = False
        self._cond = threading.Condition()

    def acquire(self, worker: str, timeout: float | None = None) -> int | None:
        """Lease the next pending task to ``worker``; None when nothing is
        pending within ``timeout`` (or the table closed / all work done)."""
        with self._cond:
            while True:
                while self._pending and self._pending[0] in self._done:
                    self._pending.popleft()  # completed while re-pending
                if self._pending:
                    idx = self._pending.popleft()
                    self._leases.setdefault(idx, {})[worker] = (
                        self._clock() + self.lease_s
                    )
                    return idx
                if self._closed or len(self._done) == self.n_tasks:
                    return None
                if not self._cond.wait(timeout):
                    return None

    def complete(self, idx: int, worker: str | None = None) -> bool:
        """Record a finished task; False when some earlier result already
        won (duplicate delivery after reassignment — drop it)."""
        with self._cond:
            if idx in self._done:
                return False
            self._done.add(idx)
            self._leases.pop(idx, None)
            self._cond.notify_all()
            return True

    def release(self, worker: str) -> list[int]:
        """Drop every lease ``worker`` holds (it died); tasks left with no
        other live lease re-enter the pending queue."""
        with self._cond:
            requeued = []
            for idx in list(self._leases):
                holders = self._leases[idx]
                if worker in holders:
                    del holders[worker]
                    if not holders:
                        del self._leases[idx]
                        if idx not in self._done and idx not in self._pending:
                            self._pending.append(idx)
                            requeued.append(idx)
            if requeued:
                self._cond.notify_all()
            return requeued

    def reap_expired(self) -> list[int]:
        """Re-queue every task whose lease deadline passed (work stealing:
        survivors pick it up; the original may still deliver and lose the
        :meth:`complete` race harmlessly)."""
        now = self._clock()
        with self._cond:
            requeued = []
            for idx, holders in list(self._leases.items()):
                expired = [w for w, dl in holders.items() if dl <= now]
                if not expired:
                    continue
                for w in expired:
                    del holders[w]
                if idx not in self._done and idx not in self._pending:
                    self._pending.append(idx)
                    requeued.append(idx)
                if not holders:
                    del self._leases[idx]
            if requeued:
                self._cond.notify_all()
            return requeued

    def all_done(self) -> bool:
        with self._cond:
            return len(self._done) == self.n_tasks

    def remaining(self) -> int:
        with self._cond:
            return self.n_tasks - len(self._done)

    def leased_to(self, worker: str) -> list[int]:
        with self._cond:
            return [i for i, holders in self._leases.items() if worker in holders]

    def close(self) -> None:
        """Wake every waiter; subsequent acquires return None."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()


class Coordinator:
    """TCP server leasing shards to remote workers and collecting results.

    One handler thread per connected worker: send ``program`` once, then
    loop lease → ``task`` frame (raw shard bytes + digest + survivor rows)
    → ``result`` frame → :meth:`LeaseTable.complete`. A monitor thread
    reaps expired leases and closes the socket of any worker whose
    heartbeat file has gone stale, which funnels every failure mode into
    the handler's exception path: release leases, requeue, survivors
    steal.
    """

    def __init__(
        self,
        shards: Sequence[str | Path],
        program: EX.ShardProgram,
        *,
        cache_dir: str | Path | None = None,
        row_filters: dict[int, np.ndarray] | None = None,
        lease_s: float = 30.0,
        heartbeat_dir: str | Path | None = None,
        heartbeat_timeout: float = 10.0,
        heartbeat_interval_s: float = 0.5,
        host: str = "127.0.0.1",
        port: int = 0,
        clock: Callable[[], float] = time.monotonic,
        max_buffered: int = 8,
    ):
        self.program = program
        self.program_fp = EX.program_fingerprint(program)
        self.cache_dir = cache_dir
        self.heartbeat_dir = Path(heartbeat_dir) if heartbeat_dir else None
        self.heartbeat_timeout = heartbeat_timeout
        self.heartbeat_interval_s = heartbeat_interval_s
        self._shards = [Path(s) for s in shards]
        self._row_filters = row_filters or {}
        self.leases = LeaseTable(len(self._shards), lease_s=lease_s, clock=clock)
        self.results: "queue.Queue[tuple[str, Any]]" = queue.Queue(
            maxsize=max(max_buffered, 2)
        )
        self._stopped = threading.Event()
        self._conns: dict[str, socket.socket] = {}
        self._conn_lock = threading.Lock()
        self._threads: list[threading.Thread] = []
        self._server = socket.create_server((host, port))
        self.address: tuple[str, int] = self._server.getsockname()[:2]
        for target in (self._accept_loop, self._monitor_loop):
            t = threading.Thread(target=target, daemon=True)
            t.start()
            self._threads.append(t)

    # -- worker-facing threads ---------------------------------------------
    def _accept_loop(self) -> None:
        while not self._stopped.is_set():
            try:
                sock, _ = self._server.accept()
            except OSError:
                return  # listener closed by stop()
            t = threading.Thread(target=self._handle, args=(sock,), daemon=True)
            t.start()
            self._threads.append(t)

    def _register(self, worker_id: str, sock: socket.socket) -> str:
        with self._conn_lock:
            wid = worker_id
            n = 1
            while wid in self._conns:
                n += 1
                wid = f"{worker_id}#{n}"
            self._conns[wid] = sock
            return wid

    def _handle(self, sock: socket.socket) -> None:
        import pickle

        wid = None
        try:
            sock.settimeout(30.0)  # a silent connection must not park forever
            frame = recv_frame(sock)
            if frame is None or frame[0] != "hello":
                return
            sock.settimeout(None)
            wid = self._register(frame[1].get("worker_id", "worker"), sock)
            send_frame(
                sock,
                "program",
                {
                    "program_fp": self.program_fp,
                    "cache_dir": (
                        str(self.cache_dir) if self.cache_dir is not None else None
                    ),
                    "heartbeat_dir": (
                        str(self.heartbeat_dir) if self.heartbeat_dir else None
                    ),
                    "heartbeat_interval_s": self.heartbeat_interval_s,
                },
                pickle.dumps(self.program, protocol=4),
            )
            self._serve_worker(wid, sock)
        except (OSError, ConnectionError, TransportError, EOFError, pickle.PickleError):
            pass  # worker died / stream broke: leases released below
        finally:
            if wid is not None:
                self.leases.release(wid)
                with self._conn_lock:
                    if self._conns.get(wid) is sock:
                        del self._conns[wid]
            try:
                sock.close()
            except OSError:
                pass

    def _serve_worker(self, wid: str, sock: socket.socket) -> None:
        while not self._stopped.is_set():
            idx = self.leases.acquire(wid, timeout=0.25)
            if idx is None:
                if self.leases.all_done() or self._stopped.is_set():
                    try:
                        send_frame(sock, "shutdown")
                    except OSError:
                        pass
                    return
                continue
            try:
                data, digest = ing.read_shard_bytes(self._shards[idx])
            except OSError as e:
                # A vanished/unreadable shard is a corpus problem, not a
                # worker problem: fail the run instead of churning the
                # lease through every worker forever.
                put_cancellable(
                    self.results,
                    ("err", f"cannot read shard {self._shards[idx]}: {e!r}"),
                    self._stopped,
                )
                return
            send_frame(
                sock,
                "task",
                {
                    "shard_index": idx,
                    "digest": digest,
                    "path": str(self._shards[idx]),
                    "row_take": self._row_filters.get(idx),
                },
                data,
            )
            frame = recv_frame(sock)
            if frame is None:
                raise ConnectionError(f"worker {wid} closed mid-task")
            kind, meta, payload = frame
            if kind == "error":
                put_cancellable(
                    self.results,
                    ("err", f"remote worker {wid} failed:\n{meta['traceback']}"),
                    self._stopped,
                )
                return
            if kind != "result":
                raise TransportError(f"unexpected frame {kind!r} from {wid}")
            ridx = meta["shard_index"]
            if meta.get("program_fp") != self.program_fp:
                continue  # stale result from another program generation
            if not self.leases.complete(ridx, wid):
                continue  # a reassigned copy already delivered this shard
            res = EX.unpack_shard_result(meta, payload)
            res.shard_index = ridx
            put_cancellable(self.results, ("ok", res), self._stopped)

    def _monitor_loop(self) -> None:
        while not self._stopped.is_set():
            self.leases.reap_expired()
            if self.heartbeat_dir is not None:
                with self._conn_lock:
                    conns = dict(self._conns)
                for wid, sock in conns.items():
                    ts = Heartbeat.last_beat(heartbeat_path(self.heartbeat_dir, wid))
                    if ts is None:
                        continue  # never beat yet: connection state decides
                    if time.time() - ts > self.heartbeat_timeout:
                        # Wedged worker: tearing its socket down funnels it
                        # into the handler's failure path (release +
                        # requeue). shutdown() — unlike close() — reliably
                        # wakes the handler thread blocked in recv.
                        _teardown(sock)
            self._stopped.wait(min(0.2, self.heartbeat_timeout / 4))

    # -- driver side -------------------------------------------------------
    def worker_count(self) -> int:
        with self._conn_lock:
            return len(self._conns)

    def stop(self) -> None:
        if self._stopped.is_set():
            return
        self._stopped.set()
        self.leases.close()
        try:
            self._server.close()
        except OSError:
            pass
        with self._conn_lock:
            conns = list(self._conns.values())
            self._conns.clear()
        for sock in conns:
            _teardown(sock)
        drain(self.results)
        for t in self._threads:
            if t is not threading.current_thread():
                t.join(timeout=2.0)


class RemoteShardExecutor:
    """Shard executor facade over :class:`Coordinator` + a worker pool.

    Drop-in peer of ``ThreadShardExecutor``/``ProcessShardExecutor``
    (selected via ``executor="remote"`` / ``REPRO_EXECUTOR=remote`` /
    ``Dataset.workers(n, remote=...)``): iterating yields
    :class:`~repro.core.executor.ShardResult` objects with the usual
    counters, and byte-equivalence with the other executors holds because
    workers run the identical compiled program and wire format.

    ``remote`` options (dict, or True/None for defaults):

    * ``spawn`` (default True) — launch ``workers`` local worker processes
      (``python -m repro.distributed.worker``). ``spawn=False`` binds the
      coordinator and waits for externally-launched workers to dial in
      (set ``host``/``port`` to something routable).
    * ``host``/``port`` — coordinator bind address (default loopback,
      ephemeral port).
    * ``lease_s``, ``heartbeat_timeout``, ``heartbeat_interval_s``,
      ``heartbeat_dir`` — liveness tuning (defaults: 30 s leases, 10 s
      heartbeat timeout, per-run temp heartbeat dir).
    * ``python`` — interpreter for spawned workers (default
      ``sys.executable``).
    """

    name = "remote"

    def __init__(
        self,
        shards: Sequence[str | Path],
        program: EX.ShardProgram,
        *,
        workers: int = 2,
        cache_dir: str | Path | None = None,
        row_filters: dict[int, np.ndarray] | None = None,
        remote: Any = None,
    ):
        if program.has_dedup:
            raise EX.UnsupportedPlanError(
                "drop_duplicates needs cross-shard state; use the thread executor"
            )
        opts = dict(remote) if isinstance(remote, dict) else {}
        self.program = program
        self.cache_hits = 0
        self.cache_misses = 0
        self.token_cache_hits = 0
        self.token_cache_misses = 0
        self._parse_s = self._pre_s = self._clean_s = self._post_s = 0.0
        self._tokenize_s = 0.0
        self._shards = [Path(s) for s in shards]
        self._stopped = threading.Event()
        self._owns_heartbeat_dir = "heartbeat_dir" not in opts
        heartbeat_dir = opts.get("heartbeat_dir") or tempfile.mkdtemp(
            prefix="repro-heartbeat-"
        )
        self._coord = Coordinator(
            self._shards,
            program,
            cache_dir=cache_dir,
            row_filters=row_filters,
            lease_s=float(opts.get("lease_s", 30.0)),
            heartbeat_dir=heartbeat_dir,
            heartbeat_timeout=float(opts.get("heartbeat_timeout", 10.0)),
            heartbeat_interval_s=float(opts.get("heartbeat_interval_s", 0.5)),
            host=opts.get("host", "127.0.0.1"),
            port=int(opts.get("port", 0)),
            max_buffered=max(2 * workers, 4),
        )
        self.address = self._coord.address
        self.workers: list[subprocess.Popen] = []
        if opts.get("spawn", True):
            self.workers = spawn_local_workers(
                self.address,
                max(int(workers), 1),
                python=opts.get("python"),
            )

    def __iter__(self) -> Iterator[EX.ShardResult]:
        consumed = 0
        while consumed < len(self._shards):
            if self._stopped.is_set():
                return
            try:
                status, body = self._coord.results.get(timeout=1.0)
            except queue.Empty:
                try:
                    self._check_liveness()
                except BaseException:
                    self.stop()
                    raise
                continue
            if status == "err":
                self.stop()
                raise RuntimeError(body)
            res: EX.ShardResult = body
            self._parse_s += res.parse_s
            self._pre_s += res.pre_clean_s
            self._clean_s += res.clean_s
            self._post_s += res.post_clean_s
            self._tokenize_s += res.tokenize_s
            self.cache_hits += res.cache_hits
            self.cache_misses += res.cache_misses
            self.token_cache_hits += res.token_cache_hits
            self.token_cache_misses += res.token_cache_misses
            consumed += 1
            yield res

    def _check_liveness(self) -> None:
        """Raise when the run can no longer finish: every spawned worker
        exited while shards remain un-done. (A *subset* of workers dying
        is the supported failure mode — their leases re-queue and
        survivors steal the work.)"""
        if self._coord.leases.all_done():
            return
        if self.workers and all(p.poll() is not None for p in self.workers):
            codes = [p.poll() for p in self.workers]
            raise RuntimeError(
                f"all {len(self.workers)} remote shard workers exited "
                f"(codes {codes}) with {self._coord.leases.remaining()} "
                "shards unfinished"
            )

    @property
    def timings(self):
        from ..core.plan import StageTimings

        return StageTimings(
            self._parse_s, self._pre_s, self._clean_s, self._post_s, self._tokenize_s
        )

    def stop(self) -> None:
        """Shut the coordinator and the spawned worker pool down; safe
        after breaking out early. Idempotent."""
        if self._stopped.is_set():
            return
        self._stopped.set()
        self._coord.stop()
        for p in self.workers:
            if p.poll() is None:
                p.terminate()
        deadline = time.monotonic() + 5.0
        for p in self.workers:
            try:
                p.wait(timeout=max(deadline - time.monotonic(), 0.1))
            except subprocess.TimeoutExpired:
                p.kill()
                p.wait(timeout=5.0)
        if self._owns_heartbeat_dir and self._coord.heartbeat_dir is not None:
            shutil.rmtree(self._coord.heartbeat_dir, ignore_errors=True)


def spawn_local_workers(
    address: tuple[str, int],
    n: int,
    *,
    python: str | None = None,
) -> list[subprocess.Popen]:
    """Launch ``n`` worker processes on this host dialing ``address``.

    The spawned interpreter sees the same ``repro`` package as the driver
    (its source root is prepended to ``PYTHONPATH``), so an un-installed
    source tree works too.
    """
    host, port = address
    src_root = Path(__file__).resolve().parent.parent.parent
    env = dict(os.environ)
    existing = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = (
        f"{src_root}{os.pathsep}{existing}" if existing else str(src_root)
    )
    procs = []
    for i in range(n):
        procs.append(
            subprocess.Popen(
                [
                    python or sys.executable,
                    "-m",
                    "repro.distributed.worker",
                    "--connect",
                    f"{host}:{port}",
                    "--worker-id",
                    f"worker-{os.getpid()}-{i}",
                ],
                env=env,
            )
        )
    return procs
