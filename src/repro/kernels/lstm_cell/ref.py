"""Pure-jnp oracle for the fused LSTM cell kernel (matches
repro.models.seq2seq.lstm_cell: gate order i,f,g,o, forget bias +1)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lstm_cell_ref(x, h, c, wx, wh, b):
    """wx: (d_in, 4, H); wh: (H, 4, H); b: (4, H)."""
    d_in, _, hidden = wx.shape
    z = (
        x @ wx.reshape(d_in, 4 * hidden)
        + h @ wh.reshape(hidden, 4 * hidden)
        + b.reshape(4 * hidden)
    ).astype(jnp.float32)
    z = z.reshape(x.shape[0], 4, hidden)
    i, f, g, o = z[:, 0], z[:, 1], z[:, 2], z[:, 3]
    c_new = jax.nn.sigmoid(f + 1.0) * c.astype(jnp.float32) + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new.astype(x.dtype), c_new.astype(x.dtype)
