"""Jit'd public wrapper for the fused LSTM cell kernel."""

from __future__ import annotations

from functools import partial

import jax

from .lstm_cell import lstm_cell

# VMEM budget sanity: the whole-contraction tiles must fit (~16 MiB/core).
_MAX_CONTRACT_ELEMS = 4 * 1024 * 1024


@partial(jax.jit, static_argnames=("blk_b", "blk_h", "interpret"))
def lstm_cell_op(x, h, c, params: dict, *, blk_b: int = 128, blk_h: int = 256,
                 interpret: bool = False):
    """params: {"wx": (d_in, 4H), "wh": (H, 4H), "b": (4H,)} — the layout
    used by repro.models.seq2seq; reshaped here to the kernel layout."""
    d_in = params["wx"].shape[0]
    hidden = h.shape[1]
    assert d_in * hidden <= _MAX_CONTRACT_ELEMS, "weights exceed VMEM tile budget"
    # (d, 4H) column layout is [i | f | g | o] blocks of width H
    wx = params["wx"].reshape(d_in, 4, hidden)
    wh = params["wh"].reshape(hidden, 4, hidden)
    b = params["b"].reshape(4, hidden)
    return lstm_cell(x, h, c, wx, wh, b, blk_b=blk_b, blk_h=blk_h, interpret=interpret)
