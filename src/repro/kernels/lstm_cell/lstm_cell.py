"""Fused LSTM cell kernel for TPU (Pallas) — the paper case-study hotspot.

One kernel fuses both gate matmuls (x@Wx + h@Wh), bias add and all four
gate nonlinearities + state update, instead of four XLA ops with HBM
round-trips between them. Weights are laid out (D, 4, H) so a hidden-block
grid tile can read all four gate slices contiguously.

Grid = (batch_blocks, hidden_blocks); the contraction dims (d_in, d_hidden)
are kept whole per tile (they fit VMEM for the case-study sizes; ops.py
asserts this). Gate math in fp32 on the VPU, matmuls on the MXU.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..pallas_compat import tpu_compiler_params


def _lstm_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref, ho_ref, co_ref):
    x = x_ref[...]  # (blk_b, d_in)
    h = h_ref[...]  # (blk_b, H)
    c = c_ref[...].astype(jnp.float32)  # (blk_b, blk_h)
    wx = wx_ref[...]  # (d_in, 4, blk_h)
    wh = wh_ref[...]  # (H, 4, blk_h)
    b = b_ref[...]  # (4, blk_h)

    blk_b = x.shape[0]
    blk_h = c.shape[1]
    zx = jax.lax.dot_general(
        x, wx.reshape(wx.shape[0], -1), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    zh = jax.lax.dot_general(
        h, wh.reshape(wh.shape[0], -1), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    z = (zx + zh).reshape(blk_b, 4, blk_h) + b.astype(jnp.float32)[None]
    i, f, g, o = z[:, 0], z[:, 1], z[:, 2], z[:, 3]
    c_new = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    co_ref[...] = c_new.astype(co_ref.dtype)
    ho_ref[...] = h_new.astype(ho_ref.dtype)


def lstm_cell(
    x: jax.Array,  # (B, d_in)
    h: jax.Array,  # (B, H)
    c: jax.Array,  # (B, H)
    wx: jax.Array,  # (d_in, 4, H)
    wh: jax.Array,  # (H, 4, H)
    b: jax.Array,  # (4, H)
    *,
    blk_b: int = 128,
    blk_h: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    bt, d_in = x.shape
    hidden = h.shape[1]
    blk_b = min(blk_b, bt)
    blk_h = min(blk_h, hidden)
    grid = (pl.cdiv(bt, blk_b), pl.cdiv(hidden, blk_h))

    out_shape = [
        jax.ShapeDtypeStruct((bt, hidden), x.dtype),
        jax.ShapeDtypeStruct((bt, hidden), x.dtype),
    ]
    ho, co = pl.pallas_call(
        _lstm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_b, d_in), lambda bi, hi: (bi, 0)),
            pl.BlockSpec((blk_b, hidden), lambda bi, hi: (bi, 0)),
            pl.BlockSpec((blk_b, blk_h), lambda bi, hi: (bi, hi)),
            pl.BlockSpec((d_in, 4, blk_h), lambda bi, hi: (0, 0, hi)),
            pl.BlockSpec((hidden, 4, blk_h), lambda bi, hi: (0, 0, hi)),
            pl.BlockSpec((4, blk_h), lambda bi, hi: (0, hi)),
        ],
        out_specs=[
            pl.BlockSpec((blk_b, blk_h), lambda bi, hi: (bi, hi)),
            pl.BlockSpec((blk_b, blk_h), lambda bi, hi: (bi, hi)),
        ],
        out_shape=out_shape,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(x, h, c, wx, wh, b)
    return ho, co
