"""Jit'd public wrapper for the flash attention kernel.

Accepts model-layout tensors (b, s, heads, head_dim), handles head-dim MXU
padding and sequence padding to block multiples, and exposes the same
signature shape as repro.models.attention.dispatch_sdpa.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention


@partial(jax.jit, static_argnames=("causal", "window", "blk_q", "blk_k", "interpret"))
def flash_attention_op(
    q: jax.Array,  # (b, sq, nq, hd)
    k: jax.Array,  # (b, skv, nkv, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    blk_q: int = 128,
    blk_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, sq, nq, hd = q.shape
    skv, nkv = k.shape[1], k.shape[2]
    blk_q = min(blk_q, max(sq, 8))
    blk_k = min(blk_k, max(skv, 8))

    def pack(x, heads):
        return jnp.moveaxis(x, 2, 1).reshape(x.shape[0] * heads, x.shape[1], hd)

    qp, kp, vp = pack(q, nq), pack(k, nkv), pack(v, nkv)
    pad_q = (-sq) % blk_q
    pad_k = (-skv) % blk_k
    if pad_q:
        qp = jnp.pad(qp, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kp = jnp.pad(kp, ((0, 0), (0, pad_k), (0, 0)))
        vp = jnp.pad(vp, ((0, 0), (0, pad_k), (0, 0)))
    out = flash_attention(
        qp, kp, vp,
        n_q_heads=nq, n_kv_heads=nkv,
        causal=causal, window=window,
        blk_q=blk_q, blk_k=blk_k, kv_len=skv, interpret=interpret,
    )
    if pad_q:
        out = out[:, :sq]
    return jnp.moveaxis(out.reshape(b, nq, sq, hd), 1, 2)
