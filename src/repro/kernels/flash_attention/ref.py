"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def flash_attention_ref(
    q: jax.Array,  # (BH, S, D)
    k: jax.Array,  # (BKV, S, D)
    v: jax.Array,
    *,
    n_q_heads: int,
    n_kv_heads: int,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    bh, sq, d = q.shape
    b = bh // n_q_heads
    groups = n_q_heads // n_kv_heads
    skv = k.shape[1]
    qr = q.reshape(b, n_kv_heads, groups, sq, d)
    kr = k.reshape(b, n_kv_heads, 1, skv, d)
    vr = v.reshape(b, n_kv_heads, 1, skv, d)
    s = jnp.einsum("bngsd,bnxtd->bngst", qr, kr).astype(jnp.float32) / np.sqrt(d)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bngst,bnxtd->bngsd", p, vr)
    return o.reshape(bh, sq, d)
