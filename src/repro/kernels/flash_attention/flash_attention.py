"""Tiled flash attention for TPU (Pallas): causal / sliding-window / GQA.

Layout: q (B*NQ, S, D), k/v (B*KVH, S, D). Grid = (bh, q_blocks, kv_blocks)
with the kv dimension innermost ("arbitrary" semantics): online-softmax
running stats (m, l, acc) live in VMEM scratch and persist across kv grid
steps; the output block is written on the last kv step.

MXU alignment: block sizes default to (128, 128); head_dim is padded to a
multiple of 128 by ops.py when needed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..pallas_compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: int, blk_q: int, blk_k: int,
    n_kv_blocks: int, kv_len: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]  # (blk_q, d)
    k = k_ref[0]  # (blk_k, d)
    v = v_ref[0]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (blk_q, blk_k)

    q_pos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
    k_pos = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
    mask = k_pos < kv_len  # real (non-padded) keys only
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_prev * corr + p.sum(axis=-1)
    m_scr[...] = m_new
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_scr[...] = acc_scr[...] * corr[:, None] + pv

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (BH, S, D) with BH = B * n_q_heads
    k: jax.Array,  # (BKV, S, D) with BKV = B * n_kv_heads
    v: jax.Array,
    *,
    n_q_heads: int,
    n_kv_heads: int,
    causal: bool = True,
    window: int = 0,
    blk_q: int = 128,
    blk_k: int = 128,
    kv_len: int = 0,  # number of real keys (0 -> all)
    interpret: bool = False,
) -> jax.Array:
    bh, sq, d = q.shape
    skv = k.shape[1]
    kv_len = kv_len or skv
    groups = n_q_heads // n_kv_heads
    n_q_blocks = pl.cdiv(sq, blk_q)
    n_kv_blocks = pl.cdiv(skv, blk_k)
    scale = 1.0 / np.sqrt(d)

    def q_index(bhi, qi, ki):
        return (bhi, qi, 0)

    def kv_index(bhi, qi, ki):
        b = bhi // n_q_heads
        h = bhi % n_q_heads
        return (b * n_kv_heads + h // groups, ki, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        blk_q=blk_q, blk_k=blk_k, n_kv_blocks=n_kv_blocks, kv_len=kv_len,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, n_q_blocks, n_kv_blocks),
        in_specs=[
            pl.BlockSpec((1, blk_q, d), q_index),
            pl.BlockSpec((1, blk_k, d), kv_index),
            pl.BlockSpec((1, blk_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, blk_q, d), q_index),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
