"""Pure-jnp oracle: sequential per-timestep mLSTM recurrence (the same
stabilized algebra as repro.models.xlstm._mlstm_step, packed layout)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mlstm_chunk_ref(q, k, v, i_gate, f_gate):
    """q/k/v: (BH, S, dh); gates: (BH, S). Returns h: (BH, S, dh)."""
    bh, s, dh = q.shape

    def step(state, xs):
        C, n, m = state
        qt, kt, vt, it, ft = xs  # (BH, dh) / (BH,)
        f_log = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(f_log + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(f_log + m - m_new)
        C = f_p[:, None, None] * C + i_p[:, None, None] * (vt[:, :, None] * kt[:, None, :])
        n = f_p[:, None] * n + i_p[:, None] * kt
        den = jnp.maximum(jnp.abs(jnp.einsum("bk,bk->b", n, qt)), 1.0)
        h = jnp.einsum("bvk,bk->bv", C, qt) / den[:, None]
        return (C, n, m_new), h

    state = (
        jnp.zeros((bh, dh, dh), jnp.float32),
        jnp.zeros((bh, dh), jnp.float32),
        jnp.full((bh,), NEG_INF, jnp.float32),
    )
    xs = (
        jnp.moveaxis(q.astype(jnp.float32), 1, 0),
        jnp.moveaxis(k.astype(jnp.float32), 1, 0),
        jnp.moveaxis(v.astype(jnp.float32), 1, 0),
        jnp.moveaxis(i_gate.astype(jnp.float32), 1, 0),
        jnp.moveaxis(f_gate.astype(jnp.float32), 1, 0),
    )
    _, hs = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(hs, 0, 1).astype(q.dtype)
