"""Jit'd public wrapper for the chunked mLSTM kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .mlstm_chunk import mlstm_chunk


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunk_op(q, k, v, i_gate, f_gate, *, chunk: int = 64, interpret: bool = False):
    """Model-layout entry: q/k/v (b, s, H, dh), gates (b, s, H)."""
    b, s, H, dh = q.shape
    pad = (-s) % chunk

    def pack(x):
        x = jnp.moveaxis(x, 2, 1).reshape(b * H, s, *x.shape[3:])
        if pad:
            width = [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2)
            x = jnp.pad(x, width)
        return x

    # padded forget gates default 0 -> log_sigmoid(0) finite; padded output
    # rows are sliced away below, and padding never affects earlier chunks
    out = mlstm_chunk(
        pack(q), pack(k), pack(v), pack(i_gate), pack(f_gate),
        chunk=chunk, interpret=interpret,
    )
    out = out[:, :s].reshape(b, H, s, dh)
    return jnp.moveaxis(out, 1, 2)
