"""Chunkwise mLSTM kernel for TPU (Pallas) — the kernel-level follow-through
of the xlstm_1_3b hillclimb (EXPERIMENTS.md §Perf).

The jnp chunked form already cut HBM traffic 139× by materializing the
(dh×dh) matrix memory per *chunk* instead of per *timestep*; this kernel
removes the remaining per-chunk HBM round-trip entirely: the state
(C, n, m) lives in VMEM scratch across the sequence-chunk grid dimension
("arbitrary" semantics — TPU grids iterate the minor dimension
sequentially), so HBM traffic is exactly the q/k/v/gate streams plus the
h output. Intra-chunk work is two MXU matmuls per chunk
((L,dh)·(dh,dh) inter + (L,L)·(L,dh) intra) plus VPU gate algebra.

Math is identical to repro.models.xlstm._mlstm_chunked (stabilized
exponential gating, see that docstring); validated against the sequential
per-step oracle in tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..pallas_compat import tpu_compiler_params

NEG_INF = -1e30


def _mlstm_chunk_kernel(
    q_ref, k_ref, v_ref, i_ref, f_ref, o_ref,
    c_scr, n_scr, m_scr,
    *, chunk: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)

    L = chunk
    qb = q_ref[0].astype(jnp.float32)  # (L, dh)
    kb = k_ref[0].astype(jnp.float32)
    vb = v_ref[0].astype(jnp.float32)
    ib = i_ref[...].astype(jnp.float32)  # (1, L) gate pre-activations
    fb = f_ref[...].astype(jnp.float32)

    C_in = c_scr[...]  # (dh_v, dh_k)
    n_in = n_scr[...]  # (1, dh_k)
    m_in = m_scr[0, 0]

    lf = jax.nn.log_sigmoid(fb)  # (1, L)
    b_cum = jnp.cumsum(lf, axis=1)
    x = ib - b_cum  # (1, L)
    # running max over j<=t via masked (L, L) max (L is small: O(L^2) VPU)
    tt = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    tri = jj <= tt
    rmax = jnp.max(jnp.where(tri, x, NEG_INF), axis=1)[None, :]  # (1, L)

    m_t = jnp.maximum(b_cum + m_in, rmax + b_cum)  # (1, L)
    inter = jnp.exp(b_cum + m_in - m_t)  # (1, L)
    # intra decay D_{tj} = exp(b_t - m_t + i_j - b_j), j <= t
    D = jnp.exp((b_cum - m_t)[0][:, None] + x[0][None, :])
    D = jnp.where(tri, D, 0.0)

    scores = jax.lax.dot_general(
        qb, kb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, L)
    W = D * scores
    num = inter[0][:, None] * jax.lax.dot_general(
        qb, C_in, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) + jax.lax.dot_general(W, vb, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    den = inter * (qb @ n_in[0])[None, :] + W.sum(axis=1)[None, :]  # (1, L)
    h = num / jnp.maximum(jnp.abs(den[0]), 1.0)[:, None]
    o_ref[0] = h.astype(o_ref.dtype)

    # state update at t = L-1
    b_last = b_cum[0, L - 1]
    m_out = jnp.maximum(b_last + m_in, jnp.max(x) + b_last)
    s_out = jnp.exp(b_last + m_in - m_out)
    w_j = jnp.exp((b_last - b_cum) + ib - m_out)  # (1, L)
    kw = kb * w_j[0][:, None]  # (L, dh)
    c_scr[...] = s_out * C_in + jax.lax.dot_general(
        vb, kw, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (dh_v, dh_k)
    n_scr[...] = s_out * n_in + jnp.sum(kw, axis=0)[None, :]
    m_scr[0, 0] = m_out


def mlstm_chunk(
    q: jax.Array,  # (BH, S, dh)
    k: jax.Array,
    v: jax.Array,
    i_gate: jax.Array,  # (BH, S) pre-activation input gate
    f_gate: jax.Array,  # (BH, S) pre-activation forget gate
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> jax.Array:
    bh, s, dh = q.shape
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk

    qkv_spec = pl.BlockSpec((1, chunk, dh), lambda b, c: (b, c, 0))
    gate_spec = pl.BlockSpec((1, chunk), lambda b, c: (b, c))
    kernel = functools.partial(_mlstm_chunk_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(bh, n_chunks),
        in_specs=[qkv_spec, qkv_spec, qkv_spec, gate_spec, gate_spec],
        out_specs=qkv_spec,
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),
            pltpu.VMEM((1, dh), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v, i_gate, f_gate)
