"""Jit'd public wrappers for the text-clean kernels + host bridging.

``clean_rows`` is the practical list[str] entry point: padding/packing on
the host, the character pipeline on device (compiled on TPU, interpret
elsewhere).

``scan_flat`` is the *backend* entry point used by
``repro.core.bytesops`` when ``REPRO_BYTES_BACKEND=pallas``: a flat
``\\x00``-separated uint8 buffer goes in, the megapass scan pass (lower +
span strips) runs on device over a padded (rows, width) matrix, and the
sentinel-marked removals are compacted back into a flat buffer —
byte-identical to the host scan.  It returns ``None`` whenever it declines
(no TPU and interpret not forced, padding blow-up, malformed buffer);
callers fall back to the host implementation, so declining is always
safe."""

from __future__ import annotations

import os
from functools import partial

import jax
import numpy as np

from ..pallas_compat import has_tpu
from .text_clean import text_clean, text_scan


@partial(jax.jit, static_argnames=("strip_html", "blk_rows", "interpret"))
def text_clean_op(rows, *, strip_html: bool = True, blk_rows: int = 256,
                  interpret: bool = False):
    return text_clean(rows, strip_html=strip_html, blk_rows=blk_rows, interpret=interpret)


@partial(
    jax.jit,
    static_argnames=("lower", "strip_html", "strip_parens", "blk_rows", "interpret"),
)
def text_scan_op(rows, *, lower: bool = True, strip_html: bool = False,
                 strip_parens: bool = False, blk_rows: int = 256,
                 interpret: bool = False):
    return text_scan(rows, lower=lower, strip_html=strip_html,
                     strip_parens=strip_parens, blk_rows=blk_rows,
                     interpret=interpret)


def pack_rows(rows: list[str], width: int | None = None) -> np.ndarray:
    """Pad/truncate UTF-8 rows into a (n, width) uint8 matrix (space pad)."""
    enc = [r.encode("utf-8", errors="ignore") for r in rows]
    width = width or max((len(e) for e in enc), default=1)
    out = np.full((len(rows), width), 32, dtype=np.uint8)
    for i, e in enumerate(enc):
        out[i, : min(len(e), width)] = np.frombuffer(e[:width], dtype=np.uint8)
    return out


def unpack_rows(mat: np.ndarray) -> list[str]:
    out = []
    for row in np.asarray(mat):
        s = row.tobytes().decode("utf-8", errors="ignore")
        out.append(" ".join(s.split()))
    return out


def clean_rows(
    rows: list[str], *, strip_html: bool = True, interpret: bool | None = None
) -> list[str]:
    """Clean a list of rows on device.  ``interpret`` defaults to the
    capability check (compiled on TPU, interpret-mode elsewhere) instead of
    unconditionally interpreting."""
    if not rows:
        return []
    if interpret is None:
        interpret = not has_tpu()
    mat = pack_rows(rows)
    cleaned = text_clean_op(mat, strip_html=strip_html, interpret=interpret)
    return unpack_rows(np.asarray(cleaned))


# Padded-matrix guards for scan_flat: refuse to build a matrix that blows
# the flat buffer up more than 8x (few long rows among many short ones) or
# past 64 MiB — the host scan is cheaper than that much padding traffic.
_MAX_PAD_BYTES = 64 << 20
_MAX_BLOWUP = 8.0
# Same knob as repro.core.engine_config.ENV_PALLAS_INTERPRET; read directly
# here to keep this bridge importable without the core engine layer.
INTERPRET_ENV = "REPRO_PALLAS_INTERPRET"


def scan_flat(
    buf: np.ndarray,
    *,
    lower: bool = True,
    strip_html: bool = False,
    strip_parens: bool = False,
    interpret: bool | None = None,
) -> np.ndarray | None:
    """Run a megapass scan pass on device over a flat row buffer.

    Returns the compacted flat result, or ``None`` to decline (caller
    falls back to the byte-identical host scan).  With ``interpret=None``
    the kernel runs compiled on TPU; without a TPU it declines unless
    ``REPRO_PALLAS_INTERPRET`` is set (tests force interpret mode there).
    """
    if interpret is None:
        if has_tpu():
            interpret = False
        elif os.environ.get(INTERPRET_ENV):
            interpret = True
        else:
            return None
    if buf.size == 0 or buf[-1] != 0:
        return None  # rows must be \x00-terminated
    sep = buf == 0
    sep_idx = np.flatnonzero(sep)
    n = sep_idx.size
    starts = np.concatenate(([0], sep_idx[:-1] + 1))
    lens = sep_idx - starts
    width = int(lens.max())
    if width == 0:
        return buf.copy()  # every row empty: nothing to scan
    width_p = -(-width // 128) * 128  # TPU lane multiple; pad is space
    if n * width_p > _MAX_PAD_BYTES or n * width_p > _MAX_BLOWUP * buf.size:
        return None
    row_of = np.cumsum(sep, dtype=np.int64) - sep
    col = np.arange(buf.size, dtype=np.int64) - starts[row_of]
    payload = ~sep
    flat_pos = row_of[payload] * width_p + col[payload]
    mat = np.full(n * width_p, 32, dtype=np.uint8)
    mat[flat_pos] = buf[payload]
    out_mat = np.asarray(
        text_scan_op(
            mat.reshape(n, width_p),
            lower=lower,
            strip_html=strip_html,
            strip_parens=strip_parens,
            interpret=interpret,
        )
    )
    out_flat = np.zeros(buf.size, dtype=np.uint8)
    out_flat[payload] = out_mat.reshape(-1)[flat_pos]
    return out_flat[(out_flat != 0) | sep]
