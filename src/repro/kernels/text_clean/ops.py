"""Jit'd public wrapper for the text-clean kernel + host bridging.

``clean_rows`` is the practical entry point: list[str] -> cleaned
list[str], doing padding/packing on the host and the character pipeline on
device (interpret=True on CPU).
"""

from __future__ import annotations

from functools import partial

import jax
import numpy as np

from .text_clean import text_clean


@partial(jax.jit, static_argnames=("strip_html", "blk_rows", "interpret"))
def text_clean_op(rows, *, strip_html: bool = True, blk_rows: int = 256,
                  interpret: bool = False):
    return text_clean(rows, strip_html=strip_html, blk_rows=blk_rows, interpret=interpret)


def pack_rows(rows: list[str], width: int | None = None) -> np.ndarray:
    """Pad/truncate UTF-8 rows into a (n, width) uint8 matrix (space pad)."""
    enc = [r.encode("utf-8", errors="ignore") for r in rows]
    width = width or max((len(e) for e in enc), default=1)
    out = np.full((len(rows), width), 32, dtype=np.uint8)
    for i, e in enumerate(enc):
        out[i, : min(len(e), width)] = np.frombuffer(e[:width], dtype=np.uint8)
    return out


def unpack_rows(mat: np.ndarray) -> list[str]:
    out = []
    for row in np.asarray(mat):
        s = row.tobytes().decode("utf-8", errors="ignore")
        out.append(" ".join(s.split()))
    return out


def clean_rows(rows: list[str], *, strip_html: bool = True, interpret: bool = True) -> list[str]:
    if not rows:
        return []
    mat = pack_rows(rows)
    cleaned = text_clean_op(mat, strip_html=strip_html, interpret=interpret)
    return unpack_rows(np.asarray(cleaned))
