"""On-device text cleaning kernel (Pallas) — P3SAPP's cleaning stage on TPU.

This is the beyond-paper adaptation of the paper's core idea: instead of
merely overlapping host preprocessing with accelerator compute, the
character-level cleaning stages (ConvertToLower + RemoveHTMLTags +
RemoveUnwantedCharacters' character classes) run *on* the accelerator that
would otherwise idle.

Input: a (rows, width) uint8 matrix of padded text rows. One VMEM pass:

* lowercase via arithmetic range test (no gather — TPU-friendly),
* tag-span removal via a per-row cumulative depth (rows are independent,
  so ``jnp.cumsum`` along the width axis is exactly the span mask),
* unwanted-character classes mapped to space.

Output: cleaned bytes with removed positions already set to space; the
host only collapses whitespace (the only step needing compaction).
Grid over row blocks; width stays whole per block (row-local cumsum).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..pallas_compat import tpu_compiler_params

SPACE = 32


def _clean_kernel(x_ref, o_ref, *, strip_html: bool):
    x = x_ref[...].astype(jnp.int32)  # (blk_r, width)

    # ConvertToLower: A-Z -> a-z
    upper = (x >= 65) & (x <= 90)
    x = jnp.where(upper, x + 32, x)

    keep = jnp.ones_like(x, dtype=jnp.bool_)
    if strip_html:
        lt = (x == 60).astype(jnp.int32)  # '<'
        gt = (x == 62).astype(jnp.int32)  # '>'
        depth = jnp.cumsum(lt - gt, axis=1)
        keep = (depth == 0) & (x != 62)

    # RemoveUnwantedCharacters: anything outside [a-z] -> space
    is_word = (x >= 97) & (x <= 122)
    out = jnp.where(is_word & keep, x, SPACE)
    o_ref[...] = out.astype(jnp.uint8)


def text_clean(
    rows: jax.Array,  # (n_rows, width) uint8, space padded
    *,
    strip_html: bool = True,
    blk_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    n, width = rows.shape
    blk_rows = min(blk_rows, n)
    kernel = functools.partial(_clean_kernel, strip_html=strip_html)
    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(n, blk_rows),),
        in_specs=[pl.BlockSpec((blk_rows, width), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((blk_rows, width), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, width), jnp.uint8),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(rows)


def _scan_kernel(x_ref, o_ref, *, lower: bool, strip_html: bool, strip_parens: bool):
    """Megapass scan-pass kernel: the byte-exact device form of the fused
    backend's LUT/SPAN sweep (``bytesops._run_scan``).

    Unlike ``_clean_kernel`` this does NOT space-mask non-letters — later
    chain stages (contraction REPLACE) need the original punctuation — and
    removed span bytes become sentinel ``\\x00`` rather than space, so the
    host can delete them and land on exactly the loops-backend bytes.
    Survival uses ``depth <= 0`` (not ``== 0``): a stray ``>`` drives the
    depth negative and ``span_strip`` keeps the bytes that follow it.
    The paren span masks its opens/closes/deltas with the HTML span's
    aliveness, which makes the two parallel depth scans sequential-exact."""
    x = x_ref[...].astype(jnp.int32)  # (blk_r, width)
    if lower:
        upper = (x >= 65) & (x <= 90)
        x = jnp.where(upper, x + 32, x)
    alive = jnp.ones_like(x, dtype=jnp.bool_)
    if strip_html:
        lt = (x == 60).astype(jnp.int32)  # '<'
        gt = (x == 62).astype(jnp.int32)  # '>'
        depth = jnp.cumsum(lt - gt, axis=1)
        alive = (depth <= 0) & (x != 62)
    if strip_parens:
        opens = (x == 40) & alive  # '('
        closes = (x == 41) & alive  # ')'
        depth2 = jnp.cumsum(opens.astype(jnp.int32) - closes.astype(jnp.int32), axis=1)
        alive &= (depth2 <= 0) & ~closes
    o_ref[...] = jnp.where(alive, x, 0).astype(jnp.uint8)


def text_scan(
    rows: jax.Array,  # (n_rows, width) uint8, space padded
    *,
    lower: bool = True,
    strip_html: bool = False,
    strip_parens: bool = False,
    blk_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    n, width = rows.shape
    blk_rows = min(blk_rows, n)
    kernel = functools.partial(
        _scan_kernel, lower=lower, strip_html=strip_html, strip_parens=strip_parens
    )
    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(n, blk_rows),),
        in_specs=[pl.BlockSpec((blk_rows, width), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((blk_rows, width), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, width), jnp.uint8),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(rows)
