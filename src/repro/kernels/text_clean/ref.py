"""Pure-jnp oracle for the text-clean kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

SPACE = 32


def text_clean_ref(rows: jax.Array, *, strip_html: bool = True) -> jax.Array:
    x = rows.astype(jnp.int32)
    upper = (x >= 65) & (x <= 90)
    x = jnp.where(upper, x + 32, x)
    keep = jnp.ones_like(x, dtype=bool)
    if strip_html:
        lt = (x == 60).astype(jnp.int32)
        gt = (x == 62).astype(jnp.int32)
        depth = jnp.cumsum(lt - gt, axis=1)
        keep = (depth == 0) & (x != 62)
    is_word = (x >= 97) & (x <= 122)
    return jnp.where(is_word & keep, x, SPACE).astype(jnp.uint8)


def text_scan_ref(
    rows: jax.Array,
    *,
    lower: bool = True,
    strip_html: bool = False,
    strip_parens: bool = False,
) -> jax.Array:
    """Oracle for the scan-pass kernel (``text_scan``): value-preserving,
    sentinel-0 for removed span bytes, ``depth <= 0`` survival, paren span
    masked by the HTML span's aliveness."""
    x = rows.astype(jnp.int32)
    if lower:
        upper = (x >= 65) & (x <= 90)
        x = jnp.where(upper, x + 32, x)
    alive = jnp.ones_like(x, dtype=bool)
    if strip_html:
        lt = (x == 60).astype(jnp.int32)
        gt = (x == 62).astype(jnp.int32)
        depth = jnp.cumsum(lt - gt, axis=1)
        alive = (depth <= 0) & (x != 62)
    if strip_parens:
        opens = (x == 40) & alive
        closes = (x == 41) & alive
        depth2 = jnp.cumsum(opens.astype(jnp.int32) - closes.astype(jnp.int32), axis=1)
        alive &= (depth2 <= 0) & ~closes
    return jnp.where(alive, x, 0).astype(jnp.uint8)
