"""Version compatibility shims for Pallas TPU kernels.

The TPU compiler-params dataclass was renamed across JAX releases
(``pltpu.TPUCompilerParams`` → ``pltpu.CompilerParams``); resolving it here
keeps every kernel importable (and runnable under ``interpret=True`` on CPU)
on any JAX the container ships.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams", None
)


def tpu_compiler_params(**kwargs):
    """``compiler_params=`` value for ``pl.pallas_call`` on any JAX version.

    Returns None (meaning "compiler defaults") when neither class exists or
    the installed class rejects the requested fields — correctness never
    depends on these hints, only scheduling.
    """
    if _PARAMS_CLS is None:  # pragma: no cover - ancient jax
        return None
    try:
        return _PARAMS_CLS(**kwargs)
    except TypeError:  # pragma: no cover - field renamed/removed upstream
        return None


def has_tpu() -> bool:
    """True when a TPU backend is attached — the capability check deciding
    whether kernels run compiled (``interpret=False``) or must interpret."""
    import jax

    try:
        return any(d.platform == "tpu" for d in jax.devices())
    except Exception:  # pragma: no cover - backend init failure == no TPU
        return False
