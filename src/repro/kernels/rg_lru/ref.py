"""Pure-jnp oracle for the RG-LRU recurrence kernel."""

from __future__ import annotations

import jax


def rg_lru_ref(a: jax.Array, b: jax.Array, h0: jax.Array | None = None) -> jax.Array:
    """h_t = a_t h_{t-1} + b_t via associative scan (same math as the
    training path in repro.models.rglru)."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h
