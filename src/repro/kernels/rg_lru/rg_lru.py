"""RG-LRU linear recurrence kernel (Griffin) for TPU (Pallas).

Computes h_t = a_t * h_{t-1} + b_t over the sequence, given precomputed
gate products a, b (fp32): the memory-bound inner loop of the Griffin
block. Grid = (batch, d_blocks, s_blocks) with the sequence dimension
innermost ("arbitrary" semantics): the recurrent state h lives in VMEM
scratch and persists across sequence grid steps. Within a block a
``fori_loop`` steps through time on (blk_d,)-wide vectors.

This is the TPU-native adaptation of a GPU scan kernel: instead of a
warp-level prefix scan, the sequential dependence is carried block-to-block
in VMEM while the (batch × d) dimensions provide the parallelism that fills
the VPU lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..pallas_compat import tpu_compiler_params


def _rg_lru_kernel(a_ref, b_ref, h0_ref, o_ref, h_scr, *, blk_s: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = h0_ref[0]

    a = a_ref[0]  # (blk_s, blk_d)
    b = b_ref[0]

    def step(t, h):
        h = a[t] * h + b[t]
        o_ref[0, t, :] = h
        return h

    h_scr[...] = jax.lax.fori_loop(0, blk_s, step, h_scr[...])


def rg_lru(
    a: jax.Array,  # (batch, seq, d) fp32 decay
    b: jax.Array,  # (batch, seq, d) fp32 gated input
    h0: jax.Array | None = None,  # (batch, d) initial state
    *,
    blk_s: int = 256,
    blk_d: int = 512,
    interpret: bool = False,
) -> jax.Array:
    bt, s, d = a.shape
    if h0 is None:
        h0 = jnp.zeros((bt, d), jnp.float32)
    blk_s = min(blk_s, s)
    blk_d = min(blk_d, d)
    grid = (bt, pl.cdiv(d, blk_d), pl.cdiv(s, blk_s))

    kernel = functools.partial(_rg_lru_kernel, blk_s=blk_s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_s, blk_d), lambda bi, di, si: (bi, si, di)),
            pl.BlockSpec((1, blk_s, blk_d), lambda bi, di, si: (bi, si, di)),
            pl.BlockSpec((1, blk_d), lambda bi, di, si: (bi, di)),
        ],
        out_specs=pl.BlockSpec((1, blk_s, blk_d), lambda bi, di, si: (bi, si, di)),
        out_shape=jax.ShapeDtypeStruct((bt, s, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((blk_d,), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b, h0)
