"""Jit'd public wrapper for the RG-LRU kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .rg_lru import rg_lru


@partial(jax.jit, static_argnames=("blk_s", "blk_d", "interpret"))
def rg_lru_op(
    a: jax.Array,
    b: jax.Array,
    h0: jax.Array | None = None,
    *,
    blk_s: int = 256,
    blk_d: int = 512,
    interpret: bool = False,
) -> jax.Array:
    orig_dtype = a.dtype
    out = rg_lru(
        a.astype(jnp.float32), b.astype(jnp.float32),
        None if h0 is None else h0.astype(jnp.float32),
        blk_s=blk_s, blk_d=blk_d, interpret=interpret,
    )
    return out.astype(orig_dtype)
