"""xLSTM 1.3B — mLSTM/sLSTM 7:1 [arXiv:2405.04517]. d_ff=0: blocks are
self-contained (mLSTM up-projects internally)."""
from . import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    rope="none", norm="layernorm", act="gelu", glu=False,
    notes="48 layers = 6 scanned units of (7 mLSTM + 1 sLSTM). Fully "
          "recurrent => long_500k runs.",
)

SMOKE = ArchConfig(
    name="xlstm-1.3b-smoke", family="ssm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=64,
    block_pattern=("mlstm", "slstm"),
    rope="none", norm="layernorm", act="gelu", glu=False,
)
