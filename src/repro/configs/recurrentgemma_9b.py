"""RecurrentGemma 9B — Griffin: RG-LRU + local attention 1:2
[arXiv:2402.19427]. Pattern unit = (rglru, rglru, attn-local-2048)."""
from . import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab_size=256000,
    window=2048, block_pattern=("rglru", "rglru", "attn"),
    rope="rope", norm="rmsnorm", act="gelu", glu=True,
    tie_embeddings=True,
    notes="38 layers = 12 scanned (rec,rec,attn) units + 2 unrolled tail "
          "rglru layers. Local attention window 2048 => sub-quadratic; "
          "long_500k runs.",
)

SMOKE = ArchConfig(
    name="recurrentgemma-9b-smoke", family="hybrid",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=192, vocab_size=64,
    window=16, block_pattern=("rglru", "rglru", "attn"),
    rope="rope", norm="rmsnorm", act="gelu", glu=True, tie_embeddings=True,
)
