"""Qwen2-VL 72B — M-RoPE, dynamic-resolution vision [arXiv:2409.12191].
Backbone only: the ViT tower is a stub; ``input_specs`` provides
precomputed patch embeddings occupying the first 256 positions."""
from . import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab_size=152064,
    qkv_bias=True, rope="mrope", rope_theta=1e6,
    norm="rmsnorm", act="silu", glu=True,
    frontend="vision", frontend_dim=1280, n_frontend_tokens=256,
)

SMOKE = ArchConfig(
    name="qwen2-vl-72b-smoke", family="vlm",
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=192, vocab_size=64,
    qkv_bias=True, rope="mrope",
    norm="rmsnorm", act="silu", glu=True,
    frontend="vision", frontend_dim=24, n_frontend_tokens=16,
)
