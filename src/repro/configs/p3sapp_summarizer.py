"""The paper's own case-study model (not part of the assigned 10): LSTM
seq2seq title generator (see repro.models.seq2seq)."""
from ..models.seq2seq import Seq2SeqConfig

CONFIG = Seq2SeqConfig(vocab_size=8000, d_embed=128, d_hidden=256,
                       n_encoder_layers=3, max_abstract_len=128, max_title_len=24)
SMOKE = Seq2SeqConfig(vocab_size=128, d_embed=16, d_hidden=32,
                      n_encoder_layers=2, max_abstract_len=24, max_title_len=8)
