"""Kimi K2 — trillion-parameter MoE, 384 routed experts top-8
[arXiv:2501.kimi2 paper-table; DeepSeek-V3-style skeleton]."""
from . import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=2048, vocab_size=163840,
    moe=MoEConfig(n_experts=384, top_k=8, d_expert=2048, n_shared=1,
                  first_k_dense=1, d_ff_dense=18432),
    rope="rope", norm="rmsnorm", act="silu", glu=True,
    notes="Assignment table gives GQA kv=8 (we follow it; the real model uses "
          "MLA). head_dim=128 per K2 tech report. First layer dense.",
)

SMOKE = ArchConfig(
    name="kimi-k2-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=64, vocab_size=64,
    moe=MoEConfig(n_experts=16, top_k=4, d_expert=64, n_shared=1,
                  first_k_dense=1, d_ff_dense=192),
)
