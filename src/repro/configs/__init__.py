"""Architecture + shape configuration registry.

Every assigned architecture has a ``<id>.py`` module exporting ``CONFIG``
(the exact published configuration) and ``SMOKE`` (a reduced same-family
config for CPU tests). ``get(name)`` returns the full config,
``get_smoke(name)`` the reduced one. ``SHAPES`` are the assigned input
shapes; per-arch applicability (``supported_shapes``) encodes the
assignment sheet's skip rules.
"""

from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Any


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int  # routed experts
    top_k: int
    d_expert: int  # per-expert FFN width
    n_shared: int = 0
    first_k_dense: int = 0  # leading layers with a dense FFN instead of MoE
    d_ff_dense: int = 0  # width of those dense FFNs
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    expert_impl: str = "ragged"  # "ragged" | "batched" (see models.moe)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    moe: MoEConfig | None = None
    qkv_bias: bool = False
    rope: str = "rope"  # rope | mrope | none
    rope_theta: float = 10000.0
    causal: bool = True  # False -> encoder-only (hubert)
    window: int = 0  # >0 -> sliding-window attention
    block_pattern: tuple[str, ...] = ("attn",)  # unit scanned over depth
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"
    glu: bool = True
    tie_embeddings: bool = False
    frontend: str = ""  # "" | audio | vision (modality stub)
    frontend_dim: int = 0  # stub embedding dim
    n_frontend_tokens: int = 256  # patches/frames occupying the seq head
    d_rnn: int = 0  # recurrent width for rglru/xlstm blocks (0 -> d_model)
    init_scale: float = 0.02
    # flash-style jnp attention chunk sizes (0 q_chunk = no query chunking,
    # kv-only streaming — required by the sequence-parallel plan)
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    # sliding-window ring-buffer KV cache (§Perf; exact). False reproduces
    # the recorded full-cache baseline.
    ring_kv: bool = True
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def resolved_d_rnn(self) -> int:
        return self.d_rnn or self.d_model

    @property
    def is_decoder(self) -> bool:
        return self.causal

    @property
    def is_subquadratic(self) -> bool:
        """False iff the arch contains unwindowed full attention."""
        return not ("attn" in self.block_pattern and self.window == 0)

    # -- analytic parameter counts (used by rooflines: 6·N·D) --------------
    def _attn_params(self) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.n_heads * hd, self.n_kv_heads * hd
        p = d * n_q + 2 * d * n_kv + n_q * d
        if self.qkv_bias:
            p += n_q + 2 * n_kv
        return p

    def _mlp_params(self, d_ff: int) -> int:
        return (3 if self.glu else 2) * self.d_model * d_ff

    def _block_params(self, kind: str) -> int:
        d, dr = self.d_model, self.resolved_d_rnn
        if kind == "attn":
            if self.moe is not None:
                m = self.moe
                experts = (m.n_experts + m.n_shared) * self._mlp_params_w(m.d_expert)
                return self._attn_params() + experts + d * m.n_experts
            return self._attn_params() + self._mlp_params(self.d_ff)
        if kind == "rglru":
            # in/gate proj, out proj, conv4, rg-lru gates + lambda, plus MLP
            rec = 2 * d * dr + dr * d + 4 * dr + 2 * dr * dr + dr
            return rec + self._mlp_params(self.d_ff)
        if kind == "mlstm":
            # up-proj to 2*dr, qkv from dr, gates, down-proj
            return d * 2 * dr + 3 * dr * dr // 1 + 2 * dr + dr * d
        if kind == "slstm":
            return 4 * d * dr + 4 * dr * dr + 4 * dr + dr * d
        raise ValueError(kind)

    def _mlp_params_w(self, d_ff: int) -> int:
        return self._mlp_params(d_ff)

    def param_count(self) -> int:
        total = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            total += self.d_model * self.vocab_size
        if self.frontend:
            total += self.frontend_dim * self.d_model
        if self.moe is not None:
            m = self.moe
            dense_layer = self._attn_params() + self._mlp_params(m.d_ff_dense)
            moe_layer = self._block_params("attn")
            return total + m.first_k_dense * dense_layer + (
                self.n_layers - m.first_k_dense
            ) * moe_layer
        pat = self.block_pattern
        n_units, rem = divmod(self.n_layers, len(pat))
        for i, kind in enumerate(pat):
            total += (n_units + (1 if i < rem else 0)) * self._block_params(kind)
        return total

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: top_k + shared only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        inactive = (self.n_layers - m.first_k_dense) * (
            m.n_experts - m.top_k
        ) * self._mlp_params(m.d_expert)
        return self.param_count() - inactive


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "hubert_xlarge",
    "deepseek_moe_16b",
    "kimi_k2_1t_a32b",
    "stablelm_3b",
    "command_r_plus_104b",
    "granite_20b",
    "qwen2_5_32b",
    "recurrentgemma_9b",
    "xlstm_1_3b",
    "qwen2_vl_72b",
]


def _module(name: str):
    return importlib.import_module(f"repro.configs.{name.replace('-', '_')}")


def get(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ArchConfig:
    return _module(name).SMOKE


def supported_shapes(cfg: ArchConfig) -> list[str]:
    """Assignment-sheet applicability (skips recorded in DESIGN.md §4)."""
    out = ["train_4k", "prefill_32k"]
    if cfg.is_decoder:
        out.append("decode_32k")
        if cfg.is_subquadratic:
            out.append("long_500k")
    return out


def all_cells() -> list[tuple[str, str]]:
    """Every runnable (arch, shape) pair — the dry-run/roofline grid."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get(arch)
        for s in supported_shapes(cfg):
            cells.append((arch, s))
    return cells
