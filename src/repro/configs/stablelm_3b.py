"""StableLM 3B [hf:stabilityai/stablelm-2; assignment table]."""
from . import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=6912, vocab_size=50304,
    rope="rope", norm="layernorm", act="silu", glu=True,
)

SMOKE = ArchConfig(
    name="stablelm-3b-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=160, vocab_size=64,
    rope="rope", norm="layernorm", act="silu", glu=True,
)
