"""DeepSeekMoE 16B — fine-grained MoE, 2 shared + 64 routed top-6
[arXiv:2401.06066; hf deepseek-ai/deepseek-moe-16b-base]."""
from . import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                  first_k_dense=1, d_ff_dense=10944),
    rope="rope", norm="rmsnorm", act="silu", glu=True,
    notes="first layer dense FFN (d_ff 10944) per the released model.",
)

SMOKE = ArchConfig(
    name="deepseek-moe-16b-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=96, vocab_size=64,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=96, n_shared=1,
                  first_k_dense=1, d_ff_dense=256),
)
