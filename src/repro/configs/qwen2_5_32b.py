"""Qwen2.5 32B — GQA kv=8 with QKV bias [hf:Qwen/Qwen2.5-32B]."""
from . import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=27648, vocab_size=152064,
    qkv_bias=True, rope="rope", rope_theta=1e6,
    norm="rmsnorm", act="silu", glu=True,
)

SMOKE = ArchConfig(
    name="qwen2.5-32b-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=192, vocab_size=64,
    qkv_bias=True, rope="rope", norm="rmsnorm", act="silu", glu=True,
)
