"""HuBERT X-Large — encoder-only audio transformer [arXiv:2106.07447].

Backbone only (assignment): the conv feature-extractor frontend is a stub;
``input_specs`` provides precomputed 512-d frame embeddings. Targets are
k-means cluster IDs (vocab 504). Encoder-only => no decode shapes.
"""
from . import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab_size=504,
    causal=False, rope="none", norm="layernorm", act="gelu", glu=False,
    frontend="audio", frontend_dim=512,
    notes="HuBERT uses conv-positional embeddings; stubbed as position-free "
          "(relative position information is out of scope for the backbone assignment).",
)

SMOKE = ArchConfig(
    name="hubert-xlarge-smoke", family="audio",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab_size=32,
    causal=False, rope="none", norm="layernorm", act="gelu", glu=False,
    frontend="audio", frontend_dim=24,
)
