"""Command R+ 104B — GQA, no biases [hf:CohereForAI/c4ai-command-r-plus]."""
from . import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=33792, vocab_size=256000,
    rope="rope", norm="layernorm", act="silu", glu=True,
    tie_embeddings=True,  # Cohere ties input/output embeddings
)

SMOKE = ArchConfig(
    name="command-r-plus-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=192, vocab_size=64,
    rope="rope", norm="layernorm", act="silu", glu=True,
    tie_embeddings=True,
)
