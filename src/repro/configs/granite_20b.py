"""Granite 20B Code — MQA (kv=1), GPT-BigCode lineage [arXiv:2405.04324]."""
from . import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
    d_ff=24576, vocab_size=49152,
    rope="rope", norm="layernorm", act="gelu", glu=False,
    notes="d_ff = 4*d, plain GELU MLP (BigCode style); MQA exercises the "
          "kv-head<model-axis sharding fallback.",
)

SMOKE = ArchConfig(
    name="granite-20b-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=1, head_dim=8,
    d_ff=256, vocab_size=64,
    rope="rope", norm="layernorm", act="gelu", glu=False,
)
