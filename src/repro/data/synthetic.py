"""Synthetic CORE-dataset corpus generator.

The paper uses the CORE scholarly-metadata dump (123M records, JSON). That
dataset is not available offline, so we generate records with the same
schema (paper §5) and the same *dirt* the cleaning pipeline must remove:

* HTML tags wrapping random spans (``<p> <i> <b> <em> <sub> <sup>``)
* parenthetical asides, digits/years, punctuation, contractions, mixed case
* stopwords interleaved naturally
* ~4% null titles/abstracts, ~3% exact duplicates (paper pre-clean targets)

Tags/parentheses are emitted balanced and non-nested per field, which is the
semantics contract of the vectorized span ops (see bytesops docstring).
Deterministic for a given seed. Sizes are controlled by byte budgets so the
5-dataset scaling study mirrors the paper's 4.18-23.58 GB series at
container scale (MBs).
"""

from __future__ import annotations

import itertools
import random
from pathlib import Path
from typing import Iterator

try:  # fast path; stdlib fallback keeps bare environments working
    import orjson

    def _dumps(obj) -> bytes:
        return orjson.dumps(obj)

except ModuleNotFoundError:  # pragma: no cover - exercised on bare envs
    import json

    def _dumps(obj) -> bytes:
        return json.dumps(obj, separators=(",", ":")).encode()

_SYLLABLES = (
    "al an ar as at con cor de den der dis ec en er es ex for gen ic il in "
    "is it lec men ment mod nal ner nol og on or per pre pro qua re ric sec "
    "sen ser sis sta sys tal tec ter tic tion tor tra tri tur ul ur ver vis"
).split()

_STOPWORDS = (
    "the of and to in a is that for it as was with be by on not he i this "
    "are or his from at which but have an had they you were their one all we "
    "can her has there been if more when will would who so no"
).split()

_CONTRACTIONS = ["can't", "won't", "isn't", "doesn't", "it's", "we're", "they've", "he'd"]
_TAGS = ["p", "i", "b", "em", "sub", "sup"]
_PUNCT = [".", ",", ";", ":", "!", "?"]

CORE_FIELDS = [
    "doi", "coreId", "oai", "identifiers", "title", "authors", "contributors",
    "datePublished", "abstract", "downloadUrl", "publisher", "journals",
    "language", "relations", "year", "topics", "subjects", "fullText",
]


class CorpusGenerator:
    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed)
        # Zipf-ish vocabulary of pseudo-words. Cumulative weights are
        # precomputed once: random.choices() would otherwise rebuild the
        # 4000-entry cumsum per call (100x generation slowdown).
        n_vocab = 4000
        self.vocab = [self._make_word() for _ in range(n_vocab)]
        weights = [1.0 / (i + 1) for i in range(n_vocab)]
        self.cum_weights = list(itertools.accumulate(weights))

    def _make_word(self) -> str:
        r = self.rng
        return "".join(r.choice(_SYLLABLES) for _ in range(r.randint(2, 4)))

    def _words(self, n: int) -> list[str]:
        r = self.rng
        out: list[str] = []
        for _ in range(n):
            if r.random() < 0.35:
                out.append(r.choice(_STOPWORDS))
            else:
                out.append(r.choices(self.vocab, cum_weights=self.cum_weights, k=1)[0])
        return out

    def _dirty_text(self, n_words: int, *, html_p: float, paren_p: float) -> str:
        """Natural-ish dirty text with balanced, non-nested tags/parens."""
        r = self.rng
        words = self._words(n_words)
        out: list[str] = []
        i = 0
        while i < len(words):
            roll = r.random()
            if roll < html_p and i + 2 < len(words):
                tag = r.choice(_TAGS)
                span = words[i : i + r.randint(1, 3)]
                out.append(f"<{tag}>" + " ".join(span) + f"</{tag}>")
                i += len(span)
            elif roll < html_p + paren_p and i + 2 < len(words):
                span = words[i : i + r.randint(1, 4)]
                out.append("(" + " ".join(span) + ")")
                i += len(span)
            else:
                w = words[i]
                if r.random() < 0.08:
                    w = w.capitalize()
                if r.random() < 0.05:
                    w = r.choice(_CONTRACTIONS)
                if r.random() < 0.04:
                    w = str(r.randint(0, 2030))
                if r.random() < 0.12:
                    w += r.choice(_PUNCT)
                out.append(w)
                i += 1
        return " ".join(out)

    def record(self) -> dict:
        r = self.rng
        title = None if r.random() < 0.04 else self._dirty_text(
            r.randint(6, 14), html_p=0.05, paren_p=0.04
        )
        abstract = None if r.random() < 0.04 else self._dirty_text(
            r.randint(60, 220), html_p=0.04, paren_p=0.05
        )
        year = r.randint(1990, 2019)
        rec = {f: None for f in CORE_FIELDS}
        rec.update(
            {
                "doi": f"10.{r.randint(1000, 9999)}/{r.randint(100000, 999999)}",
                "coreId": str(r.randint(10**7, 10**8)),
                "title": title,
                "authors": [self._make_word().capitalize() for _ in range(r.randint(1, 4))],
                "datePublished": f"{year}-01-01",
                "abstract": abstract,
                "publisher": self._make_word().capitalize(),
                "language": "en",
                "year": year,
                "topics": [self._make_word() for _ in range(r.randint(0, 3))],
                "subjects": [],
            }
        )
        return rec

    def records(self) -> Iterator[dict]:
        recent: list[dict] = []
        while True:
            if recent and self.rng.random() < 0.03:
                yield dict(self.rng.choice(recent))  # duplicate
                continue
            rec = self.record()
            recent.append(rec)
            if len(recent) > 500:
                recent.pop(0)
            yield rec


def write_corpus(
    out_dir: str | Path,
    total_bytes: int,
    n_files: int = 8,
    seed: int = 0,
) -> list[Path]:
    """Write ~total_bytes of JSONL across n_files of deliberately unequal size
    (the paper's shards range KB..GB)."""
    out_dir = Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    gen = CorpusGenerator(seed)
    it = gen.records()
    rng = random.Random(seed + 1)
    # Unequal byte budgets per file.
    raw = [rng.uniform(0.3, 1.7) for _ in range(n_files)]
    budgets = [int(total_bytes * w / sum(raw)) for w in raw]
    paths = []
    for i, budget in enumerate(budgets):
        p = out_dir / f"shard_{i:04d}.jsonl"
        written = 0
        with open(p, "wb") as fh:
            while written < budget:
                line = _dumps(next(it)) + b"\n"
                fh.write(line)
                written += len(line)
        paths.append(p)
    return paths
