"""Word-level tokenizer for the title-generation case study.

The paper's Keras lineage uses a Keras ``Tokenizer`` (word-index map built
from the cleaned corpus). Same here: vocabulary = most frequent words of
the cleaned text, with the four specials the seq2seq decoder needs.

Fitting is a count aggregation, which makes it distributable exactly like
Spark's ``CountVectorizer``: each shard counts its own words, the driver
merges the ``Counter``s, and :meth:`WordTokenizer.from_counts` turns the
merged counts into a vocabulary. Ordering is deterministic — count
descending, then word ascending — so a whole-frame fit and a shard-merged
fit of the same corpus always produce the same vocabulary (plain
``Counter.most_common`` breaks ties by insertion order, which differs
between the two).
"""

from __future__ import annotations

import hashlib
import json
from collections import Counter
from pathlib import Path
from typing import Iterable, Mapping, Sequence

import numpy as np

PAD, START, END, UNK = 0, 1, 2, 3
SPECIALS = ("<pad>", "<start>", "<end>", "<unk>")


def top_words(counts: Mapping[str, int], n: int) -> list[str]:
    """The ``n`` most frequent words under the deterministic tie-break
    (count desc, word asc) — insertion-order independent, so shard-merged
    and whole-corpus counts rank identically."""
    if n <= 0:
        return []
    ranked = sorted(counts.items(), key=lambda kv: (-kv[1], kv[0]))
    return [w for w, _ in ranked[:n]]


class WordTokenizer:
    def __init__(self, vocab: Sequence[str]):
        self.itos: list[str] = list(SPECIALS) + [w for w in vocab if w not in SPECIALS]
        self.stoi: dict[str, int] = {w: i for i, w in enumerate(self.itos)}

    @classmethod
    def from_counts(
        cls, counts: Mapping[str, int], vocab_size: int = 8000
    ) -> "WordTokenizer":
        """Build from (possibly shard-merged) word counts — the ``fit``
        half of the Spark CountVectorizer-style fit/transform split."""
        return cls(top_words(counts, max(vocab_size - len(SPECIALS), 0)))

    @classmethod
    def fit(cls, texts: Iterable[str], vocab_size: int = 8000) -> "WordTokenizer":
        counts: Counter = Counter()
        for t in texts:
            counts.update(t.split())
        return cls.from_counts(counts, vocab_size)

    def __len__(self) -> int:
        return len(self.itos)

    @property
    def fingerprint(self) -> str:
        """Stable content hash of the vocabulary (order-sensitive). Token
        cache entries are keyed by it, so refitting with different data or
        a different ``vocab_size`` invalidates cached token arrays without
        touching the cleaned-text entries."""
        h = hashlib.blake2b(digest_size=16)
        for w in self.itos:
            enc = w.encode("utf-8", errors="surrogatepass")
            h.update(len(enc).to_bytes(4, "little"))
            h.update(enc)
        return h.hexdigest()

    def encode(self, text: str, max_len: int, add_start_end: bool = False) -> np.ndarray:
        ids = [self.stoi.get(w, UNK) for w in text.split()]
        if add_start_end:
            ids = [START] + ids[: max_len - 2] + [END]
        else:
            ids = ids[:max_len]
        out = np.full(max_len, PAD, dtype=np.int32)
        out[: len(ids)] = ids
        return out

    def decode(self, ids: Iterable[int]) -> str:
        words = []
        for i in ids:
            if i == END:
                break
            if i in (PAD, START):
                continue
            words.append(self.itos[int(i)] if int(i) < len(self.itos) else "<unk>")
        return " ".join(words)

    # -- persistence (checkpointed with the model) -------------------------
    def save(self, path: str | Path) -> None:
        Path(path).write_text(json.dumps(self.itos))

    @classmethod
    def load(cls, path: str | Path) -> "WordTokenizer":
        itos = json.loads(Path(path).read_text())
        tok = cls.__new__(cls)
        tok.itos = itos
        tok.stoi = {w: i for i, w in enumerate(itos)}
        return tok
