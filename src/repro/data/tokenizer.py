"""Word-level tokenizer for the title-generation case study.

The paper's Keras lineage uses a Keras ``Tokenizer`` (word-index map built
from the cleaned corpus). Same here: vocabulary = most frequent words of
the cleaned text, with the four specials the seq2seq decoder needs.
"""

from __future__ import annotations

import json
from collections import Counter
from pathlib import Path
from typing import Iterable, Sequence

import numpy as np

PAD, START, END, UNK = 0, 1, 2, 3
SPECIALS = ("<pad>", "<start>", "<end>", "<unk>")


class WordTokenizer:
    def __init__(self, vocab: Sequence[str]):
        self.itos: list[str] = list(SPECIALS) + [w for w in vocab if w not in SPECIALS]
        self.stoi: dict[str, int] = {w: i for i, w in enumerate(self.itos)}

    @classmethod
    def fit(cls, texts: Iterable[str], vocab_size: int = 8000) -> "WordTokenizer":
        counts: Counter = Counter()
        for t in texts:
            counts.update(t.split())
        vocab = [w for w, _ in counts.most_common(max(vocab_size - len(SPECIALS), 0))]
        return cls(vocab)

    def __len__(self) -> int:
        return len(self.itos)

    def encode(self, text: str, max_len: int, add_start_end: bool = False) -> np.ndarray:
        ids = [self.stoi.get(w, UNK) for w in text.split()]
        if add_start_end:
            ids = [START] + ids[: max_len - 2] + [END]
        else:
            ids = ids[:max_len]
        out = np.full(max_len, PAD, dtype=np.int32)
        out[: len(ids)] = ids
        return out

    def decode(self, ids: Iterable[int]) -> str:
        words = []
        for i in ids:
            if i == END:
                break
            if i in (PAD, START):
                continue
            words.append(self.itos[int(i)] if int(i) < len(self.itos) else "<unk>")
        return " ".join(words)

    # -- persistence (checkpointed with the model) -------------------------
    def save(self, path: str | Path) -> None:
        Path(path).write_text(json.dumps(self.itos))

    @classmethod
    def load(cls, path: str | Path) -> "WordTokenizer":
        itos = json.loads(Path(path).read_text())
        tok = cls.__new__(cls)
        tok.itos = itos
        tok.stoi = {w: i for i, w in enumerate(itos)}
        return tok
