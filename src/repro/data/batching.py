"""Batching operators: cleaned text columns → fixed-shape model inputs.

These are the array-level operators of the lazy ``Dataset`` plan
(:mod:`repro.core.dataset`): a ``TokenSpec`` describes how one text column
becomes one token array, ``encode_rows``/``encode_column`` execute it, and
``batches`` slices the resulting arrays into fixed-shape batches — either
one fixed ``max_len`` shape, or a small fixed set of **length buckets**
(``bucket_by=``) so short rows stop paying full-width padding while jit
still sees a bounded shape set. The legacy eager helpers
(``seq2seq_arrays``, ``train_val_split``) remain as thin wrappers.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Sequence

import numpy as np

from .tokenizer import END, PAD, START, UNK, WordTokenizer

# NOTE: ``repro.core.bytesops`` is imported lazily inside the encoding
# functions: ``repro.core.__init__`` imports this module transitively, so a
# module-level import would be circular when ``repro.data`` loads first.


@dataclass(frozen=True)
class TokenSpec:
    """One text column → one fixed-length token array."""

    column: str
    max_len: int
    out: str | None = None  # output array name; default "<column>_tokens"
    add_start_end: bool = False

    @property
    def name(self) -> str:
        return self.out or f"{self.column}_tokens"


def seq2seq_specs(
    max_abstract_len: int = 128,
    max_title_len: int = 24,
    abstract_col: str = "abstract",
    title_col: str = "title",
) -> tuple[TokenSpec, TokenSpec]:
    """The case-study encoding: abstract → encoder input, title → target."""
    return (
        TokenSpec(abstract_col, max_abstract_len, out="encoder_tokens"),
        TokenSpec(title_col, max_title_len, out="decoder_tokens", add_start_end=True),
    )


# ---------------------------------------------------------------------------
# Vectorized encoding: hash the flat byte buffer per word, bulk-map via one
# vocab lookup table (exact — no hash collisions; see VocabTable)
# ---------------------------------------------------------------------------

# Bytes str.split() (no argument) treats as whitespace within ASCII:
# space, \t\n\v\f\r, and the file/group/record/unit separators \x1c-\x1f —
# plus the flat-buffer row separator. This LUT marks them all as word
# delimiters so byte-level segmentation matches str.split() exactly on
# ASCII rows. (Non-ASCII whitespace like \xa0 is multi-byte in UTF-8, so
# those rows take the per-row fallback anyway.)
_DELIM_LUT = np.zeros(256, dtype=bool)
for _b in (0, 9, 10, 11, 12, 13, 28, 29, 30, 31, 32):
    _DELIM_LUT[_b] = True

_HASH_C1 = 0x9E3779B97F4A7C15
_HASH_C2 = 0xC2B2AE3D27D4EB4F
_U64 = (1 << 64) - 1


class VocabTable:
    """Exact bulk word→id map over packed byte keys.

    Words of <=16 bytes are identified by ``(k1, k2, len)`` — bytes 0-7 and
    8-15 packed into two uint64 (zero padded) plus the byte length — which
    is collision-free, not a lossy hash: rows never contain NUL, so the
    zero padding cannot be confused with word bytes, and the length check
    separates a long word from a 16-byte word sharing its prefix. The map
    is an open-addressing hash table probed with vectorized gathers; every
    probe verifies full (k1, k2, len) equality, so a hash collision can
    only cost an extra probe, never a wrong id. Longer vocabulary words
    live in an exact bytes dict probed only for the rare >16-byte text
    words."""

    def __init__(self, stoi: dict[str, int]):
        from ..core import bytesops as B

        self.stoi = dict(stoi)
        self.long: dict[bytes, int] = {}
        entries: list[tuple[int, int, int, int]] = []
        for w, i in self.stoi.items():
            try:
                raw = w.encode("utf-8")
            except UnicodeEncodeError:
                continue  # unencodable word can never appear in a buffer
            if len(raw) > 16:
                self.long[raw] = i
                continue
            k1, k2, ln = B.pack_word(w)
            entries.append((k1, k2, ln, i))
        bits = 8
        while (1 << bits) < 4 * max(len(entries), 1):
            bits += 1
        size = 1 << bits
        self._mask = size - 1
        self._shift = np.uint64(64 - bits)
        self.hk1 = np.zeros(size, dtype=np.uint64)
        self.hk2 = np.zeros(size, dtype=np.uint64)
        self.hln = np.full(size, -1, dtype=np.int32)  # -1 marks an empty slot
        self.hid = np.zeros(size, dtype=np.int32)
        self.max_probe = 0
        for k1, k2, ln, i in entries:
            h = (((k1 * _HASH_C1) & _U64) ^ ((k2 * _HASH_C2) & _U64)) >> (64 - bits)
            probe = 0
            while self.hln[h] != -1:
                h = (h + 1) & self._mask
                probe += 1
            self.hk1[h], self.hk2[h] = k1, k2
            self.hln[h], self.hid[h] = ln, i
            self.max_probe = max(self.max_probe, probe)

    def lookup_keys(
        self, k1: np.ndarray, k2: np.ndarray, lengths: np.ndarray
    ) -> np.ndarray:
        """ids (UNK default) for packed word keys — one vectorized gather
        + compare per probe step; a word stops probing at its entry or at
        the first empty slot (absent → UNK)."""
        ids = np.full(k1.size, UNK, dtype=np.int32)
        if ids.size == 0:
            return ids
        c1, c2 = np.uint64(_HASH_C1), np.uint64(_HASH_C2)
        with np.errstate(over="ignore"):  # uint64 wraparound is the hash
            h = (((k1 * c1) ^ (k2 * c2)) >> self._shift).astype(np.int64)
        # First probe full-width: the overwhelming majority of words
        # resolve here (hit their slot or see an empty one). The few
        # cluster-walkers then continue on compressed index arrays, so
        # later probes never re-gather the whole word set.
        ln_at = self.hln[h]
        ok = (self.hk1[h] == k1) & (self.hk2[h] == k2) & (ln_at == lengths)
        if ok.any():
            ids[ok] = self.hid[h[ok]]
        rem = np.flatnonzero(~ok & (ln_at != -1))
        if rem.size:
            h, k1, k2 = h[rem], k1[rem], k2[rem]
            lengths = lengths[rem]
            for _ in range(self.max_probe):
                h = (h + 1) & self._mask
                ln_at = self.hln[h]
                ok = (self.hk1[h] == k1) & (self.hk2[h] == k2) & (ln_at == lengths)
                if ok.any():
                    ids[rem[ok]] = self.hid[h[ok]]
                keep = ~ok & (ln_at != -1)
                if not keep.any():
                    break
                rem, h = rem[keep], h[keep]
                k1, k2, lengths = k1[keep], k2[keep], lengths[keep]
        return ids

    def lookup_long(self, word_bytes: bytes) -> int:
        return self.long.get(word_bytes, UNK)


def _encode_one(
    text: str | None, stoi: dict[str, int], max_len: int, add_start_end: bool
) -> np.ndarray:
    """The per-row oracle (and exact fallback for rows the vectorized path
    cannot represent as flat ASCII bytes)."""
    ids = [stoi.get(w, UNK) for w in (text or "").split()]
    if add_start_end:
        ids = [START] + ids[: max_len - 2] + [END]
    else:
        ids = ids[:max_len]
    row = np.full(max_len, PAD, dtype=np.int32)
    row[: len(ids)] = ids
    return row


# mask64[L] keeps the low min(L, 8) bytes of a little-endian uint64 load
_MASK64 = np.zeros(17, dtype=np.uint64)
for _L in range(17):
    _MASK64[_L] = np.uint64(0xFFFFFFFFFFFFFFFF if _L >= 8 else (1 << (8 * _L)) - 1)

_LITTLE_ENDIAN = __import__("sys").byteorder == "little"


def _unaligned_u64(u: np.ndarray, byte_idx: np.ndarray) -> np.ndarray:
    """Little-endian unaligned 64-bit loads from a uint64 view: two
    aligned gathers combined by per-element shifts (two gathers instead
    of eight byte gathers)."""
    w = byte_idx >> 3
    r = ((byte_idx & 7) << 3).astype(np.uint64)
    a = u[w] >> r
    b = u[w + 1] << ((np.uint64(64) - r) & np.uint64(63))
    return a | np.where(r == np.uint64(0), np.uint64(0), b)


def _gather_u64_bytes(bufp: np.ndarray, byte_idx: np.ndarray) -> np.ndarray:
    """Byte-order-independent fallback: 8 byte gathers into a uint64 view
    (matches ``pack_word``'s native-order frombuffer packing)."""
    mat = np.empty((byte_idx.size, 8), dtype=np.uint8)
    idx = byte_idx.copy()
    for j in range(8):
        np.take(bufp, idx, out=mat[:, j])
        idx += 1
    return mat.reshape(-1).view(np.uint64)


def _pack_word_keys(
    bufp: np.ndarray, start_idx: np.ndarray, lengths: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """(k1, k2) packed keys of every word, masked to the word length
    (bytes beyond a word are neighbor garbage from the load, not
    guaranteed zero). ``bufp`` must be zero-padded to a multiple of 8
    bytes with at least 16 bytes of slack after the last word start."""
    if _LITTLE_ENDIAN:
        u = bufp.view(np.uint64)
        k1 = _unaligned_u64(u, start_idx)
    else:  # pragma: no cover - big-endian fallback
        k1 = _gather_u64_bytes(bufp, start_idx)
    k1 &= _MASK64[np.minimum(lengths, 16)]
    k2 = np.zeros(start_idx.size, dtype=np.uint64)
    long8 = np.flatnonzero(lengths > 8)
    if long8.size:
        idx2 = start_idx[long8] + 8
        if _LITTLE_ENDIAN:
            kk = _unaligned_u64(bufp.view(np.uint64), idx2)
        else:  # pragma: no cover - big-endian fallback
            kk = _gather_u64_bytes(bufp, idx2)
        kk &= _MASK64[np.minimum(lengths[long8] - 8, 16)]
        k2[long8] = kk
    return k1, k2


def encode_flat(
    buf: np.ndarray,
    table: VocabTable,
    max_len: int,
    add_start_end: bool = False,
) -> np.ndarray:
    """Encode a flat byte buffer to a (rows, max_len) int32 array without
    a per-row Python loop: segment words once, pack each word's bytes into
    exact 16-byte keys, bulk-map them through the :class:`VocabTable`, and
    scatter into the output. Rows containing non-ASCII bytes fall back to
    the per-row oracle (multi-byte whitespace and decode-dependent
    splitting make them irreducibly row-wise), so the result is
    byte-identical to encoding the decoded rows one by one."""
    from ..core import bytesops as B

    sep_pos = np.flatnonzero(buf == B.ROW_SEP)
    n = sep_pos.size
    out = np.full((n, max_len), PAD, dtype=np.int32)
    if n == 0:
        return out
    cap = max_len - 2 if add_start_end else max_len
    if add_start_end:
        out[:, 0] = START
    delim = _DELIM_LUT[buf]
    isw = ~delim
    starts = isw.copy()
    starts[1:] &= delim[:-1]
    ends = isw  # reuse; isw not needed afterwards
    ends[:-1] &= delim[1:]
    start_idx = np.flatnonzero(starts)
    counts = np.zeros(n, dtype=np.int64)
    if start_idx.size:
        # Word bytes never include whitespace, so keys pack straight from
        # the original buffer.
        lengths = (np.flatnonzero(ends) - start_idx + 1).astype(np.int32)
        word_rows = np.searchsorted(sep_pos, start_idx)
        pad = 16 + (-(buf.size + 16)) % 8
        bufp = np.concatenate([buf, np.zeros(pad, dtype=np.uint8)])
        k1, k2 = _pack_word_keys(bufp, start_idx, lengths)
        ids = table.lookup_keys(k1, k2, lengths)
        for p in np.flatnonzero(lengths > 16):  # rare >16-byte words
            s, ln = int(start_idx[p]), int(lengths[p])
            ids[p] = table.lookup_long(buf[s : s + ln].tobytes())
        counts = np.bincount(word_rows, minlength=n)
        first = np.concatenate(([0], np.cumsum(counts)[:-1]))
        colpos = np.arange(word_rows.size, dtype=np.int64) - first[word_rows]
        m = colpos < cap
        if add_start_end:
            out[word_rows[m], colpos[m] + 1] = ids[m]
        else:
            out[word_rows[m], colpos[m]] = ids[m]
    if add_start_end:
        endpos = np.minimum(counts, max(cap, 0)) + 1
        out[np.arange(n), np.minimum(endpos, max_len - 1)] = END
    nonascii = np.flatnonzero(buf >= 128)
    if nonascii.size:
        bad = np.zeros(n, dtype=bool)
        bad[np.searchsorted(sep_pos, nonascii)] = True
        row_starts = np.concatenate(([0], sep_pos[:-1] + 1))
        raw = buf.tobytes()
        for r in np.flatnonzero(bad):
            t = raw[row_starts[r] : sep_pos[r]].decode("utf-8", errors="ignore")
            out[r] = _encode_one(t, table.stoi, max_len, add_start_end)
    return out


def encode_rows(
    texts: Sequence[str | None],
    stoi: dict[str, int],
    max_len: int,
    add_start_end: bool = False,
    table: VocabTable | None = None,
) -> np.ndarray:
    """Encode rows against a word-index map into one (n, max_len) int32
    array. This is the single encoding implementation: the eager oracle
    (:func:`encode_column`) and the per-shard executor token step
    (:mod:`repro.core.executor`) both route through it / through
    :func:`encode_flat`, so they are byte-identical by construction.

    Vectorized: ASCII rows flatten into one byte buffer and bulk-encode
    (:func:`encode_flat`); rows the buffer cannot represent exactly
    (non-ASCII, NUL, non-string values) take the per-row oracle. Pass a
    prebuilt ``table`` when encoding many batches against one vocabulary.
    """
    from ..core import bytesops as B

    n = len(texts)
    rows: list[str] = []
    fallback: list[int] = []
    for i, t in enumerate(texts):
        if t is None:
            rows.append("")
        elif isinstance(t, str) and t.isascii() and "\x00" not in t:
            rows.append(t)
        else:
            rows.append("")
            fallback.append(i)
    if table is None:
        table = VocabTable(stoi)
    out = encode_flat(B.flatten(rows), table, max_len, add_start_end)
    if out.shape[0] != n:  # pragma: no cover - flatten invariant
        out = np.full((n, max_len), PAD, dtype=np.int32)
        fallback = list(range(n))
    for i in fallback:
        out[i] = _encode_one(texts[i], stoi, max_len, add_start_end)
    return out


def encode_column(
    texts: Sequence[str | None],
    tokenizer: WordTokenizer,
    max_len: int,
    add_start_end: bool = False,
) -> np.ndarray:
    return encode_rows(texts, tokenizer.stoi, max_len, add_start_end)


def encode_frame_columns(
    columns: dict[str, Sequence[str | None]],
    tokenizer: WordTokenizer,
    specs: Sequence[TokenSpec],
) -> dict[str, np.ndarray]:
    return {
        spec.name: encode_column(
            columns[spec.column], tokenizer, spec.max_len, spec.add_start_end
        )
        for spec in specs
    }


def seq2seq_arrays(
    records: Sequence[dict],
    tokenizer: WordTokenizer,
    max_abstract_len: int = 128,
    max_title_len: int = 24,
    abstract_col: str = "abstract",
    title_col: str = "title",
) -> dict[str, np.ndarray]:
    """Encode abstract (encoder input) and title (decoder target)."""
    specs = seq2seq_specs(max_abstract_len, max_title_len, abstract_col, title_col)
    columns = {
        abstract_col: [r.get(abstract_col) for r in records],
        title_col: [r.get(title_col) for r in records],
    }
    return encode_frame_columns(columns, tokenizer, specs)


# ---------------------------------------------------------------------------
# Length-bucketed assembly
# ---------------------------------------------------------------------------


def effective_lengths(arr: np.ndarray) -> np.ndarray:
    """Per-row payload length of a padded token array: 1 + index of the
    last non-PAD token (0 for all-PAD rows). Trailing padding beyond it is
    droppable without losing information, even if PAD ids appear *inside*
    the row (a literal ``<pad>`` word encodes to 0)."""
    nonpad = arr != PAD
    lens = arr.shape[1] - np.argmax(nonpad[:, ::-1], axis=1)
    return np.where(nonpad.any(axis=1), lens, 0).astype(np.int64)


def derive_buckets(max_len: int, n_buckets: int = 4) -> tuple[int, ...]:
    """A small fixed set of bucket widths ending at ``max_len`` (linear
    steps, deduplicated) — bounded shape set, jit-compilation friendly."""
    n = max(int(n_buckets), 1)
    widths = sorted({max(1, (max_len * i) // n) for i in range(1, n + 1)} | {max_len})
    return tuple(widths)


def assign_buckets(lengths: np.ndarray, buckets: Sequence[int]) -> np.ndarray:
    """Index of the smallest bucket wide enough for each row. Rows longer
    than the last bucket land in it (they were already truncated to
    ``max_len`` == the last bucket by encoding)."""
    edges = np.asarray(buckets, dtype=np.int64)
    idx = np.searchsorted(edges, np.asarray(lengths, dtype=np.int64), side="left")
    return np.minimum(idx, len(edges) - 1)


def bucket_columns(bucket_by: str | Sequence[str]) -> tuple[str, ...]:
    """Normalize ``bucket_by`` (one column name or several) to a tuple."""
    return (bucket_by,) if isinstance(bucket_by, str) else tuple(bucket_by)


def bucket_grid(
    bucket_by: str | Sequence[str],
    buckets: Sequence,
    arrays: dict[str, np.ndarray] | None = None,
) -> tuple[tuple[str, ...], tuple[tuple[int, ...], ...]]:
    """(columns, per-column bucket widths). ``buckets`` may be a flat int
    sequence (single column), a nested per-column sequence, or empty —
    then widths derive from each column's array width."""
    cols = bucket_columns(bucket_by)
    if not buckets:
        if arrays is None:
            raise ValueError("bucket widths unset and no arrays to derive them from")
        return cols, tuple(derive_buckets(arrays[c].shape[1]) for c in cols)
    if isinstance(buckets[0], (int, np.integer)):
        if len(cols) != 1:
            raise ValueError(
                f"flat bucket widths with {len(cols)} bucket columns; pass one "
                "width list per column"
            )
        return cols, (tuple(int(b) for b in buckets),)
    if len(buckets) != len(cols):
        raise ValueError(
            f"{len(buckets)} bucket width lists for {len(cols)} bucket columns"
        )
    return cols, tuple(tuple(int(b) for b in bs) for bs in buckets)


def _grid_assignment(
    arrays: dict[str, np.ndarray],
    cols: Sequence[str],
    grid: Sequence[Sequence[int]],
) -> np.ndarray:
    """Composite bucket-cell index per row (row-major over the grid — the
    same order ``itertools.product`` enumerates)."""
    n = len(next(iter(arrays.values())))
    assign = np.zeros(n, dtype=np.int64)
    for c, widths in zip(cols, grid):
        assign = assign * len(widths) + assign_buckets(
            effective_lengths(arrays[c]), widths
        )
    return assign


def slice_to_bucket(
    batch: dict[str, np.ndarray], widths: dict[str, int]
) -> dict[str, np.ndarray]:
    """Slice each bucketed column to its cell width."""
    return {
        k: (v[:, : widths[k]] if k in widths else v) for k, v in batch.items()
    }


def pad_token_fraction(batches: Sequence[dict[str, np.ndarray]], column: str) -> float:
    """Fraction of entries in ``column`` that are padding beyond each row's
    payload — the accelerator-cycle waste bucketing removes."""
    pad = total = 0
    for b in batches:
        arr = b[column]
        total += arr.size
        pad += int(arr.size - effective_lengths(arr).sum())
    return pad / total if total else 0.0


def pad_batch(batch: dict[str, np.ndarray], rows: int) -> dict[str, np.ndarray]:
    """Pad a partial batch with PAD rows up to ``rows`` (shape stability)."""
    n = len(next(iter(batch.values())))
    if n >= rows:
        return batch
    out = {}
    for k, v in batch.items():
        padded = np.full((rows,) + v.shape[1:], PAD, dtype=v.dtype)
        padded[:n] = v
        out[k] = padded
    return out


def emit_bucketed(
    arrays: dict[str, np.ndarray],
    order: np.ndarray,
    batch_size: int,
    bucket_by: str | Sequence[str],
    buckets: Sequence,
) -> tuple[list[dict[str, np.ndarray]], np.ndarray]:
    """(full bucket batches in ``order``-scan order, leftover row indices).

    Rows are scanned in ``order``; each full batch keeps only rows of one
    bucket cell and each bucketed column is sliced to its cell width. With
    several ``bucket_by`` columns the cells form a fixed grid (paired
    encoder/decoder bucketing: decoder padding drops too). Leftovers
    (per-cell remainders) come back for the caller to carry, pad, or
    drop."""
    from itertools import product

    cols, grid = bucket_grid(bucket_by, buckets, arrays)
    assignment = _grid_assignment(arrays, cols, grid)
    out: list[dict[str, np.ndarray]] = []
    leftovers: list[np.ndarray] = []
    for ci, cell in enumerate(product(*grid)):
        rows = order[assignment[order] == ci]
        if not rows.size:
            continue
        widths = dict(zip(cols, cell))
        full = (len(rows) // batch_size) * batch_size
        for s in range(0, full, batch_size):
            sel = rows[s : s + batch_size]
            out.append(
                slice_to_bucket({k: v[sel] for k, v in arrays.items()}, widths)
            )
        if full < len(rows):
            leftovers.append(rows[full:])
    rest = (
        np.concatenate(leftovers)
        if leftovers
        else np.zeros(0, dtype=np.int64)
    )
    return out, rest


def emit_remainders(
    rows: dict[str, np.ndarray],
    bucket_by: str | Sequence[str],
    buckets: Sequence,
    pad_to: int | None,
    drop_remainder: bool,
) -> list[dict[str, np.ndarray]]:
    """Per-cell remainder batches under the remainder policy (empty when
    dropped). Remainders stay per-cell so every emitted batch keeps a
    bucket-grid shape and at most batch_size rows — never one concatenated
    full-width catch-all. Shared by the whole-frame and streaming
    assemblers so their remainder semantics cannot drift."""
    from itertools import product

    out: list[dict[str, np.ndarray]] = []
    if (pad_to is None and drop_remainder) or not len(next(iter(rows.values()))):
        return out
    cols, grid = bucket_grid(bucket_by, buckets, rows)
    assignment = _grid_assignment(rows, cols, grid)
    cells = list(product(*grid))
    for ci in np.unique(assignment):
        part = {k: v[assignment == ci] for k, v in rows.items()}
        if pad_to is not None:
            part = pad_batch(part, pad_to)
        out.append(slice_to_bucket(part, dict(zip(cols, cells[ci]))))
    return out


def batches(
    arrays: dict[str, np.ndarray],
    batch_size: int,
    *,
    shuffle: bool = True,
    seed: int = 0,
    drop_remainder: bool = True,
    pad_to: int | None = None,
    bucket_by: str | Sequence[str] | None = None,
    buckets: Sequence = (),
) -> Iterator[dict[str, np.ndarray]]:
    """Fixed-size batches; a ``pad_to`` remainder is padded instead of
    dropped. With ``bucket_by``, rows are grouped by payload length into
    the fixed ``buckets`` widths and each bucketed column is sliced to its
    bucket — every batch still has one of a small fixed set of static
    shapes (a grid when several columns bucket together)."""
    n = len(next(iter(arrays.values())))
    idx = np.arange(n)
    rng = np.random.default_rng(seed)
    if shuffle:
        rng.shuffle(idx)
    if bucket_by is not None:
        _, buckets = bucket_grid(bucket_by, buckets, arrays)
        out, rest = emit_bucketed(arrays, idx, batch_size, bucket_by, buckets)
        out.extend(
            emit_remainders(
                {k: v[rest] for k, v in arrays.items()},
                bucket_by, buckets, pad_to, drop_remainder,
            )
        )
        if shuffle:
            rng.shuffle(out)
        yield from out
        return
    stop = (n // batch_size) * batch_size if drop_remainder and pad_to is None else n
    for s in range(0, stop, batch_size):
        sel = idx[s : s + batch_size]
        batch = {k: v[sel] for k, v in arrays.items()}
        if pad_to is not None and len(sel) < batch_size:
            batch = pad_batch(batch, pad_to)
        yield batch


def split_indices(
    n: int, val_fraction: float, seed: int
) -> tuple[np.ndarray, np.ndarray]:
    """(train, val) index partition — the one split rule shared by
    ``train_val_split`` and ``Dataset.split``."""
    idx = np.arange(n)
    np.random.default_rng(seed).shuffle(idx)
    n_val = max(int(n * val_fraction), 1) if n else 0
    return idx[n_val:], idx[:n_val]


def train_val_split(
    arrays: dict[str, np.ndarray], val_fraction: float = 0.1, seed: int = 0
) -> tuple[dict[str, np.ndarray], dict[str, np.ndarray]]:
    n = len(next(iter(arrays.values())))
    train, val = split_indices(n, val_fraction, seed)
    return (
        {k: v[train] for k, v in arrays.items()},
        {k: v[val] for k, v in arrays.items()},
    )
