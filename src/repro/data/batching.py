"""Batching utilities: cleaned records → fixed-shape model inputs."""

from __future__ import annotations

from typing import Iterator, Sequence

import numpy as np

from .tokenizer import PAD, WordTokenizer


def seq2seq_arrays(
    records: Sequence[dict],
    tokenizer: WordTokenizer,
    max_abstract_len: int = 128,
    max_title_len: int = 24,
    abstract_col: str = "abstract",
    title_col: str = "title",
) -> dict[str, np.ndarray]:
    """Encode abstract (encoder input) and title (decoder target)."""
    n = len(records)
    enc = np.zeros((n, max_abstract_len), dtype=np.int32)
    dec = np.zeros((n, max_title_len), dtype=np.int32)
    for i, r in enumerate(records):
        enc[i] = tokenizer.encode(r[abstract_col] or "", max_abstract_len)
        dec[i] = tokenizer.encode(r[title_col] or "", max_title_len, add_start_end=True)
    return {"encoder_tokens": enc, "decoder_tokens": dec}


def batches(
    arrays: dict[str, np.ndarray],
    batch_size: int,
    *,
    shuffle: bool = True,
    seed: int = 0,
    drop_remainder: bool = True,
) -> Iterator[dict[str, np.ndarray]]:
    n = len(next(iter(arrays.values())))
    idx = np.arange(n)
    if shuffle:
        np.random.default_rng(seed).shuffle(idx)
    stop = (n // batch_size) * batch_size if drop_remainder else n
    for s in range(0, stop, batch_size):
        sel = idx[s : s + batch_size]
        yield {k: v[sel] for k, v in arrays.items()}


def train_val_split(
    arrays: dict[str, np.ndarray], val_fraction: float = 0.1, seed: int = 0
) -> tuple[dict[str, np.ndarray], dict[str, np.ndarray]]:
    n = len(next(iter(arrays.values())))
    idx = np.arange(n)
    np.random.default_rng(seed).shuffle(idx)
    n_val = max(int(n * val_fraction), 1)
    val, train = idx[:n_val], idx[n_val:]
    return (
        {k: v[train] for k, v in arrays.items()},
        {k: v[val] for k, v in arrays.items()},
    )
