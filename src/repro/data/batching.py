"""Batching operators: cleaned text columns → fixed-shape model inputs.

These are the array-level operators of the lazy ``Dataset`` plan
(:mod:`repro.core.dataset`): a ``TokenSpec`` describes how one text column
becomes one token array, ``encode_rows``/``encode_column`` execute it, and
``batches`` slices the resulting arrays into fixed-shape batches — either
one fixed ``max_len`` shape, or a small fixed set of **length buckets**
(``bucket_by=``) so short rows stop paying full-width padding while jit
still sees a bounded shape set. The legacy eager helpers
(``seq2seq_arrays``, ``train_val_split``) remain as thin wrappers.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Sequence

import numpy as np

from .tokenizer import END, PAD, START, UNK, WordTokenizer


@dataclass(frozen=True)
class TokenSpec:
    """One text column → one fixed-length token array."""

    column: str
    max_len: int
    out: str | None = None  # output array name; default "<column>_tokens"
    add_start_end: bool = False

    @property
    def name(self) -> str:
        return self.out or f"{self.column}_tokens"


def seq2seq_specs(
    max_abstract_len: int = 128,
    max_title_len: int = 24,
    abstract_col: str = "abstract",
    title_col: str = "title",
) -> tuple[TokenSpec, TokenSpec]:
    """The case-study encoding: abstract → encoder input, title → target."""
    return (
        TokenSpec(abstract_col, max_abstract_len, out="encoder_tokens"),
        TokenSpec(title_col, max_title_len, out="decoder_tokens", add_start_end=True),
    )


def encode_rows(
    texts: Sequence[str | None],
    stoi: dict[str, int],
    max_len: int,
    add_start_end: bool = False,
) -> np.ndarray:
    """Encode rows against a word-index map into one (n, max_len) int32
    array. This is the single encoding implementation: the eager oracle
    (:func:`encode_column`) and the per-shard executor token step
    (:mod:`repro.core.executor`) both call it, so they are byte-identical
    by construction."""
    out = np.full((len(texts), max_len), PAD, dtype=np.int32)
    get = stoi.get
    for i, t in enumerate(texts):
        ids = [get(w, UNK) for w in (t or "").split()]
        if add_start_end:
            ids = [START] + ids[: max_len - 2] + [END]
        else:
            ids = ids[:max_len]
        out[i, : len(ids)] = ids
    return out


def encode_column(
    texts: Sequence[str | None],
    tokenizer: WordTokenizer,
    max_len: int,
    add_start_end: bool = False,
) -> np.ndarray:
    return encode_rows(texts, tokenizer.stoi, max_len, add_start_end)


def encode_frame_columns(
    columns: dict[str, Sequence[str | None]],
    tokenizer: WordTokenizer,
    specs: Sequence[TokenSpec],
) -> dict[str, np.ndarray]:
    return {
        spec.name: encode_column(
            columns[spec.column], tokenizer, spec.max_len, spec.add_start_end
        )
        for spec in specs
    }


def seq2seq_arrays(
    records: Sequence[dict],
    tokenizer: WordTokenizer,
    max_abstract_len: int = 128,
    max_title_len: int = 24,
    abstract_col: str = "abstract",
    title_col: str = "title",
) -> dict[str, np.ndarray]:
    """Encode abstract (encoder input) and title (decoder target)."""
    specs = seq2seq_specs(max_abstract_len, max_title_len, abstract_col, title_col)
    columns = {
        abstract_col: [r.get(abstract_col) for r in records],
        title_col: [r.get(title_col) for r in records],
    }
    return encode_frame_columns(columns, tokenizer, specs)


# ---------------------------------------------------------------------------
# Length-bucketed assembly
# ---------------------------------------------------------------------------


def effective_lengths(arr: np.ndarray) -> np.ndarray:
    """Per-row payload length of a padded token array: 1 + index of the
    last non-PAD token (0 for all-PAD rows). Trailing padding beyond it is
    droppable without losing information, even if PAD ids appear *inside*
    the row (a literal ``<pad>`` word encodes to 0)."""
    nonpad = arr != PAD
    lens = arr.shape[1] - np.argmax(nonpad[:, ::-1], axis=1)
    return np.where(nonpad.any(axis=1), lens, 0).astype(np.int64)


def derive_buckets(max_len: int, n_buckets: int = 4) -> tuple[int, ...]:
    """A small fixed set of bucket widths ending at ``max_len`` (linear
    steps, deduplicated) — bounded shape set, jit-compilation friendly."""
    n = max(int(n_buckets), 1)
    widths = sorted({max(1, (max_len * i) // n) for i in range(1, n + 1)} | {max_len})
    return tuple(widths)


def assign_buckets(lengths: np.ndarray, buckets: Sequence[int]) -> np.ndarray:
    """Index of the smallest bucket wide enough for each row. Rows longer
    than the last bucket land in it (they were already truncated to
    ``max_len`` == the last bucket by encoding)."""
    edges = np.asarray(buckets, dtype=np.int64)
    idx = np.searchsorted(edges, np.asarray(lengths, dtype=np.int64), side="left")
    return np.minimum(idx, len(edges) - 1)


def slice_to_bucket(
    batch: dict[str, np.ndarray], bucket_by: str, width: int
) -> dict[str, np.ndarray]:
    return {
        k: (v[:, :width] if k == bucket_by else v) for k, v in batch.items()
    }


def pad_token_fraction(batches: Sequence[dict[str, np.ndarray]], column: str) -> float:
    """Fraction of entries in ``column`` that are padding beyond each row's
    payload — the accelerator-cycle waste bucketing removes."""
    pad = total = 0
    for b in batches:
        arr = b[column]
        total += arr.size
        pad += int(arr.size - effective_lengths(arr).sum())
    return pad / total if total else 0.0


def pad_batch(batch: dict[str, np.ndarray], rows: int) -> dict[str, np.ndarray]:
    """Pad a partial batch with PAD rows up to ``rows`` (shape stability)."""
    n = len(next(iter(batch.values())))
    if n >= rows:
        return batch
    out = {}
    for k, v in batch.items():
        padded = np.full((rows,) + v.shape[1:], PAD, dtype=v.dtype)
        padded[:n] = v
        out[k] = padded
    return out


def emit_bucketed(
    arrays: dict[str, np.ndarray],
    order: np.ndarray,
    batch_size: int,
    bucket_by: str,
    buckets: Sequence[int],
) -> tuple[list[dict[str, np.ndarray]], np.ndarray]:
    """(full bucket batches in ``order``-scan order, leftover row indices).

    Rows are scanned in ``order``; each full batch keeps only rows of one
    bucket and is sliced to that bucket's width on the ``bucket_by``
    column. Leftovers (per-bucket remainders) come back for the caller to
    carry, pad, or drop."""
    lengths = effective_lengths(arrays[bucket_by])
    assignment = assign_buckets(lengths, buckets)
    out: list[dict[str, np.ndarray]] = []
    leftovers: list[np.ndarray] = []
    for bi, width in enumerate(buckets):
        rows = order[assignment[order] == bi]
        full = (len(rows) // batch_size) * batch_size
        for s in range(0, full, batch_size):
            sel = rows[s : s + batch_size]
            out.append(
                slice_to_bucket(
                    {k: v[sel] for k, v in arrays.items()}, bucket_by, width
                )
            )
        if full < len(rows):
            leftovers.append(rows[full:])
    rest = (
        np.concatenate(leftovers)
        if leftovers
        else np.zeros(0, dtype=np.int64)
    )
    return out, rest


def emit_remainders(
    rows: dict[str, np.ndarray],
    bucket_by: str,
    buckets: Sequence[int],
    pad_to: int | None,
    drop_remainder: bool,
) -> list[dict[str, np.ndarray]]:
    """Per-bucket remainder batches under the remainder policy (empty when
    dropped). Remainders stay per-bucket so every emitted batch keeps a
    bucket-set shape and at most batch_size rows — never one concatenated
    full-width catch-all. Shared by the whole-frame and streaming
    assemblers so their remainder semantics cannot drift."""
    out: list[dict[str, np.ndarray]] = []
    if (pad_to is None and drop_remainder) or not len(next(iter(rows.values()))):
        return out
    assignment = assign_buckets(effective_lengths(rows[bucket_by]), buckets)
    for bi in np.unique(assignment):
        part = {k: v[assignment == bi] for k, v in rows.items()}
        if pad_to is not None:
            part = pad_batch(part, pad_to)
        out.append(slice_to_bucket(part, bucket_by, buckets[bi]))
    return out


def batches(
    arrays: dict[str, np.ndarray],
    batch_size: int,
    *,
    shuffle: bool = True,
    seed: int = 0,
    drop_remainder: bool = True,
    pad_to: int | None = None,
    bucket_by: str | None = None,
    buckets: Sequence[int] = (),
) -> Iterator[dict[str, np.ndarray]]:
    """Fixed-size batches; a ``pad_to`` remainder is padded instead of
    dropped. With ``bucket_by``, rows are grouped by payload length into
    the fixed ``buckets`` widths and the bucketed column is sliced to its
    bucket — every batch still has one of ``len(buckets)`` static shapes."""
    n = len(next(iter(arrays.values())))
    idx = np.arange(n)
    rng = np.random.default_rng(seed)
    if shuffle:
        rng.shuffle(idx)
    if bucket_by is not None:
        if not buckets:
            buckets = derive_buckets(arrays[bucket_by].shape[1])
        out, rest = emit_bucketed(arrays, idx, batch_size, bucket_by, buckets)
        out.extend(
            emit_remainders(
                {k: v[rest] for k, v in arrays.items()},
                bucket_by, buckets, pad_to, drop_remainder,
            )
        )
        if shuffle:
            rng.shuffle(out)
        yield from out
        return
    stop = (n // batch_size) * batch_size if drop_remainder and pad_to is None else n
    for s in range(0, stop, batch_size):
        sel = idx[s : s + batch_size]
        batch = {k: v[sel] for k, v in arrays.items()}
        if pad_to is not None and len(sel) < batch_size:
            batch = pad_batch(batch, pad_to)
        yield batch


def split_indices(
    n: int, val_fraction: float, seed: int
) -> tuple[np.ndarray, np.ndarray]:
    """(train, val) index partition — the one split rule shared by
    ``train_val_split`` and ``Dataset.split``."""
    idx = np.arange(n)
    np.random.default_rng(seed).shuffle(idx)
    n_val = max(int(n * val_fraction), 1) if n else 0
    return idx[n_val:], idx[:n_val]


def train_val_split(
    arrays: dict[str, np.ndarray], val_fraction: float = 0.1, seed: int = 0
) -> tuple[dict[str, np.ndarray], dict[str, np.ndarray]]:
    n = len(next(iter(arrays.values())))
    train, val = split_indices(n, val_fraction, seed)
    return (
        {k: v[train] for k, v in arrays.items()},
        {k: v[val] for k, v in arrays.items()},
    )
