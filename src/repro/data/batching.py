"""Batching operators: cleaned text columns → fixed-shape model inputs.

These are the array-level operators of the lazy ``Dataset`` plan
(:mod:`repro.core.dataset`): a ``TokenSpec`` describes how one text column
becomes one token array, ``encode_column`` executes it, and ``batches``
slices the resulting arrays into fixed-shape batches (with optional
remainder padding for jit shape stability). The legacy eager helpers
(``seq2seq_arrays``, ``train_val_split``) remain as thin wrappers.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Sequence

import numpy as np

from .tokenizer import PAD, WordTokenizer


@dataclass(frozen=True)
class TokenSpec:
    """One text column → one fixed-length token array."""

    column: str
    max_len: int
    out: str | None = None  # output array name; default "<column>_tokens"
    add_start_end: bool = False

    @property
    def name(self) -> str:
        return self.out or f"{self.column}_tokens"


def seq2seq_specs(
    max_abstract_len: int = 128,
    max_title_len: int = 24,
    abstract_col: str = "abstract",
    title_col: str = "title",
) -> tuple[TokenSpec, TokenSpec]:
    """The case-study encoding: abstract → encoder input, title → target."""
    return (
        TokenSpec(abstract_col, max_abstract_len, out="encoder_tokens"),
        TokenSpec(title_col, max_title_len, out="decoder_tokens", add_start_end=True),
    )


def encode_column(
    texts: Sequence[str | None],
    tokenizer: WordTokenizer,
    max_len: int,
    add_start_end: bool = False,
) -> np.ndarray:
    out = np.zeros((len(texts), max_len), dtype=np.int32)
    for i, t in enumerate(texts):
        out[i] = tokenizer.encode(t or "", max_len, add_start_end=add_start_end)
    return out


def encode_frame_columns(
    columns: dict[str, Sequence[str | None]],
    tokenizer: WordTokenizer,
    specs: Sequence[TokenSpec],
) -> dict[str, np.ndarray]:
    return {
        spec.name: encode_column(
            columns[spec.column], tokenizer, spec.max_len, spec.add_start_end
        )
        for spec in specs
    }


def seq2seq_arrays(
    records: Sequence[dict],
    tokenizer: WordTokenizer,
    max_abstract_len: int = 128,
    max_title_len: int = 24,
    abstract_col: str = "abstract",
    title_col: str = "title",
) -> dict[str, np.ndarray]:
    """Encode abstract (encoder input) and title (decoder target)."""
    specs = seq2seq_specs(max_abstract_len, max_title_len, abstract_col, title_col)
    columns = {
        abstract_col: [r.get(abstract_col) for r in records],
        title_col: [r.get(title_col) for r in records],
    }
    return encode_frame_columns(columns, tokenizer, specs)


def pad_batch(batch: dict[str, np.ndarray], rows: int) -> dict[str, np.ndarray]:
    """Pad a partial batch with PAD rows up to ``rows`` (shape stability)."""
    n = len(next(iter(batch.values())))
    if n >= rows:
        return batch
    out = {}
    for k, v in batch.items():
        padded = np.full((rows,) + v.shape[1:], PAD, dtype=v.dtype)
        padded[:n] = v
        out[k] = padded
    return out


def batches(
    arrays: dict[str, np.ndarray],
    batch_size: int,
    *,
    shuffle: bool = True,
    seed: int = 0,
    drop_remainder: bool = True,
    pad_to: int | None = None,
) -> Iterator[dict[str, np.ndarray]]:
    """Fixed-size batches; a ``pad_to`` remainder is padded instead of dropped."""
    n = len(next(iter(arrays.values())))
    idx = np.arange(n)
    if shuffle:
        np.random.default_rng(seed).shuffle(idx)
    stop = (n // batch_size) * batch_size if drop_remainder and pad_to is None else n
    for s in range(0, stop, batch_size):
        sel = idx[s : s + batch_size]
        batch = {k: v[sel] for k, v in arrays.items()}
        if pad_to is not None and len(sel) < batch_size:
            batch = pad_batch(batch, pad_to)
        yield batch


def split_indices(
    n: int, val_fraction: float, seed: int
) -> tuple[np.ndarray, np.ndarray]:
    """(train, val) index partition — the one split rule shared by
    ``train_val_split`` and ``Dataset.split``."""
    idx = np.arange(n)
    np.random.default_rng(seed).shuffle(idx)
    n_val = max(int(n * val_fraction), 1) if n else 0
    return idx[n_val:], idx[:n_val]


def train_val_split(
    arrays: dict[str, np.ndarray], val_fraction: float = 0.1, seed: int = 0
) -> tuple[dict[str, np.ndarray], dict[str, np.ndarray]]:
    n = len(next(iter(arrays.values())))
    train, val = split_indices(n, val_fraction, seed)
    return (
        {k: v[train] for k, v in arrays.items()},
        {k: v[val] for k, v in arrays.items()},
    )
