"""P3SAPP-JAX: Spark-ML-style preprocessing pipeline + multi-pod JAX
training framework (reproduction of Khan, Liu, Alam 2019)."""

__version__ = "1.0.0"
