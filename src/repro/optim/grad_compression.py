"""int8 gradient compression with error feedback (1-bit-Adam lineage).

For bandwidth-bound data-parallel reductions: gradients are quantized to
int8 with a per-tensor fp32 scale before the cross-replica reduction and
dequantized after; the quantization residual is carried to the next step
(error feedback), which keeps SGD/Adam convergence (Seide et al. 2014,
Tang et al. 2021). Used by the train loop when ``compress_grads=True``:
the all-reduce payload shrinks 4x (fp32) / 2x (bf16) — a collective-term
optimization recorded in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: Any, error: Any | None = None) -> tuple[Any, Any, Any]:
    """Quantize a gradient pytree, folding in carried error. Returns
    (quantized tree, scales tree, new error tree)."""
    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)
    corrected = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, error)
    qs = jax.tree.map(quantize_int8, corrected)
    q = jax.tree.map(lambda t: t[0], qs, is_leaf=lambda t: isinstance(t, tuple))
    s = jax.tree.map(lambda t: t[1], qs, is_leaf=lambda t: isinstance(t, tuple))
    deq = jax.tree.map(dequantize_int8, q, s)
    new_error = jax.tree.map(lambda c, d: c - d, corrected, deq)
    return q, s, new_error


def decompress_tree(q: Any, s: Any) -> Any:
    return jax.tree.map(dequantize_int8, q, s)


def psum_compressed(grads: Any, axis_names, error: Any | None = None) -> tuple[Any, Any]:
    """Error-feedback int8 all-reduce: quantize -> psum(int32) -> dequant.

    Scales are max-combined across replicas first (one tiny fp32 psum), so
    the int8 payloads share a scale and the int32 accumulation is exact.
    """
    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)
    corrected = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, error)
    scales = jax.tree.map(lambda c: jnp.max(jnp.abs(c)) / 127.0 + 1e-12, corrected)
    scales = jax.tree.map(lambda s: jax.lax.pmax(s, axis_names), scales)
    q = jax.tree.map(
        lambda c, s: jnp.clip(jnp.round(c / s), -127, 127).astype(jnp.int8), corrected, scales
    )
    new_error = jax.tree.map(lambda c, qq, s: c - qq.astype(jnp.float32) * s, corrected, q, scales)
    summed = jax.tree.map(lambda qq: jax.lax.psum(qq.astype(jnp.int32), axis_names), q)
    mean = jax.tree.map(lambda ss, s: ss.astype(jnp.float32) * s, summed, scales)
    return mean, new_error
