"""AdamW with decoupled weight decay and global-norm clipping (pure JAX,
optax-style update API so optimizers compose with the train loop)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jax.Array
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamW:
    learning_rate: float | Callable[[jax.Array], jax.Array] = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    # moments dtype: fp32 masters by default; bf16 halves optimizer memory
    moment_dtype: Any = jnp.float32

    def init(self, params: Any) -> AdamWState:
        def zeros(p):
            return jnp.zeros(p.shape, self.moment_dtype)

        return AdamWState(
            count=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def _lr(self, count: jax.Array) -> jax.Array:
        if callable(self.learning_rate):
            return self.learning_rate(count)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(self, grads: Any, state: AdamWState, params: Any):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm > 0:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        else:
            gnorm = global_norm(grads)
        count = state.count + 1
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mm, g: (b1 * mm.astype(jnp.float32) + (1 - b1) * g).astype(self.moment_dtype), state.m, grads)
        v = jax.tree.map(lambda vv, g: (b2 * vv.astype(jnp.float32) + (1 - b2) * g * g).astype(self.moment_dtype), state.v, grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        lr = self._lr(count)

        def upd(p, mm, vv):
            mhat = mm.astype(jnp.float32) / c1
            vhat = vv.astype(jnp.float32) / c2
            step = mhat / (jnp.sqrt(vhat) + self.eps) + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamWState(count, m, v), gnorm


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(leaf.astype(jnp.float32))) for leaf in leaves)
    )


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int, floor: float = 0.1):
    def schedule(count: jax.Array) -> jax.Array:
        c = count.astype(jnp.float32)
        warm = peak_lr * c / max(warmup_steps, 1)
        prog = jnp.clip((c - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(c < warmup_steps, warm, cos)

    return schedule
