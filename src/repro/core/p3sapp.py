"""P3SAPP and CA drivers — thin adapters over the lazy ``Dataset`` planner.

``run_p3sapp`` (Algorithm 1) is now *one declarative plan*: it builds the
canonical chain

    Dataset.from_json_dirs → dropna → drop_duplicates → apply(stages) → dropna

and lets the planner (:mod:`repro.core.plan`) merge the stage chains per
column, fuse their byte ops Catalyst-style, and execute whole-frame with the
paper's stage-level timing attribution. The same plan, extended with
``.tokenize(...).batch(...).prefetch(...)``, streams straight to device
batches (see :mod:`repro.core.dataset`) — the paper's utilization argument
applied to the full path, not just the cleaning segment.

Timing attribution follows §3 of the paper exactly:

=============  =======================  =======================
stage          P3SAPP (Algorithm 1)     CA (Algorithm 2)
=============  =======================  =======================
ingestion      steps 2-8                steps 2-8
pre-cleaning   steps 9-10               steps 9-10
cleaning       steps 11-14 (pipeline)   steps 11-13 (row loop)
post-cleaning  steps 15-16 (toPandas)   step 14
=============  =======================  =======================

``preprocessing = pre_cleaning + cleaning + post_cleaning`` and
``cumulative = ingestion + preprocessing`` (paper eq. 7).
"""

from __future__ import annotations

import time
from pathlib import Path
from typing import Sequence

from . import conventional as ca
from .dataset import Dataset
from .plan import StageTimings  # re-exported; canonical home is the planner
from .stages import Stage, abstract_stages, title_stages

__all__ = [
    "StageTimings",
    "case_study_stages",
    "p3sapp_dataset",
    "record_match_accuracy",
    "run_conventional",
    "run_p3sapp",
]


def case_study_stages(abstract_col: str = "abstract", title_col: str = "title") -> list[Stage]:
    """Paper Fig. 2 + Fig. 3 workflows chained into one pipeline."""
    return abstract_stages(abstract_col) + title_stages(title_col)


def p3sapp_dataset(
    directories: Sequence[str | Path],
    fields: Sequence[str] = ("title", "abstract"),
    stages: Sequence[Stage] | None = None,
) -> Dataset:
    """The canonical Algorithm 1 chain as a lazy Dataset plan."""
    stages = list(stages) if stages is not None else case_study_stages()
    return (
        Dataset.from_json_dirs(directories, fields)  # steps 2-8
        .dropna(fields)  # step 9
        .drop_duplicates(fields)  # step 10
        .apply(*stages)  # steps 11-14
        .dropna(fields)  # step 16
    )


def run_p3sapp(
    directories: Sequence[str | Path],
    fields: Sequence[str] = ("title", "abstract"),
    stages: Sequence[Stage] | None = None,
    workers: int | None = None,
    optimize: bool = False,
) -> tuple[list[dict], StageTimings]:
    """Algorithm 1. Returns (records a.k.a. the pandas frame, timings).

    ``optimize=False`` is the paper-faithful executor; ``optimize=True``
    enables the beyond-paper planned/fused executor (EXPERIMENTS.md §Perf).
    """
    ds = p3sapp_dataset(directories, fields, stages)
    return ds.execute(workers=workers, optimize=optimize)


def run_conventional(
    directories: Sequence[str | Path],
    fields: Sequence[str] = ("title", "abstract"),
    stages: Sequence[Stage] | None = None,
) -> tuple[list[dict], StageTimings]:
    """Algorithm 2. Returns (records, timings)."""
    t = StageTimings()
    stages = list(stages) if stages is not None else case_study_stages()

    t0 = time.perf_counter()
    frame = ca.ingest_conventional(directories, fields)  # steps 2-8
    t.ingestion = time.perf_counter() - t0

    t0 = time.perf_counter()
    frame = ca.pre_clean_conventional(frame, fields)  # steps 9-10
    t.pre_cleaning = time.perf_counter() - t0

    t0 = time.perf_counter()
    frame = ca.clean_conventional(frame, stages)  # steps 11-13
    t.cleaning = time.perf_counter() - t0

    t0 = time.perf_counter()
    frame = ca.post_clean_conventional(frame, fields)  # step 14
    t.post_cleaning = time.perf_counter() - t0
    return frame.rows, t


def record_match_accuracy(
    ca_records: list[dict], pa_records: list[dict], field: str
) -> dict:
    """Paper §5.2: percentage of matching records between the two frames."""
    ca_vals = [r.get(field) for r in ca_records]
    pa_vals = set(r.get(field) for r in pa_records)
    matching = sum(1 for v in ca_vals if v in pa_vals)
    denom = max(len(ca_records), 1)
    return {
        "conventional": len(ca_records),
        "proposed": len(pa_records),
        "matching": matching,
        "percentage": 100.0 * matching / denom,
    }
