"""End-to-end P3SAPP and CA drivers with the paper's stage-level timing.

Timing attribution follows §3 of the paper exactly:

=============  =======================  =======================
stage          P3SAPP (Algorithm 1)     CA (Algorithm 2)
=============  =======================  =======================
ingestion      steps 2-8                steps 2-8
pre-cleaning   steps 9-10               steps 9-10
cleaning       steps 11-14 (pipeline)   steps 11-13 (row loop)
post-cleaning  steps 15-16 (toPandas)   step 14
=============  =======================  =======================

``preprocessing = pre_cleaning + cleaning + post_cleaning`` and
``cumulative = ingestion + preprocessing`` (paper eq. 7).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Sequence

from . import conventional as ca
from . import ingest as ing
from .frame import ColumnarFrame
from .pipeline import Pipeline
from .stages import Stage, abstract_stages, title_stages


@dataclass
class StageTimings:
    ingestion: float = 0.0
    pre_cleaning: float = 0.0
    cleaning: float = 0.0
    post_cleaning: float = 0.0

    @property
    def preprocessing(self) -> float:
        return self.pre_cleaning + self.cleaning + self.post_cleaning

    @property
    def cumulative(self) -> float:
        return self.ingestion + self.preprocessing

    def as_dict(self) -> dict:
        return {
            "ingestion": self.ingestion,
            "pre_cleaning": self.pre_cleaning,
            "cleaning": self.cleaning,
            "post_cleaning": self.post_cleaning,
            "preprocessing": self.preprocessing,
            "cumulative": self.cumulative,
        }


def case_study_stages(abstract_col: str = "abstract", title_col: str = "title") -> list[Stage]:
    """Paper Fig. 2 + Fig. 3 workflows chained into one pipeline."""
    return abstract_stages(abstract_col) + title_stages(title_col)


def run_p3sapp(
    directories: Sequence[str | Path],
    fields: Sequence[str] = ("title", "abstract"),
    stages: Sequence[Stage] | None = None,
    workers: int = 1,
    optimize: bool = False,
) -> tuple[list[dict], StageTimings]:
    """Algorithm 1. Returns (records a.k.a. the pandas frame, timings).

    ``optimize=False`` is the paper-faithful executor; ``optimize=True``
    enables the beyond-paper fused executor (EXPERIMENTS.md §Perf).
    """
    t = StageTimings()
    stages = list(stages) if stages is not None else case_study_stages()

    t0 = time.perf_counter()
    frame = ing.ingest(directories, fields, workers=workers)  # steps 2-8
    t.ingestion = time.perf_counter() - t0

    t0 = time.perf_counter()
    frame = ing.pre_clean(frame, fields)  # steps 9-10
    t.pre_cleaning = time.perf_counter() - t0

    t0 = time.perf_counter()
    model = Pipeline(stages).fit(frame)  # steps 11-13
    frame = model.transform(frame, workers=workers, optimize=optimize)  # step 14
    t.cleaning = time.perf_counter() - t0

    t0 = time.perf_counter()
    records = frame.to_records()  # step 15 (toPandas analogue)
    records = [r for r in records if all(r.get(f) for f in fields)]  # step 16
    t.post_cleaning = time.perf_counter() - t0
    return records, t


def run_conventional(
    directories: Sequence[str | Path],
    fields: Sequence[str] = ("title", "abstract"),
    stages: Sequence[Stage] | None = None,
) -> tuple[list[dict], StageTimings]:
    """Algorithm 2. Returns (records, timings)."""
    t = StageTimings()
    stages = list(stages) if stages is not None else case_study_stages()

    t0 = time.perf_counter()
    frame = ca.ingest_conventional(directories, fields)  # steps 2-8
    t.ingestion = time.perf_counter() - t0

    t0 = time.perf_counter()
    frame = ca.pre_clean_conventional(frame, fields)  # steps 9-10
    t.pre_cleaning = time.perf_counter() - t0

    t0 = time.perf_counter()
    frame = ca.clean_conventional(frame, stages)  # steps 11-13
    t.cleaning = time.perf_counter() - t0

    t0 = time.perf_counter()
    frame = ca.post_clean_conventional(frame, fields)  # step 14
    t.post_cleaning = time.perf_counter() - t0
    return frame.rows, t


def record_match_accuracy(
    ca_records: list[dict], pa_records: list[dict], field: str
) -> dict:
    """Paper §5.2: percentage of matching records between the two frames."""
    ca_vals = [r.get(field) for r in ca_records]
    pa_vals = set(r.get(field) for r in pa_records)
    matching = sum(1 for v in ca_vals if v in pa_vals)
    denom = max(len(ca_records), 1)
    return {
        "conventional": len(ca_records),
        "proposed": len(pa_records),
        "matching": matching,
        "percentage": 100.0 * matching / denom,
    }
