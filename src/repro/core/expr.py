"""Column-expression IR — the composable algebra behind the ``Dataset`` verbs.

Spark's leverage (and Spark NLP's, which runs annotator DAGs *inside* the
Catalyst plan) is not a fixed set of named transformers but an expression
algebra the optimizer can see through. This module is that algebra for the
flat-byte-buffer engine:

* ``col("abstract")`` / ``lit("x")`` / ``concat(...)`` build **string
  expressions**; chained methods (``.lower()``, ``.strip_html()``,
  ``.regex_replace()``, ``.remove_stopwords()``, ``.min_word_len(n)``, …)
  append vectorized byte ops (:mod:`repro.core.bytesops`).
* ``.word_count() >= n``, ``.contains("x")``, ``.not_empty()`` and the
  boolean operators ``& | ~`` build **predicates** that evaluate to row
  masks straight off the flat buffers — filtered rows are never decoded.
* Every node has a **structural signature** (stable across rebuilds,
  sensitive to every parameter), so expression plans fingerprint exactly
  like stage plans did and cache per column in the shard cache.

Expressions are *descriptions*; :func:`compile_expr` / :func:`compile_pred`
lower them to small picklable programs (plain tuples over ``bytesops.Op``
descriptors) that run identically in the whole-frame executor, reader
threads, and worker processes. ``Dataset.with_column/where/transform``
lower to ``Project``/``Filter`` plan nodes carrying these expressions; the
legacy ``Stage`` classes are shims that construct them (see
:meth:`repro.core.stages.Stage.to_expr`).
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Sequence

import numpy as np

from . import bytesops as B

# The English stopword list used by Spark's StopWordsRemover is long; this
# is the classic NLTK-ish core, sufficient for the case study and
# configurable. (Canonical home; ``stages.ENGLISH_STOPWORDS`` re-exports.)
ENGLISH_STOPWORDS: tuple[str, ...] = tuple(
    (
        "i me my myself we our ours ourselves you your yours yourself yourselves "
        "he him his himself she her hers herself it its itself they them their "
        "theirs themselves what which who whom this that these those am is are "
        "was were be been being have has had having do does did doing a an the "
        "and but if or because as until while of at by for with about against "
        "between into through during before after above below to from up down in "
        "out on off over under again further then once here there when where why "
        "how all any both each few more most other some such no nor not only own "
        "same so than too very s t can will just don should now"
    ).split()
)

_DEFAULT_STOPSET = B.WordSet(ENGLISH_STOPWORDS)


def _len_prefixed(parts: Sequence[bytes]) -> bytes:
    return b"".join(len(p).to_bytes(8, "little") + p for p in parts)


# ---------------------------------------------------------------------------
# String expressions
# ---------------------------------------------------------------------------


class Expr:
    """Base string expression: one text column's worth of rows."""

    # -- structural identity ------------------------------------------------
    def signature(self) -> bytes:
        raise NotImplementedError

    def fingerprint(self) -> str:
        return hashlib.blake2b(self.signature(), digest_size=16).hexdigest()

    def inputs(self) -> set[str]:
        """Free source columns this expression reads."""
        raise NotImplementedError

    def describe(self) -> str:
        raise NotImplementedError

    def __repr__(self) -> str:  # pragma: no cover - convenience
        return self.describe()

    # -- string ops (each appends one vectorized byte op) -------------------
    def _op(self, op: B.Op, label: str) -> "Expr":
        return StrOp(self, op, label)

    def lower(self) -> "Expr":
        """ASCII lowercase (one 256-entry LUT pass)."""
        return self._op(B.lut_op(B.LOWER_LUT), "lower()")

    def strip_html(self) -> "Expr":
        """Delete ``<...>`` spans (balanced per row)."""
        return self._op(B.span_op("<", ">"), "strip_html()")

    def strip_parens(self) -> "Expr":
        """Delete ``(...)`` spans (balanced per row)."""
        return self._op(B.span_op("(", ")"), "strip_parens()")

    def expand_contractions(self) -> "Expr":
        """Map English contractions (``won't`` → ``will not``, …)."""
        return self._op(B.replace_op(B.CONTRACTIONS), "expand_contractions()")

    def keep_letters(self) -> "Expr":
        """Replace everything outside ``[a-z ]`` with a space."""
        return self._op(B.lut_op(B.UNWANTED_LUT), "keep_letters()")

    def collapse_spaces(self) -> "Expr":
        """Collapse space runs; strip leading/trailing spaces per row."""
        return self._op(B.collapse_op(), "collapse_spaces()")

    def replace(self, patterns: Sequence[tuple[str, str]]) -> "Expr":
        """Literal byte replacements, one C-speed pass per pattern."""
        for p, r in patterns:
            if "\x00" in p or "\x00" in r:
                raise ValueError(
                    "replace() patterns must not match or emit NUL "
                    "(the row separator)"
                )
        pats = tuple((p.encode(), r.encode()) for p, r in patterns)
        return self._op(B.replace_op(pats), f"replace({len(pats)} patterns)")

    def regex_replace(self, pattern: str, repl: str = "") -> "Expr":
        """Regex substitution (byte-level; must not touch the row separator)."""
        return self._op(
            B.regex_op(pattern, repl), f"regex_replace({pattern!r}, {repl!r})"
        )

    def remove_stopwords(
        self, stopwords: Sequence[str] | B.WordSet | None = None
    ) -> "Expr":
        """Drop dictionary words (default: the English stopword core)."""
        if stopwords is None:
            words, n = _DEFAULT_STOPSET, len(ENGLISH_STOPWORDS)
        elif isinstance(stopwords, B.WordSet):
            words, n = stopwords, stopwords.k1.size
        else:
            words, n = B.WordSet(tuple(stopwords)), len(tuple(stopwords))
        return self._op(
            B.wordpred_op(partial(B.pred_stopword, words=words), needs_hashes=True),
            f"remove_stopwords({n} words)",
        )

    def min_word_len(self, n: int) -> "Expr":
        """Keep only words of at least ``n`` bytes."""
        return self._op(
            B.wordpred_op(partial(B.pred_short, threshold=int(n) - 1), needs_hashes=False),
            f"min_word_len({int(n)})",
        )

    def remove_words(self, pred: Callable, needs_hashes: bool = True) -> "Expr":
        """Escape hatch: drop words flagged by a custom predicate. Use a
        module-level function (optionally via ``functools.partial``) to
        keep the expression fingerprintable/cacheable."""
        return self._op(
            B.wordpred_op(pred, needs_hashes=needs_hashes),
            f"remove_words({getattr(pred, '__qualname__', repr(pred))})",
        )

    # -- predicates ---------------------------------------------------------
    def not_empty(self) -> "Pred":
        """True for rows with non-empty payload (the dropna predicate)."""
        return NotEmpty(self)

    def contains(self, needle: str) -> "Pred":
        """True for rows containing the literal ``needle``."""
        return Contains(self, needle)

    def word_count(self) -> "WordCount":
        """Per-row word count; compare it (``>= n`` …) to get a predicate."""
        return WordCount(self)


@dataclass(frozen=True)
class Col(Expr):
    name: str

    def signature(self) -> bytes:
        return b"col:" + self.name.encode()

    def inputs(self) -> set[str]:
        return {self.name}

    def describe(self) -> str:
        return f"col({self.name!r})"


@dataclass(frozen=True)
class Lit(Expr):
    value: str

    def __post_init__(self):
        if "\x00" in self.value:
            raise ValueError("lit() values must not include NUL (the row separator)")

    def signature(self) -> bytes:
        return b"lit:" + self.value.encode()

    def inputs(self) -> set[str]:
        return set()

    def describe(self) -> str:
        return f"lit({self.value!r})"


@dataclass(frozen=True, eq=False)
class StrOp(Expr):
    input: Expr
    op: B.Op
    label: str

    def signature(self) -> bytes:
        return _len_prefixed([self.input.signature(), b"op:" + B.op_signature(self.op)])

    def inputs(self) -> set[str]:
        return self.input.inputs()

    def describe(self) -> str:
        return f"{self.input.describe()}.{self.label}"


@dataclass(frozen=True, eq=False)
class Concat(Expr):
    parts: tuple[Expr, ...]
    sep: str = " "

    def __post_init__(self):
        if "\x00" in self.sep:
            raise ValueError("concat() sep must not include NUL (the row separator)")

    def signature(self) -> bytes:
        return b"concat:" + self.sep.encode() + b":" + _len_prefixed(
            [p.signature() for p in self.parts]
        )

    def inputs(self) -> set[str]:
        out: set[str] = set()
        for p in self.parts:
            out |= p.inputs()
        return out

    def describe(self) -> str:
        inner = ", ".join(p.describe() for p in self.parts)
        return f"concat({inner}, sep={self.sep!r})"


def col(name: str) -> Col:
    """Reference a source (or previously derived) column."""
    return Col(name)


def lit(value: str) -> Lit:
    """A per-row constant (for use inside :func:`concat`)."""
    return Lit(str(value))


def concat(*parts: Expr | str, sep: str = " ") -> Concat:
    """Row-wise concatenation of expressions; plain strings become
    :func:`lit` constants. At least one part must read a column."""
    exprs = tuple(p if isinstance(p, Expr) else Lit(str(p)) for p in parts)
    if not exprs:
        raise ValueError("concat() needs at least one part")
    if not any(e.inputs() for e in exprs):
        raise ValueError("concat() of literals only; reference at least one col()")
    return Concat(exprs, sep)


# ---------------------------------------------------------------------------
# Predicates (row masks)
# ---------------------------------------------------------------------------


class Pred:
    """Boolean row predicate over string expressions."""

    def signature(self) -> bytes:
        raise NotImplementedError

    def fingerprint(self) -> str:
        return hashlib.blake2b(self.signature(), digest_size=16).hexdigest()

    def inputs(self) -> set[str]:
        raise NotImplementedError

    def describe(self) -> str:
        raise NotImplementedError

    def __repr__(self) -> str:  # pragma: no cover - convenience
        return self.describe()

    def __and__(self, other: "Pred") -> "Pred":
        return BoolOp("and", self, other)

    def __or__(self, other: "Pred") -> "Pred":
        return BoolOp("or", self, other)

    def __invert__(self) -> "Pred":
        return NotOp(self)


@dataclass(frozen=True, eq=False)
class NotEmpty(Pred):
    input: Expr

    def signature(self) -> bytes:
        return b"notempty:" + self.input.signature()

    def inputs(self) -> set[str]:
        return self.input.inputs()

    def describe(self) -> str:
        return f"{self.input.describe()}.not_empty()"


@dataclass(frozen=True, eq=False)
class Contains(Pred):
    input: Expr
    needle: str

    def __post_init__(self):
        if "\x00" in self.needle:
            raise ValueError("contains() needle must not include NUL")

    def signature(self) -> bytes:
        return b"contains:" + self.needle.encode() + b":" + self.input.signature()

    def inputs(self) -> set[str]:
        return self.input.inputs()

    def describe(self) -> str:
        return f"{self.input.describe()}.contains({self.needle!r})"


@dataclass(frozen=True, eq=False)
class WordCount:
    """Per-row word count of a string expression. Not itself a predicate —
    compare it against an int to get one."""

    input: Expr

    def describe(self) -> str:
        return f"{self.input.describe()}.word_count()"

    def _cmp(self, op: str, n: Any) -> "Compare":
        if not isinstance(n, (int, np.integer)):
            raise TypeError(f"word_count() compares against an int, got {n!r}")
        return Compare(self, op, int(n))

    def __ge__(self, n): return self._cmp(">=", n)
    def __gt__(self, n): return self._cmp(">", n)
    def __le__(self, n): return self._cmp("<=", n)
    def __lt__(self, n): return self._cmp("<", n)
    def __eq__(self, n): return self._cmp("==", n)  # type: ignore[override]
    def __ne__(self, n): return self._cmp("!=", n)  # type: ignore[override]


_CMP_FNS = {
    ">=": np.greater_equal,
    ">": np.greater,
    "<=": np.less_equal,
    "<": np.less,
    "==": np.equal,
    "!=": np.not_equal,
}


@dataclass(frozen=True, eq=False)
class Compare(Pred):
    left: WordCount
    op: str
    right: int

    def signature(self) -> bytes:
        return (
            b"wc" + self.op.encode() + str(self.right).encode()
            + b":" + self.left.input.signature()
        )

    def inputs(self) -> set[str]:
        return self.left.input.inputs()

    def describe(self) -> str:
        return f"({self.left.describe()} {self.op} {self.right})"


@dataclass(frozen=True, eq=False)
class BoolOp(Pred):
    kind: str  # "and" | "or"
    left: Pred
    right: Pred

    def signature(self) -> bytes:
        return self.kind.encode() + b":" + _len_prefixed(
            [self.left.signature(), self.right.signature()]
        )

    def inputs(self) -> set[str]:
        return self.left.inputs() | self.right.inputs()

    def describe(self) -> str:
        sym = "&" if self.kind == "and" else "|"
        return f"({self.left.describe()} {sym} {self.right.describe()})"


@dataclass(frozen=True, eq=False)
class NotOp(Pred):
    input: Pred

    def signature(self) -> bytes:
        return b"not:" + self.input.signature()

    def inputs(self) -> set[str]:
        return self.input.inputs()

    def describe(self) -> str:
        return f"~{self.input.describe()}"


# ---------------------------------------------------------------------------
# Plan-level rewriting support (CSE + conjunct splitting; see core.plan)
# ---------------------------------------------------------------------------


def split_conjuncts(p: Pred) -> list[Pred]:
    """Flatten nested ``&`` into the ordered list of conjuncts."""
    if isinstance(p, BoolOp) and p.kind == "and":
        return split_conjuncts(p.left) + split_conjuncts(p.right)
    return [p]


def and_all(preds: Sequence[Pred]) -> Pred:
    """Rebuild a conjunction left-associatively (inverse of
    :func:`split_conjuncts` up to grouping)."""
    out = preds[0]
    for p in preds[1:]:
        out = BoolOp("and", out, p)
    return out


def pred_exprs(p: Pred) -> list[Expr]:
    """The string expressions a predicate evaluates (one per comparison
    leaf, in evaluation order)."""
    if isinstance(p, (NotEmpty, Contains)):
        return [p.input]
    if isinstance(p, Compare):
        return [p.left.input]
    if isinstance(p, (BoolOp,)):
        return pred_exprs(p.left) + pred_exprs(p.right)
    if isinstance(p, NotOp):
        return pred_exprs(p.input)
    raise TypeError(f"not a predicate: {p!r}")


def map_pred_exprs(p: Pred, fn: Callable[[Expr], Expr]) -> Pred:
    """Rebuild a predicate with ``fn`` applied to every string-expression
    leaf (used by the optimizer's CSE rewrite)."""
    if isinstance(p, NotEmpty):
        return NotEmpty(fn(p.input))
    if isinstance(p, Contains):
        return Contains(fn(p.input), p.needle)
    if isinstance(p, Compare):
        return Compare(WordCount(fn(p.left.input)), p.op, p.right)
    if isinstance(p, BoolOp):
        return BoolOp(p.kind, map_pred_exprs(p.left, fn), map_pred_exprs(p.right, fn))
    if isinstance(p, NotOp):
        return NotOp(map_pred_exprs(p.input, fn))
    raise TypeError(f"not a predicate: {p!r}")


def walk_exprs(e: Expr):
    """Yield every node of an expression tree, pre-order (root first)."""
    yield e
    if isinstance(e, StrOp):
        yield from walk_exprs(e.input)
    elif isinstance(e, Concat):
        for p in e.parts:
            yield from walk_exprs(p)


def resolved_signature(
    e: Expr, versions: dict[str, bytes | None]
) -> bytes | None:
    """Version-resolved structural signature: :meth:`Expr.signature` with
    every ``col()`` leaf replaced by the column's current *version token*
    (what the column holds at this point of a plan, not its name). Two
    sub-expressions with equal resolved signatures evaluate to the same
    bytes per surviving row wherever they sit in the plan — the soundness
    condition for common-subexpression elimination. ``None`` marks an
    unfingerprintable subtree (lambda word predicate, poisoned input
    version): never considered equal to anything."""
    if isinstance(e, Col):
        v = versions.get(e.name, b"src:" + e.name.encode())
        return None if v is None else b"ver:" + v
    if isinstance(e, Lit):
        return e.signature()
    if isinstance(e, StrOp):
        base = resolved_signature(e.input, versions)
        if base is None:
            return None
        try:
            osig = B.op_signature(e.op)
        except B.UnfingerprintableOpError:
            return None
        return _len_prefixed([base, b"op:" + osig])
    if isinstance(e, Concat):
        parts = [resolved_signature(p, versions) for p in e.parts]
        if any(s is None for s in parts):
            return None
        return b"concat:" + e.sep.encode() + b":" + _len_prefixed(
            [s for s in parts if s is not None]
        )
    raise TypeError(f"cannot sign expression {e!r}")


# ---------------------------------------------------------------------------
# Canonical case-study expressions (paper Fig. 2 / Fig. 3, expression form)
# ---------------------------------------------------------------------------


def clean_text(e: Expr) -> Expr:
    """The paper's §4.1.1-§4.1.3 character cleanup as one chain."""
    return (
        e.lower()
        .strip_html()
        .strip_parens()
        .expand_contractions()
        .keep_letters()
        .collapse_spaces()
    )


def abstract_expr(column: str = "abstract", threshold: int = 1) -> Expr:
    """Paper Fig. 2: abstracts are the model *feature* → full cleaning."""
    return clean_text(col(column)).remove_stopwords().min_word_len(threshold + 1)


def title_expr(column: str = "title", threshold: int = 1) -> Expr:
    """Paper Fig. 3: titles are the model *target* → keep stopwords."""
    return clean_text(col(column)).min_word_len(threshold + 1)


# ---------------------------------------------------------------------------
# Compilation: expressions → picklable flat-buffer programs
# ---------------------------------------------------------------------------
#
# Compiled string forms (plain tuples; Op descriptors are picklable):
#   ("chain", in_col, (op, ...))           ops applied to one column's buffer
#   ("concat", sep_bytes, (compiled, ...)) row-wise concat of parts
#   ("lit", value_str)                     per-row constant
# Compiled predicate forms:
#   ("nonempty", compiled) | ("wc", cmp, n, compiled)
#   | ("contains", needle_bytes, compiled)
#   | ("and", p, p) | ("or", p, p) | ("not", p)


def compile_expr(e: Expr) -> tuple:
    ops: list[B.Op] = []
    node = e
    while isinstance(node, StrOp):
        ops.append(node.op)
        node = node.input
    ops.reverse()
    if isinstance(node, Col):
        return ("chain", node.name, tuple(ops))
    if isinstance(node, Lit):
        base: tuple = ("lit", node.value)
    elif isinstance(node, Concat):
        base = ("concat", node.sep.encode(), tuple(compile_expr(p) for p in node.parts))
    else:
        raise TypeError(f"cannot compile expression root {node!r}")
    if not ops:
        return base
    # ops over a concat/lit root: wrap as a chain with a non-column source
    return ("wrap", base, tuple(ops))


def compile_pred(p: Pred) -> tuple:
    if isinstance(p, NotEmpty):
        return ("nonempty", compile_expr(p.input))
    if isinstance(p, Contains):
        return ("contains", p.needle.encode(), compile_expr(p.input))
    if isinstance(p, Compare):
        return ("wc", p.op, p.right, compile_expr(p.left.input))
    if isinstance(p, BoolOp):
        return (p.kind, compile_pred(p.left), compile_pred(p.right))
    if isinstance(p, NotOp):
        return ("not", compile_pred(p.input))
    raise TypeError(f"cannot compile predicate {p!r}")


def fuse_compiled(comp: tuple) -> tuple:
    """Catalyst-style op fusion inside a compiled expression (exact)."""
    kind = comp[0]
    if kind == "chain":
        return ("chain", comp[1], tuple(B.fuse_ops(list(comp[2]))))
    if kind == "wrap":
        return ("wrap", fuse_compiled(comp[1]), tuple(B.fuse_ops(list(comp[2]))))
    if kind == "concat":
        return ("concat", comp[1], tuple(fuse_compiled(c) for c in comp[2]))
    if kind == "nonempty":
        return ("nonempty", fuse_compiled(comp[1]))
    if kind == "contains":
        return ("contains", comp[1], fuse_compiled(comp[2]))
    if kind == "wc":
        return ("wc", comp[1], comp[2], fuse_compiled(comp[3]))
    if kind in ("and", "or"):
        return (kind, fuse_compiled(comp[1]), fuse_compiled(comp[2]))
    if kind == "not":
        return ("not", fuse_compiled(comp[1]))
    return comp


def compiled_inputs(comp: tuple) -> set[str]:
    kind = comp[0]
    if kind == "chain":
        return {comp[1]}
    if kind == "lit":
        return set()
    if kind == "wrap":
        return compiled_inputs(comp[1])
    if kind == "concat":
        out: set[str] = set()
        for c in comp[2]:
            out |= compiled_inputs(c)
        return out
    # predicate forms
    if kind == "nonempty":
        return compiled_inputs(comp[1])
    if kind in ("contains",):
        return compiled_inputs(comp[2])
    if kind == "wc":
        return compiled_inputs(comp[3])
    if kind in ("and", "or"):
        return compiled_inputs(comp[1]) | compiled_inputs(comp[2])
    if kind == "not":
        return compiled_inputs(comp[1])
    raise ValueError(f"unknown compiled form {kind!r}")


def compiled_signature(comp: tuple) -> bytes:
    """Stable byte signature of a compiled expression/predicate — the unit
    the shard cache keys on. Raises
    :class:`~repro.core.bytesops.UnfingerprintableOpError` for ops whose
    behavior cannot be captured (lambda predicates)."""
    kind = comp[0]
    if kind == "chain":
        return b"chain:" + comp[1].encode() + b":" + _len_prefixed(
            [B.op_signature(op) for op in comp[2]]
        )
    if kind == "lit":
        return b"lit:" + comp[1].encode()
    if kind == "wrap":
        return b"wrap:" + _len_prefixed(
            [compiled_signature(comp[1])] + [B.op_signature(op) for op in comp[2]]
        )
    if kind == "concat":
        return b"concat:" + comp[1] + b":" + _len_prefixed(
            [compiled_signature(c) for c in comp[2]]
        )
    if kind == "nonempty":
        return b"nonempty:" + compiled_signature(comp[1])
    if kind == "contains":
        return b"contains:" + comp[1] + b":" + compiled_signature(comp[2])
    if kind == "wc":
        return b"wc" + comp[1].encode() + str(comp[2]).encode() + b":" + compiled_signature(comp[3])
    if kind in ("and", "or"):
        return kind.encode() + b":" + _len_prefixed(
            [compiled_signature(comp[1]), compiled_signature(comp[2])]
        )
    if kind == "not":
        return b"not:" + compiled_signature(comp[1])
    raise ValueError(f"unknown compiled form {kind!r}")


# ---------------------------------------------------------------------------
# Evaluation over flat buffers
# ---------------------------------------------------------------------------


def eval_str(
    comp: tuple,
    lookup: Callable[[str], np.ndarray],
    n_rows: int,
    backend: str | None = None,
) -> np.ndarray:
    """Evaluate a compiled string expression to a flat byte buffer.
    ``lookup(col)`` returns the current flat buffer of a column.
    ``backend`` selects the bytesops execution backend for op chains
    (byte-identical across backends; see ``bytesops.execute_ops``)."""
    kind = comp[0]
    if kind == "chain":
        return B.execute_ops(lookup(comp[1]), comp[2], backend)
    if kind == "lit":
        return B.flatten([comp[1]] * n_rows)
    if kind == "wrap":
        return B.execute_ops(eval_str(comp[1], lookup, n_rows, backend), comp[2], backend)
    if kind == "concat":
        parts = [eval_str(c, lookup, n_rows, backend) for c in comp[2]]
        return B.concat_rows(parts, comp[1])
    raise ValueError(f"unknown compiled form {kind!r}")


def eval_mask(
    comp: tuple,
    lookup: Callable[[str], np.ndarray],
    n_rows: int,
    backend: str | None = None,
) -> np.ndarray:
    """Evaluate a compiled predicate to a boolean row mask — straight off
    flat byte buffers, no row ever decodes."""
    kind = comp[0]
    if kind == "nonempty":
        return B.row_nonempty(eval_str(comp[1], lookup, n_rows, backend))
    if kind == "contains":
        return B.rows_containing(eval_str(comp[2], lookup, n_rows, backend), comp[1])
    if kind == "wc":
        counts = B.row_word_counts(eval_str(comp[3], lookup, n_rows, backend))
        return _CMP_FNS[comp[1]](counts, comp[2])
    if kind == "and":
        return eval_mask(comp[1], lookup, n_rows, backend) & eval_mask(
            comp[2], lookup, n_rows, backend
        )
    if kind == "or":
        return eval_mask(comp[1], lookup, n_rows, backend) | eval_mask(
            comp[2], lookup, n_rows, backend
        )
    if kind == "not":
        return ~eval_mask(comp[1], lookup, n_rows, backend)
    raise ValueError(f"unknown compiled form {kind!r}")


def compile_project(
    entries: Sequence[tuple[str, Expr]], optimize: bool
) -> tuple[tuple[str, tuple], ...]:
    """Compile a Project node's ``(out_col, expr)`` entries.

    Entries evaluate *sequentially* (entry k sees the columns entries < k
    wrote — Spark ``withColumn`` chaining). With ``optimize``, adjacent
    in-place chains over the same column merge into one op chain and every
    chain's ops are fused (exact, see ``bytesops.fuse_ops``); without it,
    each entry's ops run one by one (the paper-faithful executor).
    """
    out: list[tuple[str, tuple]] = []
    for out_col, e in entries:
        comp = compile_expr(e)
        if (
            optimize
            and out
            and comp[0] == "chain"
            and comp[1] == out_col  # in-place over its own column
            and out[-1][0] == out_col
            and out[-1][1][0] == "chain"
        ):
            prev_col, prev = out[-1]
            out[-1] = (out_col, ("chain", prev[1], prev[2] + comp[2]))
        else:
            out.append((out_col, comp))
    if optimize:
        out = [(c, fuse_compiled(comp)) for c, comp in out]
    return tuple(out)
