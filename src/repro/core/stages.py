"""Spark ML Feature–style preprocessing stages (the paper's four new APIs).

.. deprecated::
    The ``Stage`` verbs are thin shims over the column-expression IR
    (:mod:`repro.core.expr`): each stage's whole behavior is *defined* by
    the expression its :meth:`Stage.to_expr` constructs, and
    ``Dataset.apply(*stages)`` simply lowers those expressions into a
    ``Project`` plan node — exactly what ``Dataset.with_column(name,
    expr)`` / ``transform(**exprs)`` do directly. New code should compose
    expressions (``col("abstract").lower().strip_html()...``); the stage
    classes remain for the paper-faithful API surface
    (``abstract_stages``/``title_stages``, ``run_p3sapp``) and as the
    row-wise oracle of the differential tests. Outputs are byte-identical
    either way: the stage path and the expression path compile to the same
    byte ops.

Each stage follows the Spark ML ``Transformer`` protocol (``fit`` is identity
for pure transformers, kept for API fidelity with Spark ``Pipeline.fit``) and
provides two execution paths:

* ``to_expr`` / ``flat_ops`` / ``transform_flat`` — the P3SAPP path:
  vectorized byte ops over the flat columnar buffer, derived from the
  stage's expression (see :mod:`repro.core.bytesops`).
* ``transform_row`` — the row-wise oracle with *identical semantics*, used by
  the conventional approach (Algorithm 2) and by the equivalence tests.

Stage set = the paper's §4.1 APIs (``ConvertToLower``, ``RemoveHTMLTags``,
``RemoveUnwantedCharacters``, ``RemoveShortWords``) plus the two pre-existing
Spark APIs it reuses (``Tokenizer``, ``StopWordsRemover``).
"""

from __future__ import annotations

import warnings

import numpy as np

from . import bytesops as B
from . import expr as E
from .expr import ENGLISH_STOPWORDS  # noqa: F401  (canonical home is expr.py)


class Stage:
    """Base transformer: Spark ML Feature API protocol (deprecated shim —
    see module docstring; behavior is defined by :meth:`to_expr`)."""

    def __init__(self, input_col: str, output_col: str | None = None):
        warnings.warn(
            f"{type(self).__name__} is a deprecated shim over the column "
            "expression IR and will be removed; compose col() expressions "
            "instead (see repro.core.expr and the README migration table)",
            DeprecationWarning,
            stacklevel=2,
        )
        self.input_col = input_col
        self.output_col = output_col or input_col

    # Spark Pipeline.fit() calls fit on estimators; plain transformers return
    # themselves. Kept so our Pipeline is drop-in API-compatible.
    def fit(self, frame) -> "Stage":
        return self

    # --- expression shim (single source of truth) ------------------------
    def to_expr(self, e: E.Expr) -> E.Expr:
        """The expression this stage is a shim for, applied to ``e``."""
        raise NotImplementedError

    # --- P3SAPP vectorized path ------------------------------------------
    def flat_ops(self) -> list[B.Op]:
        comp = E.compile_expr(self.to_expr(E.col(self.input_col)))
        assert comp[0] == "chain" and comp[1] == self.input_col
        return list(comp[2])

    def transform_flat(self, buf: np.ndarray) -> np.ndarray:
        return B.apply_ops(buf, self.flat_ops())

    # --- row-wise oracle (CA path) ---------------------------------------
    def transform_row(self, row: str) -> str:
        raise NotImplementedError


_ASCII_LOWER_TABLE = {c: c + 32 for c in range(ord("A"), ord("Z") + 1)}


class ConvertToLower(Stage):
    """Paper §4.1.1 — lowercase every entry of the column."""

    def to_expr(self, e):
        return e.lower()

    def transform_row(self, row):
        # ASCII-only lowering to match the byte LUT exactly.
        return row.translate(_ASCII_LOWER_TABLE)


def _strip_spans_row(row: str, open_c: str, close_c: str) -> str:
    out = []
    depth = 0
    for ch in row:
        if ch == open_c:
            depth += 1
        elif ch == close_c:
            depth = max(depth - 1, 0)
        elif depth == 0:
            out.append(ch)
    return "".join(out)


class RemoveHTMLTags(Stage):
    """Paper §4.1.2 — strip ``<...>`` spans (balanced per row, see contract)."""

    def to_expr(self, e):
        return e.strip_html()

    def transform_row(self, row):
        return _strip_spans_row(row, "<", ">")


class RemoveUnwantedCharacters(Stage):
    """Paper §4.1.3 — parenthetical text, contraction mapping, punctuation,
    digits/special characters → cleaned lowercase word stream."""

    def to_expr(self, e):
        return e.strip_parens().expand_contractions().keep_letters().collapse_spaces()

    def transform_row(self, row):
        row = _strip_spans_row(row, "(", ")")
        for pat, rep in B.CONTRACTIONS:
            row = row.replace(pat.decode(), rep.decode())
        row = "".join(ch if ("a" <= ch <= "z" or ch == " ") else " " for ch in row)
        return " ".join(w for w in row.split(" ") if w)


class RemoveShortWords(Stage):
    """Paper §4.1.4 — drop words with ``len(word) <= threshold``."""

    def __init__(self, input_col: str, output_col: str | None = None, threshold: int = 1):
        super().__init__(input_col, output_col)
        self.threshold = threshold

    def to_expr(self, e):
        return e.min_word_len(self.threshold + 1)

    def transform_row(self, row):
        return " ".join(w for w in row.split(" ") if len(w) > self.threshold)


class Tokenizer(Stage):
    """Spark ML ``Tokenizer``: whitespace split (columnar form: normalize
    whitespace; list materialization happens at the frame boundary)."""

    def to_expr(self, e):
        return e.collapse_spaces()

    def transform_row(self, row):
        return " ".join(w for w in row.split(" ") if w)


class StopWordsRemover(Stage):
    """Spark ML ``StopWordsRemover`` with vectorized 64-bit word hashing."""

    def __init__(
        self,
        input_col: str,
        output_col: str | None = None,
        stopwords: tuple[str, ...] = ENGLISH_STOPWORDS,
    ):
        super().__init__(input_col, output_col)
        self.stopwords = tuple(stopwords)
        self._stopset = frozenset(self.stopwords)
        self._words = B.WordSet(self.stopwords)

    def to_expr(self, e):
        return e.remove_stopwords(self._words)

    def transform_row(self, row):
        return " ".join(w for w in row.split(" ") if w and w not in self._stopset)


# ---------------------------------------------------------------------------
# Canonical case-study workflows (paper Fig. 2 / Fig. 3)
# ---------------------------------------------------------------------------


def abstract_stages(col: str = "abstract", threshold: int = 1) -> list[Stage]:
    """Paper Fig. 2: abstracts are the model *feature* → full cleaning.
    Expression form: :func:`repro.core.expr.abstract_expr`."""
    return [
        ConvertToLower(col),
        RemoveHTMLTags(col),
        RemoveUnwantedCharacters(col),
        StopWordsRemover(col),
        RemoveShortWords(col, threshold=threshold),
    ]


def title_stages(col: str = "title") -> list[Stage]:
    """Paper Fig. 3: titles are the model *target* → keep stopwords.
    Expression form: :func:`repro.core.expr.title_expr`."""
    return [
        ConvertToLower(col),
        RemoveHTMLTags(col),
        RemoveUnwantedCharacters(col),
        RemoveShortWords(col, threshold=1),
    ]
