"""Spark ML Feature–style preprocessing stages (the paper's four new APIs).

Each stage follows the Spark ML ``Transformer`` protocol (``fit`` is identity
for pure transformers, kept for API fidelity with Spark ``Pipeline.fit``) and
provides two execution paths:

* ``flat_ops`` / ``transform_flat`` — the P3SAPP path: vectorized byte ops
  over the flat columnar buffer (see :mod:`repro.core.bytesops`). Stages
  describe themselves as op descriptors so the pipeline executor can fuse
  adjacent compatible ops across stage boundaries.
* ``transform_row`` — the row-wise oracle with *identical semantics*, used by
  the conventional approach (Algorithm 2) and by the equivalence tests.

Stage set = the paper's §4.1 APIs (``ConvertToLower``, ``RemoveHTMLTags``,
``RemoveUnwantedCharacters``, ``RemoveShortWords``) plus the two pre-existing
Spark APIs it reuses (``Tokenizer``, ``StopWordsRemover``).
"""

from __future__ import annotations

import numpy as np

from . import bytesops as B

# The English stopword list used by Spark's StopWordsRemover is long; this is
# the classic NLTK-ish core, sufficient for the case study and configurable.
ENGLISH_STOPWORDS: tuple[str, ...] = tuple(
    (
        "i me my myself we our ours ourselves you your yours yourself yourselves "
        "he him his himself she her hers herself it its itself they them their "
        "theirs themselves what which who whom this that these those am is are "
        "was were be been being have has had having do does did doing a an the "
        "and but if or because as until while of at by for with about against "
        "between into through during before after above below to from up down in "
        "out on off over under again further then once here there when where why "
        "how all any both each few more most other some such no nor not only own "
        "same so than too very s t can will just don should now"
    ).split()
)


class Stage:
    """Base transformer: Spark ML Feature API protocol."""

    def __init__(self, input_col: str, output_col: str | None = None):
        self.input_col = input_col
        self.output_col = output_col or input_col

    # Spark Pipeline.fit() calls fit on estimators; plain transformers return
    # themselves. Kept so our Pipeline is drop-in API-compatible.
    def fit(self, frame) -> "Stage":
        return self

    # --- P3SAPP vectorized path ------------------------------------------
    def flat_ops(self) -> list[B.Op]:
        raise NotImplementedError

    def transform_flat(self, buf: np.ndarray) -> np.ndarray:
        return B.apply_ops(buf, self.flat_ops())

    # --- row-wise oracle (CA path) ---------------------------------------
    def transform_row(self, row: str) -> str:
        raise NotImplementedError


_ASCII_LOWER_TABLE = {c: c + 32 for c in range(ord("A"), ord("Z") + 1)}


class ConvertToLower(Stage):
    """Paper §4.1.1 — lowercase every entry of the column."""

    def flat_ops(self):
        return [B.lut_op(B.LOWER_LUT)]

    def transform_row(self, row):
        # ASCII-only lowering to match the byte LUT exactly.
        return row.translate(_ASCII_LOWER_TABLE)


def _strip_spans_row(row: str, open_c: str, close_c: str) -> str:
    out = []
    depth = 0
    for ch in row:
        if ch == open_c:
            depth += 1
        elif ch == close_c:
            depth = max(depth - 1, 0)
        elif depth == 0:
            out.append(ch)
    return "".join(out)


class RemoveHTMLTags(Stage):
    """Paper §4.1.2 — strip ``<...>`` spans (balanced per row, see contract)."""

    def flat_ops(self):
        return [B.span_op("<", ">")]

    def transform_row(self, row):
        return _strip_spans_row(row, "<", ">")


class RemoveUnwantedCharacters(Stage):
    """Paper §4.1.3 — parenthetical text, contraction mapping, punctuation,
    digits/special characters → cleaned lowercase word stream."""

    def flat_ops(self):
        return [
            B.span_op("(", ")"),
            B.replace_op(B.CONTRACTIONS),
            B.lut_op(B.UNWANTED_LUT),
            B.collapse_op(),
        ]

    def transform_row(self, row):
        row = _strip_spans_row(row, "(", ")")
        for pat, rep in B.CONTRACTIONS:
            row = row.replace(pat.decode(), rep.decode())
        row = "".join(ch if ("a" <= ch <= "z" or ch == " ") else " " for ch in row)
        return " ".join(w for w in row.split(" ") if w)


class RemoveShortWords(Stage):
    """Paper §4.1.4 — drop words with ``len(word) <= threshold``."""

    def __init__(self, input_col: str, output_col: str | None = None, threshold: int = 1):
        super().__init__(input_col, output_col)
        self.threshold = threshold

    def flat_ops(self):
        from functools import partial

        return [B.wordpred_op(partial(B.pred_short, threshold=self.threshold), needs_hashes=False)]

    def transform_row(self, row):
        return " ".join(w for w in row.split(" ") if len(w) > self.threshold)


class Tokenizer(Stage):
    """Spark ML ``Tokenizer``: whitespace split (columnar form: normalize
    whitespace; list materialization happens at the frame boundary)."""

    def flat_ops(self):
        return [B.collapse_op()]

    def transform_row(self, row):
        return " ".join(w for w in row.split(" ") if w)


class StopWordsRemover(Stage):
    """Spark ML ``StopWordsRemover`` with vectorized 64-bit word hashing."""

    def __init__(
        self,
        input_col: str,
        output_col: str | None = None,
        stopwords: tuple[str, ...] = ENGLISH_STOPWORDS,
    ):
        super().__init__(input_col, output_col)
        self.stopwords = tuple(stopwords)
        self._stopset = frozenset(self.stopwords)
        self._words = B.WordSet(self.stopwords)

    def flat_ops(self):
        from functools import partial

        return [B.wordpred_op(partial(B.pred_stopword, words=self._words), needs_hashes=True)]

    def transform_row(self, row):
        return " ".join(w for w in row.split(" ") if w and w not in self._stopset)


# ---------------------------------------------------------------------------
# Canonical case-study workflows (paper Fig. 2 / Fig. 3)
# ---------------------------------------------------------------------------


def abstract_stages(col: str = "abstract", threshold: int = 1) -> list[Stage]:
    """Paper Fig. 2: abstracts are the model *feature* → full cleaning."""
    return [
        ConvertToLower(col),
        RemoveHTMLTags(col),
        RemoveUnwantedCharacters(col),
        StopWordsRemover(col),
        RemoveShortWords(col, threshold=threshold),
    ]


def title_stages(col: str = "title") -> list[Stage]:
    """Paper Fig. 3: titles are the model *target* → keep stopwords."""
    return [
        ConvertToLower(col),
        RemoveHTMLTags(col),
        RemoveUnwantedCharacters(col),
        RemoveShortWords(col, threshold=1),
    ]
