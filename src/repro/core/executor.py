"""Shard executors: process-parallel cleaning + plan-fingerprint caching.

The paper's cost argument (§3, eq. 7) assumes two Spark properties our
in-thread streaming path lacked: *true multi-worker execution* of the
cleaning stages and *reuse of already-computed results* (``persist()``).
This module supplies both behind the planner:

* :class:`ShardProgram` — the per-shard physical program compiled from the
  frame-level plan (parse → select/dropna[/dedup] → per-column op chains).
  Programs are picklable: ops are plain descriptors
  (:mod:`repro.core.bytesops`), so the same program runs in a thread or in
  a worker process.
* :class:`ThreadShardExecutor` — the existing in-thread path: a
  work-stealing :class:`~repro.core.async_loader.ShardPool` of reader
  threads, each running the full program per shard. Supports cross-shard
  ``drop_duplicates`` (shared keep-first state).
* :class:`ProcessShardExecutor` — worker *processes* with a shared task
  queue (self-scheduling == work stealing). Raw shard bytes travel to
  workers as shared-memory uint8 buffers; cleaned flat column buffers plus
  their row offsets travel back the same way, so no large pickles cross
  the pipe. Falls back to the thread executor when ``workers <= 1``, when
  the platform lacks POSIX shared memory, or when the program needs
  cross-shard state (``drop_duplicates``).
* :class:`ShardCache` — the ``persist()`` analogue: an on-disk cache of
  cleaned column buffers keyed by ``(shard bytes digest, column lineage
  fingerprint)``. Re-running an unchanged plan skips cleaning entirely;
  changing one column's ops recomputes only that column (other columns
  keep hitting). Corrupted entries are treated as misses, never errors.

Executor selection honors ``REPRO_EXECUTOR`` (``thread`` | ``process``)
and the cache root honors ``REPRO_CACHE_DIR``.
"""

from __future__ import annotations

import hashlib
import multiprocessing as mp
import os
import tempfile
import threading
import time
import traceback
import dataclasses
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Iterator, Sequence

import numpy as np

from . import bytesops as B
from . import ingest as ing
from .async_loader import ShardPool
from .frame import ColumnarFrame
from .pipeline import ColumnPlan

# ---------------------------------------------------------------------------
# Shard program: the picklable per-shard physical plan
# ---------------------------------------------------------------------------

# Step kinds: ("select", cols) | ("dropna", cols) | ("dedup", cols)
#           | ("clean", ((in_col, out_col, (op, ...)), ...))
Step = tuple[str, Any]


@dataclass(frozen=True)
class ShardProgram:
    """Per-shard physical program: parse ``fields``, run ``steps``, emit
    ``output_columns`` (empty tuple = every live column)."""

    fields: tuple[str, ...]
    steps: tuple[Step, ...]
    output_columns: tuple[str, ...] = ()

    @property
    def has_dedup(self) -> bool:
        return any(kind == "dedup" for kind, _ in self.steps)


class UnsupportedPlanError(ValueError):
    """The plan cannot be compiled to a per-shard program."""


def compile_shard_program(
    frame_nodes: Sequence[Any],
    *,
    optimize: bool = True,
    output_columns: Sequence[str] = (),
) -> ShardProgram:
    """Compile an (optimized) frame-level plan into a :class:`ShardProgram`.

    ``frame_nodes[0]`` must be a ``SourceJsonDirs``; ``Split`` is whole-frame
    only and rejected here.
    """
    from . import plan as P  # local import: plan.py imports this module
    from .pipeline import compile_column_plans

    src = frame_nodes[0]
    if not isinstance(src, P.SourceJsonDirs):
        raise UnsupportedPlanError("shard programs require a SourceJsonDirs source")
    steps: list[Step] = []
    for node in frame_nodes[1:]:
        if isinstance(node, P.Select):
            steps.append(("select", tuple(node.fields)))
        elif isinstance(node, P.DropNA):
            steps.append(("dropna", tuple(node.subset)))
        elif isinstance(node, P.DropDuplicates):
            steps.append(("dedup", tuple(node.subset)))
        elif isinstance(node, P.ApplyStages):
            plans = compile_column_plans(node.stages, optimize)
            steps.append(("clean", tuple((i, o, tuple(ops)) for i, o, ops in plans)))
        else:
            raise UnsupportedPlanError(f"not shard-executable: {node.describe()}")
    return ShardProgram(tuple(src.fields), tuple(steps), tuple(output_columns))


# ---------------------------------------------------------------------------
# Column lineage fingerprints (the plan half of the cache key)
# ---------------------------------------------------------------------------


def _lineage_fingerprints(
    program: ShardProgram,
) -> tuple[dict[int, dict[str, str]], dict[str, str]] | None:
    """Per-clean-step, per-output-column lineage fingerprints.

    A column's fingerprint at a clean step covers, in order, every earlier
    step that can change that step's output buffer for a given shard: the
    op chains along its own lineage and every row filter (``dropna``) —
    including, transitively, the lineages of the filter's subset columns,
    since *their* values decide which rows survive. Keys are step indices
    into ``program.steps``: a column written by two clean steps gets a
    *different* fingerprint at each, so the steps never alias one cache
    entry. ``{}``-valued / missing columns are uncacheable (e.g. a
    predicate that cannot be fingerprinted, such as a lambda). Returns
    None when the whole program is uncacheable: ``dedup`` holds
    cross-shard state, so a shard's output is not a pure function of
    (shard bytes, program).
    """
    if program.has_dedup:
        return None

    def h(sig: bytes) -> bytes:
        return hashlib.blake2b(sig, digest_size=16).digest()

    # None in ``lineage`` poisons a column: its value depends on something
    # we cannot fingerprint, so nothing derived from it may cache.
    lineage: dict[str, bytes | None] = {
        f: b"src:" + f.encode() for f in program.fields
    }
    per_step: dict[int, dict[str, str]] = {}
    for step_idx, (kind, arg) in enumerate(program.steps):
        if kind == "select":
            lineage = {c: lineage[c] for c in arg if c in lineage}
        elif kind == "dropna":
            subset = [lineage.get(c) for c in arg]
            if any(sig is None for sig in subset):
                # Unfingerprintable column decides the row set → nothing
                # downstream is a pure function of fingerprintable state.
                lineage = {c: None for c in lineage}
                continue
            token = b"dropna:" + b",".join(
                c.encode() + b"=" + lineage.get(c, b"?") for c in arg
            )
            lineage = {
                c: h(sig + b"|" + token) if sig is not None else None
                for c, sig in lineage.items()
            }
        elif kind == "clean":
            fps: dict[str, str] = {}
            for in_col, out_col, ops in arg:
                base = lineage.get(in_col, b"src:" + in_col.encode())
                if base is None:
                    lineage[out_col] = None
                    continue
                try:
                    ops_fp = B.ops_fingerprint(ops).encode()
                except B.UnfingerprintableOpError:
                    lineage[out_col] = None
                    continue
                sig = h(base + b"|ops:" + ops_fp)
                lineage[out_col] = sig
                fps[out_col] = sig.hex()
            per_step[step_idx] = fps
    final = {c: sig.hex() for c, sig in lineage.items() if sig is not None}
    return per_step, final


def step_column_fingerprints(
    program: ShardProgram,
) -> dict[int, dict[str, str]] | None:
    """Cache-key fingerprints per clean step (see ``_lineage_fingerprints``)."""
    walked = _lineage_fingerprints(program)
    return None if walked is None else walked[0]


def column_fingerprints(program: ShardProgram) -> dict[str, str] | None:
    """End-of-program lineage fingerprint of every (fingerprintable)
    column. None when the program holds cross-shard state (dedup)."""
    walked = _lineage_fingerprints(program)
    return None if walked is None else walked[1]


# ---------------------------------------------------------------------------
# On-disk shard cache (the Spark persist() analogue)
# ---------------------------------------------------------------------------


def default_cache_dir() -> Path:
    env = os.environ.get("REPRO_CACHE_DIR")
    if env:
        return Path(env)
    return Path(tempfile.gettempdir()) / "repro_shard_cache"


class ShardCache:
    """Content-addressed store of cleaned column buffers.

    One ``.npy`` file per (shard digest, column, lineage fingerprint).
    Writes are atomic (tmp + rename); reads treat any malformed entry as a
    miss and delete it, so a corrupted cache degrades to recompute.
    """

    def __init__(self, root: str | Path | None = None):
        self.root = Path(root) if root is not None else default_cache_dir()
        self.root.mkdir(parents=True, exist_ok=True)

    def key(self, shard_digest: str, column: str, column_fp: str) -> str:
        return hashlib.blake2b(
            f"{shard_digest}:{column}:{column_fp}".encode(), digest_size=16
        ).hexdigest()

    def _path(self, key: str) -> Path:
        return self.root / f"{key}.npy"

    def load(self, key: str) -> np.ndarray | None:
        path = self._path(key)
        try:
            buf = np.load(path, allow_pickle=False)
            if buf.dtype != np.uint8 or buf.ndim != 1:
                raise ValueError("wrong cache payload shape")
            return buf
        except FileNotFoundError:
            return None
        except Exception:
            # Corrupted entry (truncated write, garbage, wrong format):
            # recompute instead of crashing, and drop the bad file.
            try:
                path.unlink()
            except OSError:
                pass
            return None

    def store(self, key: str, buf: np.ndarray) -> None:
        path = self._path(key)
        try:
            fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
            try:
                with os.fdopen(fd, "wb") as fh:
                    np.save(fh, buf, allow_pickle=False)
                os.replace(tmp, path)
            finally:
                if os.path.exists(tmp):
                    os.unlink(tmp)
        except OSError:
            pass  # cache is best-effort; never fail the pipeline


# ---------------------------------------------------------------------------
# Program execution (shared by thread and process workers)
# ---------------------------------------------------------------------------


@dataclass
class ShardResult:
    """One processed shard: the cleaned frame plus execution accounting.

    ``payload`` holds the executor's ``postprocess(frame)`` output (e.g.
    tokenized arrays) when a postprocess hook was installed."""

    frame: ColumnarFrame
    parse_s: float = 0.0
    pre_clean_s: float = 0.0
    clean_s: float = 0.0
    post_clean_s: float = 0.0
    cache_hits: int = 0
    cache_misses: int = 0
    payload: Any = None
    # Flat buffers not yet folded into ``frame`` (materialize=False only).
    flat: dict = dataclasses.field(default_factory=dict)


class GlobalDedup:
    """Thread-safe keep-first dedup across shards (stream arrival order)."""

    def __init__(self, subset: tuple[str, ...]):
        self.subset = subset
        self._seen: set = set()
        self._lock = threading.Lock()

    def keep_mask(self, frame: ColumnarFrame) -> np.ndarray:
        cols = [frame[f] for f in self.subset]
        n = len(frame)
        # Build keys outside the lock so reader threads only serialize on
        # the set membership check, not the per-row tuple construction.
        keys = [tuple(c[i] for c in cols) for i in range(n)]
        keep = np.ones(n, dtype=bool)
        with self._lock:
            for i, key in enumerate(keys):
                if key in self._seen:
                    keep[i] = False
                else:
                    self._seen.add(key)
        return keep

    def filter(self, frame: ColumnarFrame) -> ColumnarFrame:
        return frame.take(self.keep_mask(frame))


# -- flat-buffer row ops (cleaned columns stay flat through the program) ----


def _flat_row_lengths(buf: np.ndarray) -> np.ndarray:
    """Per-row byte length *including* the trailing separator."""
    sep_idx = np.flatnonzero(buf == B.ROW_SEP)
    return np.diff(np.concatenate(([-1], sep_idx))).astype(np.int64)


def _flat_nonempty_mask(buf: np.ndarray) -> np.ndarray:
    return _flat_row_lengths(buf) > 1


def _flat_take(buf: np.ndarray, keep: np.ndarray) -> np.ndarray:
    """Row-filter a flat buffer without decoding it."""
    if buf.size == 0 or keep.all():
        return buf
    return buf[np.repeat(keep, _flat_row_lengths(buf))]


def _run_clean_step(
    frame: ColumnarFrame,
    flat: dict[str, np.ndarray],
    plans: Sequence[ColumnPlan],
    cache: ShardCache | None,
    step_fps: dict[str, str] | None,
    digest: str | None,
    result: ShardResult,
) -> None:
    """Run one stage-chain step over flat buffers, one cache lookup per
    output column. A hit replaces the op chain with a disk read; a miss
    (including a corrupt or row-count-stale entry) recomputes just that
    column and rewrites the entry, so partially-changed plans only pay for
    the columns whose lineage actually changed."""
    n = len(frame)
    cacheable = cache is not None and step_fps is not None and digest is not None
    for in_col, out_col, ops in plans:
        key = None
        if cacheable:
            fp = step_fps.get(out_col)
            key = cache.key(digest, out_col, fp) if fp else None
            hit = cache.load(key) if key else None
            if hit is not None and B.n_rows(hit) == n:
                flat[out_col] = hit
                result.cache_hits += 1
                continue
        src = flat[in_col] if in_col in flat else frame.flat(in_col)
        out = B.apply_ops(src, list(ops))
        flat[out_col] = out
        if key:
            # Uncacheable columns (key None) count neither hit nor miss:
            # no lookup happened, and a warm run should still report 100%.
            result.cache_misses += 1
            cache.store(key, out)


def execute_program(
    frame: ColumnarFrame,
    program: ShardProgram,
    *,
    dedups: dict[int, GlobalDedup] | None = None,
    cache: ShardCache | None = None,
    col_fps: dict[int, dict[str, str]] | None = None,
    digest: str | None = None,
    materialize: bool = True,
) -> ShardResult:
    """Run every step of ``program`` on one parsed shard frame.

    Cleaned columns live as *flat* byte buffers from their op chain until
    the very end — row filters apply straight to the buffers — so no
    decode/re-encode round trip happens inside the program. With
    ``materialize=False`` the buffers are left in ``result.flat`` for
    zero-copy transport (the process executor ships them via shared
    memory); ``materialize=True`` folds them back into the frame.
    """
    result = ShardResult(frame)
    flat: dict[str, np.ndarray] = {}
    seen_clean = False
    for step_idx, (kind, arg) in enumerate(program.steps):
        t0 = time.perf_counter()
        if kind == "select":
            for c in arg:  # flat-only columns need a frame slot to survive
                if c in flat and c not in frame.columns:
                    frame = frame.ensure_column(c)
            frame = frame.select([c for c in arg if c in frame.columns])
            flat = {c: b for c, b in flat.items() if c in arg}
        elif kind == "dropna":
            keep = np.ones(len(frame), dtype=bool)
            for c in arg:
                if c in flat:
                    keep &= _flat_nonempty_mask(flat[c])
                else:
                    col = frame[c]
                    keep &= np.array(
                        [v is not None and v != "" for v in col], dtype=bool
                    )
            if not keep.all():
                frame = frame.take(keep)
                flat = {c: _flat_take(b, keep) for c, b in flat.items()}
        elif kind == "dedup":
            if dedups is None:
                raise UnsupportedPlanError(
                    "dedup step requires executor-provided cross-shard state"
                )
            # Dedup compares real values: decode any flat subset column
            # back into the frame first (dedup plans are thread-only and
            # uncacheable, so this is the status-quo cost).
            for c in dedups[step_idx].subset:
                if c in flat:
                    frame = frame.ensure_column(c).with_flat(c, flat.pop(c))
            keep = dedups[step_idx].keep_mask(frame)
            if not keep.all():
                frame = frame.take(keep)
                flat = {c: _flat_take(b, keep) for c, b in flat.items()}
        elif kind == "clean":
            step_fps = col_fps.get(step_idx) if col_fps is not None else None
            _run_clean_step(frame, flat, arg, cache, step_fps, digest, result)
        dt = time.perf_counter() - t0
        if kind == "clean":
            seen_clean = True
            result.clean_s += dt
        elif seen_clean:
            result.post_clean_s += dt
        else:
            result.pre_clean_s += dt
    if program.output_columns:
        live = set(program.output_columns)
        for c in live:
            if c in flat and c not in frame.columns:
                frame = frame.ensure_column(c)
        frame = frame.select([c for c in frame.columns if c in live])
        flat = {c: b for c, b in flat.items() if c in live}
    if materialize:
        for c, b in flat.items():
            frame = frame.ensure_column(c).with_flat(c, b)
        flat = {}
    result.frame = frame
    result.flat = flat
    return result


# ---------------------------------------------------------------------------
# Thread executor (the ShardPool path, now program-driven)
# ---------------------------------------------------------------------------


class ThreadShardExecutor:
    """Work-stealing reader threads, one full program run per shard.

    The only executor that supports cross-shard ``drop_duplicates`` (the
    keep-first set lives in this process).
    """

    name = "thread"

    def __init__(
        self,
        shards: Sequence[str | Path],
        program: ShardProgram,
        *,
        workers: int = 2,
        cache_dir: str | Path | None = None,
        postprocess=None,
    ):
        self.program = program
        self._postprocess = postprocess
        self.cache_hits = 0
        self.cache_misses = 0
        self._cache = ShardCache(cache_dir) if cache_dir is not None else None
        self._col_fps = step_column_fingerprints(program) if self._cache else None
        self._dedups = {
            i: GlobalDedup(arg)
            for i, (kind, arg) in enumerate(program.steps)
            if kind == "dedup"
        }
        self._agg_lock = threading.Lock()
        self._parse_s = self._pre_s = self._clean_s = self._post_s = 0.0
        self._pool = ShardPool(
            shards, self._process, n_readers=max(int(workers), 1)
        )

    def _process(self, path: Path) -> ShardResult:
        t0 = time.perf_counter()
        if self._cache is not None:
            data, digest = ing.read_shard_bytes(path)
            frame = ing.parse_shard_bytes(data, self.program.fields)
        else:
            digest = None
            frame = ing.parse_shard(path, self.program.fields)
        parse_s = time.perf_counter() - t0
        res = execute_program(
            frame,
            self.program,
            dedups=self._dedups,
            cache=self._cache,
            col_fps=self._col_fps,
            digest=digest,
        )
        res.parse_s = parse_s
        if self._postprocess is not None:
            # Runs inside the reader thread, so per-shard tokenization
            # overlaps across shards exactly like cleaning does.
            res.payload = self._postprocess(res.frame)
        return res

    def _account(self, res: ShardResult) -> None:
        with self._agg_lock:
            self._parse_s += res.parse_s
            self._pre_s += res.pre_clean_s
            self._clean_s += res.clean_s
            self._post_s += res.post_clean_s
            self.cache_hits += res.cache_hits
            self.cache_misses += res.cache_misses

    @property
    def timings(self):
        from .plan import StageTimings

        return StageTimings(self._parse_s, self._pre_s, self._clean_s, self._post_s)

    def __iter__(self) -> Iterator[ShardResult]:
        for res in self._pool:
            self._account(res)
            yield res

    def stop(self) -> None:
        self._pool.stop()


# ---------------------------------------------------------------------------
# Process executor (shared-memory transport, self-scheduling workers)
# ---------------------------------------------------------------------------


def shared_memory_available() -> bool:
    try:
        from multiprocessing import shared_memory

        seg = shared_memory.SharedMemory(create=True, size=16)
        seg.close()
        seg.unlink()
        return True
    except Exception:  # pragma: no cover - platform without /dev/shm
        return False


def _utf8_roundtrips(v: str) -> bool:
    """False for strings flatten() would mangle (lone surrogates from the
    stdlib-json fallback): those must ride the obj_rows side channel so
    the process executor stays value-identical with the thread path."""
    try:
        v.encode("utf-8")
        return "\x00" not in v
    except UnicodeEncodeError:
        return False


def _pack_columns(
    frame: ColumnarFrame, flat: dict[str, np.ndarray], columns: Sequence[str]
) -> tuple[bytes, list[dict]]:
    """Pack columns as (flat uint8 bytes + int64 row-end offsets) sections.

    Cleaned columns ship their program-output buffer as-is (no re-encode);
    untouched columns flatten here and carry their non-string originals
    (None, numbers, …) in the metadata so the round trip is value-exact —
    the thread and whole-frame executors never coerce those."""
    parts: list[bytes] = []
    metas: list[dict] = []
    pos = 0
    for col in columns:
        if col in flat:
            buf = flat[col]
            obj_rows: list[tuple[int, Any]] = []  # op output is always a string
        else:
            buf = frame.flat(col)
            obj_rows = [
                (i, v)
                for i, v in enumerate(frame[col])
                if not isinstance(v, str) or not _utf8_roundtrips(v)
            ]
        offsets = np.flatnonzero(buf == B.ROW_SEP).astype(np.int64)
        raw = buf.tobytes()
        offs = offsets.tobytes()
        metas.append(
            {
                "name": col,
                "buf_off": pos,
                "buf_len": len(raw),
                "offs_off": pos + len(raw),
                "n_rows": int(offsets.size),
                "obj_rows": obj_rows,
            }
        )
        parts.append(raw)
        parts.append(offs)
        pos += len(raw) + len(offs)
    return b"".join(parts), metas


def _unpack_columns(payload: memoryview, metas: list[dict]) -> ColumnarFrame:
    cols: dict[str, np.ndarray] = {}
    for m in metas:
        raw = bytes(payload[m["buf_off"] : m["buf_off"] + m["buf_len"]])
        offsets = np.frombuffer(
            payload, dtype=np.int64, count=m["n_rows"], offset=m["offs_off"]
        )
        starts = np.concatenate(([0], offsets[:-1] + 1)) if m["n_rows"] else []
        rows: list = [
            raw[s:e].decode("utf-8", errors="ignore")
            for s, e in zip(starts, offsets)
        ]
        for i, v in m["obj_rows"]:
            rows[i] = v
        cols[m["name"]] = np.array(rows, dtype=object)
    return ColumnarFrame(cols)


def _worker_main(task_q, result_q, program: ShardProgram, cache_dir) -> None:
    """Worker process: pull (shm, size, digest) tasks until sentinel."""
    from multiprocessing import shared_memory

    cache = ShardCache(cache_dir) if cache_dir is not None else None
    col_fps = step_column_fingerprints(program) if cache is not None else None
    while True:
        task = task_q.get()
        if task is None:
            break
        task_id, shm_name, nbytes, digest = task
        try:
            t0 = time.perf_counter()
            seg = shared_memory.SharedMemory(name=shm_name)
            try:
                data = bytes(seg.buf[:nbytes])
            finally:
                seg.close()
            frame = ing.parse_shard_bytes(data, program.fields)
            parse_s = time.perf_counter() - t0
            res = execute_program(
                frame,
                program,
                cache=cache,
                col_fps=col_fps,
                digest=digest,
                materialize=False,
            )
            res.parse_s = parse_s
            out_cols = list(dict.fromkeys(list(res.frame.columns) + list(res.flat)))
            payload, metas = _pack_columns(res.frame, res.flat, out_cols)
            out = shared_memory.SharedMemory(create=True, size=max(len(payload), 1))
            out.buf[: len(payload)] = payload
            out_name = out.name
            out.close()
            result_q.put(
                (
                    "ok",
                    task_id,
                    {
                        "shm": out_name,
                        "size": len(payload),
                        "columns": metas,
                        "parse_s": res.parse_s,
                        "pre_clean_s": res.pre_clean_s,
                        "clean_s": res.clean_s,
                        "post_clean_s": res.post_clean_s,
                        "cache_hits": res.cache_hits,
                        "cache_misses": res.cache_misses,
                    },
                )
            )
        except BaseException:
            result_q.put(("err", task_id, traceback.format_exc()))


class ProcessShardExecutor:
    """Worker processes pulling shards from a shared queue (work stealing).

    Transport is shared memory in both directions: the feeder thread reads
    each shard once (digesting as it reads), places the raw bytes in a
    segment, and workers return cleaned flat column buffers + row offsets
    in a segment of their own. In-flight shards are bounded so the feeder
    never races ahead of slow consumers.
    """

    name = "process"

    def __init__(
        self,
        shards: Sequence[str | Path],
        program: ShardProgram,
        *,
        workers: int = 2,
        cache_dir: str | Path | None = None,
        max_inflight: int | None = None,
        postprocess=None,
    ):
        self._postprocess = postprocess
        if program.has_dedup:
            raise UnsupportedPlanError(
                "drop_duplicates needs cross-shard state; use the thread executor"
            )
        self.program = program
        self.cache_hits = 0
        self.cache_misses = 0
        self._parse_s = self._pre_s = self._clean_s = self._post_s = 0.0
        self._shards = [Path(s) for s in shards]
        self._stopped = threading.Event()
        self._feed_errors: list[BaseException] = []
        self._inflight = threading.Semaphore(max_inflight or max(2 * workers, 4))
        self._in_segs: dict[int, str] = {}
        self._seg_lock = threading.Lock()
        # Start the resource-tracker daemon before forking: workers must
        # inherit it, or each spawns its own and cross-process unlinks are
        # reported as leaks at shutdown.
        shared_memory_available()
        # fork shares the parsed program and avoids re-importing jax in
        # every worker; spawn is the portable fallback.
        methods = mp.get_all_start_methods()
        ctx = mp.get_context("fork" if "fork" in methods else "spawn")
        self._task_q = ctx.Queue()
        self._result_q = ctx.Queue()
        self._procs = [
            ctx.Process(
                target=_worker_main,
                args=(self._task_q, self._result_q, program, cache_dir),
                daemon=True,
            )
            for _ in range(max(int(workers), 1))
        ]
        for p in self._procs:
            p.start()
        self._feeder = threading.Thread(target=self._feed, daemon=True)
        self._feeder.start()

    def _feed(self) -> None:
        from multiprocessing import shared_memory

        try:
            for i, path in enumerate(self._shards):
                while not self._inflight.acquire(timeout=0.1):
                    if self._stopped.is_set():
                        return
                if self._stopped.is_set():
                    return
                data, digest = ing.read_shard_bytes(path)
                seg = shared_memory.SharedMemory(create=True, size=max(len(data), 1))
                seg.buf[: len(data)] = data
                with self._seg_lock:
                    self._in_segs[i] = seg.name
                self._task_q.put((i, seg.name, len(data), digest))
                seg.close()
        except BaseException as e:  # deleted shard, /dev/shm full, ...
            # Surface the real cause to the consumer; without this the
            # consumer only sees "workers exited before delivering".
            self._feed_errors.append(e)
        finally:
            for _ in self._procs:
                self._task_q.put(None)

    def _release_input(self, task_id: int) -> None:
        from multiprocessing import shared_memory

        with self._seg_lock:
            name = self._in_segs.pop(task_id, None)
        if name is not None:
            try:
                seg = shared_memory.SharedMemory(name=name)
                seg.close()
                seg.unlink()
            except FileNotFoundError:
                pass

    def _next_result(self):
        """Result-queue get that notices dead workers instead of blocking
        forever (an OOM-killed or segfaulted worker never sends its
        result)."""
        import queue as _queue

        while True:
            try:
                return self._result_q.get(timeout=1.0)
            except _queue.Empty:
                if self._feed_errors:
                    raise self._feed_errors[0]
                crashed = [
                    p.exitcode
                    for p in self._procs
                    if not p.is_alive() and p.exitcode not in (0, None)
                ]
                if crashed:
                    raise RuntimeError(
                        f"shard worker died with exit code {crashed[0]} "
                        "(no result for its shard)"
                    )
                if all(not p.is_alive() for p in self._procs):
                    raise RuntimeError(
                        "all shard workers exited before delivering every result"
                    )

    def __iter__(self) -> Iterator[ShardResult]:
        from multiprocessing import shared_memory

        for _ in range(len(self._shards)):
            if self._stopped.is_set():
                return
            try:
                msg = self._next_result()
            except BaseException:
                self.stop()
                raise
            status, task_id, body = msg
            self._release_input(task_id)
            self._inflight.release()
            if status == "err":
                self.stop()
                raise RuntimeError(f"shard worker failed:\n{body}")
            seg = shared_memory.SharedMemory(name=body["shm"])
            try:
                frame = _unpack_columns(seg.buf[: body["size"]], body["columns"])
            finally:
                seg.close()
                seg.unlink()
            self._parse_s += body["parse_s"]
            self._pre_s += body["pre_clean_s"]
            self._clean_s += body["clean_s"]
            self._post_s += body["post_clean_s"]
            self.cache_hits += body["cache_hits"]
            self.cache_misses += body["cache_misses"]
            res = ShardResult(
                frame,
                parse_s=body["parse_s"],
                pre_clean_s=body["pre_clean_s"],
                clean_s=body["clean_s"],
                post_clean_s=body["post_clean_s"],
                cache_hits=body["cache_hits"],
                cache_misses=body["cache_misses"],
            )
            if self._postprocess is not None:
                res.payload = self._postprocess(frame)
            yield res

    @property
    def timings(self):
        from .plan import StageTimings

        return StageTimings(self._parse_s, self._pre_s, self._clean_s, self._post_s)

    def _drain_results(self) -> None:
        from multiprocessing import shared_memory

        try:
            while True:
                msg = self._result_q.get_nowait()
                if msg[0] == "ok":
                    try:
                        seg = shared_memory.SharedMemory(name=msg[2]["shm"])
                        seg.close()
                        seg.unlink()
                    except FileNotFoundError:
                        pass
                self._release_input(msg[1])
        except Exception:
            pass

    def stop(self) -> None:
        """Abandon remaining shards; safe after breaking out early.
        Idempotent."""
        if self._stopped.is_set():
            return
        self._stopped.set()
        self._inflight.release()  # unblock a parked feeder
        self._feeder.join(timeout=5.0)
        # Abandon queued tasks so workers reach their sentinels quickly
        # (the feeder's sentinels sit behind them in the queue).
        try:
            while True:
                task = self._task_q.get_nowait()
                if task is not None:
                    self._release_input(task[0])
        except Exception:
            pass
        for _ in self._procs:
            self._task_q.put(None)
        self._drain_results()
        for p in self._procs:
            p.join(timeout=2.0)
            if p.is_alive():
                p.terminate()
                p.join(timeout=2.0)
        # Results a worker managed to emit between the drains above.
        self._drain_results()
        from multiprocessing import shared_memory

        with self._seg_lock:
            leftover = list(self._in_segs.values())
            self._in_segs.clear()
        for name in leftover:
            try:
                seg = shared_memory.SharedMemory(name=name)
                seg.close()
                seg.unlink()
            except FileNotFoundError:
                pass


# ---------------------------------------------------------------------------
# Executor selection
# ---------------------------------------------------------------------------


def make_executor(
    shards: Sequence[str | Path],
    program: ShardProgram,
    *,
    workers: int = 2,
    cache_dir: str | Path | None = None,
    executor: str | None = None,
    postprocess=None,
):
    """Pick the physical shard executor.

    Explicit ``executor`` wins, then ``REPRO_EXECUTOR``, then the default:
    processes when ``workers > 1``, threads otherwise. Requests for the
    process executor fall back to threads — never error — when the program
    needs cross-shard dedup state, the platform lacks shared memory, or
    ``workers <= 1``.
    """
    choice = executor or os.environ.get("REPRO_EXECUTOR") or ""
    choice = choice.strip().lower()
    if choice not in ("", "thread", "process"):
        raise ValueError(f"unknown executor {choice!r}; use 'thread' or 'process'")
    explicit = bool(choice)
    if not choice:
        choice = "process" if workers > 1 else "thread"
    # More worker processes than cores only adds fork + scheduling cost;
    # clamp (the thread pool is unclamped — its readers overlap blocking
    # I/O, not CPU). When the *default* selection lands on one effective
    # worker the process executor is pure overhead, so fall back to
    # threads — but an explicit request (argument or REPRO_EXECUTOR, e.g.
    # the CI job exercising this path) is honored even on one core.
    n_proc = max(min(workers, os.cpu_count() or workers), 1)
    if choice == "process" and (
        workers <= 1
        or program.has_dedup
        or not shared_memory_available()
        or (n_proc <= 1 and not explicit)
    ):
        choice = "thread"
    if choice == "process" and "fork" not in mp.get_all_start_methods():
        # spawn-only platforms pickle the program into each worker; a plan
        # with a lambda predicate executes fine in-process, so degrade to
        # threads instead of crashing at Process.start().
        import pickle

        try:
            pickle.dumps(program)
        except Exception:
            choice = "thread"
    if choice == "process":
        return ProcessShardExecutor(
            shards, program, workers=n_proc, cache_dir=cache_dir,
            postprocess=postprocess,
        )
    return ThreadShardExecutor(
        shards, program, workers=workers, cache_dir=cache_dir,
        postprocess=postprocess,
    )
