"""Shard executors: process-parallel cleaning + plan-fingerprint caching.

The paper's cost argument (§3, eq. 7) assumes two Spark properties our
in-thread streaming path lacked: *true multi-worker execution* of the
cleaning stages and *reuse of already-computed results* (``persist()``).
This module supplies both behind the planner:

* :class:`ShardProgram` — the per-shard physical program compiled from the
  frame-level plan (parse → select/dropna/filter[/dedup] → per-column
  compiled expressions). Programs are picklable: compiled expressions are
  plain tuples over op descriptors (:mod:`repro.core.expr` /
  :mod:`repro.core.bytesops`), so the same program runs in a thread or in
  a worker process; ``filter`` steps evaluate predicates to row masks
  straight off the flat buffers (no decode).
* :class:`ThreadShardExecutor` — the existing in-thread path: a
  work-stealing :class:`~repro.core.async_loader.ShardPool` of reader
  threads, each running the full program per shard. Supports cross-shard
  ``drop_duplicates`` (shared keep-first state).
* :class:`ProcessShardExecutor` — worker *processes* with a shared task
  queue (self-scheduling == work stealing). Raw shard bytes travel to
  workers as shared-memory uint8 buffers; cleaned flat column buffers plus
  their row offsets travel back the same way, so no large pickles cross
  the pipe. Falls back to the thread executor when ``workers <= 1``, when
  the platform lacks POSIX shared memory, or when the program needs
  cross-shard state (``drop_duplicates``).
* :class:`ShardCache` — the ``persist()`` analogue: an on-disk cache of
  cleaned column buffers keyed by ``(shard bytes digest, column lineage
  fingerprint)``. Re-running an unchanged plan skips cleaning entirely;
  changing one column's ops recomputes only that column (other columns
  keep hitting). Corrupted entries are treated as misses, never errors.
* **Token space** — a program may carry a :class:`TokenPlan` (encode text
  columns to int32 token arrays inside the worker) and/or ``count_words``
  (per-shard word ``Counter`` for driver-merged vocabulary fitting, the
  Spark ``CountVectorizer`` fit half). Token arrays and word counts cache
  under their own keys — ``(shard digest, column lineage fingerprint,
  token-spec params, vocab fingerprint)`` — with invalidation independent
  of the cleaned-text entries, and a shard whose token products are fully
  cached skips parsing and cleaning altogether.

Executor selection, worker counts, cache roots, and bytes backends all
resolve through :class:`repro.core.engine_config.EngineConfig` (explicit
argument > builder verb > ``REPRO_*`` env knob > default).
"""

from __future__ import annotations

import atexit
import hashlib
import json
import multiprocessing as mp
import os
import tempfile
import threading
import time
import traceback
import dataclasses
from collections import Counter
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Iterator, Sequence

import numpy as np

from . import bytesops as B
from . import expr as E
from . import ingest as ing
from ..data.batching import TokenSpec, VocabTable, encode_flat, encode_rows
from .async_loader import ShardPool
from .engine_config import EngineConfig
from .frame import ColumnarFrame

# Vocabulary lookup tables are pure functions of the vocabulary (keyed by
# its content fingerprint); building one sorts the whole vocab, so reuse
# it across shards instead of rebuilding per shard x spec.
_VOCAB_TABLES: dict[str, VocabTable] = {}


def _vocab_table(tp: "TokenPlan") -> VocabTable:
    table = _VOCAB_TABLES.get(tp.vocab_fp)
    if table is None:
        if len(_VOCAB_TABLES) > 8:  # a worker only ever sees a few vocabs
            _VOCAB_TABLES.clear()
        table = VocabTable(tp.stoi)
        _VOCAB_TABLES[tp.vocab_fp] = table
    return table

# ---------------------------------------------------------------------------
# Shard program: the picklable per-shard physical plan
# ---------------------------------------------------------------------------

# Step kinds: ("select", cols) | ("dropna", cols) | ("dedup", cols)
#           | ("project", ((out_col, compiled_expr), ...))
#           | ("filter", compiled_pred)
#           | ("dedup_emit", cols)   pass 1 of two-pass dedup: emit per-row
#                                    key digests of ``cols`` (no row change)
#           | ("dedup_take", cols)   pass 2: keep only the executor-provided
#                                    canonical-survivor rows for this shard
# Compiled expressions/predicates are the plain-tuple programs of
# :mod:`repro.core.expr` — picklable, so the same program runs in a reader
# thread or a worker process.
Step = tuple[str, Any]

# Reserved token-space product name for two-pass dedup key digests: a
# ``(rows, 4)`` int32 view of 16-byte blake2b digests, so pass-1 keys ride
# the exact token-array transport and cache paths.
DEDUP_KEYS = "__dedup_keys__"


def _has_step(program: "ShardProgram", kind: str) -> bool:
    return any(k == kind for k, _ in program.steps)


def _dedup_key_digests(cols: Sequence[Sequence], n: int) -> np.ndarray:
    """Per-row 16-byte digests of the dedup-subset values, injectively
    serialized (type tag + length prefix), viewed as ``(n, 4)`` int32.
    Digest equality stands in for the value-tuple equality whole-frame
    ``drop_duplicates`` uses (blake2b-128: collisions are negligible
    against any real corpus size)."""
    out = np.empty((n, 4), dtype=np.int32)
    for i in range(n):
        h = hashlib.blake2b(digest_size=16)
        for col in cols:
            v = col[i]
            if v is None:
                b_ = b"\x00"
            elif isinstance(v, str):
                b_ = b"\x01" + v.encode("utf-8", "surrogatepass")
            elif isinstance(v, (bool, int, float)):
                # Match the Python equality classes the whole-frame
                # tuple-key dedup uses: True == 1 == 1.0 and 0.0 == -0.0
                # must serialize identically; NaN never equals anything,
                # so each occurrence gets a unique nonce.
                if v != v:  # NaN
                    # NaN never equals anything (whole-frame keeps every
                    # NaN row), so each occurrence gets a random nonce —
                    # unique across rows, shards, and cached passes.
                    b_ = b"\x03nan" + os.urandom(8)
                else:
                    try:
                        exact = float(v) == v
                    except OverflowError:  # int beyond float range
                        exact = False
                    if exact:
                        b_ = b"\x03" + repr(float(v) + 0.0).encode()
                    else:
                        b_ = b"\x03" + repr(int(v)).encode()
            else:
                b_ = b"\x02" + repr(v).encode("utf-8")
            h.update(len(b_).to_bytes(8, "little"))
            h.update(b_)
        out[i] = np.frombuffer(h.digest(), dtype=np.int32)
    return out


@dataclass(frozen=True)
class TokenPlan:
    """Token-space tail of a shard program: encode ``specs`` against a
    fixed word-index map. Plain dict + specs, so the plan pickles into
    worker processes like every other program part."""

    specs: tuple[TokenSpec, ...]
    stoi: dict[str, int]
    vocab_fp: str


@dataclass(frozen=True)
class ShardProgram:
    """Per-shard physical program: parse ``fields``, run ``steps``, emit
    ``output_columns`` (empty tuple = every live column). ``tokens``
    appends token encoding; ``count_words`` appends per-shard word
    counting (vocabulary fitting).

    ``backend`` is the bytesops execution backend the program's op chains
    run under (resolved at compile time from the explicit option or
    ``REPRO_BYTES_BACKEND``, so it travels — pickled with the program —
    to process-pool and remote workers whose environment may differ).
    Backends are byte-identical by contract, which is why the *cache*
    lineage fingerprints deliberately exclude it."""

    fields: tuple[str, ...]
    steps: tuple[Step, ...]
    output_columns: tuple[str, ...] = ()
    tokens: TokenPlan | None = None
    count_words: tuple[str, ...] = ()
    backend: str = "loops"

    @property
    def has_dedup(self) -> bool:
        return any(kind == "dedup" for kind, _ in self.steps)


class UnsupportedPlanError(ValueError):
    """The plan cannot be compiled to a per-shard program."""


def compile_shard_program(
    frame_nodes: Sequence[Any],
    *,
    optimize: bool = True,
    output_columns: Sequence[str] = (),
    tokens: TokenPlan | None = None,
    count_words: Sequence[str] = (),
    backend: str | None = None,
) -> ShardProgram:
    """Compile an (optimized) frame-level plan into a :class:`ShardProgram`.

    ``frame_nodes[0]`` must be a ``SourceJsonDirs``; ``Split`` is whole-frame
    only and rejected here.
    """
    from . import plan as P  # local import: plan.py imports this module

    src = frame_nodes[0]
    if not isinstance(src, P.SourceJsonDirs):
        raise UnsupportedPlanError("shard programs require a SourceJsonDirs source")
    steps: list[Step] = []
    for node in frame_nodes[1:]:
        if isinstance(node, P.Select):
            steps.append(("select", tuple(node.fields)))
        elif isinstance(node, P.DropNA):
            steps.append(("dropna", tuple(node.subset)))
        elif isinstance(node, P.DropDuplicates):
            steps.append(("dedup", tuple(node.subset)))
        elif isinstance(node, P.Project):
            steps.append(("project", E.compile_project(node.exprs, optimize)))
        elif isinstance(node, P.Filter):
            comp = E.compile_pred(node.pred)
            if optimize:
                comp = E.fuse_compiled(comp)
            steps.append(("filter", comp))
        else:
            raise UnsupportedPlanError(f"not shard-executable: {node.describe()}")
    return ShardProgram(
        tuple(src.fields),
        tuple(steps),
        tuple(output_columns),
        tokens=tokens,
        count_words=tuple(count_words),
        backend=EngineConfig().resolve_backend(backend),
    )


# ---------------------------------------------------------------------------
# Column lineage fingerprints (the plan half of the cache key)
# ---------------------------------------------------------------------------


def _lineage_fingerprints(
    program: ShardProgram,
) -> tuple[dict[int, dict[str, str]], dict[str, str]] | None:
    """Per-project-step, per-output-column lineage fingerprints.

    A column's fingerprint at a project step covers, in order, every
    earlier step that can change that step's output buffer for a given
    shard: the expressions along its own lineage and every row filter
    (``dropna`` / ``filter``) — including, transitively, the lineages of
    the columns the filter reads, since *their* values decide which rows
    survive. Keys are step indices into ``program.steps``: a column
    written by two project steps gets a *different* fingerprint at each,
    so the steps never alias one cache entry. ``{}``-valued / missing
    columns are uncacheable (e.g. a predicate that cannot be
    fingerprinted, such as a lambda). Returns None when the whole program
    is uncacheable: ``dedup`` holds cross-shard state, so a shard's output
    is not a pure function of (shard bytes, program) — and neither is a
    ``dedup_take`` shard, whose surviving rows are elected from the whole
    corpus. (``dedup_emit`` stays cacheable: the key digests are a pure
    per-shard function of the prefix.)
    """
    if program.has_dedup or _has_step(program, "dedup_take"):
        return None

    def h(sig: bytes) -> bytes:
        return hashlib.blake2b(sig, digest_size=16).digest()

    # None in ``lineage`` poisons a column: its value depends on something
    # we cannot fingerprint, so nothing derived from it may cache.
    lineage: dict[str, bytes | None] = {
        f: b"src:" + f.encode() for f in program.fields
    }

    def _row_filter_token(tag: bytes, cols: Sequence[str], extra: bytes) -> bytes | None:
        """Token mixed into every column's lineage by a row filter; None
        when any column the filter reads is poisoned."""
        bases = [lineage.get(c, b"src:" + c.encode()) for c in cols]
        if any(sig is None for sig in bases):
            return None
        return tag + extra + b"|" + b",".join(
            c.encode() + b"=" + sig for c, sig in zip(cols, bases)
        )

    per_step: dict[int, dict[str, str]] = {}
    for step_idx, (kind, arg) in enumerate(program.steps):
        if kind == "select":
            lineage = {c: lineage[c] for c in arg if c in lineage}
        elif kind in ("dropna", "filter"):
            if kind == "dropna":
                token = _row_filter_token(b"dropna:", arg, b"")
            else:
                try:
                    psig = E.compiled_signature(arg)
                except B.UnfingerprintableOpError:
                    token = None
                else:
                    token = _row_filter_token(
                        b"filter:", sorted(E.compiled_inputs(arg)), psig
                    )
            if token is None:
                # Unfingerprintable column/predicate decides the row set →
                # nothing downstream is a pure function of fingerprintable
                # state.
                lineage = {c: None for c in lineage}
                continue
            lineage = {
                c: h(sig + b"|" + token) if sig is not None else None
                for c, sig in lineage.items()
            }
        elif kind == "project":
            fps: dict[str, str] = {}
            for out_col, comp in arg:
                in_cols = sorted(E.compiled_inputs(comp))
                bases = [lineage.get(c, b"src:" + c.encode()) for c in in_cols]
                if any(b_ is None for b_ in bases):
                    lineage[out_col] = None
                    continue
                try:
                    esig = E.compiled_signature(comp)
                except B.UnfingerprintableOpError:
                    lineage[out_col] = None
                    continue
                sig = h(
                    b",".join(
                        c.encode() + b"=" + b_ for c, b_ in zip(in_cols, bases)
                    )
                    + b"|expr:"
                    + esig
                )
                lineage[out_col] = sig
                fps[out_col] = sig.hex()
            per_step[step_idx] = fps
    final = {c: sig.hex() for c, sig in lineage.items() if sig is not None}
    return per_step, final


def step_column_fingerprints(
    program: ShardProgram,
) -> dict[int, dict[str, str]] | None:
    """Cache-key fingerprints per clean step (see ``_lineage_fingerprints``)."""
    walked = _lineage_fingerprints(program)
    return None if walked is None else walked[0]


def column_fingerprints(program: ShardProgram) -> dict[str, str] | None:
    """End-of-program lineage fingerprint of every (fingerprintable)
    column. None when the program holds cross-shard state (dedup)."""
    walked = _lineage_fingerprints(program)
    return None if walked is None else walked[1]


def token_fingerprints(program: ShardProgram) -> dict[str, str] | None:
    """Cache-key fingerprint per token output: the source column's final
    lineage fingerprint (so any upstream op or filter change invalidates),
    the spec's own parameters (so changing one ``TokenSpec`` invalidates
    only that array), and the vocabulary fingerprint (so a refit
    invalidates token entries without touching cleaned-text entries).
    Missing entries mean that output is uncacheable; None disables token
    caching for the whole program (dedup / no token plan)."""
    if program.tokens is None:
        return None
    walked = _lineage_fingerprints(program)
    if walked is None:
        return None
    final = walked[1]
    out: dict[str, str] = {}
    for spec in program.tokens.specs:
        base = final.get(spec.column)
        if base is None:
            continue
        sig = (
            f"{base}|tok:{spec.column}->{spec.name}"
            f":{spec.max_len}:{spec.add_start_end}"
            f"|vocab:{program.tokens.vocab_fp}"
        )
        out[spec.name] = hashlib.blake2b(sig.encode(), digest_size=16).hexdigest()
    return out


def count_fingerprint(program: ShardProgram) -> str | None:
    """Cache-key fingerprint for a shard's word counts: the final lineage
    fingerprints of every counted column (the counts are a pure function
    of those buffers). None when counting is off or any column is
    uncacheable."""
    if not program.count_words:
        return None
    walked = _lineage_fingerprints(program)
    if walked is None:
        return None
    final = walked[1]
    parts = []
    for c in program.count_words:
        fp = final.get(c)
        if fp is None:
            return None
        parts.append(f"{c}={fp}")
    sig = "counts|" + "|".join(parts)
    return hashlib.blake2b(sig.encode(), digest_size=16).hexdigest()


def dedup_keys_fingerprint(program: ShardProgram) -> str | None:
    """Cache-key fingerprint for a shard's two-pass dedup key digests: the
    final lineage fingerprints of the subset columns (the keys are a pure
    function of those buffers and the surviving prefix rows). None when
    the program emits no keys or any subset column is uncacheable."""
    subset = next(
        (arg for kind, arg in program.steps if kind == "dedup_emit"), None
    )
    if subset is None:
        return None
    walked = _lineage_fingerprints(program)
    if walked is None:
        return None
    final = walked[1]
    parts = []
    for c in subset:
        fp = final.get(c)
        if fp is None:
            return None
        parts.append(f"{c}={fp}")
    sig = "dedupkeys|" + "|".join(parts)
    return hashlib.blake2b(sig.encode(), digest_size=16).hexdigest()


def split_dedup_programs(
    frame_nodes: Sequence[Any],
    *,
    optimize: bool = True,
    count_columns: Sequence[str] = (),
    output_columns: Sequence[str] | None = None,
    tokens: TokenPlan | None = None,
    backend: str | None = None,
) -> tuple[ShardProgram, ShardProgram]:
    """Compile the two programs of two-pass canonical-survivor dedup.

    The plan must hold exactly one ``DropDuplicates`` node. Pass 1 runs
    the plan prefix up to it — re-planned against the dedup subset, so
    transforms that only feed the counted columns are pruned away — and
    emits per-row key digests (``dedup_emit``). The driver merges the
    digests, electing the first occurrence in deterministic
    ``(shard index, row index)`` order — exactly the row whole-frame
    keep-first dedup retains. Pass 2 re-runs the full plan with the dedup
    step replaced by ``dedup_take`` of the elected survivor rows, so the
    stream stays a pure per-shard program (process-executor capable, no
    cross-shard mutable state) yet byte-identical to whole-frame.

    Pass 2's tail is configurable so both streaming terminals share the
    protocol: ``count_columns`` appends word counting (``fit_vocab``),
    ``tokens`` appends token encoding (``iter_batches``). By default the
    emitted columns are ``count_columns``; pass ``output_columns`` to
    override (e.g. the tokenize spec columns).
    """
    from . import plan as P

    idxs = [
        i for i, n in enumerate(frame_nodes) if isinstance(n, P.DropDuplicates)
    ]
    if len(idxs) != 1:
        # Build-time diagnostic (program compilation — nothing has spawned
        # yet), naming each offending Dedup node. The plan analyzer
        # (P005, repro.analysis) rejects this shape at validate time; this
        # is the compile-time backstop for direct callers.
        from ..analysis.diagnostics import (
            Diagnostic,
            PlanValidationError,
            node_ref,
        )

        provenance = tuple(node_ref(i, frame_nodes[i]) for i in idxs)
        raise PlanValidationError(
            [
                Diagnostic(
                    "P005",
                    f"two-pass dedup requires exactly one DropDuplicates "
                    f"node, found {len(idxs)}: a partial-subset "
                    "drop_duplicates cannot stack with another "
                    "drop_duplicates in a per-shard program",
                    provenance=provenance,
                )
            ]
        )
    j = idxs[0]
    subset = tuple(frame_nodes[j].subset)
    prefix = list(frame_nodes[:j])
    if optimize:
        prefix = P.optimize_plan(prefix, subset)
    pass1 = compile_shard_program(prefix, optimize=optimize, backend=backend)
    pass1 = dataclasses.replace(
        pass1, steps=pass1.steps + (("dedup_emit", subset),)
    )
    full = compile_shard_program(
        frame_nodes,
        optimize=optimize,
        output_columns=(
            count_columns if output_columns is None else output_columns
        ),
        tokens=tokens,
        count_words=count_columns,
        backend=backend,
    )
    steps2 = list(full.steps)
    if steps2[j - 1] != ("dedup", subset):  # nodes[1:] map 1:1 to steps
        raise UnsupportedPlanError(
            f"plan-to-step mapping drift: expected dedup at step {j - 1}, "
            f"found {steps2[j - 1]!r}"
        )
    steps2[j - 1] = ("dedup_take", subset)
    pass2 = dataclasses.replace(full, steps=tuple(steps2))
    return pass1, pass2


def elect_survivors(
    shards: Sequence[str | Path],
    pass1: ShardProgram,
    exec_kw: dict,
    stats: dict | None = None,
) -> dict[int, np.ndarray]:
    """Run pass 1 of two-pass dedup (see :func:`split_dedup_programs`)
    over every shard and keep, per key digest, the minimal ``(shard
    index, row index)`` occurrence — the row whole-frame keep-first dedup
    retains. Returns per-shard sorted survivor row indices (an entry for
    every shard, possibly empty), the ``row_filters`` input of
    :func:`make_executor`."""
    survivors: dict[bytes, tuple[int, int]] = {}
    exec1 = make_executor(shards, pass1, **exec_kw)
    try:
        for res in exec1:
            keys = res.tokens.get(DEDUP_KEYS)
            if keys is None or not len(keys):
                continue
            si = res.shard_index
            # Within-shard first occurrence per key is vectorized
            # (np.unique on the 16-byte digests); only the per-shard
            # uniques cross into the Python merge loop.
            voids = np.ascontiguousarray(keys).view(
                np.dtype((np.void, 16))
            ).reshape(-1)
            uniq, first = np.unique(voids, return_index=True)
            for k_void, ri in zip(uniq, first):
                k = k_void.tobytes()
                best = survivors.get(k)
                if best is None or (si, int(ri)) < best:
                    survivors[k] = (si, int(ri))
    finally:
        exec1.stop()
        if stats is not None:
            stats["token_cache_hits"] = (
                stats.get("token_cache_hits", 0) + exec1.token_cache_hits
            )
            stats["token_cache_misses"] = (
                stats.get("token_cache_misses", 0) + exec1.token_cache_misses
            )
    per_shard: dict[int, list[int]] = {i: [] for i in range(len(shards))}
    for si, ri in survivors.values():
        per_shard[si].append(ri)
    return {
        i: np.sort(np.asarray(rows, dtype=np.int64))
        for i, rows in per_shard.items()
    }


# ---------------------------------------------------------------------------
# On-disk shard cache (the Spark persist() analogue)
# ---------------------------------------------------------------------------


def default_cache_dir() -> Path:
    env = os.environ.get("REPRO_CACHE_DIR")
    if env:
        return Path(env)
    return Path(tempfile.gettempdir()) / "repro_shard_cache"


class ShardCache:
    """Content-addressed store of cleaned column buffers, token arrays,
    and per-shard word counts.

    One ``.npy`` file per (shard digest, column, lineage fingerprint).
    Writes are atomic (tmp + rename); reads treat any malformed entry as a
    miss and delete it, so a corrupted cache degrades to recompute. Entry
    kinds never alias: text entries are 1-D uint8 flat buffers, token
    entries are 2-D int32 arrays, counts are JSON-encoded uint8 — and the
    loaders validate shape/dtype, so a key collision across kinds reads as
    a miss rather than garbage.
    """

    def __init__(self, root: str | Path | None = None):
        self.root = Path(root) if root is not None else default_cache_dir()
        self.root.mkdir(parents=True, exist_ok=True)

    def key(self, shard_digest: str, column: str, column_fp: str) -> str:
        return hashlib.blake2b(
            f"{shard_digest}:{column}:{column_fp}".encode(), digest_size=16
        ).hexdigest()

    def _path(self, key: str) -> Path:
        return self.root / f"{key}.npy"

    def load(self, key: str) -> np.ndarray | None:
        path = self._path(key)
        try:
            buf = np.load(path, allow_pickle=False)
            if buf.dtype != np.uint8 or buf.ndim != 1:
                raise ValueError("wrong cache payload shape")
            return buf
        except FileNotFoundError:
            return None
        except Exception:
            # Corrupted entry (truncated write, garbage, wrong format):
            # recompute instead of crashing, and drop the bad file.
            try:
                path.unlink()
            except OSError:
                pass
            return None

    def contains(self, key: str) -> bool:
        """Existence probe (no validation) — used for cheap driver-side
        fast-path checks; loaders still validate on read."""
        return self._path(key).exists()

    def load_tokens(self, key: str, max_len: int) -> np.ndarray | None:
        """Load a token-array entry ((rows, max_len) int32); corrupt or
        wrong-shape entries degrade to a miss."""
        path = self._path(key)
        try:
            arr = np.load(path, allow_pickle=False)
            if arr.dtype != np.int32 or arr.ndim != 2 or arr.shape[1] != max_len:
                raise ValueError("wrong token cache payload shape")
            return arr
        except FileNotFoundError:
            return None
        except Exception:
            try:
                path.unlink()
            except OSError:
                pass
            return None

    def load_counts(self, key: str) -> Counter | None:
        buf = self.load(key)
        if buf is None:
            return None
        try:
            return Counter(json.loads(buf.tobytes().decode("utf-8")))
        except Exception:
            try:
                self._path(key).unlink()
            except OSError:
                pass
            return None

    def store_counts(self, key: str, counts: Counter) -> None:
        try:
            data = json.dumps(dict(counts), ensure_ascii=False).encode("utf-8")
        except (TypeError, ValueError, UnicodeEncodeError):
            return  # unserializable corner (lone surrogates): skip caching
        self.store(key, np.frombuffer(data, dtype=np.uint8))

    def store(self, key: str, buf: np.ndarray) -> None:
        path = self._path(key)
        try:
            fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
            try:
                with os.fdopen(fd, "wb") as fh:
                    np.save(fh, buf, allow_pickle=False)
                os.replace(tmp, path)
            finally:
                if os.path.exists(tmp):
                    os.unlink(tmp)
        except OSError:
            pass  # cache is best-effort; never fail the pipeline


# ---------------------------------------------------------------------------
# Program execution (shared by thread and process workers)
# ---------------------------------------------------------------------------


@dataclass
class ShardResult:
    """One processed shard: the cleaned frame plus execution accounting.

    For token-space programs ``tokens`` holds the int32 arrays (one per
    ``TokenSpec``) and ``word_counts`` the shard's word ``Counter`` — the
    frame may then be empty (the process executor ships only token
    buffers, and a fully token-cached shard skips parsing entirely)."""

    frame: ColumnarFrame
    parse_s: float = 0.0
    pre_clean_s: float = 0.0
    clean_s: float = 0.0
    post_clean_s: float = 0.0
    tokenize_s: float = 0.0
    cache_hits: int = 0
    cache_misses: int = 0
    token_cache_hits: int = 0
    token_cache_misses: int = 0
    tokens: dict = dataclasses.field(default_factory=dict)
    word_counts: Counter | None = None
    # Flat buffers not yet folded into ``frame`` (materialize=False only).
    flat: dict = dataclasses.field(default_factory=dict)
    # Which shard (position in the executor's shard list) produced this
    # result — arrival order is nondeterministic under work stealing, so
    # consumers that need a deterministic ordering (two-pass dedup
    # election) key on this instead.
    shard_index: int = -1


class GlobalDedup:
    """Thread-safe keep-first dedup across shards (stream arrival order)."""

    def __init__(self, subset: tuple[str, ...]):
        self.subset = subset
        self._seen: set = set()
        self._lock = threading.Lock()

    def keep_mask(self, frame: ColumnarFrame) -> np.ndarray:
        cols = [frame[f] for f in self.subset]
        n = len(frame)
        # Build keys outside the lock so reader threads only serialize on
        # the set membership check, not the per-row tuple construction.
        keys = [tuple(c[i] for c in cols) for i in range(n)]
        keep = np.ones(n, dtype=bool)
        with self._lock:
            for i, key in enumerate(keys):
                if key in self._seen:
                    keep[i] = False
                else:
                    self._seen.add(key)
        return keep

    def filter(self, frame: ColumnarFrame) -> ColumnarFrame:
        return frame.take(self.keep_mask(frame))


# -- flat-buffer row ops (cleaned columns stay flat through the program) ----


def _flat_take(buf: np.ndarray, keep: np.ndarray) -> np.ndarray:
    """Row-filter a flat buffer without decoding it."""
    if buf.size == 0 or keep.all():
        return buf
    return buf[np.repeat(keep, B.row_lengths(buf))]


def _run_project_step(
    n: int,
    flat: dict[str, np.ndarray],
    lookup,
    entries: Sequence[tuple[str, tuple]],
    cache: ShardCache | None,
    step_fps: dict[str, str] | None,
    digest: str | None,
    result: ShardResult,
    backend: str = "loops",
) -> None:
    """Run one Project step's compiled expressions over flat buffers, one
    cache lookup per output column. A hit replaces the expression with a
    disk read; a miss (including a corrupt or row-count-stale entry)
    recomputes just that column and rewrites the entry, so
    partially-changed plans only pay for the columns whose lineage
    actually changed."""
    cacheable = cache is not None and step_fps is not None and digest is not None

    for out_col, comp in entries:
        if comp[0] == "chain" and not comp[2]:
            # Pure alias (a CSE consumer whose whole chain was hoisted):
            # share the memoized buffer; no lookup, no hit/miss counted.
            flat[out_col] = lookup(comp[1])
            continue
        key = None
        if cacheable:
            fp = step_fps.get(out_col)
            key = cache.key(digest, out_col, fp) if fp else None
            hit = cache.load(key) if key else None
            if hit is not None and B.n_rows(hit) == n:
                flat[out_col] = hit
                result.cache_hits += 1
                continue
        out = E.eval_str(comp, lookup, n, backend)
        flat[out_col] = out
        if key:
            # Uncacheable columns (key None) count neither hit nor miss:
            # no lookup happened, and a warm run should still report 100%.
            result.cache_misses += 1
            cache.store(key, out)


def _cached_product_keys(
    program: ShardProgram,
    cache: ShardCache | None,
    token_fps: dict[str, str] | None,
    count_fp: str | None,
    digest: str | None,
    dedup_fp: str | None = None,
) -> list[str] | None:
    """Cache keys of every token-space product the program emits, or None
    when the program/cache cannot serve a shard from cache at all."""
    if cache is None or digest is None:
        return None
    emits_keys = _has_step(program, "dedup_emit")
    if program.tokens is None and not program.count_words and not emits_keys:
        return None
    keys: list[str] = []
    if program.tokens is not None:
        if not token_fps or set(token_fps) != {s.name for s in program.tokens.specs}:
            return None
        keys += [
            cache.key(digest, spec.name, token_fps[spec.name])
            for spec in program.tokens.specs
        ]
    if program.count_words:
        if count_fp is None:
            return None
        keys.append(cache.key(digest, "__word_counts__", count_fp))
    if emits_keys:
        if dedup_fp is None:
            return None
        keys.append(cache.key(digest, DEDUP_KEYS, dedup_fp))
    return keys


def products_fully_cached(
    program: ShardProgram,
    cache: ShardCache | None,
    token_fps: dict[str, str] | None,
    count_fp: str | None,
    digest: str,
    dedup_fp: str | None = None,
) -> bool:
    """Cheap existence probe for the full-shard fast path (the process
    executor's feeder uses it to skip the shared-memory copy entirely)."""
    keys = _cached_product_keys(
        program, cache, token_fps, count_fp, digest, dedup_fp
    )
    return keys is not None and all(cache.contains(k) for k in keys)


def _load_cached_products(
    program: ShardProgram,
    cache: ShardCache | None,
    token_fps: dict[str, str] | None,
    count_fp: str | None,
    digest: str | None,
    dedup_fp: str | None = None,
) -> ShardResult | None:
    """Serve a shard entirely from the token-space cache: when every
    product the program emits (all token arrays, the word counts, the
    two-pass dedup key digests) is cached under the current fingerprints,
    the shard needs no parse, no cleaning, and no encode. None → run the
    program normally."""
    if cache is None or digest is None:
        return None
    emits_keys = _has_step(program, "dedup_emit")
    if program.tokens is None and not program.count_words and not emits_keys:
        return None
    tokens: dict[str, np.ndarray] = {}
    hits = 0
    n: int | None = None
    if program.tokens is not None:
        if not token_fps or set(token_fps) != {s.name for s in program.tokens.specs}:
            return None
        for spec in program.tokens.specs:
            key = cache.key(digest, spec.name, token_fps[spec.name])
            arr = cache.load_tokens(key, spec.max_len)
            if arr is None or (n is not None and len(arr) != n):
                return None  # partial/inconsistent: recompute the shard
            n = len(arr)
            tokens[spec.name] = arr
        hits += len(tokens)
    counts: Counter | None = None
    if program.count_words:
        if count_fp is None:
            return None
        counts = cache.load_counts(cache.key(digest, "__word_counts__", count_fp))
        if counts is None:
            return None
        hits += 1
    if emits_keys:
        if dedup_fp is None:
            return None
        arr = cache.load_tokens(cache.key(digest, DEDUP_KEYS, dedup_fp), 4)
        if arr is None:
            return None
        tokens[DEDUP_KEYS] = arr
        hits += 1
    result = ShardResult(ColumnarFrame({}))
    result.tokens = tokens
    result.word_counts = counts
    result.token_cache_hits = hits
    return result


def execute_program(
    frame: ColumnarFrame,
    program: ShardProgram,
    *,
    dedups: dict[int, GlobalDedup] | None = None,
    cache: ShardCache | None = None,
    col_fps: dict[int, dict[str, str]] | None = None,
    token_fps: dict[str, str] | None = None,
    count_fp: str | None = None,
    dedup_fp: str | None = None,
    digest: str | None = None,
    row_take: np.ndarray | None = None,
    materialize: bool = True,
) -> ShardResult:
    """Run every step of ``program`` on one parsed shard frame.

    Cleaned columns live as *flat* byte buffers from their op chain until
    the very end — row filters apply straight to the buffers — so no
    decode/re-encode round trip happens inside the program; token encoding
    and word counting read the surviving rows straight off those buffers.
    With ``materialize=False`` the buffers are left in ``result.flat`` for
    zero-copy transport (the process executor ships them via shared
    memory); ``materialize=True`` folds them back into the frame.
    """
    result = ShardResult(frame)
    flat: dict[str, np.ndarray] = {}
    # Raw source columns flatten at most once; the memo is row-filtered in
    # lockstep with ``flat`` so filters never force a re-flatten either.
    src_flat: dict[str, np.ndarray] = {}

    def lookup(c: str) -> np.ndarray:
        if c in flat:
            return flat[c]
        if c not in src_flat:
            src_flat[c] = frame.flat(c)
        return src_flat[c]

    def take_rows(keep: np.ndarray) -> None:
        nonlocal frame, flat, src_flat
        if keep.all():
            return
        frame = frame.take(keep)
        flat = {c: _flat_take(b, keep) for c, b in flat.items()}
        src_flat = {c: _flat_take(b, keep) for c, b in src_flat.items()}

    seen_clean = False
    for step_idx, (kind, arg) in enumerate(program.steps):
        t0 = time.perf_counter()
        if kind == "select":
            for c in arg:  # flat-only columns need a frame slot to survive
                if c in flat and c not in frame.columns:
                    frame = frame.ensure_column(c)
            frame = frame.select([c for c in arg if c in frame.columns])
            flat = {c: b for c, b in flat.items() if c in arg}
            src_flat = {c: b for c, b in src_flat.items() if c in arg}
        elif kind == "dropna":
            keep = np.ones(len(frame), dtype=bool)
            for c in arg:
                if c in flat:
                    keep &= B.row_nonempty(flat[c])
                else:
                    col = frame[c]
                    keep &= np.array(
                        [v is not None and v != "" for v in col], dtype=bool
                    )
            take_rows(keep)
        elif kind == "filter":
            take_rows(E.eval_mask(arg, lookup, len(frame), program.backend))
        elif kind == "dedup":
            if dedups is None:
                raise UnsupportedPlanError(
                    "dedup step requires executor-provided cross-shard state"
                )
            # Dedup compares real values: decode any flat subset column
            # back into the frame first (dedup plans are thread-only and
            # uncacheable, so this is the status-quo cost).
            for c in dedups[step_idx].subset:
                if c in flat:
                    frame = frame.ensure_column(c).with_flat(c, flat.pop(c))
                    src_flat.pop(c, None)
            keep = dedups[step_idx].keep_mask(frame)
            take_rows(keep)
        elif kind == "dedup_emit":
            # Pass 1 of two-pass dedup: per-row key digests of the subset
            # columns at this point (rows unchanged). Cacheable — the
            # digests are a pure per-shard function of the prefix.
            keys_arr = None
            key = None
            if cache is not None and dedup_fp is not None and digest is not None:
                key = cache.key(digest, DEDUP_KEYS, dedup_fp)
                keys_arr = cache.load_tokens(key, 4)
                if keys_arr is not None and len(keys_arr) == len(frame):
                    result.token_cache_hits += 1
                else:
                    keys_arr = None
            if keys_arr is None:
                vals = [
                    B.unflatten(flat[c]) if c in flat else list(frame[c])
                    for c in arg
                ]
                keys_arr = _dedup_key_digests(vals, len(frame))
                if key:
                    result.token_cache_misses += 1
                    cache.store(key, keys_arr)
            result.tokens[DEDUP_KEYS] = keys_arr
        elif kind == "dedup_take":
            # Pass 2: keep exactly the canonical-survivor rows the driver
            # elected for this shard (row indices at this plan point).
            if row_take is None:
                raise UnsupportedPlanError(
                    "dedup_take step requires executor-provided survivor rows"
                )
            keep = np.zeros(len(frame), dtype=bool)
            keep[np.asarray(row_take, dtype=np.int64)] = True
            take_rows(keep)
        elif kind == "project":
            step_fps = col_fps.get(step_idx) if col_fps is not None else None
            _run_project_step(
                len(frame), flat, lookup, arg, cache, step_fps, digest, result,
                program.backend,
            )
        dt = time.perf_counter() - t0
        if kind == "project":
            seen_clean = True
            result.clean_s += dt
        elif seen_clean:
            result.post_clean_s += dt
        else:
            result.pre_clean_s += dt
    if program.output_columns:
        live = set(program.output_columns)
        for c in live:
            if c in flat and c not in frame.columns:
                frame = frame.ensure_column(c)
        frame = frame.select([c for c in frame.columns if c in live])
        flat = {c: b for c, b in flat.items() if c in live}

    # -- token space: encode + count on the surviving rows ------------------
    if program.tokens is not None or program.count_words:
        rows_memo: dict[str, list] = {}

        def rows_of(col: str) -> list:
            if col not in rows_memo:
                if col in flat:
                    rows_memo[col] = B.unflatten(flat[col])
                else:
                    rows_memo[col] = list(frame[col])
            return rows_memo[col]

        t0 = time.perf_counter()
        n = len(frame)
        if program.tokens is not None:
            tp = program.tokens
            table = _vocab_table(tp)
            for spec in tp.specs:
                key = None
                if cache is not None and token_fps is not None and digest is not None:
                    fp = token_fps.get(spec.name)
                    key = cache.key(digest, spec.name, fp) if fp else None
                    if key:
                        hit = cache.load_tokens(key, spec.max_len)
                        if hit is not None and len(hit) == n:
                            result.tokens[spec.name] = hit
                            result.token_cache_hits += 1
                            continue
                if spec.column in flat:
                    # Cleaned columns encode straight off their flat byte
                    # buffer — no unflatten, no per-row Python.
                    arr = encode_flat(
                        flat[spec.column], table, spec.max_len, spec.add_start_end
                    )
                else:
                    arr = encode_rows(
                        rows_of(spec.column), tp.stoi, spec.max_len,
                        spec.add_start_end, table=table,
                    )
                result.tokens[spec.name] = arr
                if key:
                    result.token_cache_misses += 1
                    cache.store(key, arr)
        if program.count_words:
            counts = None
            key = None
            if cache is not None and count_fp is not None and digest is not None:
                key = cache.key(digest, "__word_counts__", count_fp)
                counts = cache.load_counts(key)
                if counts is not None:
                    result.token_cache_hits += 1
            if counts is None:
                counts = Counter()
                for col in program.count_words:
                    for t in rows_of(col):
                        counts.update((t or "").split())
                if key:
                    result.token_cache_misses += 1
                    cache.store_counts(key, counts)
            result.word_counts = counts
        result.tokenize_s += time.perf_counter() - t0

    if materialize:
        for c, b in flat.items():
            frame = frame.ensure_column(c).with_flat(c, b)
        flat = {}
    result.frame = frame
    result.flat = flat
    return result


# ---------------------------------------------------------------------------
# Thread executor (the ShardPool path, now program-driven)
# ---------------------------------------------------------------------------


class ThreadShardExecutor:
    """Work-stealing reader threads, one full program run per shard.

    The only executor that supports cross-shard ``drop_duplicates`` (the
    keep-first set lives in this process).
    """

    name = "thread"

    def __init__(
        self,
        shards: Sequence[str | Path],
        program: ShardProgram,
        *,
        workers: int = 2,
        cache_dir: str | Path | None = None,
        row_filters: dict[int, np.ndarray] | None = None,
    ):
        self.program = program
        self.cache_hits = 0
        self.cache_misses = 0
        self.token_cache_hits = 0
        self.token_cache_misses = 0
        self._cache = ShardCache(cache_dir) if cache_dir is not None else None
        self._col_fps = step_column_fingerprints(program) if self._cache else None
        self._token_fps = token_fingerprints(program) if self._cache else None
        self._count_fp = count_fingerprint(program) if self._cache else None
        self._dedup_fp = dedup_keys_fingerprint(program) if self._cache else None
        self._row_filters = row_filters
        self._shard_idx = {Path(s): i for i, s in enumerate(shards)}
        self._dedups = {
            i: GlobalDedup(arg)
            for i, (kind, arg) in enumerate(program.steps)
            if kind == "dedup"
        }
        self._agg_lock = threading.Lock()
        self._parse_s = self._pre_s = self._clean_s = self._post_s = 0.0
        self._tokenize_s = 0.0
        self._pool = ShardPool(
            shards, self._process, n_readers=max(int(workers), 1)
        )

    def _process(self, path: Path) -> ShardResult:
        idx = self._shard_idx[path]
        t0 = time.perf_counter()
        if self._cache is not None:
            data, digest = ing.read_shard_bytes(path)
            fast = _load_cached_products(
                self.program, self._cache, self._token_fps, self._count_fp,
                digest, self._dedup_fp,
            )
            if fast is not None:
                fast.parse_s = time.perf_counter() - t0
                fast.shard_index = idx
                return fast
            frame = ing.parse_shard_bytes(data, self.program.fields)
        else:
            digest = None
            frame = ing.parse_shard(path, self.program.fields)
        parse_s = time.perf_counter() - t0
        res = execute_program(
            frame,
            self.program,
            dedups=self._dedups,
            cache=self._cache,
            col_fps=self._col_fps,
            token_fps=self._token_fps,
            count_fp=self._count_fp,
            dedup_fp=self._dedup_fp,
            digest=digest,
            row_take=(
                self._row_filters.get(idx)
                if self._row_filters is not None
                else None
            ),
            # Token/count/key products are the output; folding flat buffers
            # back into the frame would be wasted decode work.
            materialize=(
                self.program.tokens is None
                and not self.program.count_words
                and not _has_step(self.program, "dedup_emit")
            ),
        )
        res.parse_s = parse_s
        res.shard_index = idx
        return res

    def _account(self, res: ShardResult) -> None:
        with self._agg_lock:
            self._parse_s += res.parse_s
            self._pre_s += res.pre_clean_s
            self._clean_s += res.clean_s
            self._post_s += res.post_clean_s
            self._tokenize_s += res.tokenize_s
            self.cache_hits += res.cache_hits
            self.cache_misses += res.cache_misses
            self.token_cache_hits += res.token_cache_hits
            self.token_cache_misses += res.token_cache_misses

    @property
    def timings(self):
        from .plan import StageTimings

        return StageTimings(
            self._parse_s, self._pre_s, self._clean_s, self._post_s, self._tokenize_s
        )

    def __iter__(self) -> Iterator[ShardResult]:
        for res in self._pool:
            self._account(res)
            yield res

    def stop(self) -> None:
        self._pool.stop()


# ---------------------------------------------------------------------------
# Process executor (shared-memory transport, self-scheduling workers)
# ---------------------------------------------------------------------------


def shared_memory_available() -> bool:
    try:
        from multiprocessing import shared_memory

        seg = shared_memory.SharedMemory(create=True, size=16)
        seg.close()
        seg.unlink()
        return True
    except Exception:  # pragma: no cover - platform without /dev/shm
        return False


def _utf8_roundtrips(v: str) -> bool:
    """False for strings flatten() would mangle (lone surrogates from the
    stdlib-json fallback): those must ride the obj_rows side channel so
    the process executor stays value-identical with the thread path."""
    try:
        v.encode("utf-8")
        return "\x00" not in v
    except UnicodeEncodeError:
        return False


def _pack_columns(
    frame: ColumnarFrame, flat: dict[str, np.ndarray], columns: Sequence[str]
) -> tuple[bytes, list[dict]]:
    """Pack columns as (flat uint8 bytes + int64 row-end offsets) sections.

    Cleaned columns ship their program-output buffer as-is (no re-encode);
    untouched columns flatten here and carry their non-string originals
    (None, numbers, …) in the metadata so the round trip is value-exact —
    the thread and whole-frame executors never coerce those."""
    parts: list[bytes] = []
    metas: list[dict] = []
    pos = 0
    for col in columns:
        if col in flat:
            buf = flat[col]
            obj_rows: list[tuple[int, Any]] = []  # op output is always a string
        else:
            buf = frame.flat(col)
            obj_rows = [
                (i, v)
                for i, v in enumerate(frame[col])
                if not isinstance(v, str) or not _utf8_roundtrips(v)
            ]
        offsets = np.flatnonzero(buf == B.ROW_SEP).astype(np.int64)
        raw = buf.tobytes()
        offs = offsets.tobytes()
        metas.append(
            {
                "name": col,
                "buf_off": pos,
                "buf_len": len(raw),
                "offs_off": pos + len(raw),
                "n_rows": int(offsets.size),
                "obj_rows": obj_rows,
            }
        )
        parts.append(raw)
        parts.append(offs)
        pos += len(raw) + len(offs)
    return b"".join(parts), metas


def _unpack_columns(payload: memoryview, metas: list[dict]) -> ColumnarFrame:
    cols: dict[str, np.ndarray] = {}
    for m in metas:
        raw = bytes(payload[m["buf_off"] : m["buf_off"] + m["buf_len"]])
        offsets = np.frombuffer(
            payload, dtype=np.int64, count=m["n_rows"], offset=m["offs_off"]
        )
        starts = np.concatenate(([0], offsets[:-1] + 1)) if m["n_rows"] else []
        rows: list = [
            raw[s:e].decode("utf-8", errors="ignore")
            for s, e in zip(starts, offsets)
        ]
        for i, v in m["obj_rows"]:
            rows[i] = v
        cols[m["name"]] = np.array(rows, dtype=object)
    return ColumnarFrame(cols)


def _pack_tokens(
    payload: bytes, tokens: dict[str, np.ndarray]
) -> tuple[bytes, list[dict]]:
    """Append int32 token arrays to a payload as 8-byte-aligned raw
    sections (metadata records name/offset/shape)."""
    buf = bytearray(payload)
    metas: list[dict] = []
    for name, arr in tokens.items():
        buf += b"\x00" * ((-len(buf)) % 8)
        metas.append(
            {
                "name": name,
                "off": len(buf),
                "rows": int(arr.shape[0]),
                "width": int(arr.shape[1]),
            }
        )
        buf += np.ascontiguousarray(arr, dtype=np.int32).tobytes()
    return bytes(buf), metas


def _unpack_tokens(payload: memoryview, metas: list[dict]) -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}
    for m in metas:
        arr = np.frombuffer(
            payload, dtype=np.int32, count=m["rows"] * m["width"], offset=m["off"]
        ).reshape(m["rows"], m["width"])
        out[m["name"]] = arr.copy()  # the shm segment is unlinked after
    return out


def program_fingerprint(program: ShardProgram) -> str:
    """Content fingerprint of a compiled shard program. The remote data
    plane keys result dedup on ``(shard_index, program_fingerprint)``: a
    shard re-leased after a worker death is byte-identical work, so the
    first result under the pair wins and any late duplicate is dropped."""
    import pickle

    return hashlib.blake2b(
        pickle.dumps(program, protocol=4), digest_size=16
    ).hexdigest()


class ProgramContext:
    """Per-process execution state for one compiled program: the shard
    cache handle plus every derived fingerprint, computed once per worker
    instead of once per shard. Both the multiprocessing worker
    (:func:`_worker_main`) and the remote TCP worker
    (:mod:`repro.distributed.worker`) drive shards through :meth:`run`."""

    def __init__(self, program: ShardProgram, cache_dir: str | Path | None):
        self.program = program
        self.cache = ShardCache(cache_dir) if cache_dir is not None else None
        has_cache = self.cache is not None
        self.col_fps = step_column_fingerprints(program) if has_cache else None
        self.token_fps = token_fingerprints(program) if has_cache else None
        self.count_fp = count_fingerprint(program) if has_cache else None
        self.dedup_fp = dedup_keys_fingerprint(program) if has_cache else None
        self.token_space = (
            program.tokens is not None
            or bool(program.count_words)
            or _has_step(program, "dedup_emit")
        )

    def run(
        self,
        data: bytes | None,
        path: str | Path | None,
        digest: str | None,
        row_take: np.ndarray | None,
    ) -> ShardResult:
        """Execute the program on one shard: serve fully-cached products
        without parsing when possible, else parse ``data`` (read from
        ``path`` when ``data`` is None — the fully-cached fast path's rare
        fallback) and run every step. Wall time not attributed to a
        specific stage lands in ``parse_s``."""
        t0 = time.perf_counter()
        res = _load_cached_products(
            self.program, self.cache, self.token_fps, self.count_fp, digest,
            self.dedup_fp,
        )
        if res is None:
            if data is None:
                with open(path, "rb") as fh:
                    data = fh.read()
            frame = ing.parse_shard_bytes(data, self.program.fields)
            res = execute_program(
                frame,
                self.program,
                cache=self.cache,
                col_fps=self.col_fps,
                token_fps=self.token_fps,
                count_fp=self.count_fp,
                dedup_fp=self.dedup_fp,
                digest=digest,
                row_take=row_take,
                materialize=False,
            )
        res.parse_s = time.perf_counter() - t0 - res.tokenize_s - (
            res.pre_clean_s + res.clean_s + res.post_clean_s
        )
        return res


def pack_shard_result(res: ShardResult, *, token_space: bool) -> tuple[dict, bytes]:
    """Serialize one :class:`ShardResult` into the executor wire format:
    flat column sections (:func:`_pack_columns`) followed by 8-byte-aligned
    int32 token sections (:func:`_pack_tokens`), with a metadata dict
    carrying section offsets, counters, and timings. The identical bytes
    ride a shared-memory segment (:class:`ProcessShardExecutor`) or a TCP
    frame (:mod:`repro.distributed.transport`)."""
    if token_space:
        # Token arrays / counts are the product; text columns stay in the
        # worker instead of riding the transport for nothing.
        payload, metas = b"", []
    else:
        out_cols = list(dict.fromkeys(list(res.frame.columns) + list(res.flat)))
        payload, metas = _pack_columns(res.frame, res.flat, out_cols)
    payload, tok_metas = _pack_tokens(payload, res.tokens)
    meta = {
        "size": len(payload),
        "columns": metas,
        "tokens": tok_metas,
        "word_counts": (
            dict(res.word_counts) if res.word_counts is not None else None
        ),
        "parse_s": res.parse_s,
        "pre_clean_s": res.pre_clean_s,
        "clean_s": res.clean_s,
        "post_clean_s": res.post_clean_s,
        "tokenize_s": res.tokenize_s,
        "cache_hits": res.cache_hits,
        "cache_misses": res.cache_misses,
        "token_cache_hits": res.token_cache_hits,
        "token_cache_misses": res.token_cache_misses,
    }
    return meta, payload


def unpack_shard_result(meta: dict, payload: memoryview) -> ShardResult:
    """Driver-side inverse of :func:`pack_shard_result`; ``payload`` may be
    a shared-memory view or a received TCP frame."""
    res = ShardResult(
        _unpack_columns(payload, meta["columns"]),
        parse_s=meta["parse_s"],
        pre_clean_s=meta["pre_clean_s"],
        clean_s=meta["clean_s"],
        post_clean_s=meta["post_clean_s"],
        tokenize_s=meta.get("tokenize_s", 0.0),
        cache_hits=meta["cache_hits"],
        cache_misses=meta["cache_misses"],
        token_cache_hits=meta.get("token_cache_hits", 0),
        token_cache_misses=meta.get("token_cache_misses", 0),
    )
    res.tokens = _unpack_tokens(payload, meta.get("tokens", []))
    counts = meta.get("word_counts")
    res.word_counts = Counter(counts) if counts is not None else None
    return res


def _out_seg_name(run_id: str, task_id: int) -> str:
    """Deterministic name for a worker's output segment: the driver can
    sweep orphans left by a worker that died between creating the segment
    and delivering its name (SIGKILL, OOM) without ever learning the name
    from the worker."""
    return f"repro_{run_id}_{task_id}"


def _unlink_segment(name: str) -> None:
    from multiprocessing import shared_memory

    try:
        seg = shared_memory.SharedMemory(name=name)
        seg.close()
        seg.unlink()
    except FileNotFoundError:
        pass


def _worker_main(task_q, result_q, program: ShardProgram, cache_dir, run_id) -> None:
    """Worker process: pull (task_id, shm_name, meta, digest, row_take)
    tasks until sentinel. ``meta`` is the byte count of the shared-memory
    segment — or, when ``shm_name`` is None (feeder's fully-cached fast
    path, no shm copy made), the shard's file path for the rare fallback
    re-read (an entry vanished or corrupted between probe and load).
    ``row_take`` is the shard's canonical-survivor rows for a
    ``dedup_take`` program (None otherwise)."""
    from multiprocessing import shared_memory

    ctx = ProgramContext(program, cache_dir)
    while True:
        task = task_q.get()
        if task is None:
            break
        task_id, shm_name, meta, digest, row_take = task
        out = None
        delivered = False
        try:
            if shm_name is None:
                data, path = None, meta
            else:
                path = None
                seg = shared_memory.SharedMemory(name=shm_name)
                try:
                    data = bytes(seg.buf[:meta])
                finally:
                    seg.close()
            res = ctx.run(data, path, digest, row_take)
            body, payload = pack_shard_result(res, token_space=ctx.token_space)
            name = _out_seg_name(run_id, task_id)
            try:
                out = shared_memory.SharedMemory(
                    create=True, size=max(len(payload), 1), name=name
                )
            except FileExistsError:
                # Stale block from a crashed earlier run that collided on
                # the id: reclaim it.
                _unlink_segment(name)
                out = shared_memory.SharedMemory(
                    create=True, size=max(len(payload), 1), name=name
                )
            out.buf[: len(payload)] = payload
            body["shm"] = out.name
            out.close()
            result_q.put(("ok", task_id, body))
            delivered = True
        except BaseException:
            result_q.put(("err", task_id, traceback.format_exc()))
        finally:
            if out is not None and not delivered:
                # The driver never learned this segment's name; unlink it
                # here or the block outlives the run.
                try:
                    out.unlink()
                except FileNotFoundError:
                    pass


class ProcessShardExecutor:
    """Worker processes pulling shards from a shared queue (work stealing).

    Transport is shared memory in both directions: the feeder thread reads
    each shard once (digesting as it reads), places the raw bytes in a
    segment, and workers return cleaned flat column buffers + row offsets
    in a segment of their own. In-flight shards are bounded so the feeder
    never races ahead of slow consumers.
    """

    name = "process"

    def __init__(
        self,
        shards: Sequence[str | Path],
        program: ShardProgram,
        *,
        workers: int = 2,
        cache_dir: str | Path | None = None,
        max_inflight: int | None = None,
        row_filters: dict[int, np.ndarray] | None = None,
    ):
        if program.has_dedup:
            raise UnsupportedPlanError(
                "drop_duplicates needs cross-shard state; use the thread executor"
            )
        self._row_filters = row_filters
        self.program = program
        self.cache_hits = 0
        self.cache_misses = 0
        self.token_cache_hits = 0
        self.token_cache_misses = 0
        self._parse_s = self._pre_s = self._clean_s = self._post_s = 0.0
        self._tokenize_s = 0.0
        # Driver-side fast-path probe state: when every token-space
        # product of a shard already sits in the cache, the feeder skips
        # the shared-memory copy (workers load straight from disk).
        self._cache = ShardCache(cache_dir) if cache_dir is not None else None
        self._token_fps = token_fingerprints(program) if self._cache else None
        self._count_fp = count_fingerprint(program) if self._cache else None
        self._dedup_fp = dedup_keys_fingerprint(program) if self._cache else None
        self._shards = [Path(s) for s in shards]
        self._stopped = threading.Event()
        self._feed_errors: list[BaseException] = []
        self._inflight = threading.Semaphore(max_inflight or max(2 * workers, 4))
        self._in_segs: dict[int, str] = {}
        self._seg_lock = threading.Lock()
        # Segment-leak bookkeeping: output segments carry deterministic
        # names derived from this run id, and every task whose output the
        # driver already unlinked lands in _consumed — so the sweep in
        # stop() (and the atexit last resort) can unlink exactly the
        # blocks a killed worker orphaned.
        self.run_id = f"{os.getpid():x}x{os.urandom(4).hex()}"
        self._consumed: set[int] = set()
        atexit.register(self._sweep_segments)
        # Start the resource-tracker daemon before forking: workers must
        # inherit it, or each spawns its own and cross-process unlinks are
        # reported as leaks at shutdown.
        shared_memory_available()
        # fork shares the parsed program and avoids re-importing jax in
        # every worker; spawn is the portable fallback.
        methods = mp.get_all_start_methods()
        ctx = mp.get_context("fork" if "fork" in methods else "spawn")
        self._task_q = ctx.Queue()
        self._result_q = ctx.Queue()
        self._procs = [
            ctx.Process(
                target=_worker_main,
                args=(self._task_q, self._result_q, program, cache_dir, self.run_id),
                daemon=True,
            )
            for _ in range(max(int(workers), 1))
        ]
        for p in self._procs:
            p.start()
        self._feeder = threading.Thread(target=self._feed, daemon=True)
        self._feeder.start()

    def _feed(self) -> None:
        from multiprocessing import shared_memory

        try:
            for i, path in enumerate(self._shards):
                while not self._inflight.acquire(timeout=0.1):
                    if self._stopped.is_set():
                        return
                if self._stopped.is_set():
                    return
                data, digest = ing.read_shard_bytes(path)
                row_take = (
                    self._row_filters.get(i)
                    if self._row_filters is not None
                    else None
                )
                if products_fully_cached(
                    self.program, self._cache, self._token_fps,
                    self._count_fp, digest, self._dedup_fp,
                ):
                    # Fully cached: no shm copy; ship the path so the
                    # worker can fall back to its own read if an entry
                    # vanishes between this probe and its load.
                    self._task_q.put((i, None, str(path), digest, row_take))
                    continue
                seg = shared_memory.SharedMemory(create=True, size=max(len(data), 1))
                seg.buf[: len(data)] = data
                with self._seg_lock:
                    self._in_segs[i] = seg.name
                self._task_q.put((i, seg.name, len(data), digest, row_take))
                seg.close()
        except BaseException as e:  # deleted shard, /dev/shm full, ...
            # Surface the real cause to the consumer; without this the
            # consumer only sees "workers exited before delivering".
            self._feed_errors.append(e)
        finally:
            for _ in self._procs:
                self._task_q.put(None)

    def _release_input(self, task_id: int) -> None:
        from multiprocessing import shared_memory

        with self._seg_lock:
            name = self._in_segs.pop(task_id, None)
        if name is not None:
            try:
                seg = shared_memory.SharedMemory(name=name)
                seg.close()
                seg.unlink()
            except FileNotFoundError:
                pass

    def _next_result(self):
        """Result-queue get that notices dead workers instead of blocking
        forever (an OOM-killed or segfaulted worker never sends its
        result)."""
        import queue as _queue

        while True:
            try:
                return self._result_q.get(timeout=1.0)
            except _queue.Empty:
                if self._feed_errors:
                    raise self._feed_errors[0]
                crashed = [
                    p.exitcode
                    for p in self._procs
                    if not p.is_alive() and p.exitcode not in (0, None)
                ]
                if crashed:
                    raise RuntimeError(
                        f"shard worker died with exit code {crashed[0]} "
                        "(no result for its shard)"
                    )
                if all(not p.is_alive() for p in self._procs):
                    raise RuntimeError(
                        "all shard workers exited before delivering every result"
                    )

    def __iter__(self) -> Iterator[ShardResult]:
        from multiprocessing import shared_memory

        for _ in range(len(self._shards)):
            if self._stopped.is_set():
                return
            try:
                msg = self._next_result()
            except BaseException:
                self.stop()
                raise
            status, task_id, body = msg
            self._release_input(task_id)
            self._inflight.release()
            if status == "err":
                self._consumed.add(task_id)  # worker unlinked its own block
                self.stop()
                raise RuntimeError(f"shard worker failed:\n{body}")
            seg = shared_memory.SharedMemory(name=body["shm"])
            try:
                view = seg.buf[: body["size"]]
                res = unpack_shard_result(body, view)
                del view  # release the exported buffer before closing
            finally:
                seg.close()
                seg.unlink()
                self._consumed.add(task_id)
            self._parse_s += res.parse_s
            self._pre_s += res.pre_clean_s
            self._clean_s += res.clean_s
            self._post_s += res.post_clean_s
            self._tokenize_s += res.tokenize_s
            self.cache_hits += res.cache_hits
            self.cache_misses += res.cache_misses
            self.token_cache_hits += res.token_cache_hits
            self.token_cache_misses += res.token_cache_misses
            res.shard_index = task_id
            yield res

    @property
    def timings(self):
        from .plan import StageTimings

        return StageTimings(
            self._parse_s, self._pre_s, self._clean_s, self._post_s, self._tokenize_s
        )

    def _drain_results(self) -> None:
        try:
            while True:
                msg = self._result_q.get_nowait()
                if msg[0] == "ok":
                    _unlink_segment(msg[2]["shm"])
                self._consumed.add(msg[1])
                self._release_input(msg[1])
        except Exception:
            pass

    def _sweep_segments(self) -> None:
        """Unlink every shared-memory block this run may still own: feeder
        input segments not yet released, and any deterministically-named
        worker output segment whose result the driver never consumed (a
        SIGKILLed worker can orphan one between creating the block and
        delivering its name). Runs from stop() and, as a last resort, from
        an atexit hook, so even an abandoned executor cannot leak."""
        with self._seg_lock:
            leftover = list(self._in_segs.values())
            self._in_segs.clear()
        for name in leftover:
            _unlink_segment(name)
        for i in range(len(self._shards)):
            if i not in self._consumed:
                _unlink_segment(_out_seg_name(self.run_id, i))

    def stop(self) -> None:
        """Abandon remaining shards; safe after breaking out early.
        Idempotent."""
        if self._stopped.is_set():
            return
        self._stopped.set()
        self._inflight.release()  # unblock a parked feeder
        self._feeder.join(timeout=5.0)
        # Abandon queued tasks so workers reach their sentinels quickly
        # (the feeder's sentinels sit behind them in the queue).
        try:
            while True:
                task = self._task_q.get_nowait()
                if task is not None:
                    self._release_input(task[0])
        except Exception:
            pass
        for _ in self._procs:
            self._task_q.put(None)
        self._drain_results()
        for p in self._procs:
            p.join(timeout=2.0)
            if p.is_alive():
                p.terminate()
                p.join(timeout=2.0)
        # Results a worker managed to emit between the drains above, then
        # every block that can still be ours (inputs + orphaned outputs).
        self._drain_results()
        self._sweep_segments()
        atexit.unregister(self._sweep_segments)


# ---------------------------------------------------------------------------
# Executor selection
# ---------------------------------------------------------------------------


def make_executor(
    shards: Sequence[str | Path],
    program: ShardProgram,
    *,
    workers: int = 2,
    cache_dir: str | Path | None = None,
    executor: str | None = None,
    row_filters: dict[int, np.ndarray] | None = None,
    remote: Any = None,
):
    """Pick the physical shard executor.

    Explicit ``executor`` wins, then ``REPRO_EXECUTOR``, then the default:
    processes when ``workers > 1``, threads otherwise. Requests for the
    process executor fall back to threads — never error — when the program
    needs cross-shard dedup state, the platform lacks shared memory, or
    ``workers <= 1``.

    ``executor="remote"`` (or ``REPRO_EXECUTOR=remote``) runs shards on
    the distributed data plane — a coordinator leasing shards to TCP
    worker processes (:mod:`repro.distributed.coordinator`); ``remote``
    carries its options (see :class:`RemoteShardExecutor`). Like the
    process executor it falls back to threads for cross-shard dedup
    programs and unpicklable programs.
    """
    choice = EngineConfig(executor=executor).resolve_executor()
    explicit = bool(choice)
    if not choice:
        choice = "process" if workers > 1 else "thread"
    if choice == "remote":
        import pickle

        try:
            pickle.dumps(program)
            picklable = True
        except Exception:
            picklable = False
        if program.has_dedup or not picklable:
            choice = "thread"
        else:
            from ..distributed.coordinator import RemoteShardExecutor

            return RemoteShardExecutor(
                shards,
                program,
                workers=max(int(workers), 1),
                cache_dir=cache_dir,
                row_filters=row_filters,
                remote=remote,
            )
    # More worker processes than cores only adds fork + scheduling cost;
    # clamp (the thread pool is unclamped — its readers overlap blocking
    # I/O, not CPU). When the *default* selection lands on one effective
    # worker the process executor is pure overhead, so fall back to
    # threads — but an explicit request (argument or REPRO_EXECUTOR, e.g.
    # the CI job exercising this path) is honored even on one core.
    n_proc = max(min(workers, os.cpu_count() or workers), 1)
    if choice == "process" and (
        workers <= 1
        or program.has_dedup
        or not shared_memory_available()
        or (n_proc <= 1 and not explicit)
    ):
        choice = "thread"
    if choice == "process" and "fork" not in mp.get_all_start_methods():
        # spawn-only platforms pickle the program into each worker; a plan
        # with a lambda predicate executes fine in-process, so degrade to
        # threads instead of crashing at Process.start().
        import pickle

        try:
            pickle.dumps(program)
        except Exception:
            choice = "thread"
    if choice == "process":
        return ProcessShardExecutor(
            shards, program, workers=n_proc, cache_dir=cache_dir,
            row_filters=row_filters,
        )
    return ThreadShardExecutor(
        shards, program, workers=workers, cache_dir=cache_dir,
        row_filters=row_filters,
    )
