"""Conventional approach (CA) — faithful re-implementation of Algorithm 2.

The paper's CA is the pandas idiom of its era:

* ingest: per file ``pd.read_json`` + ``DataFrame.append`` — **append copies
  the whole frame** (quadratic growth), which is exactly why the paper's
  Table 2 CA ingestion blows up super-linearly. pandas is not installed in
  this container, so ``RowFrame`` reproduces those semantics (copy-on-append
  row store) with stdlib ``json`` as the parser.
* cleaning: a Python loop over rows applying the row-wise cleaning functions
  (the same oracles the P3SAPP stages are validated against — Algorithm 2
  steps 11-13).

This module exists as the measured baseline for benchmarks/bench_* and as
the reference for the record-match accuracy study (paper Tables 5-6).
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Sequence

from .ingest import _normalize, list_shards
from .stages import Stage


class RowFrame:
    """pandas-era DataFrame emulation: copy-on-append row store."""

    def __init__(self, rows: list[dict] | None = None):
        self.rows: list[dict] = rows if rows is not None else []

    def append(self, other: "RowFrame") -> "RowFrame":
        # pd.DataFrame.append returned a NEW frame, copying both inputs.
        return RowFrame([dict(r) for r in self.rows] + [dict(r) for r in other.rows])

    def __len__(self) -> int:
        return len(self.rows)


def ingest_conventional(
    directories: Sequence[str | Path], fields: Sequence[str] = ("title", "abstract")
) -> RowFrame:
    """Algorithm 2 steps 1-8."""
    data = RowFrame()
    for path in list_shards(directories):
        rows = []
        with open(path, "r", encoding="utf-8") as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                rec = json.loads(line)
                # Same NUL normalization as the columnar ingestion, so the
                # CA oracle and the P3SAPP flat path see identical input.
                rows.append({f: _normalize(rec.get(f)) for f in fields})
        data = data.append(RowFrame(rows))
    return data


def pre_clean_conventional(frame: RowFrame, fields: Sequence[str]) -> RowFrame:
    """Algorithm 2 steps 9-10: drop nulls, drop duplicates (keep first)."""
    out: list[dict] = []
    seen: set = set()
    for r in frame.rows:
        if any(r.get(f) is None or r.get(f) == "" for f in fields):
            continue
        key = tuple(r.get(f) for f in fields)
        if key in seen:
            continue
        seen.add(key)
        out.append(r)
    return RowFrame(out)


def clean_conventional(frame: RowFrame, stages: Sequence[Stage]) -> RowFrame:
    """Algorithm 2 steps 11-13: FOR all rows, perform text cleaning."""
    for st in stages:
        for r in frame.rows:
            val = r.get(st.input_col) or ""
            r[st.output_col] = st.transform_row(val)
    return frame


def post_clean_conventional(frame: RowFrame, fields: Sequence[str]) -> RowFrame:
    """Algorithm 2 step 14: remove rows that became NULL/empty."""
    return RowFrame([r for r in frame.rows if all(r.get(f) for f in fields)])
