"""The paper's contribution: P3SAPP preprocessing pipeline.

Public API:
    Dataset                        — lazy plan: ingestion → device batches
    col / lit / concat             — composable column expressions
    abstract_expr / title_expr     — the paper's Fig. 2/3 workflows as expressions
    run_p3sapp / run_conventional  — Algorithm 1 / Algorithm 2 drivers
    Pipeline, stages               — Spark-ML-style transformer chain (deprecated shims)
    ColumnarFrame                  — the DataFrame analogue
    AsyncLoader / ShardPool        — accelerator-overlap input pipeline
    DeviceFeed / OverlapProfiler   — donated double-buffered device handoff
                                     with device-idle accounting
"""

from .async_loader import AsyncLoader, LoaderStats, ShardPool
from .dataset import Dataset
from .device_pipeline import (
    BucketGrid,
    DeviceBatch,
    DeviceFeed,
    OverlapProfiler,
    OverlapReport,
)
from .expr import abstract_expr, col, concat, lit, title_expr
from .frame import ColumnarFrame
from .p3sapp import (
    StageTimings,
    case_study_stages,
    p3sapp_dataset,
    record_match_accuracy,
    run_conventional,
    run_p3sapp,
)
from .pipeline import Pipeline, PipelineModel
from .stages import (
    ConvertToLower,
    RemoveHTMLTags,
    RemoveShortWords,
    RemoveUnwantedCharacters,
    StopWordsRemover,
    Tokenizer,
    abstract_stages,
    title_stages,
)
