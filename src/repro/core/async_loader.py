"""Async input pipeline: overlap host preprocessing with device compute.

The paper's motivating problem is the accelerator idling at 0% load during
ingestion/preprocessing. On a TPU pod the production fix is structural:
preprocessing runs on host CPUs *concurrently* with the device step, behind
a bounded prefetch queue, so the device never waits once the pipeline is
warm. This module provides that substrate:

* ``ShardPool`` — work-stealing over shard files: N reader threads pull
  shards from a shared queue, so one slow shard (straggler) never blocks
  the rest of the feed. This is the input-pipeline half of straggler
  mitigation (the collective-level half is the synchronous SPMD step).
* ``AsyncLoader`` — bounded prefetch + device double-buffering: batch k+1
  is transferred while batch k computes (``jax.device_put`` is async).
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Callable, Iterator, Sequence

# jax is imported lazily (first device_put): remote preprocessing workers
# (repro.distributed.worker) import this module for ShardPool/queue helpers
# and must not pay jax startup — they never touch a device.

_SENTINEL = object()


@dataclass
class LoaderStats:
    """Prefetch-queue health counters for one :class:`AsyncLoader`.

    ``starvation`` counts consumer arrivals at an *empty* queue — each one
    is a step where the device would have idled waiting for the host.
    ``max_depth`` is the high-water queue occupancy (how much of the
    prefetch budget the producer actually uses); ``wait_s`` accumulates
    consumer blocked time as measured by the loader's (injectable) clock.
    """

    prefetch: int = 0
    produced: int = 0
    consumed: int = 0
    starvation: int = 0
    max_depth: int = 0
    wait_s: float = 0.0
    depth: int = 0  # gauge: queue occupancy at the last consumer get

    def as_dict(self) -> dict:
        return dict(
            prefetch=self.prefetch,
            produced=self.produced,
            consumed=self.consumed,
            starvation=self.starvation,
            max_depth=self.max_depth,
            wait_s=self.wait_s,
            depth=self.depth,
        )


def put_cancellable(q: "queue.Queue", item, cancelled: threading.Event) -> None:
    """Bounded put that gives up once the consumer cancelled the feed."""
    while not cancelled.is_set():
        try:
            q.put(item, timeout=0.1)
            return
        except queue.Full:
            continue


def drain(q: "queue.Queue") -> None:
    while True:
        try:
            q.get_nowait()
        except queue.Empty:
            break


# The coordinator/worker feed paths (repro.distributed) share these; the
# old underscore names remain for in-repo callers.
_put_cancellable = put_cancellable
_drain = drain


class ShardPool:
    """Work-stealing worker pool over an ordered list of work items.

    The canonical use is shard files → preprocessed record batches, but any
    work item type goes: the shard executors
    (:mod:`repro.core.executor`) feed it paths and consume
    :class:`~repro.core.executor.ShardResult` objects. String/path items
    are normalized to :class:`~pathlib.Path`; everything else passes
    through untouched.
    """

    def __init__(
        self,
        shards: Sequence,
        process_shard: Callable[[Any], Any],
        n_readers: int = 2,
        max_queue: int = 8,
    ):
        self._shards: "queue.Queue[object]" = queue.Queue()
        for s in shards:
            self._shards.put(Path(s) if isinstance(s, (str, Path)) else s)
        self._out: "queue.Queue[object]" = queue.Queue(maxsize=max_queue)
        self._process = process_shard
        self._errors: list[BaseException] = []
        self._stopped = threading.Event()
        self._threads = [
            threading.Thread(target=self._worker, daemon=True) for _ in range(n_readers)
        ]
        self._n_live = n_readers
        self._lock = threading.Lock()
        for t in self._threads:
            t.start()

    def _worker(self) -> None:
        try:
            while not self._stopped.is_set():
                try:
                    shard = self._shards.get_nowait()
                except queue.Empty:
                    break
                _put_cancellable(self._out, self._process(shard), self._stopped)
        except BaseException as e:  # propagate to consumer
            self._errors.append(e)
        finally:
            with self._lock:
                self._n_live -= 1
                last = self._n_live == 0
            if last:
                _put_cancellable(self._out, _SENTINEL, self._stopped)

    def stop(self) -> None:
        """Abandon remaining shards and unblock readers; safe to call after
        breaking out of iteration early. Idempotent."""
        self._stopped.set()
        _drain(self._shards)
        _drain(self._out)
        for t in self._threads:
            t.join(timeout=5.0)

    def __iter__(self) -> Iterator:
        while True:
            item = self._out.get()
            if item is _SENTINEL:
                break
            yield item
        if self._errors:
            raise self._errors[0]


class AsyncLoader:
    """Bounded-prefetch, double-buffered host→device feed.

    ``batches`` is any iterator of pytrees of numpy arrays. The background
    thread keeps up to ``prefetch`` ready batches; consumption device-puts
    the next batch while the previous one is still computing — batch k is
    yielded only after batch k+1's transfer has been issued.

    ``device_put`` replaces the per-leaf ``jax.device_put`` (tests stub it;
    :class:`~repro.core.device_pipeline.DeviceFeed` passes a host no-op and
    owns the transfer itself). ``clock`` feeds the :class:`LoaderStats`
    wait accounting, so queue starvation is fake-clock testable.
    """

    def __init__(
        self,
        batches: Iterator,
        prefetch: int = 2,
        sharding=None,
        *,
        device_put: Callable[[Any], Any] | None = None,
        clock: Callable[[], float] = time.perf_counter,
    ):
        self._q: "queue.Queue[object]" = queue.Queue(maxsize=max(prefetch, 1))
        self._sharding = sharding
        self._device_put = device_put
        self._clock = clock
        self.stats = LoaderStats(prefetch=max(prefetch, 1))
        self._err: list[BaseException] = []
        self._closed = threading.Event()

        def fill() -> None:
            try:
                for b in batches:
                    _put_cancellable(self._q, b, self._closed)
                    self.stats.produced += 1
                    self.stats.max_depth = max(self.stats.max_depth, self._q.qsize())
                    if self._closed.is_set():
                        break
            except BaseException as e:
                self._err.append(e)
            finally:
                # Closing the source runs its finalizers (a streaming
                # generator shutting down its shard executor); raw executors
                # fed in directly expose stop() instead of close().
                finalize = getattr(batches, "close", None) or getattr(
                    batches, "stop", None
                )
                if finalize is not None:
                    finalize()
                _put_cancellable(self._q, _SENTINEL, self._closed)

        self._thread = threading.Thread(target=fill, daemon=True)
        self._thread.start()

    def close(self) -> None:
        """Stop the fill thread; safe after breaking out of iteration early
        (e.g. a fixed-step training loop over an endless epoch stream)."""
        self._closed.set()
        _drain(self._q)  # a blocked put() wakes and sees the flag
        self._thread.join(timeout=5.0)

    @property
    def running(self) -> bool:
        """True while the fill thread is alive (close() joins it)."""
        return self._thread.is_alive()

    def _get(self):
        """Dequeue with starvation/wait accounting: an empty queue at
        arrival means the consumer (ultimately the device) would stall."""
        s = self.stats
        s.depth = self._q.qsize()
        starved = s.depth == 0
        if starved:
            s.starvation += 1
        t0 = self._clock()
        item = self._q.get()
        s.wait_s += self._clock() - t0
        if item is _SENTINEL:
            if starved:  # waiting for end-of-stream is not starvation
                s.starvation -= 1
        else:
            s.consumed += 1
        return item

    def __iter__(self) -> Iterator:
        pending = None
        while True:
            item = self._get()
            if item is _SENTINEL:
                break
            device_batch = self._put(item)
            if pending is not None:
                yield pending
            pending = device_batch
        if pending is not None:
            yield pending
        if self._err:
            raise self._err[0]

    def _put(self, batch):
        if self._device_put is not None:
            return self._device_put(batch)
        import jax

        if self._sharding is not None:
            return jax.tree.map(lambda x: jax.device_put(x, self._sharding), batch)
        return jax.tree.map(jax.device_put, batch)
