"""Vectorized byte-level text operations — the columnar execution engine.

This is the TPU-era analogue of Spark's Tungsten columnar execution: every
preprocessing stage is a handful of C-speed vector passes over a *flat*
buffer instead of a Python loop per row (the conventional approach,
Algorithm 2 in the paper).

Flat representation
-------------------
A column of ``n`` strings is stored as a single ``uint8`` array in which rows
are separated by ``ROW_SEP`` (``\\x00``).  Text is treated as ASCII-oriented
UTF-8 (the paper's corpus is English scholarly text); bytes outside
``[a-z ]`` are removed by the unwanted-character LUT anyway.

Op descriptors
--------------
Stages describe themselves as small *ops* (LUT / SPAN / REPLACE / COLLAPSE /
WORDPRED).  The executor (``apply_ops``) runs them; ``fuse_ops`` performs
Catalyst-style adjacent-op fusion:

* ``LUT ∘ LUT``      → one composed 256-entry LUT (one pass instead of two)
* ``WORDPRED | WORDPRED`` → one word-segmentation + hash pass evaluating the
  OR of the predicates (exact: predicates are word-local, so removing words
  in one pass is equivalent to sequential removal)
* adjacent ``COLLAPSE`` ops deduplicate.

Backends: megapass lowering
---------------------------
Beyond adjacent fusion, :func:`compile_megapass` lowers a whole op chain to
a small *pass program* executed by :func:`run_megapass` — the whole-stage
codegen analogue: instead of materializing one intermediate buffer per op,
the chain is segmented into

* **scan passes** — a maximal ``LUT``/``SPAN`` run.  The value LUTs compose
  into one 256-entry table; each span's open/close detection becomes a
  boolean LUT over the *raw* bytes (``composed_lut_so_far == open_byte``),
  and the span masks are made sequential-exact by zeroing every span's
  depth delta at positions an earlier span already deleted.  One gather at
  the end applies the composed LUT and compacts — a single output write
  where the loops backend writes once per op.
* **word passes** — an optional pure-LUT prefix plus a maximal
  ``COLLAPSE``/``WORDPRED`` run.  Words are segmented once, the OR of all
  predicates is evaluated on that one segmentation, and a single keep-mask
  compaction emits surviving words with exactly one space per gap (word
  predicates are word-local and every word-level stage re-collapses, so
  this equals sequential application byte-for-byte).
* **barriers** — ``REPLACE``/``REGEX`` ops change lengths via
  ``bytes.replace``/``re.sub`` and run materialized, exactly as in the
  loops backend.

:func:`execute_ops` dispatches between backends — ``loops`` (one pass per
op, the paper-faithful P3SAPP executor), ``fused`` (megapass), and
``pallas`` (megapass whose scan passes offload to the
``kernels/text_clean`` Pallas kernel when the pass matches the kernel's
shape, falling back to the host scan otherwise).  Selection:  explicit
argument > ``REPRO_BYTES_BACKEND`` env var > ``loops``.  **All backends
are byte-identical by contract**; any chain the megapass compiler cannot
prove exact (e.g. a LUT that remaps the row separator) falls back to
``loops`` wholesale.  Fusion wins are measured in EXPERIMENTS.md §Perf
(data layer) and ``benchmarks/bench_kernels.py``.

Semantics contract (shared with the row-wise oracles in ``stages.py``)
----------------------------------------------------------------------
* HTML tags and parentheses are balanced and non-nested within each row
  (the corpus generator guarantees this; the span mask resets its depth at
  every row separator so malformed rows can never swallow a separator).
* ``\\x00`` never appears inside a row (ingestion strips it).
"""

from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Callable, Sequence

import numpy as np

ROW_SEP = 0
SPACE = 32

# ---------------------------------------------------------------------------
# flatten / unflatten
# ---------------------------------------------------------------------------


def flatten(rows: Sequence[str]) -> np.ndarray:
    """Join rows with ROW_SEP into one uint8 buffer (trailing sep included)."""
    joined = ("\x00".join(rows) + "\x00").encode("utf-8", errors="ignore") if len(rows) else b""
    return np.frombuffer(joined, dtype=np.uint8).copy()


def unflatten(buf: np.ndarray) -> list[str]:
    """Inverse of :func:`flatten`."""
    if buf.size == 0:
        return []
    parts = buf.tobytes().split(b"\x00")
    if parts and parts[-1] == b"":
        parts = parts[:-1]
    return [p.decode("utf-8", errors="ignore") for p in parts]


def n_rows(buf: np.ndarray) -> int:
    return int((buf == ROW_SEP).sum())


# ---------------------------------------------------------------------------
# Lookup tables
# ---------------------------------------------------------------------------

LOWER_LUT = np.arange(256, dtype=np.uint8)
LOWER_LUT[ord("A") : ord("Z") + 1] += 32

# RemoveUnwantedCharacters: keep [a-z], space, ROW_SEP; everything else
# (digits, punctuation, specials, residual uppercase, UTF-8 >127) → space.
UNWANTED_LUT = np.full(256, SPACE, dtype=np.uint8)
UNWANTED_LUT[ord("a") : ord("z") + 1] = np.arange(ord("a"), ord("z") + 1, dtype=np.uint8)
UNWANTED_LUT[SPACE] = SPACE
UNWANTED_LUT[ROW_SEP] = ROW_SEP


# Contraction mapping: applied on flat bytes after lowercasing, before
# punctuation stripping; each entry is one C-speed ``bytes.replace`` pass.
CONTRACTIONS: tuple[tuple[bytes, bytes], ...] = (
    (b"won't", b"will not"),
    (b"can't", b"can not"),
    (b"shan't", b"shall not"),
    (b"n't", b" not"),
    (b"'re", b" are"),
    (b"'ve", b" have"),
    (b"'ll", b" will"),
    (b"'m", b" am"),
    (b"'d", b" would"),
    (b"'s", b""),
    (b"'", b""),
)


# ---------------------------------------------------------------------------
# Character-level passes
# ---------------------------------------------------------------------------


def apply_lut(buf: np.ndarray, lut: np.ndarray) -> np.ndarray:
    return lut[buf]


def span_strip(buf: np.ndarray, open_b: int, close_b: int) -> np.ndarray:
    """Delete ``open .. close`` spans (both delimiters included).

    Depth resets at every row separator (fast path when rows are balanced).
    """
    opens = buf == open_b
    closes = buf == close_b
    delta = np.subtract(opens, closes, dtype=np.int8)
    depth = np.cumsum(delta, dtype=np.int32)
    sep = buf == ROW_SEP
    sep_depths = depth[sep]
    if sep_depths.size and sep_depths.any():  # malformed rows: per-row reset
        row_id = np.cumsum(sep, dtype=np.int32) - sep
        start_depth = np.concatenate(([0], sep_depths)).astype(np.int32)[row_id]
        inside = (depth - start_depth) > 0
    else:
        inside = depth > 0  # includes opener, excludes closer
    keep = ~(inside | closes) | sep
    return buf[keep]


def replace_patterns(buf: np.ndarray, patterns: Sequence[tuple[bytes, bytes]]) -> np.ndarray:
    raw = buf.tobytes()
    for pat, rep in patterns:
        raw = raw.replace(pat, rep)
    return np.frombuffer(raw, dtype=np.uint8).copy()


def expand_contractions(buf: np.ndarray) -> np.ndarray:
    return replace_patterns(buf, CONTRACTIONS)


def collapse_spaces(buf: np.ndarray) -> np.ndarray:
    """Collapse space runs; strip leading/trailing spaces of each row."""
    if buf.size == 0:
        return buf
    sp = buf == SPACE
    sep = buf == ROW_SEP
    prev_sp_or_start = np.empty_like(sp)
    prev_sp_or_start[0] = True
    prev_sp_or_start[1:] = sp[:-1] | sep[:-1]
    buf2 = buf[~(sp & prev_sp_or_start)]
    sp2 = buf2 == SPACE
    next_sep = np.empty_like(sp2)
    next_sep[-1] = True
    next_sep[:-1] = buf2[1:] == ROW_SEP
    return buf2[~(sp2 & next_sep)]


def regex_sub(buf: np.ndarray, pattern: bytes, repl: bytes) -> np.ndarray:
    """One compiled-regex substitution pass over the flat bytes.

    Row-local as long as no match touches ``\\x00`` (the row separator).
    Construction-time probing (:func:`regex_op`) rejects the common
    separator-matching patterns (``.``, ``\\W``, ``[^a-z]``, …), and the
    row count is re-verified here — exact enforcement, since a match that
    crossed a separator would have to consume it."""
    import re

    raw = buf.tobytes()
    out = re.sub(pattern, repl, raw)
    if out.count(b"\x00") != raw.count(b"\x00"):
        raise ValueError(
            f"regex_replace({pattern.decode(errors='replace')!r}) matched the "
            "row separator and would merge or split rows; exclude NUL from "
            "the pattern (e.g. use [^a-z\\x01-\\x1f] style classes)"
        )
    return np.frombuffer(out, dtype=np.uint8).copy()


# ---------------------------------------------------------------------------
# Row-level reductions (predicates over flat buffers; no decode)
# ---------------------------------------------------------------------------


def row_lengths(buf: np.ndarray) -> np.ndarray:
    """Per-row byte length *including* the trailing separator."""
    sep_idx = np.flatnonzero(buf == ROW_SEP)
    return np.diff(np.concatenate(([-1], sep_idx))).astype(np.int64)


def row_nonempty(buf: np.ndarray) -> np.ndarray:
    """Boolean mask of rows with at least one byte of payload."""
    return row_lengths(buf) > 1


def row_word_counts(buf: np.ndarray) -> np.ndarray:
    """Per-row number of space-separated words (vectorized, no decode)."""
    n = n_rows(buf)
    counts = np.zeros(n, dtype=np.int64)
    if buf.size == 0:
        return counts
    sep = buf == ROW_SEP
    _, _, start_idx, _ = _segment_words(buf)
    if start_idx.size:
        row_of_byte = np.cumsum(sep, dtype=np.int64) - sep
        np.add.at(counts, row_of_byte[start_idx], 1)
    return counts


def rows_containing(buf: np.ndarray, needle: bytes) -> np.ndarray:
    """Boolean mask of rows whose payload contains ``needle`` (a literal
    byte string without ``\\x00``, so a match can never span rows)."""
    n = n_rows(buf)
    mask = np.zeros(n, dtype=bool)
    if not needle or buf.size == 0:
        mask[:] = bool(n) and not needle
        return mask
    m = len(needle)
    if m > buf.size:
        return mask
    pat = np.frombuffer(needle, dtype=np.uint8)
    hit = buf[: buf.size - m + 1] == pat[0]
    for j in range(1, m):
        hit &= buf[j : buf.size - m + 1 + j] == pat[j]
    pos = np.flatnonzero(hit)
    if pos.size:
        sep = buf == ROW_SEP
        row_of_byte = np.cumsum(sep, dtype=np.int64) - sep
        mask[row_of_byte[pos]] = True
    return mask


def concat_rows(bufs: Sequence[np.ndarray], sep: bytes = b" ") -> np.ndarray:
    """Row-wise concatenation of equal-row-count flat buffers with ``sep``
    between the parts (byte-level; rows never decode to str)."""
    if not bufs:
        raise ValueError("concat_rows needs at least one buffer")
    split = [b.tobytes().split(b"\x00")[:-1] for b in bufs]
    counts = {len(rows) for rows in split}
    if len(counts) > 1:
        raise ValueError(f"ragged concat inputs: row counts {sorted(counts)}")
    joined = b"".join(sep.join(parts) + b"\x00" for parts in zip(*split))
    return np.frombuffer(joined, dtype=np.uint8).copy()


# ---------------------------------------------------------------------------
# Word-level passes (segmented vector ops, no per-word Python)
# ---------------------------------------------------------------------------


def _segment_words(buf: np.ndarray):
    """Return (is_word_byte, word_id_per_byte, start_idx, lengths)."""
    delim = (buf == SPACE) | (buf == ROW_SEP)
    isw = ~delim
    starts = isw.copy()
    starts[1:] &= delim[:-1]
    start_idx = np.flatnonzero(starts)
    wid = np.cumsum(starts, dtype=np.int32) - 1  # valid where isw
    if start_idx.size:
        lengths = np.add.reduceat(isw.astype(np.int32), start_idx)
    else:
        lengths = np.zeros(0, dtype=np.int32)
    return isw, wid, start_idx, lengths


class WordView:
    """Lazy per-word key view. ``k1``/``k2`` pack bytes 0-7 / 8-15 of each
    word (zero padded), so (k1, k2, length) identifies any word of <=16
    bytes *exactly* — no hash collisions. Words longer than 16 bytes cannot
    equal any dictionary word of <=16 bytes (length check)."""

    def __init__(self, buf: np.ndarray, start_idx: np.ndarray, lengths: np.ndarray):
        self._buf = buf
        self.start_idx = start_idx
        self.lengths = lengths
        self._k1: np.ndarray | None = None
        self._k2: np.ndarray | None = None

    def _pack(self, offset: int, subset: np.ndarray | None = None) -> np.ndarray:
        starts = self.start_idx if subset is None else self.start_idx[subset]
        lens = self.lengths if subset is None else self.lengths[subset]
        pad = np.zeros(8, dtype=np.uint8)
        bufp = np.concatenate([self._buf, pad])
        cols = np.arange(8, dtype=np.int64)
        mat = bufp[starts[:, None] + (offset + cols)[None, :]]
        mat[cols[None, :] >= (lens[:, None] - offset)] = 0
        return mat.reshape(-1).view(np.uint64)

    @property
    def k1(self) -> np.ndarray:
        if self._k1 is None:
            self._k1 = self._pack(0)
        return self._k1

    @property
    def k2(self) -> np.ndarray:
        if self._k2 is None:
            long = np.flatnonzero(self.lengths > 8)
            k2 = np.zeros(self.start_idx.size, dtype=np.uint64)
            if long.size:
                k2[long] = self._pack(8, subset=long)
            self._k2 = k2
        return self._k2


def pack_word(word: str) -> tuple[int, int, int]:
    """(k1, k2, length) key of a dictionary word (must be <=16 bytes)."""
    b = word.encode("utf-8")
    if len(b) > 16:
        raise ValueError(f"dictionary word too long: {word!r}")
    padded = b + b"\x00" * (16 - len(b))
    k = np.frombuffer(padded, dtype=np.uint64)
    return int(k[0]), int(k[1]), len(b)


class WordSet:
    """Sorted exact-match set of <=16-byte words (e.g. stopwords)."""

    def __init__(self, words: Sequence[str]):
        keys = sorted({pack_word(w) for w in words})
        self.k1 = np.array([k[0] for k in keys], dtype=np.uint64)
        self.k2 = np.array([k[1] for k in keys], dtype=np.uint64)
        self.ln = np.array([k[2] for k in keys], dtype=np.int32)
        self._max_dup = self._compute_max_dup()

    def contains(self, view: WordView) -> np.ndarray:
        if self.k1.size == 0 or view.start_idx.size == 0:
            return np.zeros(view.start_idx.size, dtype=bool)
        k1 = view.k1
        pos = np.searchsorted(self.k1, k1)
        # self.k1 can contain duplicates (same first-8 bytes, different tail);
        # check up to 2 candidate slots — enough for English stopword lists,
        # asserted at construction time below.
        hit = np.zeros(k1.size, dtype=bool)
        for off in range(self._max_dup):
            p = np.clip(pos + off, 0, self.k1.size - 1)
            hit |= (
                (self.k1[p] == k1)
                & (self.k2[p] == view.k2)
                & (self.ln[p] == view.lengths)
            )
        return hit

    def signature(self) -> bytes:
        """Stable content signature (for plan fingerprinting)."""
        return b"wordset:" + self.k1.tobytes() + self.k2.tobytes() + self.ln.tobytes()

    def _compute_max_dup(self) -> int:
        if self.k1.size < 2:
            return 1
        runs = 1
        best = 1
        for i in range(1, self.k1.size):
            runs = runs + 1 if self.k1[i] == self.k1[i - 1] else 1
            best = max(best, runs)
        return best


def remove_words(
    buf: np.ndarray,
    bad_fn: Callable[[WordView | None, np.ndarray], np.ndarray],
    needs_hashes: bool = True,
) -> np.ndarray:
    """Delete words flagged by ``bad_fn(word_view|None, lengths)``."""
    # Word-level stages always normalize whitespace (Spark operates on token
    # arrays; our textual form rejoins with single spaces) — so the no-op
    # paths still collapse.
    isw, wid, start_idx, lengths = _segment_words(buf)
    if start_idx.size == 0:
        return collapse_spaces(buf)
    view = WordView(buf, start_idx, lengths) if needs_hashes else None
    bad = bad_fn(view, lengths)
    if not bad.any():
        return collapse_spaces(buf)
    kill = np.zeros(buf.size, dtype=bool)
    w = np.clip(wid, 0, None)
    kill[isw] = bad[w[isw]]
    return collapse_spaces(buf[~kill])


def remove_short_words(buf: np.ndarray, threshold: int) -> np.ndarray:
    return remove_words(buf, lambda v, ln: ln <= threshold, needs_hashes=False)


def remove_stopwords(buf: np.ndarray, stopwords: "WordSet") -> np.ndarray:
    return remove_words(buf, lambda v, ln: stopwords.contains(v))


# ---------------------------------------------------------------------------
# Op descriptors + fusing executor (Catalyst-style plan optimization)
# ---------------------------------------------------------------------------


@dataclass(frozen=True, eq=False)
class Op:
    kind: str  # "lut" | "span" | "replace" | "collapse" | "wordpred" | "regex"
    lut: np.ndarray | None = None
    span: tuple[int, int] | None = None
    patterns: tuple[tuple[bytes, bytes], ...] | None = None
    pred: Callable | None = None  # (hashes|None, lengths) -> bool[n_words]
    needs_hashes: bool = False
    regex: tuple[bytes, bytes] | None = None  # (pattern, repl)


# Module-level predicates (picklable for the process-pool executor).


def pred_short(view, ln, threshold: int):
    return ln <= threshold


def pred_stopword(view, ln, words: "WordSet"):
    return words.contains(view)


def pred_or(view, ln, p1, p2):
    return p1(view, ln) | p2(view, ln)


def lut_op(lut: np.ndarray) -> Op:
    return Op("lut", lut=lut)


def span_op(open_c: str, close_c: str) -> Op:
    return Op("span", span=(ord(open_c), ord(close_c)))


def replace_op(patterns: Sequence[tuple[bytes, bytes]]) -> Op:
    return Op("replace", patterns=tuple(patterns))


def collapse_op() -> Op:
    return Op("collapse")


def wordpred_op(pred: Callable, needs_hashes: bool) -> Op:
    return Op("wordpred", pred=pred, needs_hashes=needs_hashes)


def regex_op(pattern: str, repl: str) -> Op:
    """Regex substitution op. The pattern must compile, must not be able to
    match the row separator, and the replacement must not introduce one —
    otherwise a substitution could merge or split rows. Probing here
    catches the common separator-matchers (``.``, ``\\W``, ``[^...]``
    classes) at plan-build time; :func:`regex_sub` re-verifies the row
    count at execution, so exotic patterns that slip past the probes still
    fail loudly instead of corrupting rows."""
    import re

    pat = pattern.encode("utf-8")
    rep = repl.encode("utf-8")
    rx = re.compile(pat)  # fail fast on bad patterns, at plan-build time
    if b"\x00" in rep:
        raise ValueError("regex replacement must not emit NUL (the row separator)")
    for probe in (b"\x00", b"a\x00", b"\x00a", b"ab\x00cd"):
        if any(b"\x00" in m.group() for m in rx.finditer(probe)):
            raise ValueError(
                f"regex pattern {pattern!r} can match NUL (the row separator) "
                "and would merge or split rows; exclude \\x00 explicitly"
            )
    return Op("regex", regex=(pat, rep))


def apply_op(buf: np.ndarray, op: Op) -> np.ndarray:
    if op.kind == "lut":
        return apply_lut(buf, op.lut)
    if op.kind == "span":
        return span_strip(buf, *op.span)
    if op.kind == "replace":
        return replace_patterns(buf, op.patterns)
    if op.kind == "collapse":
        return collapse_spaces(buf)
    if op.kind == "wordpred":
        return remove_words(buf, op.pred, needs_hashes=op.needs_hashes)
    if op.kind == "regex":
        return regex_sub(buf, *op.regex)
    raise ValueError(f"unknown op {op.kind}")


def apply_ops(buf: np.ndarray, ops: Sequence[Op]) -> np.ndarray:
    for op in ops:
        buf = apply_op(buf, op)
    return buf


class UnfingerprintableOpError(ValueError):
    """The op's behavior cannot be captured in a stable signature (e.g. a
    lambda predicate): callers must treat its outputs as uncacheable
    rather than risk serving stale results under a colliding key."""


def _pred_signature(pred) -> bytes:
    """Stable byte signature of a word predicate (module-level function or a
    ``functools.partial`` tree over them) — the cache key must change when any
    parameter (threshold, stopword list, …) changes."""
    import functools

    if isinstance(pred, functools.partial):
        parts = [b"partial:", _pred_signature(pred.func)]
        for a in pred.args:
            parts.append(_value_signature(a))
        for k in sorted(pred.keywords):
            parts.append(k.encode() + b"=" + _value_signature(pred.keywords[k]))
        return b"|".join(parts)
    qualname = getattr(pred, "__qualname__", None)
    if qualname is None or "<lambda>" in qualname or "<locals>" in qualname:
        # Lambdas / closures all share a qualname; two different ones must
        # never produce the same fingerprint.
        raise UnfingerprintableOpError(
            f"cannot fingerprint predicate {pred!r}; use a module-level "
            "function (optionally via functools.partial) to make it cacheable"
        )
    module = getattr(pred, "__module__", "") or ""
    parts = [f"{module}.{qualname}".encode()]
    code = getattr(pred, "__code__", None)
    if code is not None:
        # Include the bytecode so *editing the function body* invalidates
        # cached results, not just renaming it.
        parts.append(code.co_code)
        parts.append(
            repr([c for c in code.co_consts if not hasattr(c, "co_code")]).encode()
        )
    return b"\x1f".join(parts)


def _value_signature(value) -> bytes:
    """Deterministic, collision-averse signature of a predicate parameter.

    repr() is not good enough here: set iteration order varies per process
    (hash randomization → a cache that never hits across runs) and custom
    reprs may omit the parameters that matter (→ stale hits). Anything we
    cannot serialize deterministically raises, poisoning the column into
    the uncacheable-but-correct path."""
    if isinstance(value, WordSet):
        return value.signature()
    if callable(value):
        return _pred_signature(value)
    if isinstance(value, np.ndarray):
        return b"nd:" + value.tobytes()
    if value is None or isinstance(value, (bool, int, float, str, bytes)):
        return f"{type(value).__name__}:{value!r}".encode()
    if isinstance(value, (tuple, list)):
        return b"seq:" + b",".join(_value_signature(v) for v in value)
    if isinstance(value, (set, frozenset)):
        return b"set:" + b",".join(sorted(_value_signature(v) for v in value))
    if isinstance(value, dict):
        return b"map:" + b",".join(
            _value_signature(k) + b"=" + _value_signature(v)
            for k, v in sorted(value.items(), key=lambda kv: repr(kv[0]))
        )
    raise UnfingerprintableOpError(
        f"cannot fingerprint predicate parameter {value!r} "
        f"({type(value).__name__}); pass plain data or a WordSet"
    )


def op_signature(op: Op) -> bytes:
    """Stable byte signature of one op — the unit of plan fingerprinting."""
    if op.kind == "lut":
        return b"lut:" + op.lut.tobytes()
    if op.kind == "span":
        return b"span:%d,%d" % op.span
    if op.kind == "replace":
        # Length-prefix each side: joining with separators would let two
        # different pattern lists collide into one signature (e.g. a
        # pattern containing the separator), which the cache must never do.
        parts = [b"replace:"]
        for p, r in op.patterns:
            parts.append(len(p).to_bytes(4, "little") + p)
            parts.append(len(r).to_bytes(4, "little") + r)
        return b"".join(parts)
    if op.kind == "collapse":
        return b"collapse"
    if op.kind == "wordpred":
        return b"wordpred:" + _pred_signature(op.pred)
    if op.kind == "regex":
        pat, rep = op.regex
        return (
            b"regex:"
            + len(pat).to_bytes(4, "little") + pat
            + len(rep).to_bytes(4, "little") + rep
        )
    raise ValueError(f"unknown op {op.kind}")


def ops_fingerprint(ops: Sequence[Op]) -> str:
    """Hex fingerprint of an op chain (order-sensitive, parameter-exact)."""
    import hashlib

    h = hashlib.blake2b(digest_size=16)
    for op in ops:
        sig = op_signature(op)
        h.update(len(sig).to_bytes(8, "little"))
        h.update(sig)
    return h.hexdigest()


def fuse_ops(ops: Sequence[Op]) -> list[Op]:
    """Adjacent-op fusion. Exact: see module docstring."""
    fused: list[Op] = []
    for op in ops:
        prev = fused[-1] if fused else None
        if prev is not None and prev.kind == op.kind == "lut":
            fused[-1] = lut_op(op.lut[prev.lut])
        elif prev is not None and prev.kind == op.kind == "collapse":
            pass  # idempotent
        elif prev is not None and prev.kind == op.kind == "wordpred":
            from functools import partial

            fused[-1] = wordpred_op(
                partial(pred_or, p1=prev.pred, p2=op.pred),
                prev.needs_hashes or op.needs_hashes,
            )
        else:
            fused.append(op)
    return fused


# ---------------------------------------------------------------------------
# Megapass backend: whole-chain lowering to single-sweep pass programs
# ---------------------------------------------------------------------------

BACKENDS = ("loops", "fused", "pallas")
BACKEND_ENV = "REPRO_BYTES_BACKEND"

_IDENTITY_LUT = np.arange(256, dtype=np.uint8)


def resolve_backend(backend: str | None = None) -> str:
    """Backend selection: explicit argument > REPRO_BYTES_BACKEND > loops."""
    b = backend or os.environ.get(BACKEND_ENV, "") or "loops"
    if b not in BACKENDS:
        raise ValueError(f"unknown bytes backend {b!r}; expected one of {BACKENDS}")
    return b


@dataclass(frozen=True)
class ScanPass:
    """A maximal LUT/SPAN run lowered to one sweep + one compaction.

    ``lut`` is the full composed value LUT of the run; ``spans`` holds one
    detection pair per span op describing open/close positions in terms of
    the *raw* bytes (``composed_lut_at_that_point == delimiter``), so no
    intermediate values materialize.  Each detector is either a plain byte
    value (the delimiter's preimage under the composed LUT is a single
    byte — one vector compare) or a 256-entry boolean LUT (general case).
    ``pairs`` keeps the mapped (open, close) byte values for the Pallas
    eligibility check."""

    lut: np.ndarray
    spans: tuple[tuple[object, object], ...]
    pairs: tuple[tuple[int, int], ...]


@dataclass(frozen=True)
class WordPass:
    """An optional pure-LUT prefix + a maximal COLLAPSE/WORDPRED run: one
    segmentation, OR of all predicates, one keep-mask compaction whose
    output is fully collapsed (every word-level stage re-collapses)."""

    lut: np.ndarray | None
    preds: tuple[tuple[Callable, bool], ...]  # (pred, needs_hashes)


def _sep_safe(lut: np.ndarray) -> bool:
    """True iff ``lut`` maps ROW_SEP to ROW_SEP and nothing else to it —
    the condition under which separator positions in the raw buffer equal
    separator positions in the mapped values (required wherever the fused
    program consults row structure)."""
    return bool(lut[ROW_SEP] == ROW_SEP and not (lut[1:] == ROW_SEP).any())


def _compose_luts(ops: Sequence[Op]) -> np.ndarray:
    lut = _IDENTITY_LUT
    for op in ops:
        lut = op.lut[lut]
    return lut


def _detector(cur: np.ndarray, byte: int):
    """Raw-byte detector for ``composed_lut[raw] == byte``: the preimage
    byte itself when unique (vector compare at run time), else the boolean
    LUT (gather)."""
    pre = np.flatnonzero(cur == byte)
    if pre.size == 1:
        return int(pre[0])
    return cur == byte


def _detect(buf: np.ndarray, det) -> np.ndarray:
    if isinstance(det, np.ndarray):
        return det[buf]
    return buf == det


def _compile_scan(run: Sequence[Op]) -> ScanPass | None:
    cur = _IDENTITY_LUT
    spans: list[tuple[object, object]] = []
    pairs: list[tuple[int, int]] = []
    for op in run:
        if op.kind == "lut":
            cur = op.lut[cur]
        else:
            open_b, close_b = op.span
            # Span detection consults row structure (per-row depth reset)
            # and delimiter identity; bail to the loops backend on the
            # degenerate shapes where raw-byte detection is not exact.
            if not _sep_safe(cur) or ROW_SEP in (open_b, close_b) or open_b == close_b:
                return None
            spans.append((_detector(cur, open_b), _detector(cur, close_b)))
            pairs.append((open_b, close_b))
    return ScanPass(lut=cur, spans=tuple(spans), pairs=tuple(pairs))


def compile_megapass(ops: Sequence[Op]) -> list[tuple[str, object]] | None:
    """Lower an op chain to a pass program: ``[("scan", ScanPass) |
    ("word", WordPass) | ("op", Op), ...]``.  Returns ``None`` when any
    segment cannot be proven byte-identical to sequential execution —
    callers then fall back to :func:`apply_ops`."""
    ops = list(ops)
    passes: list[tuple[str, object]] = []
    i, n = 0, len(ops)
    while i < n:
        kind = ops[i].kind
        if kind in ("replace", "regex"):
            passes.append(("op", ops[i]))
            i += 1
            continue
        head_lut: np.ndarray | None = None
        if kind in ("lut", "span"):
            j = i
            while j < n and ops[j].kind in ("lut", "span"):
                j += 1
            # A trailing pure-LUT suffix feeds the following word pass (so
            # e.g. [unwanted-LUT, collapse, wordpred] is ONE pass, not two).
            t = j
            if j < n and ops[j].kind in ("collapse", "wordpred"):
                while t > i and ops[t - 1].kind == "lut":
                    t -= 1
            if t > i:
                scan = _compile_scan(ops[i:t])
                if scan is None:
                    return None
                passes.append(("scan", scan))
            if t < j:
                head_lut = _compose_luts(ops[t:j])
                if not _sep_safe(head_lut):
                    return None
            i = j
            if head_lut is None:
                continue
        if i < n and ops[i].kind in ("collapse", "wordpred"):
            j = i
            while j < n and ops[j].kind in ("collapse", "wordpred"):
                j += 1
            preds = tuple(
                (op.pred, op.needs_hashes) for op in ops[i:j] if op.kind == "wordpred"
            )
            passes.append(("word", WordPass(lut=head_lut, preds=preds)))
            i = j
            continue
        if head_lut is not None:  # pragma: no cover - unreachable by construction
            return None
        return None  # unknown op kind
    return passes


def _run_scan(buf: np.ndarray, sp: ScanPass) -> np.ndarray:
    """One sweep for a LUT/SPAN run.  Span masking is *sparse*: delimiter
    bytes are rare in real text, so depths are computed on the hit list
    (O(hits)) and dead byte ranges scattered into the keep mask — the
    full-buffer work is two compares and one flatnonzero per span instead
    of an O(n) cumsum.  Semantics match iterated :func:`span_strip`
    exactly: row-local depth (reset at every separator), any byte at
    positive depth dies, every close byte dies, spans already deleted by
    an earlier span op neither open, close, nor count."""
    identity = sp.lut is _IDENTITY_LUT
    if buf.size == 0 or not sp.spans:
        return buf if identity else sp.lut[buf]
    sep_idx = np.flatnonzero(buf == ROW_SEP)
    alive = np.ones(buf.size, dtype=bool)
    for open_det, close_det in sp.spans:
        opens = _detect(buf, open_det)
        closes = _detect(buf, close_det)
        np.logical_or(opens, closes, out=opens)
        hits = np.flatnonzero(opens)
        if hits.size:
            live = alive[hits]
            if not live.all():
                hits = hits[live]
        if hits.size == 0:
            continue
        is_close = closes[hits]
        sign = np.where(is_close, np.int32(-1), np.int32(1))
        g = np.cumsum(sign)
        rows_h = np.searchsorted(sep_idx, hits)  # hit's row (sep_idx entry = row end)
        first = np.ones(hits.size, dtype=bool)
        first[1:] = rows_h[1:] != rows_h[:-1]
        fpos = np.flatnonzero(first)
        counts = np.diff(np.append(fpos, hits.size))
        d = g - np.repeat((g - sign)[fpos], counts)  # row-local inclusive depth
        if sep_idx.size:
            row_end = np.where(
                rows_h < sep_idx.size,
                sep_idx[np.minimum(rows_h, sep_idx.size - 1)],
                buf.size,
            )
        else:
            row_end = np.full(hits.size, buf.size, dtype=np.int64)
        nxt = np.empty_like(hits)
        nxt[:-1] = hits[1:]
        nxt[-1] = buf.size
        end = np.minimum(nxt, row_end)
        inside = d > 0
        dead = inside | is_close
        # A byte at positive depth kills everything up to the next hit (or
        # row end — unclosed spans swallow the rest of the row, never the
        # separator); a stray close at depth <= 0 kills only itself.
        lens = np.where(inside, end - hits, 1)[dead]
        alive[_span_indices(hits[dead], lens)] = False
    out = buf[alive]
    return out if identity else sp.lut[out]


def _pallas_scan_args(sp: ScanPass) -> dict | None:
    """Kernel-shape check for a scan pass: composed LUT is identity or
    lowercasing, spans are the canonical ``<>`` / ``()`` prefix (in that
    order), and each span's detection LUT is exactly what the kernel
    computes (``final_lut[raw] == delimiter``)."""
    if np.array_equal(sp.lut, LOWER_LUT):
        lower = True
    elif np.array_equal(sp.lut, _IDENTITY_LUT):
        lower = False
    else:
        return None
    allowed = ((ord("<"), ord(">")), (ord("("), ord(")")))
    if sp.pairs not in (allowed[:1], allowed[1:], allowed, ()):
        return None

    def det_array(det):
        return det if isinstance(det, np.ndarray) else _IDENTITY_LUT == det

    for (open_b, close_b), (open_det, close_det) in zip(sp.pairs, sp.spans):
        if not np.array_equal(det_array(open_det), sp.lut == open_b):
            return None
        if not np.array_equal(det_array(close_det), sp.lut == close_b):
            return None
    return {
        "lower": lower,
        "strip_html": allowed[0] in sp.pairs,
        "strip_parens": allowed[1] in sp.pairs,
    }


def _run_scan_pallas(buf: np.ndarray, sp: ScanPass) -> np.ndarray:
    """Offload a scan pass to the Pallas text-clean kernel when it matches
    the kernel's shape; byte-identical host fallback otherwise (also taken
    when jax is absent, e.g. on the jax-free remote shard workers).

    Multiprocessing children (the fork-based process shard executor and
    the pipeline's process pool) always take the host fallback: jax is
    multithreaded, so touching it in a forked child of a process whose
    parent may already have imported it is a deadlock — and the fallback
    is byte-identical by contract, so declining costs only the offload."""
    kwargs = _pallas_scan_args(sp)
    if kwargs is None or not sp.spans or buf.size == 0:
        return _run_scan(buf, sp)  # pure-LUT passes don't pay padding traffic
    import multiprocessing as _mp

    if _mp.parent_process() is not None:
        return _run_scan(buf, sp)
    try:
        from repro.kernels.text_clean.ops import scan_flat
    except Exception:
        return _run_scan(buf, sp)
    out = scan_flat(buf, **kwargs)
    if out is None:  # kernel declined (no jax, padding blow-up, …)
        return _run_scan(buf, sp)
    return out


def _span_indices(starts: np.ndarray, lens: np.ndarray) -> np.ndarray:
    """Flat indices covering ``[starts[k], starts[k]+lens[k])`` for all k —
    O(total span bytes), no Python loop."""
    total = int(lens.sum())
    cum = np.cumsum(lens) - lens
    return np.repeat(starts - cum, lens) + np.arange(total, dtype=np.int64)


def _run_word(buf: np.ndarray, wp: WordPass) -> np.ndarray:
    if buf.size == 0:
        return buf
    lut = wp.lut
    needs = any(nh for _, nh in wp.preds)
    # Word content is only consulted by hash-based predicates; otherwise
    # detection runs via boolean LUTs over the raw bytes and the value LUT
    # applies once, after compaction, to the (smaller) output.
    vals = buf if lut is None else (lut[buf] if needs else None)
    if vals is not None:
        sep = vals == ROW_SEP
        delim = sep | (vals == SPACE)
    else:
        sep = buf == ROW_SEP  # lut is sep-safe (checked at compile time)
        delim = (lut == SPACE)[buf] | sep
    isw = ~delim
    starts = isw.copy()
    starts[1:] &= delim[:-1]
    start_idx = np.flatnonzero(starts)
    if start_idx.size == 0:  # no words: a collapsed row is empty
        out = (buf if vals is None else vals)[sep]
        return out  # ROW_SEP is lut-invariant, so no final map needed
    lengths = np.add.reduceat(isw.astype(np.int32), start_idx)
    bad = np.zeros(start_idx.size, dtype=bool)
    if wp.preds:
        view = WordView(vals, start_idx, lengths) if needs else None
        for pred, _nh in wp.preds:
            bad |= pred(view, lengths)
    if bad.any():
        keep = isw
        keep[_span_indices(start_idx[bad], lengths[bad])] = False
        good = ~bad
        good_starts = start_idx[good]
        good_lens = lengths[good]
    else:
        keep = isw
        good_starts = start_idx
        good_lens = lengths
    # Collapse: emit exactly one space per gap between consecutive
    # surviving words of a row — the byte right after a surviving word's
    # end is always a (mapped) space when another word follows in the same
    # row, and all of a gap's space bytes map to the same output byte, so
    # keeping this one is byte-identical to sequential collapse.
    sep_idx = np.flatnonzero(sep)
    rows_g = np.searchsorted(sep_idx, good_starts)
    if good_starts.size > 1:
        not_last = np.empty(good_starts.size, dtype=bool)
        not_last[:-1] = rows_g[:-1] == rows_g[1:]
        not_last[-1] = False
        keep[good_starts[not_last] + good_lens[not_last]] = True
    keep |= sep
    out = (buf if vals is None else vals)[keep]
    return out if vals is not None or lut is None else lut[out]


def run_megapass(
    buf: np.ndarray, passes: Sequence[tuple[str, object]], *, pallas: bool = False
) -> np.ndarray:
    for kind, p in passes:
        if kind == "scan":
            buf = _run_scan_pallas(buf, p) if pallas else _run_scan(buf, p)
        elif kind == "word":
            buf = _run_word(buf, p)
        else:
            buf = apply_op(buf, p)
    return buf


# compile_megapass is cheap but runs once per shard x column; memoize by op
# identity (ops are built once at plan-compile time and live as long as the
# program).  Holding the ops tuple keeps the ids stable — a live object can
# never share an id with a cached one.
_MEGAPASS_CACHE: dict[tuple[int, ...], tuple[tuple[Op, ...], object]] = {}


def _compile_cached(ops: Sequence[Op]):
    key = tuple(id(op) for op in ops)
    hit = _MEGAPASS_CACHE.get(key)
    if hit is not None:
        return hit[1]
    prog = compile_megapass(ops)
    if len(_MEGAPASS_CACHE) >= 128:
        _MEGAPASS_CACHE.clear()
    _MEGAPASS_CACHE[key] = (tuple(ops), prog)
    return prog


def execute_ops(
    buf: np.ndarray, ops: Sequence[Op], backend: str | None = None
) -> np.ndarray:
    """Run an op chain under the selected backend (see module docstring).

    Byte-identical across backends; chains the megapass compiler cannot
    prove exact fall back to the loops backend wholesale."""
    b = resolve_backend(backend)
    if b == "loops" or not ops:
        return apply_ops(buf, ops)
    prog = _compile_cached(ops)
    if prog is None:
        return apply_ops(buf, ops)
    return run_megapass(buf, prog, pallas=(b == "pallas"))
