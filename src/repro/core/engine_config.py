"""One configuration surface for the execution engine.

Every engine knob used to resolve ad hoc at its point of use —
``REPRO_EXECUTOR`` inside :func:`repro.core.executor.make_executor`,
``REPRO_WORKERS`` inside ``Dataset._resolve_workers``, ``REPRO_CACHE`` /
``REPRO_CACHE_DIR`` in a module-level helper, ``REPRO_BYTES_BACKEND``
inside :func:`repro.core.bytesops.resolve_backend`, and
``REPRO_PALLAS_INTERPRET`` inside the Pallas bridge. :class:`EngineConfig`
is now the single owner of those knobs and of the one resolution order
they all share:

    explicit argument  >  builder verb (``.workers()/.cache()/.backend()``)
                       >  environment variable  >  default

``Dataset`` builds an :class:`EngineConfig` from its option dict
(:meth:`EngineConfig.from_options`), and :func:`make_executor`,
:func:`compile_shard_program`, the :class:`~repro.core.pipeline.Pipeline`
adapters, and the serving path (``Dataset.row_program()`` /
:mod:`repro.runtime.serve_loop`) all resolve through it — no call site
reads an engine environment variable directly anymore (the Pallas bridge
keeps its tri-state capability check but names the same
:data:`ENV_PALLAS_INTERPRET` knob).

The knobs:

=======================  =====================================================
``REPRO_EXECUTOR``       physical shard executor: ``thread``/``process``/
                         ``remote`` (empty = auto: processes when workers > 1)
``REPRO_WORKERS``        default worker count for every terminal
``REPRO_CACHE``          truthy = enable the on-disk shard cache
``REPRO_CACHE_DIR``      shard-cache root (with ``REPRO_CACHE`` or
                         ``.cache(True)``)
``REPRO_BYTES_BACKEND``  byte-kernel backend: ``loops``/``fused``/``pallas``
``REPRO_PALLAS_INTERPRET``  force Pallas interpret mode off-TPU
=======================  =====================================================

This module stays jax-free and import-light (it is pulled in by the
fork-side ``core.executor`` closure, rule R002).
"""

from __future__ import annotations

import os
from dataclasses import dataclass
from pathlib import Path
from typing import Any

ENV_EXECUTOR = "REPRO_EXECUTOR"
ENV_WORKERS = "REPRO_WORKERS"
ENV_CACHE = "REPRO_CACHE"
ENV_CACHE_DIR = "REPRO_CACHE_DIR"
ENV_BACKEND = "REPRO_BYTES_BACKEND"
ENV_PALLAS_INTERPRET = "REPRO_PALLAS_INTERPRET"

_TRUTHY = ("1", "true", "yes", "on")

# Sentinel distinguishing "no explicit cache choice" (environment decides)
# from an explicit ``.cache(False)`` (stored as None: cache off, env ignored).
_UNSET: Any = object()

EXECUTORS = ("", "thread", "process", "remote")


def _env_truthy(name: str) -> bool:
    return os.environ.get(name, "").strip().lower() in _TRUTHY


@dataclass(frozen=True)
class EngineConfig:
    """Explicitly-chosen engine options; ``resolve_*`` methods apply the
    env-then-default fallback. A field left at its default means "no
    explicit choice" and falls through to the environment knob."""

    executor: str | None = None
    workers: int | None = None
    cache_dir: Path | None = _UNSET
    backend: str | None = None
    remote: Any = None

    @classmethod
    def from_options(cls, options: dict[str, Any]) -> "EngineConfig":
        """Build from a ``Dataset`` option dict (the builder-verb layer).
        ``cache_dir`` is tri-state: absent = env decides, None = explicitly
        off, a path = explicitly on."""
        return cls(
            executor=options.get("executor"),
            workers=options.get("workers"),
            cache_dir=options["cache_dir"] if "cache_dir" in options else _UNSET,
            backend=options.get("backend"),
            remote=options.get("remote"),
        )

    # -- resolution (explicit > env > default) -----------------------------
    def resolve_executor(self, explicit: str | None = None) -> str:
        """``""`` means auto (processes when workers > 1, else threads —
        :func:`make_executor` applies that last step because it also owns
        the fallback rules)."""
        choice = (explicit or self.executor or os.environ.get(ENV_EXECUTOR) or "")
        choice = choice.strip().lower()
        if choice not in EXECUTORS:
            raise ValueError(
                f"unknown executor {choice!r}; use 'thread', 'process' or 'remote'"
            )
        return choice

    def resolve_workers(self, explicit: int | None = None, default: int = 1) -> int:
        if explicit is not None:
            return max(int(explicit), 1)
        if self.workers is not None:
            return max(int(self.workers), 1)
        env = os.environ.get(ENV_WORKERS)
        if env:
            try:
                return max(int(env), 1)
            except ValueError:
                pass
        return default

    def resolve_cache_dir(self) -> Path | None:
        """None = shard cache off. Explicit ``.cache(path)`` / ``.cache(False)``
        beats ``REPRO_CACHE`` (truthy = on, rooted at ``REPRO_CACHE_DIR`` or
        the system temp dir)."""
        if self.cache_dir is not _UNSET:
            return self.cache_dir
        if _env_truthy(ENV_CACHE):
            from .executor import default_cache_dir

            return default_cache_dir()
        return None

    def resolve_backend(self, explicit: str | None = None) -> str:
        from . import bytesops as B

        return B.resolve_backend(explicit or self.backend)

    @staticmethod
    def resolve_pallas_interpret() -> bool:
        """Whether ``REPRO_PALLAS_INTERPRET`` forces interpret-mode Pallas
        off-TPU (the bridge itself additionally auto-compiles on real TPU —
        see :func:`repro.kernels.text_clean.ops.scan_flat`)."""
        return bool(os.environ.get(ENV_PALLAS_INTERPRET))

    def executor_kwargs(
        self, *, workers: int | None = None, default_workers: int = 1
    ) -> dict[str, Any]:
        """The keyword set :func:`repro.core.executor.make_executor` takes,
        fully resolved — the one spelling every terminal shares."""
        return dict(
            workers=self.resolve_workers(workers, default_workers),
            cache_dir=self.resolve_cache_dir(),
            executor=self.executor,
            remote=self.remote,
        )
