"""P3SAPP data ingestion (paper Algorithm 1, steps 1-10).

Spark-SQL-JSON analogue: every shard file is parsed straight into columnar
buffers (orjson when available, stdlib json otherwise → object arrays),
shards are unioned columnar-cheaply, and the pre-cleaning steps (null drop,
dedup) are frame-level vector ops.

File-level parallelism (Spark partitions == files) is exposed through a
process pool; on this 1-core container it degrades gracefully to serial.
"""

from __future__ import annotations

import hashlib
from concurrent.futures import ProcessPoolExecutor
from pathlib import Path
from typing import Iterable, Sequence

import numpy as np

try:  # orjson is the fast path; stdlib json keeps bare environments working
    import orjson as _json

    _loads = _json.loads
except ModuleNotFoundError:  # pragma: no cover - exercised on bare envs
    import json as _json

    _loads = _json.loads

from .frame import ColumnarFrame


def _normalize(value):
    """NUL bytes cannot survive into the columnar engine (ROW_SEP is \\x00).

    Normalizing here — once, at ingestion — keeps the P3SAPP flat path and
    the row-wise CA oracle looking at identical text.
    """
    if isinstance(value, str) and "\x00" in value:
        return value.replace("\x00", " ")
    return value


def _parse_line_iter(lines: Iterable[bytes], fields: Sequence[str]) -> dict[str, list]:
    """One parse loop shared by the streaming and in-memory paths — they
    must never drift, or the executors stop being byte-identical."""
    cols: dict[str, list] = {f: [] for f in fields}
    for line in lines:
        line = line.strip()
        if not line:
            continue
        rec = _loads(line)
        for f in fields:
            cols[f].append(_normalize(rec.get(f)))
    return cols


def _parse_lines(data: bytes, fields: Sequence[str]) -> dict[str, list]:
    return _parse_line_iter(data.split(b"\n"), fields)


def _parse_file(args) -> dict[str, list]:
    # Streams line by line: whole-frame ingest() must not hold full shard
    # bytes in memory (only the executor/cache path needs them, for the
    # digest — that's read_shard_bytes).
    path, fields = args
    with open(path, "rb") as fh:
        return _parse_line_iter(fh, fields)


def shard_digest(data: bytes) -> str:
    """Content digest of raw shard bytes — half of the shard-cache key (the
    other half is the plan fingerprint; see :mod:`repro.core.executor`)."""
    return hashlib.blake2b(data, digest_size=16).hexdigest()


def read_shard_bytes(path: str | Path) -> tuple[bytes, str]:
    """Read one shard file, digesting during the read (one pass over the
    bytes, shared by caching and parsing)."""
    with open(path, "rb") as fh:
        data = fh.read()
    return data, shard_digest(data)


def parse_shard_bytes(data: bytes, fields: Sequence[str]) -> ColumnarFrame:
    """Parse raw shard bytes (e.g. out of a shared-memory buffer)."""
    cols = _parse_lines(data, tuple(fields))
    return ColumnarFrame({f: np.array(cols[f], dtype=object) for f in fields})


def parse_shard(path: str | Path, fields: Sequence[str]) -> ColumnarFrame:
    """Parse one shard file into a ColumnarFrame (streaming-executor unit)."""
    cols = _parse_file((str(path), tuple(fields)))
    return ColumnarFrame({f: np.array(cols[f], dtype=object) for f in fields})


def list_shards(directories: Sequence[str | Path]) -> list[Path]:
    files: list[Path] = []
    for d in directories:
        d = Path(d)
        if d.is_file():
            files.append(d)
        else:
            files.extend(sorted(p for p in d.rglob("*.jsonl") if p.is_file()))
    return files


def ingest(
    directories: Sequence[str | Path],
    fields: Sequence[str] = ("title", "abstract"),
    workers: int = 1,
) -> ColumnarFrame:
    """Steps 2-8: read every file of every directory, select fields, union."""
    files = list_shards(directories)
    if not files:
        return ColumnarFrame.empty(fields)
    jobs = [(str(p), tuple(fields)) for p in files]
    if workers <= 1:
        parsed = [_parse_file(j) for j in jobs]
    else:
        with ProcessPoolExecutor(max_workers=workers) as pool:
            parsed = list(pool.map(_parse_file, jobs))
    frames = [
        ColumnarFrame({f: np.array(c[f], dtype=object) for f in fields}) for c in parsed
    ]
    return ColumnarFrame.concat(frames)


def pre_clean(frame: ColumnarFrame, subset: Sequence[str] | None = None) -> ColumnarFrame:
    """Steps 9-10: remove NULL rows, remove duplicates."""
    return frame.dropna(subset).drop_duplicates(subset)
