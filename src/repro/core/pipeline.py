"""Spark-ML-style Pipeline: chained transformer stages over a ColumnarFrame.

Fidelity to the paper (Algorithm 1, steps 11-14):

* stages are declared up front (step 11),
* ``Pipeline.fit`` produces a ``PipelineModel`` (step 13; all our stages are
  pure transformers so fitting is structural, exactly like a Spark pipeline
  that contains only transformers),
* ``PipelineModel.transform`` runs all stages (step 14).

Both classes are thin adapters over the expression layer: a
``PipelineModel`` compiles its stages into per-column op plans
(``column_plans``; each stage's ops derive from its expression, see
:meth:`repro.core.stages.Stage.to_expr`) and hands them to
:func:`run_column_plans`. The ``Dataset`` planner (:mod:`repro.core.plan`)
runs the same expressions through its ``Project`` nodes, so both paths are
byte-identical by construction.

Execution model — the P3SAPP speedup: per *column* we flatten once into a
byte buffer, run that column's stage chain as vectorized passes, and
unflatten once. Two executor modes:

* ``optimize=False`` — paper-faithful: each stage's ops run in sequence.
* ``optimize=True``  — beyond-paper: the per-column op list is fused
  Catalyst-style across stage boundaries (LUT∘LUT, OR-ed word predicates,
  deduped collapses) before execution. Exact, see bytesops docstring.

Optionally the per-column work fans out over a process pool (Spark
``local[k]`` analogue) by splitting the buffer on row boundaries into ``k``
chunks — embarrassingly parallel because every stage is row-local.
"""

from __future__ import annotations

import os
from concurrent.futures import ProcessPoolExecutor
from typing import Sequence

import numpy as np

from . import bytesops as B
from .engine_config import EngineConfig
from .frame import ColumnarFrame
from .stages import Stage

# One compiled per-column execution unit: read input_col, run ops, write
# output_col. The plan optimizer and the streaming executor share this form.
ColumnPlan = tuple[str, str, list[B.Op]]


class Pipeline:
    def __init__(self, stages: Sequence[Stage]):
        self.stages = list(stages)

    def fit(self, frame: ColumnarFrame) -> "PipelineModel":
        return PipelineModel([s.fit(frame) for s in self.stages])


def _split_on_rows(buf: np.ndarray, k: int) -> list[np.ndarray]:
    """Split a flat buffer into <=k chunks at row-separator boundaries."""
    if k <= 1 or buf.size == 0:
        return [buf]
    sep_idx = np.flatnonzero(buf == B.ROW_SEP)
    if sep_idx.size < k:
        return [buf]
    cut_rows = np.linspace(0, sep_idx.size, k + 1).astype(np.int64)[1:-1]
    cuts = sep_idx[cut_rows - 1] + 1
    return np.split(buf, cuts)


def _run_ops(args) -> np.ndarray:
    """Pool task: ``(ops, buf)`` or ``(ops, buf, backend)``. The driver
    resolves the backend through :class:`EngineConfig` before fan-out, so
    every chunk of a run uses the same backend regardless of worker env."""
    ops, buf = args[0], args[1]
    backend = args[2] if len(args) > 2 else None
    return B.execute_ops(buf, ops, backend)


def compile_column_plans(
    stages: Sequence[Stage], optimize: bool
) -> list[ColumnPlan]:
    """Ordered (input_col, output_col, ops) execution plans for a stage chain.

    Consecutive stages reading/writing the same column merge into one plan;
    a stage with ``output_col != input_col`` forks a new plan fed by the
    current state of its input column.
    """
    plans: list[ColumnPlan] = []
    current: dict[str, int] = {}  # column -> index of its live plan
    for s in stages:
        ops = s.flat_ops()
        if s.input_col not in current:
            plans.append((s.input_col, s.input_col, []))
            current[s.input_col] = len(plans) - 1
        if s.output_col == s.input_col:
            plans[current[s.input_col]][2].extend(ops)
        else:
            src_plan = current[s.input_col]
            plans.append((plans[src_plan][1], s.output_col, list(ops)))
            current[s.output_col] = len(plans) - 1
            # Seal the source plan: later stages on input_col must not
            # retroactively change what this fork read (Spark order
            # semantics) — they start a fresh plan instead.
            current.pop(s.input_col, None)
    if optimize:
        plans = [(i, o, B.fuse_ops(ops)) for i, o, ops in plans]
    return plans


def run_column_plans(
    frame: ColumnarFrame,
    plans: Sequence[ColumnPlan],
    workers: int = 1,
    backend: str | None = None,
) -> ColumnarFrame:
    """Physical executor: flatten each input column once, run its fused op
    chain (optionally fanned out over a process pool), unflatten once."""
    backend = EngineConfig(backend=backend).resolve_backend()
    bufs: dict[str, np.ndarray] = {}
    out = frame
    pool = ProcessPoolExecutor(max_workers=workers) if workers > 1 else None
    try:
        for in_col, out_col, ops in plans:
            src = bufs.get(in_col)
            if src is None:
                src = frame.flat(in_col)
            if pool is None:
                res = _run_ops((ops, src, backend))
            else:
                chunks = _split_on_rows(src, workers)
                parts = list(pool.map(_run_ops, [(ops, c, backend) for c in chunks]))
                res = np.concatenate(parts) if parts else src
            bufs[out_col] = res
            out = out.ensure_column(out_col).with_flat(out_col, res)
    finally:
        if pool is not None:
            pool.shutdown()
    return out


class PipelineModel:
    def __init__(self, stages: Sequence[Stage]):
        self.stages = list(stages)

    def column_plans(self, optimize: bool) -> list[ColumnPlan]:
        return compile_column_plans(self.stages, optimize)

    def transform(
        self,
        frame: ColumnarFrame,
        workers: int = 1,
        optimize: bool = True,
        backend: str | None = None,
    ) -> ColumnarFrame:
        return run_column_plans(
            frame, self.column_plans(optimize), workers, backend=backend
        )


def default_workers() -> int:
    return max(1, os.cpu_count() or 1)
