"""Logical plan + planner behind the lazy ``Dataset`` API.

The paper's thesis is that preprocessing declared as one pipeline beats
imperative glue because the engine can plan the whole flow (P3SAPP, §3).
This module is that engine for the full path — ingestion to device batches,
not just the cleaning segment:

* **Logical plan** — a linear sequence of immutable nodes
  (``SourceJsonDirs → Select/DropNA/DropDuplicates/Project/Filter/Split →
  Tokenize → Batch → Prefetch``) built by :class:`repro.core.dataset.Dataset`.
  ``Project`` carries ``(out_col, expression)`` entries and ``Filter`` a
  row predicate — both from the column-expression IR
  (:mod:`repro.core.expr`); the legacy ``Stage`` verbs lower to them.
* **Optimizer** (:func:`optimize_plan`) — Catalyst-style rewrites, all
  exact: adjacent ``Project`` nodes merge (their in-place chains then fuse
  via ``bytesops.fuse_ops``), adjacent ``DropNA``/``Filter`` nodes merge,
  a ``DropNA`` or ``Filter`` commutes backward past a ``Project`` that
  does not write any column it reads (dropped rows are never cleaned) —
  splitting ``&``-conjunctions and ``DropNA`` subsets so the raw-column
  half keeps moving when the derived half must stay
  (:func:`_split_row_filter`) — derived columns nothing downstream reads
  are pruned, a source-level liveness pass projects away columns nothing
  downstream reads, and sub-expressions shared across consumers hoist
  into ``__cse_*`` intermediates computed once (:func:`_cse_pass`).
* **Physical executors** — :func:`execute_frame_plan` runs the frame-level
  prefix whole-frame with the paper's stage-timing attribution
  (:class:`StageTimings`), while :func:`stream_batches` runs the same plan
  per shard over a work-stealing shard executor — reader threads or worker
  processes with shared-memory transport and an optional plan-fingerprint
  shard cache (:mod:`repro.core.executor`) — so cleaning/tokenizing/batching
  overlap device compute end-to-end when fed into an
  :class:`~repro.core.async_loader.AsyncLoader`.
* **Fingerprints** — :func:`plan_fingerprint` stably hashes the optimized
  plan; composed per column with each shard's bytes digest it keys the
  on-disk shard cache (the Spark ``persist()`` analogue).
"""

from __future__ import annotations

import hashlib
import heapq
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Iterator, Sequence

import numpy as np

from ..data.batching import (
    TokenSpec,
    emit_bucketed,
    encode_frame_columns,
    pad_batch,
    split_indices,
)
from . import expr as E
from . import ingest as ing
from .frame import ColumnarFrame


@dataclass
class StageTimings:
    """Paper §3 timing attribution (eq. 7), extended with the token step:
    ``tokenize`` covers text→int32 encoding and vocabulary counting, so
    the Table-3-style attribution spans the full text→tensor path."""

    ingestion: float = 0.0
    pre_cleaning: float = 0.0
    cleaning: float = 0.0
    post_cleaning: float = 0.0
    tokenize: float = 0.0

    @property
    def preprocessing(self) -> float:
        return self.pre_cleaning + self.cleaning + self.post_cleaning + self.tokenize

    @property
    def cumulative(self) -> float:
        return self.ingestion + self.preprocessing

    def as_dict(self) -> dict:
        return {
            "ingestion": self.ingestion,
            "pre_cleaning": self.pre_cleaning,
            "cleaning": self.cleaning,
            "post_cleaning": self.post_cleaning,
            "tokenize": self.tokenize,
            "preprocessing": self.preprocessing,
            "cumulative": self.cumulative,
        }


# ---------------------------------------------------------------------------
# Logical plan nodes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PlanNode:
    def describe(self) -> str:
        return type(self).__name__


@dataclass(frozen=True)
class SourceJsonDirs(PlanNode):
    directories: tuple[str, ...]
    fields: tuple[str, ...]

    def describe(self) -> str:
        return f"SourceJsonDirs(dirs={len(self.directories)}, fields={list(self.fields)})"


@dataclass(frozen=True)
class SourceFrame(PlanNode):
    frame: Any  # ColumnarFrame

    def describe(self) -> str:
        return f"SourceFrame(rows={len(self.frame)}, fields={self.frame.field_names})"


@dataclass(frozen=True)
class Select(PlanNode):
    fields: tuple[str, ...]

    def describe(self) -> str:
        return f"Select({list(self.fields)})"


@dataclass(frozen=True)
class DropNA(PlanNode):
    subset: tuple[str, ...]

    def describe(self) -> str:
        return f"DropNA({list(self.subset)})"


@dataclass(frozen=True)
class DropDuplicates(PlanNode):
    subset: tuple[str, ...]

    def describe(self) -> str:
        return f"DropDuplicates({list(self.subset)})"


@dataclass(frozen=True, eq=False)
class Project(PlanNode):
    """Sequential ``(out_col, expression)`` entries — entry k sees the
    columns entries < k wrote (Spark ``withColumn`` chaining)."""

    exprs: tuple[tuple[str, E.Expr], ...]

    def written(self) -> set[str]:
        return {out for out, _ in self.exprs}

    def describe(self) -> str:
        inner = ", ".join(f"{out}={e.describe()}" for out, e in self.exprs)
        return f"Project({inner})"


@dataclass(frozen=True, eq=False)
class Filter(PlanNode):
    """Row filter by a byte-buffer predicate (``Dataset.where``)."""

    pred: E.Pred

    def describe(self) -> str:
        return f"Filter({self.pred.describe()})"


@dataclass(frozen=True)
class Split(PlanNode):
    """Deterministic row split (train/val); ``part`` selects the side."""

    fraction: float
    seed: int
    part: str  # "train" | "val"

    def describe(self) -> str:
        return f"Split({self.part}, fraction={self.fraction}, seed={self.seed})"


@dataclass(frozen=True)
class Tokenize(PlanNode):
    tokenizer: Any  # WordTokenizer
    specs: tuple[TokenSpec, ...]

    def describe(self) -> str:
        parts = [
            f"{s.column}->{s.name}[max_len={s.max_len}"
            + (", start_end" if s.add_start_end else "")
            + "]"
            for s in self.specs
        ]
        return f"Tokenize({', '.join(parts)})"


@dataclass(frozen=True)
class Batch(PlanNode):
    batch_size: int
    shuffle: bool = True
    seed: int = 0
    drop_remainder: bool = True
    pad_to: int | None = None
    # Length-bucketed assembly: rows grouped by the payload length of the
    # ``bucket_by`` token column(s) into the fixed ``buckets`` widths —
    # one width list for a single column, one list per column (a 2-D
    # grid) for paired encoder/decoder bucketing.
    bucket_by: str | tuple[str, ...] | None = None
    buckets: tuple = ()

    def describe(self) -> str:
        base = (
            f"Batch(size={self.batch_size}, shuffle={self.shuffle}, "
            f"seed={self.seed}, drop_remainder={self.drop_remainder}, "
            f"pad_to={self.pad_to}"
        )
        if self.bucket_by is not None:
            bb = (
                self.bucket_by
                if isinstance(self.bucket_by, str)
                else list(self.bucket_by)
            )
            bk = [
                list(b) if isinstance(b, tuple) else b for b in self.buckets
            ]
            base += f", bucket_by={bb}, buckets={bk}"
        return base + ")"


@dataclass(frozen=True)
class Prefetch(PlanNode):
    prefetch: int = 2
    sharding: Any = None

    def describe(self) -> str:
        return f"Prefetch(depth={self.prefetch}, sharding={self.sharding is not None})"


FRAME_NODES = (
    SourceJsonDirs, SourceFrame, Select, DropNA, DropDuplicates, Project, Filter, Split
)
ARRAY_NODES = (Tokenize, Batch, Prefetch)


def is_frame_node(node: PlanNode) -> bool:
    return isinstance(node, FRAME_NODES)


def split_plan(nodes: Sequence[PlanNode]) -> tuple[list[PlanNode], list[PlanNode]]:
    """(frame-level prefix, array-level suffix)."""
    frame_nodes = [n for n in nodes if is_frame_node(n)]
    array_nodes = [n for n in nodes if not is_frame_node(n)]
    return frame_nodes, array_nodes


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------


def _filter_read_cols(node: PlanNode) -> set[str]:
    if isinstance(node, DropNA):
        return set(node.subset)
    assert isinstance(node, Filter)
    return node.pred.inputs()


def _merge_adjacent(nodes: list[PlanNode]) -> list[PlanNode]:
    out: list[PlanNode] = []
    for node in nodes:
        prev = out[-1] if out else None
        if isinstance(node, Project) and isinstance(prev, Project):
            out[-1] = Project(prev.exprs + node.exprs)
        elif isinstance(node, DropNA) and isinstance(prev, DropNA):
            merged = prev.subset + tuple(f for f in node.subset if f not in prev.subset)
            out[-1] = DropNA(merged)
        elif isinstance(node, Filter) and isinstance(prev, Filter):
            out[-1] = Filter(prev.pred & node.pred)
        elif isinstance(node, Select) and isinstance(prev, Select):
            out[-1] = node  # the later projection wins
        else:
            out.append(node)
    return out


def _split_row_filter(a: Project, b: PlanNode) -> list[PlanNode] | None:
    """Conjunct-split pushdown: a blocked conjunction filter splits at a
    ``Project`` — conjuncts reading only columns the Project does not
    write commute below it, conjuncts on derived columns stay put. Rows a
    raw-column conjunct rejects are then never cleaned even when the same
    ``where`` also constrains a derived column. ``None`` when no split
    applies (single conjunct, or nothing/everything pushable)."""
    written = a.written()
    if isinstance(b, DropNA):
        push = tuple(c for c in b.subset if c not in written)
        stay = tuple(c for c in b.subset if c in written)
        if push and stay:
            return [DropNA(push), a, DropNA(stay)]
        return None
    assert isinstance(b, Filter)
    conjuncts = E.split_conjuncts(b.pred)
    if len(conjuncts) < 2:
        return None
    push = [c for c in conjuncts if not (c.inputs() & written)]
    stay = [c for c in conjuncts if c.inputs() & written]
    if push and stay:
        return [Filter(E.and_all(push)), a, Filter(E.and_all(stay))]
    return None


def _pull_filters_back(nodes: list[PlanNode]) -> list[PlanNode]:
    """A row filter (``DropNA`` or ``Filter``) commutes backward past a
    ``Project`` that does not write any column the filter reads — dropped
    rows are then never flattened/cleaned. This generalizes the original
    dropna pullback to arbitrary ``where`` predicates. A filter that
    cannot move as a unit splits at the conjunction: its raw-column
    conjuncts keep commuting toward the source while the derived-column
    conjuncts stay behind the Project (see :func:`_split_row_filter`)."""
    changed = True
    while changed:
        changed = False
        i = 0
        while i < len(nodes) - 1:
            a, b = nodes[i], nodes[i + 1]
            if isinstance(a, Project) and isinstance(b, (DropNA, Filter)):
                if not (_filter_read_cols(b) & a.written()):
                    nodes[i], nodes[i + 1] = b, a
                    changed = True
                else:
                    split = _split_row_filter(a, b)
                    if split is not None:
                        nodes[i : i + 2] = split
                        changed = True
            i += 1
        nodes = _merge_adjacent(nodes)
    return nodes


def _node_read_written(node: PlanNode) -> tuple[set[str], set[str]]:
    """(columns the node reads, columns it writes) — liveness bookkeeping."""
    if isinstance(node, (DropNA, DropDuplicates)):
        return set(node.subset), set()
    if isinstance(node, Filter):
        return node.pred.inputs(), set()
    return set(), set()


def _prune_and_project(
    nodes: list[PlanNode], final_schema: Sequence[str]
) -> list[PlanNode]:
    """Backward liveness pass: drop ``Project`` entries whose output nothing
    downstream reads (unused derived columns), then narrow the JSON source
    to the columns actually consumed. Entry pruning needs to know the
    terminal's schema; with an empty ``final_schema`` only the source
    narrowing runs (conservative)."""
    prune = bool(final_schema)
    needed = set(final_schema)
    out_rev: list[PlanNode] = []
    for node in reversed(nodes[1:]):
        if isinstance(node, Select):
            needed = set(node.fields)
        elif isinstance(node, Tokenize):
            needed = {spec.column for spec in node.specs}
        elif isinstance(node, Project):
            kept: list[tuple[str, E.Expr]] = []
            for out_col, e in reversed(node.exprs):
                reads = e.inputs()
                if prune and out_col not in needed:
                    continue  # dead derived column: never computed
                if out_col not in reads:
                    needed.discard(out_col)
                needed |= reads
                kept.append((out_col, e))
            if not kept:
                continue  # entire node was dead
            node = Project(tuple(reversed(kept)))
        else:
            reads, _ = _node_read_written(node)
            needed |= reads
        out_rev.append(node)
    nodes = [nodes[0]] + list(reversed(out_rev))
    src = nodes[0]
    if isinstance(src, SourceJsonDirs):
        kept_fields = tuple(f for f in src.fields if f in needed)
        if kept_fields and kept_fields != src.fields:
            nodes[0] = SourceJsonDirs(src.directories, kept_fields)
    return nodes


_CSE_PREFIX = "__cse_"


def _cse_name(sig: bytes) -> str:
    return _CSE_PREFIX + hashlib.blake2b(sig, digest_size=16).hexdigest()[:12]


def _cse_pass(nodes: list[PlanNode], final_schema: Sequence[str]) -> list[PlanNode]:
    """Cross-node common-subexpression elimination (exact).

    Two walks over the frame plan, both tracking a per-column *version
    token* so ``col("x")`` before and after an overwrite of ``x`` never
    aliases (:func:`repro.core.expr.resolved_signature`). The first walk
    counts version-resolved occurrences of every non-leaf sub-expression
    across ``Project`` entries and ``Filter`` predicates; a sub-expression
    occurring at least twice is elected unless it only ever appears inside
    one strictly larger shared expression (then the larger one is elected
    instead). The second walk hoists each elected sub-expression into a
    synthetic ``__cse_<fp>`` Project entry at its first use and rewrites
    every consumer — later Project entries *and* Filter predicates — to
    read the memoized column, so a chain shared by a ``where`` and a
    derived column evaluates once per shard. Expression evaluation is
    row-local, so computing the intermediate at the earliest consumer and
    row-filtering it alongside every other buffer is value-preserving.
    A terminal ``Select`` keeps the synthetic columns out of the result
    schema; with an empty ``final_schema`` the pass is skipped (there is
    no terminal schema to hide them behind).
    """
    if not final_schema:
        return nodes

    # A user ``Select`` between two consumers would drop the synthetic
    # column, so sharing is scoped to Select-free regions: occurrences key
    # on (region, signature) and a hoisted definition never outlives its
    # region.
    occ: dict[tuple[int, bytes], int] = {}
    parents: dict[tuple[int, bytes], set[bytes | None]] = {}

    def count(e: E.Expr, versions: dict, region: int, parent: bytes | None) -> None:
        if isinstance(e, (E.Col, E.Lit)):
            return
        sig = E.resolved_signature(e, versions)
        kids = [e.input] if isinstance(e, E.StrOp) else list(e.parts)
        for k in kids:
            count(k, versions, region, sig)
        if sig is not None:
            occ[region, sig] = occ.get((region, sig), 0) + 1
            parents.setdefault((region, sig), set()).add(parent)

    versions: dict[str, bytes | None] = {}
    region = 0
    for node in nodes:
        if isinstance(node, Select):
            region += 1
        elif isinstance(node, Project):
            for out_col, e in node.exprs:
                sig_e = E.resolved_signature(e, versions)
                count(e, versions, region, None)
                versions[out_col] = sig_e
        elif isinstance(node, Filter):
            for e in E.pred_exprs(node.pred):
                count(e, versions, region, None)

    selected: set[tuple[int, bytes]] = set()
    for (reg, sig), n in occ.items():
        if n < 2:
            continue
        ps = parents.get((reg, sig), set())
        if len(ps) == 1:
            (p,) = ps
            if p is not None and occ.get((reg, p), 0) >= 2:
                continue  # covered by a strictly larger shared expression
        selected.add((reg, sig))
    if not selected:
        return nodes

    defined: dict[tuple[int, bytes], str] = {}
    region = 0

    def rewrite(
        e: E.Expr, versions: dict, defs: list[tuple[str, E.Expr]]
    ) -> E.Expr:
        """Replace elected subtrees (signatures from the *original* tree)
        with references to their synthetic column, defining it at first
        use."""
        if isinstance(e, (E.Col, E.Lit)):
            return e
        sig = E.resolved_signature(e, versions)
        if isinstance(e, E.StrOp):
            new_in = rewrite(e.input, versions, defs)
            new_e: E.Expr = (
                e if new_in is e.input else E.StrOp(new_in, e.op, e.label)
            )
        else:  # Concat
            new_parts = tuple(rewrite(p, versions, defs) for p in e.parts)
            new_e = (
                e
                if all(a is b for a, b in zip(new_parts, e.parts))
                else E.Concat(new_parts, e.sep)
            )
        if sig is not None and (region, sig) in selected:
            name = defined.get((region, sig))
            if name is None:
                name = _cse_name(sig)
                defined[region, sig] = name
                defs.append((name, new_e))
            return E.Col(name)
        return new_e

    versions = {}
    out_nodes: list[PlanNode] = []
    for node in nodes:
        if isinstance(node, Select):
            region += 1
            out_nodes.append(node)
        elif isinstance(node, Project):
            entries: list[tuple[str, E.Expr]] = []
            for out_col, e in node.exprs:
                sig_e = E.resolved_signature(e, versions)
                defs: list[tuple[str, E.Expr]] = []
                new_e = rewrite(e, versions, defs)
                entries.extend(defs)
                entries.append((out_col, new_e))
                versions[out_col] = sig_e
            out_nodes.append(Project(tuple(entries)))
        elif isinstance(node, Filter):
            defs = []
            new_pred = E.map_pred_exprs(
                node.pred, lambda ex: rewrite(ex, versions, defs)
            )
            if defs:
                out_nodes.append(Project(tuple(defs)))
            out_nodes.append(Filter(new_pred))
        else:
            out_nodes.append(node)
    if not defined:
        return nodes
    return out_nodes + [Select(tuple(final_schema))]


def optimize_plan(
    nodes: Sequence[PlanNode], final_schema: Sequence[str] = ()
) -> list[PlanNode]:
    """Catalyst-style logical rewrites (exact: never change the result)."""
    out = _merge_adjacent(list(nodes))
    out = _pull_filters_back(out)
    out = _prune_and_project(out, final_schema)
    out = _cse_pass(out, final_schema)
    return _merge_adjacent(out)


def _node_signature(node: PlanNode) -> bytes:
    """Stable byte signature of one node (parameter-exact for expressions)."""
    if isinstance(node, Project):
        parts = [b"Project"]
        for out_col, e in node.exprs:
            parts.append(out_col.encode() + b"=" + e.signature())
        return b"|".join(parts)
    if isinstance(node, Filter):
        return b"Filter:" + node.pred.signature()
    if isinstance(node, SourceJsonDirs):
        # describe() elides the directory list; the fingerprint must not.
        return f"SourceJsonDirs({list(node.directories)}, {list(node.fields)})".encode()
    if isinstance(node, SourceFrame):
        return f"SourceFrame(rows={len(node.frame)}, fields={node.frame.field_names})".encode()
    if isinstance(node, Tokenize):
        # Spec parameters in full; tokenizer identity is deliberately
        # excluded — plan fingerprints key *preprocessing*, not
        # vocabularies (the token cache adds the vocab fingerprint).
        parts = [b"Tokenize"]
        for s in node.specs:
            parts.append(
                f"{s.column}->{s.name}:max_len={s.max_len}"
                f":start_end={s.add_start_end}".encode()
            )
        return b"|".join(parts)
    # Remaining nodes are fully described by their parameters.
    return node.describe().encode()


def plan_fingerprint(
    nodes: Sequence[PlanNode], final_schema: Sequence[str] = (), optimize: bool = True
) -> str:
    """Stable hex fingerprint of the (optimized) plan.

    Changes whenever any node or any stage op parameter changes; invariant
    under re-construction of an identical chain. The shard cache composes
    this per column (see :func:`repro.core.executor.column_fingerprints`)
    with the source shard's bytes digest.
    """
    frame_nodes, array_nodes = split_plan(nodes)
    if optimize:
        frame_nodes = optimize_plan(frame_nodes, final_schema)
    h = hashlib.blake2b(digest_size=16)
    for node in list(frame_nodes) + list(array_nodes):
        sig = _node_signature(node)
        h.update(len(sig).to_bytes(8, "little"))
        h.update(sig)
    return h.hexdigest()


def explain(
    nodes: Sequence[PlanNode],
    final_schema: Sequence[str] = (),
    optimize: bool = True,
    backend: str | None = None,
) -> str:
    lines = ["== logical plan =="]
    lines += [f"  {i}: {n.describe()}" for i, n in enumerate(nodes)]
    if optimize:
        opt = optimize_plan(nodes, final_schema)
        lines.append("== optimized plan ==")
        lines += [f"  {i}: {n.describe()}" for i, n in enumerate(opt)]
    # Plan-level (explicit) backend choice only: the env var applies at
    # execution time and must not make explain() output non-deterministic.
    lines.append("== physical ==")
    lines.append(f"  bytes backend: {backend or 'loops'}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Whole-frame physical executor (with the paper's timing attribution)
# ---------------------------------------------------------------------------


def run_project_frame(
    frame: ColumnarFrame,
    compiled: Sequence[tuple[str, tuple]],
    workers: int = 1,
    backend: str | None = None,
) -> ColumnarFrame:
    """Whole-frame Project executor: flatten each input column once, run
    the compiled expression, unflatten once. Pure op chains optionally fan
    out over a process pool by splitting the buffer on row boundaries
    (every byte op is row-local, so this is embarrassingly parallel)."""
    from .pipeline import _run_ops, _split_on_rows

    flat: dict[str, np.ndarray] = {}
    src_flat: dict[str, np.ndarray] = {}  # raw columns flatten at most once

    def lookup(c: str) -> np.ndarray:
        if c in flat:
            return flat[c]
        if c not in src_flat:
            src_flat[c] = frame.flat(c)
        return src_flat[c]

    pool = None
    if workers > 1:
        from concurrent.futures import ProcessPoolExecutor

        pool = ProcessPoolExecutor(max_workers=workers)
    out = frame
    try:
        for out_col, comp in compiled:
            if comp[0] == "chain" and not comp[2]:
                buf = lookup(comp[1])  # pure alias (CSE consumer): no copy
            elif pool is not None and comp[0] == "chain":
                src = lookup(comp[1])
                chunks = _split_on_rows(src, workers)
                parts = list(
                    pool.map(_run_ops, [(list(comp[2]), c, backend) for c in chunks])
                )
                buf = np.concatenate(parts) if parts else src
            else:
                buf = E.eval_str(comp, lookup, len(frame), backend)
            flat[out_col] = buf
            out = out.ensure_column(out_col).with_flat(out_col, buf)
    finally:
        if pool is not None:
            pool.shutdown()
    return out


def _exec_frame_node(
    node: PlanNode,
    frame: ColumnarFrame | None,
    workers: int,
    optimize: bool,
    backend: str | None = None,
) -> ColumnarFrame:
    if isinstance(node, SourceJsonDirs):
        return ing.ingest(node.directories, node.fields, workers=workers)
    if isinstance(node, SourceFrame):
        return node.frame
    assert frame is not None, "plan must start with a source node"
    if isinstance(node, Select):
        return frame.select(list(node.fields))
    if isinstance(node, DropNA):
        return frame.dropna(list(node.subset))
    if isinstance(node, DropDuplicates):
        return frame.drop_duplicates(list(node.subset))
    if isinstance(node, Project):
        compiled = E.compile_project(node.exprs, optimize)
        return run_project_frame(frame, compiled, workers=workers, backend=backend)
    if isinstance(node, Filter):
        comp = E.compile_pred(node.pred)
        if optimize:
            comp = E.fuse_compiled(comp)
        memo: dict[str, np.ndarray] = {}  # predicate leaves share one flatten

        def lk(c: str) -> np.ndarray:
            if c not in memo:
                memo[c] = frame.flat(c)
            return memo[c]

        keep = E.eval_mask(comp, lk, len(frame), backend)
        return frame if keep.all() else frame.take(keep)
    if isinstance(node, Split):
        train, val = split_indices(len(frame), node.fraction, node.seed)
        return frame.take(np.sort(train) if node.part == "train" else np.sort(val))
    raise ValueError(f"not a frame-level node: {node!r}")


def execute_frame_plan(
    nodes: Sequence[PlanNode],
    *,
    workers: int = 1,
    optimize: bool = True,
    final_schema: Sequence[str] = (),
    backend: str | None = None,
) -> tuple[ColumnarFrame, StageTimings]:
    """Run the frame-level plan whole-frame, attributing wall time to the
    paper's phases: source → ingestion, filters before the first stage chain
    → pre-cleaning, stage chains → cleaning, everything after → post-cleaning.

    ``optimize=False`` is the paper-faithful executor (no plan rewrites, no
    op fusion); ``optimize=True`` is the beyond-paper planned/fused path.
    """
    frame_nodes, array_nodes = split_plan(nodes)
    if array_nodes:
        raise ValueError(f"array-level nodes in frame execution: {array_nodes}")
    if optimize:
        frame_nodes = optimize_plan(frame_nodes, final_schema)
    return continue_frame_plan(
        None,
        StageTimings(),
        frame_nodes,
        workers=workers,
        optimize=optimize,
        backend=backend,
    )


def continue_frame_plan(
    frame: ColumnarFrame | None,
    timings: StageTimings,
    nodes: Sequence[PlanNode],
    *,
    workers: int = 1,
    optimize: bool = True,
    seen_cleaning: bool = False,
    backend: str | None = None,
) -> tuple[ColumnarFrame, StageTimings]:
    """Run ``nodes`` starting from an already-materialized ``frame`` (or from
    scratch when ``frame`` is None), accumulating onto a copy of ``timings``.
    This is how a derived plan resumes from a memoized prefix instead of
    re-ingesting."""
    t = StageTimings(
        timings.ingestion,
        timings.pre_cleaning,
        timings.cleaning,
        timings.post_cleaning,
        timings.tokenize,
    )
    for node in nodes:
        t0 = time.perf_counter()
        frame = _exec_frame_node(node, frame, workers, optimize, backend)
        dt = time.perf_counter() - t0
        if isinstance(node, (SourceJsonDirs, SourceFrame)):
            t.ingestion += dt
        elif isinstance(node, Project):
            seen_cleaning = True
            t.cleaning += dt
        elif seen_cleaning:
            t.post_cleaning += dt
        else:
            t.pre_cleaning += dt
    assert frame is not None, "empty plan"
    return frame, t


def execute_array_nodes(
    frame: ColumnarFrame, array_nodes: Sequence[PlanNode]
) -> dict[str, np.ndarray]:
    """Materialize the Tokenize node of the array-level suffix whole-frame."""
    tok = next((n for n in array_nodes if isinstance(n, Tokenize)), None)
    if tok is None:
        raise ValueError("plan has no Tokenize node; add .tokenize(...) first")
    columns = {spec.column: frame[spec.column] for spec in tok.specs}
    return encode_frame_columns(columns, tok.tokenizer, tok.specs)


# ---------------------------------------------------------------------------
# Streaming physical executor: per-shard over a shard executor
# ---------------------------------------------------------------------------


def _drain_bucketed(
    pool: dict[str, np.ndarray],
    order: np.ndarray,
    batch: Batch,
    rng: np.random.Generator,
    final: bool,
) -> tuple[list[dict[str, np.ndarray]], dict[str, np.ndarray] | None]:
    """Bucketed drain: (emitted batches, carry rows). Full batches are
    per-bucket-cell, sliced to the cell widths; per-cell remainders carry
    to the next window, or on the final drain follow the batch node's
    remainder policy (shared ``emit_remainders``). When shuffling, the
    emitted batch order is permuted too — matching the whole-frame
    assembler — so the stream is not a systematic short-to-long length
    run within every window."""
    from ..data.batching import bucket_grid, emit_remainders

    _, buckets = bucket_grid(batch.bucket_by, batch.buckets, pool)
    out, rest = emit_bucketed(pool, order, batch.batch_size, batch.bucket_by, buckets)
    carry: dict[str, np.ndarray] | None = None
    if rest.size:
        rest_rows = {k: v[rest] for k, v in pool.items()}
        if not final:
            carry = rest_rows
        else:
            out.extend(
                emit_remainders(
                    rest_rows, batch.bucket_by, buckets,
                    batch.pad_to, batch.drop_remainder,
                )
            )
    if batch.shuffle:
        rng.shuffle(out)
    return out, carry


def _batched(
    chunks: Iterator[dict[str, np.ndarray]],
    batch: Batch,
    rng: np.random.Generator,
    shuffle_buffer: int,
) -> Iterator[dict[str, np.ndarray]]:
    """Accumulate per-shard arrays and slice fixed-size batches; when
    shuffling, permute within a bounded buffer (streaming cannot see the
    whole epoch, so this is windowed shuffle a la tf.data). With a
    bucketed batch node, rows group by payload length within the same
    window (windowed bucketing a la tf.data bucket_by_sequence_length)."""
    parts: list[dict[str, np.ndarray]] = []
    n_buf = 0
    threshold = shuffle_buffer if batch.shuffle else batch.batch_size

    def drain(final: bool) -> Iterator[dict[str, np.ndarray]]:
        nonlocal parts, n_buf
        if not parts:
            return
        keys = parts[0].keys()
        pool = {k: np.concatenate([p[k] for p in parts]) for k in keys}
        parts, n_buf = [], 0
        n = len(next(iter(pool.values())))
        order = rng.permutation(n) if batch.shuffle else np.arange(n)
        if batch.bucket_by is not None:
            out, carry = _drain_bucketed(pool, order, batch, rng, final)
            if carry is not None:
                parts, n_buf = [carry], len(next(iter(carry.values())))
            yield from out
            return
        if batch.shuffle:
            pool = {k: v[order] for k, v in pool.items()}
        full_stop = (n // batch.batch_size) * batch.batch_size
        for s in range(0, full_stop, batch.batch_size):
            yield {k: v[s : s + batch.batch_size] for k, v in pool.items()}
        if full_stop < n:
            rest = {k: v[full_stop:] for k, v in pool.items()}
            if not final:
                parts, n_buf = [rest], n - full_stop
            elif batch.pad_to is not None:
                yield pad_batch(rest, batch.pad_to)
            elif not batch.drop_remainder:
                yield rest

    for chunk in chunks:
        if not len(next(iter(chunk.values()))):
            continue
        parts.append(chunk)
        n_buf += len(next(iter(chunk.values())))
        if n_buf >= threshold:
            yield from drain(final=False)
    yield from drain(final=True)


def stream_batches(
    nodes: Sequence[PlanNode],
    *,
    workers: int = 2,
    optimize: bool = True,
    epochs: int | None = 1,
    shuffle_buffer: int | None = None,
    final_schema: Sequence[str] = (),
    executor: str | None = None,
    cache_dir: str | Path | None = None,
    stats: dict | None = None,
    remote: Any = None,
    backend: str | None = None,
) -> Iterator[dict[str, np.ndarray]]:
    """Per-shard streaming execution: parse → filter → clean each shard
    inside a shard executor (reader threads or worker processes, see
    :func:`repro.core.executor.make_executor`), then tokenize and batch
    across shard boundaries.

    Preprocessing of shard k+1 overlaps consumption of shard k, so when the
    resulting iterator feeds an AsyncLoader the host pipeline runs fully
    concurrent with device compute. Shard results complete in work-stealing
    order but are reassembled in *shard* order on the driver (a small heap,
    bounded by the in-flight shard count), so the batch stream is
    deterministic run-to-run and across executors; records additionally
    match whole-frame execution as a multiset.
    Full-subset dedup keeps that guarantee directly — duplicate rows are
    interchangeable. A *partial*-subset drop_duplicates (where the variant
    that survives matters) streams via the two-pass canonical-survivor
    protocol instead: an election pass picks each key's whole-frame
    keep-first row, then every epoch runs the pure per-shard ``dedup_take``
    program (see :func:`repro.core.executor.split_dedup_programs`). Only a
    partial dedup *stacked with another dedup* is rejected.

    ``cache_dir`` enables the plan-fingerprint shard cache; ``executor``
    forces ``"thread"``/``"process"``/``"remote"`` (default: env
    ``REPRO_EXECUTOR``, then processes when ``workers > 1``); ``remote``
    carries distributed data-plane options (see
    :class:`repro.distributed.coordinator.RemoteShardExecutor`). When
    ``stats`` is a dict it receives
    ``executor``, ``cache_hits``, ``cache_misses`` and per-epoch ``timings``
    after each epoch completes.
    """
    from ..analysis import PlanValidationError, check_streaming_plan
    from . import executor as EX

    frame_nodes, array_nodes = split_plan(nodes)
    if optimize:
        frame_nodes = optimize_plan(frame_nodes, final_schema)

    # Static shape validation against the same (optimized) frame plan this
    # function streams — every failure below surfaces here as a coded,
    # provenance-bearing diagnostic before any shard executor spawns.
    shape_errors = [
        d
        for d in check_streaming_plan(nodes, optimized_frame_nodes=frame_nodes)
        if d.severity == "error"
    ]
    if shape_errors:
        raise PlanValidationError(shape_errors)

    # Backstop raises: unreachable via the public API (the analyzer above
    # rejects these shapes first); kept so a bypassed or regressed analyzer
    # still fails loudly instead of executing a malformed plan.
    src = frame_nodes[0]
    if not isinstance(src, SourceJsonDirs):
        raise ValueError("streaming execution requires a SourceJsonDirs plan")
    if any(isinstance(n, Split) for n in frame_nodes):
        raise ValueError("Split is whole-frame only; drop .prefetch() or .split()")
    tok = next((n for n in array_nodes if isinstance(n, Tokenize)), None)
    batch = next((n for n in array_nodes if isinstance(n, Batch)), None)
    if tok is None or batch is None:
        raise ValueError("streaming needs .tokenize(...) and .batch(...) in the plan")

    dedups = [n for n in frame_nodes[1:] if isinstance(n, DropDuplicates)]
    partial = [d for d in dedups if not set(d.subset) >= set(src.fields)]
    if partial and len(dedups) > 1:
        # The election pass for one partial dedup would itself run under
        # the scheduling-dependent cross-shard state of the other.
        raise ValueError(
            f"streaming drop_duplicates({list(partial[0].subset)}) with "
            f"partial subsets cannot stack with another drop_duplicates; "
            f"drop .prefetch() for whole-frame execution"
        )

    shards = ing.list_shards(src.directories)
    # Compile the per-shard program once — token encoding included, so the
    # executors (reader threads or worker processes) emit int32 token
    # buffers and the driver never runs a per-word Python loop.
    spec_cols = tuple(dict.fromkeys(spec.column for spec in tok.specs))
    token_plan = EX.TokenPlan(
        specs=tuple(tok.specs),
        stoi=dict(tok.tokenizer.stoi),
        vocab_fp=tok.tokenizer.fingerprint,
    )
    row_filters = None
    if partial:
        # Two-pass canonical-survivor protocol (shared with fit_vocab):
        # elect the whole-frame keep-first survivor rows once, then every
        # epoch streams the pure per-shard dedup_take program — identical
        # multiset to whole-frame execution on any executor.
        pass1, program = EX.split_dedup_programs(
            frame_nodes,
            optimize=optimize,
            output_columns=spec_cols,
            tokens=token_plan,
            backend=backend,
        )
        row_filters = EX.elect_survivors(
            shards,
            pass1,
            dict(
                workers=max(workers, 1),
                cache_dir=cache_dir,
                executor=executor,
                remote=remote,
            ),
            stats,
        )
    else:
        program = EX.compile_shard_program(
            frame_nodes,
            optimize=optimize,
            output_columns=spec_cols,
            tokens=token_plan,
            backend=backend,
        )

    epoch = 0
    while epochs is None or epoch < epochs:
        exec_ = EX.make_executor(
            shards,
            program,
            workers=max(workers, 1),
            cache_dir=cache_dir,
            executor=executor,
            remote=remote,
            row_filters=row_filters,
        )

        def chunks() -> Iterator[dict[str, np.ndarray]]:
            # Reassemble completion-ordered results in *shard* order via a
            # small heap (bounded by in-flight shards ≈ workers), so the
            # downstream bucketing/batching sees a deterministic row stream
            # and iter_batches is reproducible run-to-run regardless of
            # executor choice or work-stealing schedule.
            heap: list[tuple[int, int, dict[str, np.ndarray]]] = []
            seq = 0  # tiebreak: dict payloads are not comparable
            next_idx = 0
            for res in exec_:
                heapq.heappush(heap, (res.shard_index, seq, res.tokens))
                seq += 1
                while heap and heap[0][0] == next_idx:
                    yield heapq.heappop(heap)[2]
                    next_idx += 1
            while heap:  # defensive: drain any gap in shard indexes
                yield heapq.heappop(heap)[2]

        rng = np.random.default_rng(batch.seed + epoch)
        buffer = shuffle_buffer or max(8 * batch.batch_size, 1024)
        produced = 0
        try:
            for b in _batched(chunks(), batch, rng, buffer):
                produced += 1
                yield b
        finally:
            # Abandoned mid-epoch (consumer broke out / AsyncLoader closed):
            # stop the workers instead of preprocessing the rest of the
            # corpus into a queue nobody drains.
            exec_.stop()
            if stats is not None:
                stats["executor"] = exec_.name
                stats["cache_hits"] = stats.get("cache_hits", 0) + exec_.cache_hits
                stats["cache_misses"] = (
                    stats.get("cache_misses", 0) + exec_.cache_misses
                )
                stats["token_cache_hits"] = (
                    stats.get("token_cache_hits", 0) + exec_.token_cache_hits
                )
                stats["token_cache_misses"] = (
                    stats.get("token_cache_misses", 0) + exec_.token_cache_misses
                )
                stats["timings"] = exec_.timings
        if not produced:
            return  # empty epoch: stop instead of re-reading the corpus forever
        epoch += 1
