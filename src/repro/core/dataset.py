"""Lazy, Spark-DataFrame-style ``Dataset``: one declarative plan from JSON
shards to device-resident batches.

Builder methods append logical plan nodes (:mod:`repro.core.plan`) instead
of executing; terminal actions hand the plan to the planner, which merges
``Project`` nodes and fuses their expression chains, pushes ``where``
filters and projections toward the source, prunes dead derived columns,
and picks whole-frame or streaming per-shard execution. One chain covers
the whole paper pipeline *and* the model-input path — cleaning, vocabulary
fitting, tokenization, and bucketed batch assembly all live inside the
plan, declared with composable column expressions
(:mod:`repro.core.expr`)::

    keep = col("title").not_empty() & col("abstract").not_empty()
    clean = (Dataset.from_json_dirs([corpus])
             .where(keep).drop_duplicates()
             .transform(abstract=abstract_expr(), title=title_expr())
             .where(keep))
    tok = clean.fit_vocab(vocab_size=8000)       # shard-merged word counts
    loader = (clean
              .tokenize(tok, seq2seq_specs())    # bulk-encoded inside executors
              .batched(32, bucket_by=("encoder_tokens", "decoder_tokens"))
              .prefetch(2)
              .device_batches())

The legacy ``Stage`` verbs still work — ``.apply(*stages)`` lowers each
stage to its expression (:meth:`repro.core.stages.Stage.to_expr`), so both
spellings build the identical plan.

Terminals:

* ``collect()`` / ``to_records()`` / ``execute()`` — whole-frame, with the
  paper's :class:`~repro.core.plan.StageTimings` attribution.
* ``fit_vocab()`` — a :class:`~repro.data.tokenizer.WordTokenizer` fitted
  via per-shard ``Counter`` aggregation in the shard executors (merged on
  the driver; deterministic count-desc/word-asc ranking) or, when the
  frame is already memoized, a whole-frame count — identical either way.
* ``arrays()`` — tokenized model-input arrays.
* ``iter_batches()`` / ``device_batches()`` — batches; with ``.prefetch()``
  in the chain and an un-materialized JSON source these stream per shard
  over a work-stealing pool — the executors emit int32 token buffers
  directly — so host preprocessing overlaps device compute.

Whole-frame results are memoized on the frame-level prefix, so fitting a
tokenizer and then training off the same chain ingests/cleans only once.

Execution options are builder verbs too: ``.workers(n)`` sets the default
parallelism for every terminal (streaming terminals then run shards in
worker *processes* when ``n > 1`` — see :mod:`repro.core.executor`), and
``.cache()`` turns on the on-disk plan-fingerprint shard cache so re-runs
of an unchanged plan skip cleaning entirely. The verbs layer onto the
``REPRO_*`` environment knobs through one resolution order — explicit verb
> env > default — owned by :class:`repro.core.engine_config.EngineConfig`.
"""

from __future__ import annotations

import time
from collections import Counter
from pathlib import Path
from typing import Any, Iterator, Sequence

import numpy as np

from ..data.batching import TokenSpec, batches as _array_batches, derive_buckets
from ..data.tokenizer import WordTokenizer
from . import expr as E
from . import plan as P
from .async_loader import AsyncLoader
from .engine_config import EngineConfig
from .frame import ColumnarFrame
from .stages import Stage


class Dataset:
    """Immutable handle on a logical preprocessing plan."""

    def __init__(
        self,
        nodes: Sequence[P.PlanNode],
        schema: Sequence[str],
        parent: "Dataset | None" = None,
        options: dict | None = None,
    ):
        self._nodes = tuple(nodes)
        self.schema = tuple(schema)
        self._parent = parent
        self._options = dict(options or {})
        self._frame_cache: dict[tuple, tuple[ColumnarFrame, P.StageTimings]] = {}

    # -- construction ------------------------------------------------------
    @classmethod
    def from_json_dirs(
        cls, directories: Sequence[str | Path], fields: Sequence[str] = ("title", "abstract")
    ) -> "Dataset":
        node = P.SourceJsonDirs(tuple(str(d) for d in directories), tuple(fields))
        return cls([node], fields)

    @classmethod
    def from_frame(cls, frame: ColumnarFrame) -> "Dataset":
        return cls([P.SourceFrame(frame)], frame.field_names)

    @classmethod
    def from_records(cls, records: Sequence[dict], fields: Sequence[str]) -> "Dataset":
        return cls.from_frame(ColumnarFrame.from_records(records, fields))

    # -- plan builders (lazy) ----------------------------------------------
    def _derive(self, node: P.PlanNode, schema: Sequence[str]) -> "Dataset":
        if not P.is_frame_node(node):
            pass  # array-level nodes may follow anything below
        elif any(not P.is_frame_node(n) for n in self._nodes):
            raise ValueError(
                f"{type(node).__name__} is frame-level and must come before "
                "tokenize/batch/prefetch"
            )
        return Dataset(self._nodes + (node,), schema, parent=self, options=self._options)

    def _resolve_subset(self, subset: Sequence[str] | None) -> tuple[str, ...]:
        cols = tuple(subset) if subset is not None else self.schema
        unknown = [c for c in cols if c not in self.schema]
        if unknown:
            raise KeyError(f"unknown columns {unknown}; schema is {list(self.schema)}")
        return cols

    def select(self, fields: Sequence[str]) -> "Dataset":
        fields = self._resolve_subset(fields)
        return self._derive(P.Select(fields), fields)

    def dropna(self, subset: Sequence[str] | None = None) -> "Dataset":
        return self._derive(P.DropNA(self._resolve_subset(subset)), self.schema)

    def drop_duplicates(self, subset: Sequence[str] | None = None) -> "Dataset":
        return self._derive(P.DropDuplicates(self._resolve_subset(subset)), self.schema)

    def _check_expr_inputs(self, e, what: str, schema: Sequence[str]) -> None:
        unknown = sorted(e.inputs() - set(schema))
        if unknown:
            raise KeyError(
                f"{what} reads unknown columns {unknown}; schema is {list(schema)}"
            )

    def with_column(self, name: str, expression: E.Expr) -> "Dataset":
        """Derive (or overwrite) one column from a composable expression::

            ds.with_column("abstract", col("abstract").lower().strip_html())
            ds.with_column("text", concat(col("title"), col("abstract")))
        """
        if not isinstance(expression, E.Expr):
            raise TypeError(f"with_column() needs an expression, got {expression!r}")
        self._check_expr_inputs(expression, f"with_column({name!r})", self.schema)
        schema = list(self.schema)
        if name not in schema:
            schema.append(name)
        return self._derive(P.Project(((name, expression),)), schema)

    def transform(self, **expressions: E.Expr) -> "Dataset":
        """Several :meth:`with_column` steps as one ``Project`` node;
        entries evaluate in keyword order, each seeing the previous ones::

            ds.transform(abstract=abstract_expr(), title=title_expr())
        """
        if not expressions:
            return self
        schema = list(self.schema)
        entries = []
        for name, e in expressions.items():
            if not isinstance(e, E.Expr):
                raise TypeError(f"transform({name}=...) needs an expression, got {e!r}")
            self._check_expr_inputs(e, f"transform({name}=...)", schema)
            entries.append((name, e))
            if name not in schema:
                schema.append(name)
        return self._derive(P.Project(tuple(entries)), schema)

    def where(self, pred: E.Pred) -> "Dataset":
        """Keep rows satisfying a byte-buffer predicate::

            ds.where(col("abstract").word_count() >= 5)
            ds.where(col("title").not_empty() & ~col("title").contains("retracted"))

        The optimizer pushes the filter back toward the source past any
        ``Project`` that does not write a column it reads, so filtered
        rows are never cleaned (generalized dropna pullback).
        """
        if isinstance(pred, E.WordCount):
            raise TypeError("where() needs a predicate; compare word_count() to an int")
        if not isinstance(pred, E.Pred):
            raise TypeError(f"where() needs a predicate expression, got {pred!r}")
        self._check_expr_inputs(pred, "where(...)", self.schema)
        return self._derive(P.Filter(pred), self.schema)

    def apply(self, *stages: Stage) -> "Dataset":
        """Deprecated shim: lower legacy ``Stage`` verbs to their
        expressions (one ``Project`` node; see ``stages.Stage.to_expr``).
        Byte-identical to composing the expressions directly."""
        if not stages:
            return self
        schema = list(self.schema)
        entries = []
        for s in stages:
            if s.input_col not in schema:
                raise KeyError(
                    f"stage {type(s).__name__} reads unknown column {s.input_col!r}"
                )
            entries.append((s.output_col, s.to_expr(E.col(s.input_col))))
            if s.output_col not in schema:
                schema.append(s.output_col)
        return self._derive(P.Project(tuple(entries)), schema)

    def split(self, val_fraction: float = 0.1, seed: int = 0) -> tuple["Dataset", "Dataset"]:
        """(train, val) datasets over a deterministic row partition."""
        train = self._derive(P.Split(val_fraction, seed, "train"), self.schema)
        val = self._derive(P.Split(val_fraction, seed, "val"), self.schema)
        return train, val

    def tokenize(
        self,
        tokenizer: Any,
        specs: Sequence[TokenSpec] | None = None,
        *,
        col: str | None = None,
        max_len: int = 128,
        add_start_end: bool = False,
    ) -> "Dataset":
        """Attach token encoding: either explicit ``specs`` or one ``col``."""
        if specs is None:
            if col is None:
                raise ValueError("tokenize() needs specs=... or col=...")
            specs = (TokenSpec(col, max_len, add_start_end=add_start_end),)
        specs = tuple(specs)
        for spec in specs:
            if spec.column not in self.schema:
                raise KeyError(f"tokenize spec reads unknown column {spec.column!r}")
        return self._derive(P.Tokenize(tokenizer, specs), [s.name for s in specs])

    # -- vocabulary fitting (terminal; Spark CountVectorizer-style) --------
    def _counts_mode(self) -> str:
        """How ``fit_vocab`` counts: ``"stream"`` (one pass through the
        shard executors), ``"two-pass"`` (canonical-survivor dedup
        election, then a counting pass over the survivors — the streaming
        protocol for partial-subset ``drop_duplicates``), or ``"whole"``
        (count the materialized frame)."""
        owner = self._frame_prefix_dataset()
        if self._has_memoized_frame():
            return "whole"  # already materialized: count that frame
        if not isinstance(owner._nodes[0], P.SourceJsonDirs):
            return "whole"
        if any(isinstance(n, P.Split) for n in owner._nodes):
            return "whole"  # whole-frame only
        src_fields = set(owner._nodes[0].fields)
        dedups = [n for n in owner._nodes if isinstance(n, P.DropDuplicates)]
        partial = [d for d in dedups if not set(d.subset) >= src_fields]
        if not partial:
            return "stream"  # full-subset dedup: duplicate rows interchange
        if len(dedups) == 1:
            return "two-pass"
        # A partial-subset dedup stacked with another dedup: the election
        # pass would itself run under scheduling-dependent cross-shard
        # state, so fall back to the exact whole-frame count.
        return "whole"

    def fit_vocab(
        self,
        columns: Sequence[str] | None = None,
        vocab_size: int = 8000,
        *,
        workers: int | None = None,
        optimize: bool = True,
        executor: str | None = None,
        stats: dict | None = None,
    ) -> WordTokenizer:
        """Fit a :class:`WordTokenizer` on the cleaned text of ``columns``
        (default: every frame column) — the fit half of the Spark
        fit-then-transform split.

        On an unmaterialized JSON source this runs as a per-shard word
        ``Counter`` inside the shard executors (thread or process, same
        selection rules as streaming batches) merged on the driver, so
        fitting never makes a second driver-side pass over the corpus;
        otherwise it counts the memoized whole frame. Both orders produce
        the identical vocabulary: counter merge is commutative and the
        ranking tie-break is deterministic (count desc, word asc). With
        the shard cache enabled, per-shard counts are cached too — a
        refit over unchanged data and plan reads no shard at all.

        Plans with a partial-subset ``drop_duplicates`` stream too, via
        the two-pass canonical-survivor protocol: pass 1 emits per-row
        dedup-key digests, the driver elects each key's first occurrence
        in deterministic ``(shard, row)`` order, and pass 2 counts only
        the elected survivors — byte-identical to the whole-frame fit on
        every executor (see :func:`repro.core.executor.split_dedup_programs`)."""
        from . import executor as EX
        from . import ingest as ing

        owner = self._frame_prefix_dataset()
        # Validate the frame prefix before any executor spawns. Never with
        # the streaming shape checks: fit_vocab falls back to the exact
        # whole-frame count for plans that cannot stream (see _counts_mode).
        owner._require_valid(streaming=False, optimize=optimize)
        cols = tuple(columns) if columns is not None else owner.schema
        unknown = [c for c in cols if c not in owner.schema]
        if unknown:
            raise KeyError(f"unknown columns {unknown}; schema is {list(owner.schema)}")
        counts: Counter = Counter()
        n_workers = self._resolve_workers(workers, default=2)
        mode = self._counts_mode()
        if mode != "whole":
            frame_nodes, _ = P.split_plan(owner._nodes)
            if optimize:
                frame_nodes = P.optimize_plan(frame_nodes, cols)
            exec_kw = dict(
                workers=n_workers,
                cache_dir=self._resolve_cache_dir(),
                executor=executor or self._options.get("executor"),
                remote=self._options.get("remote"),
            )
            shards = ing.list_shards(frame_nodes[0].directories)
            row_filters = None
            if mode == "two-pass":
                pass1, program = EX.split_dedup_programs(
                    frame_nodes, optimize=optimize, count_columns=cols,
                    backend=self._resolve_backend(),
                )
                row_filters = self._elect_survivors(
                    shards, pass1, exec_kw, stats
                )
            else:
                program = EX.compile_shard_program(
                    frame_nodes, optimize=optimize, output_columns=cols,
                    count_words=cols, backend=self._resolve_backend(),
                )
            exec_ = EX.make_executor(
                shards, program, row_filters=row_filters, **exec_kw
            )
            try:
                for res in exec_:
                    if res.word_counts:
                        counts.update(res.word_counts)
            finally:
                exec_.stop()
                if stats is not None:
                    stats["executor"] = exec_.name
                    stats["two_pass"] = mode == "two-pass"
                    stats["token_cache_hits"] = (
                        stats.get("token_cache_hits", 0) + exec_.token_cache_hits
                    )
                    stats["token_cache_misses"] = (
                        stats.get("token_cache_misses", 0) + exec_.token_cache_misses
                    )
                    stats["timings"] = exec_.timings
        else:
            frame, _ = owner._materialize(
                self._resolve_workers(workers), optimize, exact=workers is not None
            )
            if stats is not None:
                stats["executor"] = "whole-frame"
            for col in cols:
                for t in frame[col]:
                    counts.update((t or "").split())
        return WordTokenizer.from_counts(counts, vocab_size)

    def _elect_survivors(
        self, shards, pass1, exec_kw: dict, stats: dict | None
    ) -> dict[int, np.ndarray]:
        """Pass 1 of two-pass dedup — delegates to the shared
        :func:`repro.core.executor.elect_survivors` (the streaming batch
        path in :func:`repro.core.plan.stream_batches` uses the same
        election)."""
        from . import executor as EX

        return EX.elect_survivors(shards, pass1, exec_kw, stats)

    def _resolve_bucket_widths(
        self, spec: TokenSpec, widths: Sequence[int] | None, n_buckets: int
    ) -> tuple[int, ...]:
        if not widths:
            return derive_buckets(spec.max_len, n_buckets)
        resolved = tuple(sorted({int(b) for b in widths}))
        if resolved[0] < 1:
            raise ValueError(f"bucket widths must be >= 1, got {resolved}")
        if resolved[-1] < spec.max_len:
            # The last bucket must fit any row (rows were already
            # truncated to max_len by encoding).
            resolved = resolved + (spec.max_len,)
        return resolved

    def batch(
        self,
        batch_size: int,
        *,
        shuffle: bool = True,
        seed: int = 0,
        drop_remainder: bool = True,
        pad_to: int | None = None,
        bucket_by: str | Sequence[str] | None = None,
        buckets: Sequence | None = None,
        n_buckets: int = 4,
    ) -> "Dataset":
        """Fixed-shape batches. With ``bucket_by`` (a token output name, or
        several), rows are grouped by payload length into a small fixed
        set of bucket widths — ``buckets`` explicitly, else ``n_buckets``
        linear steps up to each spec's ``max_len`` — and each bucketed
        column is sliced to its bucket width, so short rows stop paying
        full-width padding while jit still sees a bounded shape set.
        ``bucket_by=("encoder_tokens", "decoder_tokens")`` builds the 2-D
        grid (paired bucketing: decoder padding drops too); pass nested
        ``buckets`` (one width list per column) to pin the grid."""
        tok = next((n for n in self._nodes if isinstance(n, P.Tokenize)), None)
        if tok is None:
            raise ValueError("batch() requires .tokenize(...) earlier in the chain")
        if buckets and bucket_by is None:
            raise ValueError(
                "buckets=... needs bucket_by=<token output name(s)>; without "
                "it the batches would silently stay fixed-max_len"
            )
        bb: str | tuple[str, ...] | None = bucket_by if isinstance(
            bucket_by, (str, type(None))
        ) else tuple(bucket_by)
        resolved: tuple = ()
        if bb is not None:
            from ..data.batching import bucket_columns

            cols = bucket_columns(bb)
            specs_by_name = {s.name: s for s in tok.specs}
            for c in cols:
                if c not in specs_by_name:
                    raise KeyError(
                        f"bucket_by={c!r} is not a token output; "
                        f"available: {[s.name for s in tok.specs]}"
                    )
            if buckets and not isinstance(buckets[0], (int, np.integer)):
                if len(buckets) != len(cols):
                    raise ValueError(
                        f"{len(buckets)} bucket width lists for "
                        f"{len(cols)} bucket columns"
                    )
                per_col: Sequence[Sequence[int] | None] = list(buckets)
            else:
                if buckets and len(cols) != 1:
                    raise ValueError(
                        "flat buckets=... with several bucket_by columns; "
                        "pass one width list per column"
                    )
                per_col = [buckets] + [None] * (len(cols) - 1)
            widths = tuple(
                self._resolve_bucket_widths(specs_by_name[c], w, n_buckets)
                for c, w in zip(cols, per_col)
            )
            resolved = widths[0] if isinstance(bb, str) else widths
        node = P.Batch(
            batch_size, shuffle, seed, drop_remainder, pad_to, bb, resolved
        )
        return self._derive(node, self.schema)

    def batched(self, batch_size: int, **kwargs: Any) -> "Dataset":
        """Alias of :meth:`batch` — the bucketed-assembly verb
        (``.batched(32, bucket_by=("encoder_tokens", "decoder_tokens"))``)."""
        return self.batch(batch_size, **kwargs)

    def prefetch(self, prefetch: int = 2, *, sharding: Any = None) -> "Dataset":
        """Declare streaming intent: terminal batch iteration runs per-shard
        over a work-stealing pool and feeds AsyncLoader with this depth."""
        return self._derive(P.Prefetch(prefetch, sharding), self.schema)

    # -- execution options (lazy; no plan nodes) ---------------------------
    def _with_options(self, **options: Any) -> "Dataset":
        # parent=self: the new handle shares this dataset's position in the
        # memoization chain, so adding options after a terminal still
        # resumes from the already-materialized frame (empty suffix).
        return Dataset(
            self._nodes, self.schema, parent=self,
            options={**self._options, **options},
        )

    def workers(
        self,
        n: int,
        *,
        executor: str | None = None,
        remote: Any = None,
    ) -> "Dataset":
        """Default worker count for every terminal of this chain (and, for
        streaming terminals, which physical executor runs the shards:
        ``"thread"``/``"process"``/``"remote"``; default picks processes
        when ``n > 1``). Passing ``remote=...`` (True or an options dict —
        see :class:`repro.distributed.coordinator.RemoteShardExecutor`)
        selects the distributed data plane: a coordinator leasing shards to
        ``n`` TCP worker processes with heartbeat liveness and restart-safe
        reassignment."""
        if n < 1:
            raise ValueError(f"workers must be >= 1, got {n}")
        opts: dict[str, Any] = {"workers": int(n)}
        if remote is not None:
            opts["remote"] = remote
            if executor is None:
                executor = "remote"
        if executor is not None:
            opts["executor"] = executor
        return self._with_options(**opts)

    def cache(self, directory: str | Path | bool = True) -> "Dataset":
        """Enable the on-disk plan-fingerprint shard cache for streaming
        terminals (the Spark ``persist()`` analogue). ``True`` uses
        ``REPRO_CACHE_DIR`` or the system temp dir; a path pins the cache
        root. ``False`` disables a previously enabled cache."""
        from .executor import default_cache_dir

        if directory is False:
            return self._with_options(cache_dir=None)
        root = default_cache_dir() if directory is True else Path(directory)
        return self._with_options(cache_dir=root)

    def backend(self, name: str) -> "Dataset":
        """Select the byte-kernel backend compiled into this chain's shard
        programs: ``"loops"`` (per-op vectorized passes), ``"fused"``
        (single-pass megapass lowering), or ``"pallas"`` (fused, with an
        eligible cleaning prefix offloaded to the Pallas text-scan kernel).
        Outputs are byte-identical across backends — this is a physical
        executor choice, so shard-cache keys and memoized frames are shared
        across backends. Default resolves from ``REPRO_BYTES_BACKEND``,
        then ``"loops"``."""
        from . import bytesops as B

        if name not in B.BACKENDS:
            raise ValueError(f"unknown bytes backend {name!r}; one of {B.BACKENDS}")
        return self._with_options(backend=name)

    def engine_config(self) -> EngineConfig:
        """This chain's explicit engine options as an
        :class:`~repro.core.engine_config.EngineConfig`; its ``resolve_*``
        methods apply the documented explicit-verb > env > default order."""
        return EngineConfig.from_options(self._options)

    def _resolve_backend(self) -> str | None:
        return self._options.get("backend")

    def _resolve_cache_dir(self) -> Path | None:
        return self.engine_config().resolve_cache_dir()

    def _resolve_workers(self, explicit: int | None, default: int = 1) -> int:
        return self.engine_config().resolve_workers(explicit, default)

    # -- plan inspection ---------------------------------------------------
    def validate(
        self, *, streaming: bool | None = None, optimize: bool = True
    ) -> list:
        """Statically analyze this plan; returns every
        :class:`repro.analysis.Diagnostic` (empty list = clean).

        Runs typed schema inference and expression type checking over the
        node list, the streaming shape checks when this chain would stream
        (or when ``streaming=True`` forces them), and — with ``optimize``
        — static verification of every optimizer rewrite. Every terminal
        calls this first, so an invalid plan raises a coded,
        provenance-bearing :class:`repro.analysis.PlanValidationError`
        before any executor thread, worker process, or remote coordinator
        starts."""
        from ..analysis import analyze_plan

        if streaming is None:
            streaming = self._streaming()
        return analyze_plan(
            self._nodes,
            final_schema=self._needed_columns(),
            streaming=streaming,
            optimize=optimize,
        )

    def _require_valid(
        self, *, streaming: bool | None = None, optimize: bool = True
    ) -> None:
        """Raise :class:`repro.analysis.PlanValidationError` on any
        error-severity diagnostic (warnings — e.g. an unfingerprintable
        lambda op — never block execution)."""
        from ..analysis import PlanValidationError

        errors = [
            d
            for d in self.validate(streaming=streaming, optimize=optimize)
            if d.severity == "error"
        ]
        if errors:
            raise PlanValidationError(errors)

    @property
    def plan(self) -> tuple[P.PlanNode, ...]:
        return self._nodes

    def optimized_plan(self) -> list[P.PlanNode]:
        frame_nodes, array_nodes = P.split_plan(self._nodes)
        return P.optimize_plan(frame_nodes, self._needed_columns()) + array_nodes

    def explain(self) -> str:
        return P.explain(
            self._nodes, self._needed_columns(), backend=self._resolve_backend()
        )

    # -- execution helpers -------------------------------------------------
    def _frame_prefix_dataset(self) -> "Dataset":
        """Nearest ancestor whose plan is entirely frame-level."""
        ds: Dataset = self
        while ds._nodes and not P.is_frame_node(ds._nodes[-1]):
            if ds._parent is None:
                # Hand-built Dataset (constructed from raw nodes, no
                # builder ancestry): synthesize the frame prefix so
                # validation and terminals still resolve a frame schema.
                prefix = []
                for n in ds._nodes:
                    if not P.is_frame_node(n):
                        break
                    prefix.append(n)
                return Dataset(prefix, ds.schema, options=ds._options)
            ds = ds._parent
        return ds

    def _frame_schema(self) -> tuple[str, ...]:
        return self._frame_prefix_dataset().schema

    def _needed_columns(self) -> tuple[str, ...]:
        """Columns the terminal actually consumes: with a Tokenize node only
        its spec columns are live, letting the planner project the source
        down to them (streaming path; the whole-frame cache stays full-width
        because it is shared across terminals)."""
        tok = next((n for n in self._nodes if isinstance(n, P.Tokenize)), None)
        if tok is not None:
            return tuple(dict.fromkeys(spec.column for spec in tok.specs))
        return self._frame_schema()

    def _materialize(
        self, workers: int, optimize: bool, exact: bool = False
    ) -> tuple[ColumnarFrame, P.StageTimings]:
        owner = self._frame_prefix_dataset()
        key = (workers, optimize)

        def lookup(ds: "Dataset"):
            # The frame is worker-count-invariant (only timings differ), so
            # an entry with the same optimize flag is a valid reuse —
            # .workers(n) after a terminal must not force a re-clean. But a
            # caller who passed workers= explicitly (``exact``) is often
            # sweeping worker counts for timings, so only the exact key
            # counts there.
            hit = ds._frame_cache.get(key)
            if hit is None and not exact:
                hit = next(
                    (v for (_, o), v in ds._frame_cache.items() if o == optimize),
                    None,
                )
            return hit

        hit = lookup(owner)
        if hit is not None:
            return hit
        # Resume from the deepest memoized ancestor prefix, if any: a chain
        # like clean.split() then re-runs only the cheap suffix nodes.
        base: tuple[ColumnarFrame, P.StageTimings] | None = None
        base_len = 0
        ds = owner._parent
        while ds is not None:
            cached = lookup(ds)
            if cached is not None:
                base, base_len = cached, len(ds._nodes)
                break
            ds = ds._parent
        if base is None:
            hit = P.execute_frame_plan(
                owner._nodes, workers=workers, optimize=optimize,
                final_schema=owner.schema, backend=self._resolve_backend(),
            )
        else:
            suffix = owner._nodes[base_len:]
            seen_cleaning = any(
                isinstance(n, P.Project) for n in owner._nodes[:base_len]
            )
            hit = P.continue_frame_plan(
                base[0], base[1], suffix,
                workers=workers, optimize=optimize, seen_cleaning=seen_cleaning,
                backend=self._resolve_backend(),
            )
        owner._frame_cache[key] = hit
        return hit

    def _array_nodes(self) -> list[P.PlanNode]:
        return [n for n in self._nodes if not P.is_frame_node(n)]

    def _batch_node(self) -> P.Batch:
        node = next((n for n in self._nodes if isinstance(n, P.Batch)), None)
        if node is None:
            raise ValueError("no .batch(...) in the plan")
        return node

    def bucket_grid_spec(self):
        """The fixed :class:`~repro.core.device_pipeline.BucketGrid` this
        plan's batches are assembled on, or None when the plan does not
        bucket (then every batch already has the one ``max_len`` shape).
        This is the static shape contract ``DeviceFeed`` pads against so
        the jit'd device step compiles once per grid cell."""
        from ..data.batching import bucket_columns
        from .device_pipeline import BucketGrid

        batch = self._batch_node()
        if batch.bucket_by is None or not batch.buckets:
            return None
        cols = bucket_columns(batch.bucket_by)
        widths = batch.buckets
        if widths and isinstance(widths[0], (int, np.integer)):
            widths = (widths,)
        return BucketGrid(batch.batch_size, dict(zip(cols, widths)))

    def _has_memoized_frame(self) -> bool:
        """True when this chain's frame prefix is already materialized —
        possibly on an options-hop ancestor sharing the same prefix."""
        owner = self._frame_prefix_dataset()
        ds: Dataset | None = owner
        while ds is not None and len(ds._nodes) == len(owner._nodes):
            if ds._frame_cache:
                return True
            ds = ds._parent
        return False

    def _streaming(self) -> bool:
        if not any(isinstance(n, P.Prefetch) for n in self._nodes):
            return False
        # Already materialized — reuse the frame, don't re-read shards.
        if self._has_memoized_frame():
            return False
        return isinstance(self._nodes[0], P.SourceJsonDirs) and not any(
            isinstance(n, P.Split) for n in self._nodes
        )

    # -- terminal actions --------------------------------------------------
    def collect(
        self, *, workers: int | None = None, optimize: bool = True
    ) -> ColumnarFrame:
        """Materialize the frame (plan must be frame-level only)."""
        if self._array_nodes():
            raise ValueError("collect() on a tokenized plan; use arrays()/iter_batches()")
        self._require_valid(streaming=False, optimize=optimize)
        return self._materialize(
            self._resolve_workers(workers), optimize, exact=workers is not None
        )[0]

    def execute(
        self, *, workers: int | None = None, optimize: bool = True
    ) -> tuple[list[dict], P.StageTimings]:
        """(records, StageTimings) — the legacy ``run_p3sapp`` contract."""
        if self._array_nodes():
            raise ValueError(
                "execute()/to_records() on a tokenized plan; use arrays()/iter_batches()"
            )
        self._require_valid(streaming=False, optimize=optimize)
        frame, t = self._materialize(
            self._resolve_workers(workers), optimize, exact=workers is not None
        )
        t = P.StageTimings(**{k: getattr(t, k) for k in
                              ("ingestion", "pre_cleaning", "cleaning",
                               "post_cleaning", "tokenize")})
        t0 = time.perf_counter()
        records = frame.to_records()
        t.post_cleaning += time.perf_counter() - t0
        return records, t

    def to_records(
        self, *, workers: int | None = None, optimize: bool = True
    ) -> list[dict]:
        return self.execute(workers=workers, optimize=optimize)[0]

    def arrays(
        self, *, workers: int | None = None, optimize: bool = True
    ) -> dict[str, np.ndarray]:
        """Materialize tokenized model-input arrays whole-frame."""
        self._require_valid(streaming=False, optimize=optimize)
        frame, _ = self._materialize(
            self._resolve_workers(workers), optimize, exact=workers is not None
        )
        return P.execute_array_nodes(frame, self._array_nodes())

    def iter_batches(
        self,
        *,
        workers: int | None = None,
        optimize: bool = True,
        epochs: int | None = 1,
        shuffle_buffer: int | None = None,
        executor: str | None = None,
        stats: dict | None = None,
    ) -> Iterator[dict[str, np.ndarray]]:
        """Batch iterator; streams per shard when ``.prefetch()`` is declared
        and the source has not already been materialized.

        Worker count resolves explicit ``workers`` > ``.workers(n)`` >
        ``REPRO_WORKERS`` > default (2 for streaming, 1 whole-frame);
        likewise ``executor`` falls back to ``.workers(executor=...)`` then
        ``REPRO_EXECUTOR``. ``stats`` (a dict) receives executor/cache
        counters after each streamed epoch.

        The plan is validated eagerly — at this call, not at the first
        ``next()`` — so an invalid plan raises a diagnostic-bearing
        :class:`repro.analysis.PlanValidationError` before any executor
        thread, worker process, or remote coordinator starts."""
        self._require_valid(optimize=optimize)
        batch = self._batch_node()
        if self._streaming():
            return P.stream_batches(
                self._nodes,
                workers=self._resolve_workers(workers, default=2),
                optimize=optimize,
                epochs=epochs,
                shuffle_buffer=shuffle_buffer,
                final_schema=self._needed_columns(),
                executor=executor or self._options.get("executor"),
                cache_dir=self._resolve_cache_dir(),
                stats=stats,
                remote=self._options.get("remote"),
                backend=self._resolve_backend(),
            )
        return self._whole_frame_batches(batch, workers, optimize, epochs)

    def _whole_frame_batches(
        self,
        batch: P.Batch,
        workers: int | None,
        optimize: bool,
        epochs: int | None,
    ) -> Iterator[dict[str, np.ndarray]]:
        arrays = self.arrays(workers=workers, optimize=optimize)
        epoch = 0
        while epochs is None or epoch < epochs:
            produced = 0
            for b in _array_batches(
                arrays,
                batch.batch_size,
                shuffle=batch.shuffle,
                seed=batch.seed + epoch,
                drop_remainder=batch.drop_remainder,
                pad_to=batch.pad_to,
                bucket_by=batch.bucket_by,
                buckets=batch.buckets,
            ):
                produced += 1
                yield b
            if not produced:
                return  # empty epoch: stop instead of spinning forever
            epoch += 1

    def device_batches(
        self,
        *,
        workers: int | None = None,
        optimize: bool = True,
        epochs: int | None = 1,
        prefetch: int | None = None,
        sharding: Any = None,
        executor: str | None = None,
        overlap: bool = False,
        profiler: Any = None,
    ):
        """Terminal: batches prefetched onto device via AsyncLoader, so host
        preprocessing overlaps device compute end-to-end. With
        ``overlap=True`` (or an explicit ``profiler``) returns a
        :class:`~repro.core.device_pipeline.DeviceFeed` instead: batches
        snap onto the plan's fixed bucket grid, transfers double-buffer
        ahead of compute, and the feed's :class:`OverlapProfiler` accounts
        device-idle time per step."""
        self._require_valid(optimize=optimize)
        node = next((n for n in self._nodes if isinstance(n, P.Prefetch)), None)
        depth = prefetch if prefetch is not None else (node.prefetch if node else 2)
        shard = sharding if sharding is not None else (node.sharding if node else None)
        it = self.iter_batches(
            workers=workers, optimize=optimize, epochs=epochs, executor=executor
        )
        if overlap or profiler is not None:
            from .device_pipeline import DeviceFeed

            return DeviceFeed(
                it,
                grid=self.bucket_grid_spec(),
                prefetch=depth,
                sharding=shard,
                profiler=profiler,
            )
        return AsyncLoader(it, prefetch=depth, sharding=shard)

    def row_program(self, *, optimize: bool = True):
        """Terminal: lower this plan to a per-request
        :class:`~repro.runtime.row_program.RowProgram` for online serving.

        The *same* optimized step chain the shard executors run — compiled
        by the same :func:`repro.core.executor.compile_shard_program` from
        the same plan, carrying the same frozen token specs and vocabulary
        fingerprint — packaged for single-row execution with no
        shard/pool/shared-memory machinery, so a served request is
        byte-identical to the training path by construction.

        Requires a tokenized ``SourceJsonDirs`` chain whose steps are all
        row-local; cross-row plans (``drop_duplicates``, ``split``) raise
        a :class:`repro.analysis.PlanValidationError` carrying ``P016``
        diagnostics.
        """
        from ..analysis import PlanValidationError, check_row_program_plan
        from ..runtime.row_program import RowProgram
        from . import executor as EX

        self._require_valid(streaming=False, optimize=optimize)
        errors = [
            d for d in check_row_program_plan(self._nodes) if d.severity == "error"
        ]
        if errors:
            raise PlanValidationError(errors)
        tok = next(n for n in self._nodes if isinstance(n, P.Tokenize))
        frame_nodes, _ = P.split_plan(self._nodes)
        if optimize:
            frame_nodes = P.optimize_plan(frame_nodes, self._needed_columns())
        spec_cols = tuple(dict.fromkeys(spec.column for spec in tok.specs))
        token_plan = EX.TokenPlan(
            specs=tuple(tok.specs),
            stoi=dict(tok.tokenizer.stoi),
            vocab_fp=tok.tokenizer.fingerprint,
        )
        program = EX.compile_shard_program(
            frame_nodes,
            optimize=optimize,
            output_columns=spec_cols,
            tokens=token_plan,
            backend=self._resolve_backend(),
        )
        return RowProgram(
            fields=program.fields,
            steps=program.steps,
            specs=program.tokens.specs,
            stoi=program.tokens.stoi,
            vocab_fp=program.tokens.vocab_fp,
            backend=program.backend,
            fingerprint=EX.program_fingerprint(program),
        )
