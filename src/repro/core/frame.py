"""ColumnarFrame — the Spark-DataFrame analogue of this framework.

Columns are NumPy object arrays of ``str | None``. All frame operations
(null drop, dedup, select, union) are columnar; text transformation happens
on flat byte buffers (:mod:`repro.core.bytesops`) via the Pipeline.
"""

from __future__ import annotations

from typing import Mapping, Sequence

import numpy as np

from . import bytesops as B


class ColumnarFrame:
    def __init__(self, columns: Mapping[str, np.ndarray]):
        cols = {k: np.asarray(v, dtype=object) for k, v in columns.items()}
        lengths = {len(v) for v in cols.values()}
        if len(lengths) > 1:
            raise ValueError(f"ragged columns: { {k: len(v) for k, v in cols.items()} }")
        self.columns: dict[str, np.ndarray] = cols
        self._n = lengths.pop() if lengths else 0

    # -- construction ------------------------------------------------------
    @classmethod
    def from_records(cls, records: Sequence[Mapping], fields: Sequence[str]) -> "ColumnarFrame":
        cols = {f: np.array([r.get(f) for r in records], dtype=object) for f in fields}
        return cls(cols)

    @classmethod
    def empty(cls, fields: Sequence[str]) -> "ColumnarFrame":
        return cls({f: np.zeros(0, dtype=object) for f in fields})

    # -- basics --------------------------------------------------------------
    def __len__(self) -> int:
        return self._n

    def __getitem__(self, col: str) -> np.ndarray:
        return self.columns[col]

    @property
    def field_names(self) -> list[str]:
        return list(self.columns)

    def select(self, fields: Sequence[str]) -> "ColumnarFrame":
        return ColumnarFrame({f: self.columns[f] for f in fields})

    def take(self, mask_or_idx) -> "ColumnarFrame":
        return ColumnarFrame({k: v[mask_or_idx] for k, v in self.columns.items()})

    def union(self, other: "ColumnarFrame") -> "ColumnarFrame":
        """Spark ``DataFrame.union``: cheap columnar concatenation."""
        return ColumnarFrame(
            {k: np.concatenate([v, other.columns[k]]) for k, v in self.columns.items()}
        )

    @staticmethod
    def concat(frames: Sequence["ColumnarFrame"]) -> "ColumnarFrame":
        if not frames:
            raise ValueError("no frames")
        keys = frames[0].field_names
        return ColumnarFrame(
            {k: np.concatenate([f.columns[k] for f in frames]) for k in keys}
        )

    # -- pre-cleaning (paper Algorithm 1 steps 9-10) -------------------------
    def dropna(self, subset: Sequence[str] | None = None) -> "ColumnarFrame":
        subset = subset or self.field_names
        keep = np.ones(self._n, dtype=bool)
        for f in subset:
            col = self.columns[f]
            keep &= np.array([v is not None and v != "" for v in col], dtype=bool)
        return self.take(keep)

    def drop_duplicates(self, subset: Sequence[str] | None = None) -> "ColumnarFrame":
        """Keep-first dedup (deterministic, unlike Spark's dropDuplicates)."""
        subset = subset or self.field_names
        seen: set = set()
        keep = np.ones(self._n, dtype=bool)
        cols = [self.columns[f] for f in subset]
        for i in range(self._n):
            key = tuple(c[i] for c in cols)
            if key in seen:
                keep[i] = False
            else:
                seen.add(key)
        return self.take(keep)

    def ensure_column(self, col: str) -> "ColumnarFrame":
        """Frame with ``col`` present (empty strings when newly created)."""
        if col in self.columns:
            return self
        cols = dict(self.columns)
        cols[col] = np.array([""] * self._n, dtype=object)
        return ColumnarFrame(cols)

    # -- flat-buffer access (pipeline execution) ----------------------------
    def flat(self, col: str) -> np.ndarray:
        vals = ["" if v is None else str(v).replace("\x00", " ") for v in self.columns[col]]
        return B.flatten(vals)

    def with_flat(self, col: str, buf: np.ndarray) -> "ColumnarFrame":
        rows = B.unflatten(buf)
        if len(rows) != self._n:
            raise AssertionError(
                f"row-count invariant violated on column {col!r}: {len(rows)} != {self._n}"
            )
        new_cols = dict(self.columns)
        new_cols[col] = np.array(rows, dtype=object)
        return ColumnarFrame(new_cols)

    # -- boundary conversion (paper Algorithm 1 step 15: toPandas) ----------
    def to_records(self) -> list[dict]:
        keys = self.field_names
        cols = [self.columns[k] for k in keys]
        return [dict(zip(keys, vals)) for vals in zip(*cols)] if self._n else []

    def tokens(self, col: str) -> list[list[str]]:
        """Materialize a whitespace-tokenized view (Spark Tokenizer output)."""
        return [("" if v is None else v).split() for v in self.columns[col]]
