"""On-accelerator preprocessing: P3SAPP's cleaning stage as a TPU kernel.

The paper's framing: the accelerator idles while the host cleans text. The
beyond-paper fix implemented here: run the character-level cleaning ON the
accelerator (repro.kernels.text_clean), leaving the host only whitespace
compaction and the word-level stages. On CPU containers the kernel runs in
interpret mode (correctness path); on TPU it is a single VMEM pass.
"""

from __future__ import annotations


from ..kernels.text_clean.ops import clean_rows
from .frame import ColumnarFrame
from .stages import RemoveShortWords, Stage, StopWordsRemover


class DeviceCleaner:
    """Drop-in cleaning engine: char-level stages on device, word-level on
    host. Equivalent to ConvertToLower + RemoveHTMLTags +
    RemoveUnwantedCharacters-character-classes (no contraction mapping —
    recorded divergence: contractions lose their apostrophes instead of
    expanding; see DESIGN.md)."""

    def __init__(self, word_stages: list[Stage] | None = None, interpret: bool = True):
        self.word_stages = word_stages or []
        self.interpret = interpret

    def transform(self, frame: ColumnarFrame, cols: list[str]) -> ColumnarFrame:
        out = frame
        for col in cols:
            rows = ["" if v is None else str(v) for v in out[col]]
            cleaned = clean_rows(rows, interpret=self.interpret)
            buf = None
            from . import bytesops as B

            buf = B.flatten(cleaned)
            for st in self.word_stages:
                buf = st.transform_flat(buf)
            out = out.with_flat(col, buf)
        return out


def device_case_study_cleaner(interpret: bool = True) -> DeviceCleaner:
    return DeviceCleaner(
        word_stages=[StopWordsRemover("x"), RemoveShortWords("x", threshold=1)],
        interpret=interpret,
    )
