"""Host→device overlap engine: the batch-assembly tail of the lazy plan.

The paper's framing: the accelerator idles while the host preprocesses
text. PRs 1–6 made the host side fast, cached, and distributed; this
module closes the loop at the device boundary. :class:`DeviceFeed` takes
the length-bucketed token batches streaming out of the plan, snaps every
batch onto the **fixed bucket grid** (row-pads partial batches, width-pads
each bucketed column up to its grid rung — so the jit'd step sees a small
closed shape set and compiles once per cell), and transfers via
double-buffered, sharding-aware ``jax.device_put``: batch k+1's transfer
is issued before batch k is yielded, so host work and H2D copies hide
behind device compute. Donation is handled at the *step* boundary: the
consuming jit'd step donates the batch buffers back to XLA, and the feed
marks the yielded :class:`DeviceBatch` consumed — a reuse-after-donate is
a hard error, not silent corruption.

The :class:`OverlapProfiler` is the measurement half of the paper's
claim: per step it accounts host-wait (the device would have idled) vs
device-compute time and reports a **device-idle fraction** — ~0 on a warm
cache means preprocessing is fully hidden (``bench_cumulative --overlap``
gates this in CI).

The seed-era on-accelerator cleaning path (:class:`DeviceCleaner`,
char-level cleaning as a Pallas kernel) remains, rebuilt on ``col()``
expressions instead of the deprecated ``Stage`` shims.
"""

from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Any, Callable, Iterator, Mapping, Sequence

import numpy as np

from ..data.tokenizer import PAD
from .async_loader import AsyncLoader, LoaderStats

# ---------------------------------------------------------------------------
# Fixed bucket grid: the closed shape set the jit'd step compiles against
# ---------------------------------------------------------------------------


class BucketGrid:
    """The static shape contract between batch assembly and the device step.

    ``widths`` maps each bucketed array column to its ladder of bucket
    widths (ascending). :meth:`snap` pads a host batch onto the grid: rows
    up to ``batch_size`` (PAD rows), each laddered column up to the
    smallest rung that fits. Every snapped batch then has one of
    ``n_cells`` shapes, so an epoch compiles the device step at most once
    per cell — never once per batch.
    """

    def __init__(self, batch_size: int, widths: Mapping[str, Sequence[int]]):
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        self.batch_size = int(batch_size)
        self.widths = {
            c: tuple(sorted(int(w) for w in ws)) for c, ws in widths.items()
        }
        for c, ws in self.widths.items():
            if not ws:
                raise ValueError(f"empty bucket ladder for column {c!r}")

    @property
    def n_cells(self) -> int:
        n = 1
        for ws in self.widths.values():
            n *= len(ws)
        return n

    def _rung(self, column: str, width: int) -> int:
        ladder = self.widths[column]
        for w in ladder:
            if width <= w:
                return w
        raise ValueError(
            f"column {column!r} is {width} wide, beyond the top bucket "
            f"{ladder[-1]} — the batch was not assembled on this grid"
        )

    def snap(self, batch: Mapping[str, np.ndarray]) -> dict[str, np.ndarray]:
        """Pad ``batch`` onto the grid (prefix-preserving, PAD fill)."""
        out: dict[str, np.ndarray] = {}
        for k, v in batch.items():
            v = np.asarray(v)
            rows = v.shape[0]
            width = v.shape[1] if v.ndim > 1 else None
            target_w = (
                self._rung(k, width)
                if width is not None and k in self.widths
                else width
            )
            if rows == self.batch_size and (width is None or target_w == width):
                out[k] = v
                continue
            shape = (self.batch_size,) + (
                (target_w,) + v.shape[2:] if width is not None else v.shape[1:]
            )
            padded = np.full(shape, PAD, dtype=v.dtype)
            if width is None:
                padded[:rows] = v
            else:
                padded[:rows, :width] = v
            out[k] = padded
        return out

    def cell_key(self, batch: Mapping[str, Any]) -> tuple:
        """Hashable static-shape key of a (snapped) batch."""
        return tuple(sorted((k, tuple(np.shape(v))) for k, v in batch.items()))


# ---------------------------------------------------------------------------
# Device batches with donation safety
# ---------------------------------------------------------------------------


class DeviceBatch(Mapping):
    """One grid-snapped batch on device.

    Behaves as a read-only mapping of device arrays. Once the consuming
    step donated the buffers (:meth:`mark_donated`, done by
    ``DeviceFeed.step(...)`` on exit), any further access raises — XLA has
    already reused the memory, so a late read would be garbage.
    """

    def __init__(self, arrays: dict[str, Any], cell: tuple):
        self._arrays = arrays
        self.cell = cell
        self.donated = False

    def mark_donated(self) -> None:
        self.donated = True

    def _check(self) -> None:
        if self.donated:
            raise RuntimeError(
                "reuse after donate: this DeviceBatch was consumed by a "
                "donating device step; its buffers belong to XLA now"
            )

    @property
    def arrays(self) -> dict[str, Any]:
        self._check()
        return self._arrays

    def __getitem__(self, key: str):
        self._check()
        return self._arrays[key]

    def __iter__(self):
        return iter(self._arrays)

    def __len__(self) -> int:
        return len(self._arrays)


# ---------------------------------------------------------------------------
# Overlap accounting
# ---------------------------------------------------------------------------


@dataclass
class OverlapReport:
    """Per-epoch overlap accounting (all times from the profiler clock).

    ``device_idle_fraction`` is steady-state: the first-batch pipeline
    fill (``startup_s``) is startup latency, not overlap failure, so it is
    reported separately and excluded from the fraction.
    """

    steps: int = 0
    host_wait_s: float = 0.0  # post-startup consumer stalls (device idle)
    startup_s: float = 0.0  # first-batch pipeline fill
    device_s: float = 0.0  # time inside profiled device steps
    transfer_s: float = 0.0  # host→device copies issued by the feed
    starved_steps: int = 0  # steps that waited > eps on the host

    @property
    def device_idle_fraction(self) -> float:
        busy = self.host_wait_s + self.device_s
        return self.host_wait_s / busy if busy > 0 else 0.0

    def as_dict(self) -> dict:
        d = dict(self.__dict__)
        d["device_idle_fraction"] = self.device_idle_fraction
        return d


class OverlapProfiler:
    """Accumulates host-wait vs device-compute time for one feed epoch.

    The clock is injectable, so the idle-fraction math is exactly testable
    against a fake clock; ``starvation_eps`` separates true stalls from
    the microseconds a warm queue handoff costs on a real clock.
    """

    def __init__(
        self,
        clock: Callable[[], float] = time.perf_counter,
        starvation_eps: float = 1e-3,
    ):
        self.clock = clock
        self.starvation_eps = starvation_eps
        self._r = OverlapReport()

    def record_wait(self, dt: float, startup: bool = False) -> None:
        if startup:
            self._r.startup_s += dt
            return
        self._r.host_wait_s += dt
        if dt > self.starvation_eps:
            self._r.starved_steps += 1

    def record_transfer(self, dt: float) -> None:
        self._r.transfer_s += dt

    @contextmanager
    def step(self):
        """Time one device-compute segment (caller blocks on the result
        inside the ``with`` for honest accounting)."""
        t0 = self.clock()
        yield
        self._r.device_s += self.clock() - t0
        self._r.steps += 1

    def report(self) -> OverlapReport:
        return self._r


# ---------------------------------------------------------------------------
# The feed
# ---------------------------------------------------------------------------


class DeviceFeed:
    """Donated, double-buffered host→device handoff with idle accounting.

    ``batches`` is an iterator of host dict-batches (token arrays out of
    ``Dataset.iter_batches``). With ``prefetch >= 1`` an
    :class:`~repro.core.async_loader.AsyncLoader` in host mode runs the
    upstream pipeline in a fill thread (its :class:`LoaderStats` expose
    queue depth/starvation); ``prefetch=0`` pulls synchronously — no
    threads, exact fake-clock semantics for tests.

    Iteration yields :class:`DeviceBatch` objects one transfer ahead:
    batch k+1 is already in flight when batch k is handed to the step.
    Wrap each device step in :meth:`step` — it times the compute segment
    and, when ``donate=True`` (default), marks the batch consumed so the
    donating jit'd step (``donate_argnums``) can never observe a stale
    read.
    """

    def __init__(
        self,
        batches: Iterator,
        *,
        grid: BucketGrid | None = None,
        prefetch: int = 2,
        sharding: Any = None,
        donate: bool = True,
        device_put: Callable[[np.ndarray], Any] | None = None,
        clock: Callable[[], float] = time.perf_counter,
        profiler: OverlapProfiler | None = None,
    ):
        self.grid = grid
        self.donate = donate
        self._sharding = sharding
        self._device_put = device_put
        self._clock = clock
        self.profiler = profiler or OverlapProfiler(clock=clock)
        self._loader: AsyncLoader | None = None
        if prefetch >= 1:
            self._loader = AsyncLoader(
                batches,
                prefetch=prefetch,
                device_put=lambda b: b,  # host prefetch only; we transfer
                clock=clock,
            )
            self._source: Iterator = iter(self._loader)
        else:
            self._source = iter(batches)

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        if self._loader is not None:
            self._loader.close()
        else:
            finalize = getattr(self._source, "close", None)
            if finalize is not None:
                finalize()

    @property
    def loader_stats(self) -> LoaderStats | None:
        """Queue gauges of the host prefetch stage (None when prefetch=0)."""
        return self._loader.stats if self._loader is not None else None

    # -- transfer ----------------------------------------------------------
    def _put_leaf(self, x: np.ndarray):
        if self._device_put is not None:
            return self._device_put(x)
        import jax

        if self._sharding is not None:
            return jax.device_put(x, self._sharding)
        return jax.device_put(x)

    def _transfer(self, host_batch: Mapping[str, np.ndarray]) -> DeviceBatch:
        snapped = self.grid.snap(host_batch) if self.grid is not None else host_batch
        cell = (
            self.grid.cell_key(snapped)
            if self.grid is not None
            else tuple(sorted((k, np.shape(v)) for k, v in snapped.items()))
        )
        t0 = self._clock()
        arrays = {k: self._put_leaf(np.asarray(v)) for k, v in snapped.items()}
        self.profiler.record_transfer(self._clock() - t0)
        return DeviceBatch(arrays, cell)

    # -- consumption -------------------------------------------------------
    def __iter__(self) -> Iterator[DeviceBatch]:
        pending: DeviceBatch | None = None
        first = True
        while True:
            t0 = self._clock()
            try:
                host = next(self._source)
            except StopIteration:
                break
            self.profiler.record_wait(self._clock() - t0, startup=first)
            first = False
            nxt = self._transfer(host)
            if pending is not None:
                yield pending
            pending = nxt
        if pending is not None:
            yield pending

    @contextmanager
    def step(self, batch: DeviceBatch | None = None):
        """Time one device step; with ``donate=True`` the batch is marked
        consumed on exit (the step's ``donate_argnums`` owns it now)."""
        with self.profiler.step():
            yield
        if batch is not None and self.donate:
            batch.mark_donated()

    def report(self) -> OverlapReport:
        return self.profiler.report()


# ---------------------------------------------------------------------------
# On-accelerator cleaning (expression-native rebuild of the seed path)
# ---------------------------------------------------------------------------


class DeviceCleaner:
    """Drop-in cleaning engine: char-level stages on device, word-level on
    host. Equivalent to ``lower + strip_html + keep_letters`` character
    classes (no contraction mapping — recorded divergence: contractions
    lose their apostrophes instead of expanding; see DESIGN.md). The host
    half is a ``col()`` expression chain (word-level verbs only), compiled
    once and applied to the flat byte buffers the device pass returns.
    """

    def __init__(self, word_expr: Callable | None = None, interpret: bool = True):
        from . import expr as E

        self.interpret = interpret
        if word_expr is None:
            self._ops: tuple = ()
        else:
            compiled = E.compile_expr(word_expr(E.col("__device_cleaned")))
            kind, source, ops = compiled
            if kind != "chain" or source != "__device_cleaned":
                raise ValueError(
                    "word_expr must be a pure per-column chain "
                    "(Expr -> Expr over its input column)"
                )
            self._ops = tuple(ops)

    def transform(self, frame, cols: list[str]):
        from ..kernels.text_clean.ops import clean_rows
        from . import bytesops as B

        out = frame
        for c in cols:
            rows = ["" if v is None else str(v) for v in out[c]]
            cleaned = clean_rows(rows, interpret=self.interpret)
            buf = B.flatten(cleaned)
            if self._ops:
                buf = B.apply_ops(buf, list(self._ops))
            out = out.with_flat(c, buf)
        return out


def device_case_study_cleaner(interpret: bool = True) -> DeviceCleaner:
    """The case-study word tail (stopwords + short words) over the device
    char-level pass — expression form of the old Stage pair."""
    return DeviceCleaner(
        word_expr=lambda e: e.remove_stopwords().min_word_len(2),
        interpret=interpret,
    )
