"""CLI for the contract linter: ``python -m repro.analysis --contracts
src/repro``. Exit code 1 when any error-severity diagnostic fires, so it
slots into CI next to ruff. Stdlib-only — the lint job installs no
numpy/jax."""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .contracts import ALL_RULES, lint_contracts


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static repo-contract linter (R0xx rules).",
    )
    parser.add_argument(
        "--contracts",
        metavar="PACKAGE_DIR",
        help="package directory to lint (e.g. src/repro)",
    )
    parser.add_argument(
        "--rules",
        default=None,
        help=f"comma-separated rule subset (default: {','.join(ALL_RULES)})",
    )
    args = parser.parse_args(argv)
    if not args.contracts:
        parser.error("nothing to do: pass --contracts <package dir>")
    root = Path(args.contracts)
    if not root.is_dir():
        parser.error(f"not a directory: {root}")
    rules = (
        tuple(r.strip() for r in args.rules.split(",") if r.strip())
        if args.rules
        else None
    )
    diags = lint_contracts(root, rules=rules)
    for d in diags:
        print(d.render())
    errors = sum(1 for d in diags if d.severity == "error")
    print(
        f"contracts: {errors} error(s), {len(diags) - errors} warning(s) "
        f"over {root}"
    )
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
