"""Structured diagnostics shared by the plan analyzer and contract linter.

Every analysis failure is a :class:`Diagnostic` with a stable code —
``P0xx`` plan shape/schema, ``E0xx`` expression typing, ``R0xx`` repo
contracts — a human message, and provenance lines rendered like
``Dataset.explain()`` node listings (``node 3: Filter(...)``) or
``file:line`` for contract findings. Error-severity plan diagnostics
raise as :class:`PlanValidationError` before any executor thread,
process, or remote worker starts.

This module is stdlib-only on purpose: the contract-linter CLI
(``python -m repro.analysis``) runs in CI's lint job, which installs no
numpy/jax.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class Diagnostic:
    """One analysis finding with a stable code and provenance."""

    code: str  # "P0xx" plan | "E0xx" expression | "R0xx" repo contract
    message: str
    severity: str = "error"  # "error" | "warning"
    provenance: tuple[str, ...] = field(default=())

    def render(self) -> str:
        lines = [f"{self.code} {self.severity}: {self.message}"]
        lines += [f"    at {p}" for p in self.provenance]
        return "\n".join(lines)


def node_ref(index: int, node) -> str:
    """Provenance line for one plan node, in ``explain()``'s listing style."""
    return f"node {index}: {node.describe()}"


class PlanValidationError(ValueError):
    """A plan failed static validation.

    Subclasses ``ValueError`` so pre-analyzer call sites that caught the
    old mid-execution raises keep working; carries the structured
    ``diagnostics`` so tools can dispatch on codes instead of matching
    message text.
    """

    def __init__(self, diagnostics):
        self.diagnostics = tuple(diagnostics)
        body = "\n".join(d.render() for d in self.diagnostics)
        n = len(self.diagnostics)
        super().__init__(
            f"plan failed validation with {n} diagnostic{'s' if n != 1 else ''}:\n{body}"
        )
