"""Static analysis: plan diagnostics + repo contract linting.

Two halves:

* **Plan analyzer** (:mod:`plan_analyzer`, :mod:`expr_check`,
  :mod:`rewrites`) — typed schema inference, expression type checking,
  streaming-shape checks, and static verification of every optimizer
  rewrite. Surfaced as ``Dataset.validate()`` and auto-run at the head
  of every terminal, so an invalid plan fails with coded,
  provenance-bearing :class:`Diagnostic`\\ s before any executor thread,
  worker process, or remote coordinator starts.
* **Contract linter** (:mod:`contracts`, ``python -m repro.analysis``)
  — AST/import-graph rules for the repo's structural invariants (the
  jax-free worker tier, fork-safe byte paths, atomic cache/heartbeat
  writes, no bare excepts in the runtime).

This ``__init__`` stays stdlib-only: the contracts CLI runs in CI's lint
job with no numpy/jax installed, so the plan-analysis names (which pull
in :mod:`repro.core`) resolve lazily via PEP 562.
"""

from .diagnostics import Diagnostic, PlanValidationError, node_ref

_LAZY = {
    "analyze_plan": "plan_analyzer",
    "infer_schema": "plan_analyzer",
    "check_streaming_plan": "plan_analyzer",
    "check_row_program_plan": "plan_analyzer",
    "check_transform": "expr_check",
    "check_predicate": "expr_check",
    "verify_plan_rewrites": "rewrites",
    "verify_rewrite_pair": "rewrites",
    "lint_contracts": "contracts",
    "build_import_graph": "contracts",
}

__all__ = ["Diagnostic", "PlanValidationError", "node_ref", *_LAZY]


def __getattr__(name: str):
    submodule = _LAZY.get(name)
    if submodule is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module("." + submodule, __name__), name)
