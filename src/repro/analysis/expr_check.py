"""Expression type checker: predicate vs transform position, build-time
regex compilation, fingerprintability.

The expression IR (:mod:`repro.core.expr`) has two kinds — string
``Expr`` (transform position: ``Project`` entries) and boolean ``Pred``
(predicate position: ``Filter``) — plus ``WordCount``, which is neither
until compared against an int. The ``Dataset`` builder verbs enforce
this at construction time; this checker re-establishes it over any plan
node list (hand-built plans, deserialized plans, future per-request
serving plans) and adds the checks the builders skip: every regex op
compiles, every op fingerprints (a lambda word predicate is legal but
uncacheable and invisible to CSE — a warning, not an error).

Codes:

* ``E001`` — transform position needs a string expression
* ``E002`` — predicate position needs a predicate
* ``E003`` — regex op does not compile
* ``E004`` (warning) — unfingerprintable op (lambda word predicate)
* ``E005`` — expression reads a column the schema does not hold
"""

from __future__ import annotations

import re

from ..core import bytesops as B
from ..core import expr as E
from .diagnostics import Diagnostic


def _check_expr_body(
    what: str, e: E.Expr, columns: dict[str, str], ref: tuple[str, ...]
) -> list[Diagnostic]:
    """Checks shared by both positions: column reads, op validity."""
    diags: list[Diagnostic] = []
    unknown = sorted(n for n in e.inputs() if n not in columns)
    if unknown:
        diags.append(
            Diagnostic(
                "E005",
                f"{what} reads unknown column(s) {unknown}; "
                f"columns here are {sorted(columns)}",
                provenance=ref,
            )
        )
    for node in E.walk_exprs(e):
        if not isinstance(node, E.StrOp):
            continue
        op = node.op
        if op.kind == "regex" and op.regex is not None:
            try:
                re.compile(op.regex[0])
            except re.error as exc:
                diags.append(
                    Diagnostic(
                        "E003",
                        f"{what}: regex op {op.regex[0]!r} does not compile: {exc}",
                        provenance=ref,
                    )
                )
        try:
            B.op_signature(op)
        except B.UnfingerprintableOpError:
            diags.append(
                Diagnostic(
                    "E004",
                    f"{what}: op {node.label} is unfingerprintable (lambda "
                    "word predicate?) — it cannot cache and is invisible to "
                    "CSE; use a module-level function or functools.partial",
                    severity="warning",
                    provenance=ref,
                )
            )
    return diags


def check_transform(
    out_col: str, e, columns: dict[str, str], ref: tuple[str, ...]
) -> list[Diagnostic]:
    """Type-check one ``Project`` entry (transform position)."""
    what = f"Project entry {out_col!r}"
    if isinstance(e, E.Pred):
        return [
            Diagnostic(
                "E001",
                f"{what} needs a string expression, got the predicate "
                f"{e.describe()}; predicates belong in .where(...)",
                provenance=ref,
            )
        ]
    if isinstance(e, E.WordCount):
        return [
            Diagnostic(
                "E001",
                f"{what} needs a string expression, got {e.describe()} "
                "(an integer-valued count, not a column transform)",
                provenance=ref,
            )
        ]
    if not isinstance(e, E.Expr):
        return [
            Diagnostic(
                "E001",
                f"{what} needs a string expression, got {e!r}",
                provenance=ref,
            )
        ]
    return _check_expr_body(what, e, columns, ref)


def check_predicate(
    pred, columns: dict[str, str], ref: tuple[str, ...]
) -> list[Diagnostic]:
    """Type-check one ``Filter`` node's predicate (predicate position)."""
    if isinstance(pred, E.WordCount):
        return [
            Diagnostic(
                "E002",
                f"Filter needs a predicate, got {pred.describe()}; compare "
                "word_count() to an int (e.g. >= 5) to form one",
                provenance=ref,
            )
        ]
    if isinstance(pred, E.Expr):
        return [
            Diagnostic(
                "E002",
                f"Filter needs a predicate, got the string expression "
                f"{pred.describe()}; string transforms belong in a Project",
                provenance=ref,
            )
        ]
    if not isinstance(pred, E.Pred):
        return [
            Diagnostic(
                "E002", f"Filter needs a predicate, got {pred!r}", provenance=ref
            )
        ]
    diags: list[Diagnostic] = []
    for e in E.pred_exprs(pred):
        diags += _check_expr_body(f"Filter({pred.describe()})", e, columns, ref)
    return diags
