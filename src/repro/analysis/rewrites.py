"""Static rewrite verifier: re-check every optimizer rewrite for value
preservation before anything executes.

The optimizer (:func:`repro.core.plan.optimize_plan`) promises *exact*
rewrites — merge, conjunct-split filter pushdown, dead-column pruning,
source narrowing, cross-node CSE. This module re-derives that promise
per plan instead of trusting it: it walks the logical and the optimized
frame plans in parallel, tracking a per-column *version* (an
alias-transparent resolved signature of what the column holds), and
compares the artifacts a correct rewrite must preserve:

* the multiset of row-filter conjuncts per *era* (the stretch between
  order-pinning nodes — ``DropDuplicates``/``Split``; filters commute
  freely within an era but must never cross one) — ``P012``;
* the ``DropDuplicates`` sequence and the versions of its key columns —
  ``P015``;
* the version of every final-schema column (``P011`` when a column is
  lost outright, ``P013`` when its value lineage changed);
* well-formedness of the optimized plan itself: no node reads a column
  no prior node defines — ``P010``.

Alias transparency is the load-bearing difference from
:func:`repro.core.expr.resolved_signature` (the CSE-internal resolver):
here ``col("__cse_ab12")`` resolves straight to the signature of the
expression it memoizes, so the hoisted form and the inlined form compare
equal — which is exactly the property that makes the CSE rewrite exact.

Unfingerprintable subtrees (lambda word predicates) resolve to ``None``
and are excluded from comparison; if the two sides disagree on *how
many* conjuncts are unverifiable, that surfaces as a ``P012`` warning
rather than silence.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

from ..core import bytesops as B
from ..core import expr as E
from ..core import plan as P
from .diagnostics import Diagnostic, node_ref

_MISSING = b"<missing>"


def _len_prefixed(parts: Sequence[bytes]) -> bytes:
    return b"".join(len(p).to_bytes(8, "little") + p for p in parts)


def _resolve_expr(e, versions: dict[str, bytes | None]) -> bytes | None:
    """Alias-transparent version-resolved signature (None = unverifiable)."""
    if isinstance(e, E.Col):
        return versions.get(e.name, b"src:" + e.name.encode())
    if isinstance(e, E.Lit):
        return e.signature()
    if isinstance(e, E.StrOp):
        base = _resolve_expr(e.input, versions)
        if base is None:
            return None
        try:
            osig = B.op_signature(e.op)
        except B.UnfingerprintableOpError:
            return None
        return _len_prefixed([base, b"op:" + osig])
    if isinstance(e, E.Concat):
        parts = [_resolve_expr(p, versions) for p in e.parts]
        if any(s is None for s in parts):
            return None
        return b"concat:" + e.sep.encode() + b":" + _len_prefixed(
            [s for s in parts if s is not None]
        )
    return None


def _resolve_pred(p, versions: dict[str, bytes | None]) -> bytes | None:
    if isinstance(p, E.NotEmpty):
        base = _resolve_expr(p.input, versions)
        return None if base is None else b"notempty:" + base
    if isinstance(p, E.Contains):
        base = _resolve_expr(p.input, versions)
        if base is None:
            return None
        return b"contains:" + p.needle.encode() + b":" + base
    if isinstance(p, E.Compare):
        base = _resolve_expr(p.left.input, versions)
        if base is None:
            return None
        return b"wc" + p.op.encode() + str(p.right).encode() + b":" + base
    if isinstance(p, E.BoolOp):
        left = _resolve_pred(p.left, versions)
        right = _resolve_pred(p.right, versions)
        if left is None or right is None:
            return None
        return p.kind.encode() + b":" + _len_prefixed([left, right])
    if isinstance(p, E.NotOp):
        base = _resolve_pred(p.input, versions)
        return None if base is None else b"not:" + base
    return None


@dataclass
class _WalkState:
    """Everything a correct rewrite must preserve, from one plan walk."""

    final: dict[str, bytes | None] = field(default_factory=dict)
    # (era, resolved conjunct signature) — row filters, DropNA included
    conjuncts: list[tuple[int, bytes]] = field(default_factory=list)
    unverifiable: int = 0  # conjuncts that resolved to None
    # ordered DropDuplicates records: (subset names, subset col versions)
    dedups: list[tuple[tuple[str, ...], tuple[bytes, ...]]] = field(
        default_factory=list
    )
    # (node index, node, missing column names) — reads of undefined columns
    undefined: list[tuple[int, object, list[str]]] = field(default_factory=list)


def _walk(frame_nodes: Sequence[P.PlanNode]) -> _WalkState:
    st = _WalkState()
    versions: dict[str, bytes | None] = {}
    era = 0
    if not frame_nodes:
        return st
    src = frame_nodes[0]
    if isinstance(src, P.SourceJsonDirs):
        fields: tuple[str, ...] = src.fields
    elif isinstance(src, P.SourceFrame):
        fields = tuple(src.frame.field_names)
    else:
        fields = ()
    versions = {f: b"src:" + f.encode() for f in fields}

    def missing(cols) -> list[str]:
        return sorted(c for c in cols if c not in versions)

    def conjunct(sig: bytes | None) -> None:
        if sig is None:
            st.unverifiable += 1
        else:
            st.conjuncts.append((era, sig))

    for i, node in enumerate(frame_nodes[1:], start=1):
        if isinstance(node, P.Select):
            miss = missing(node.fields)
            if miss:
                st.undefined.append((i, node, miss))
            versions = {c: versions[c] for c in node.fields if c in versions}
        elif isinstance(node, P.DropNA):
            miss = missing(node.subset)
            if miss:
                st.undefined.append((i, node, miss))
            for c in node.subset:
                v = versions.get(c, _MISSING)
                conjunct(None if v is None else b"dropna:" + v)
        elif isinstance(node, P.Filter):
            if not isinstance(node.pred, E.Pred):
                conjunct(None)
                continue
            miss = missing(node.pred.inputs())
            if miss:
                st.undefined.append((i, node, miss))
            for conj in E.split_conjuncts(node.pred):
                conjunct(_resolve_pred(conj, versions))
        elif isinstance(node, P.DropDuplicates):
            miss = missing(node.subset)
            if miss:
                st.undefined.append((i, node, miss))
            # None (unverifiable) maps to a fixed token; signatures are
            # never empty, so ``or`` is safe here.
            sigs = tuple(
                versions.get(c, _MISSING) or b"<?>" for c in node.subset
            )
            st.dedups.append((tuple(node.subset), sigs))
            era += 1
        elif isinstance(node, P.Project):
            for out_col, e in node.exprs:
                if isinstance(e, E.Expr):
                    miss = missing(e.inputs())
                    if miss:
                        st.undefined.append((i, node, miss))
                    versions[out_col] = _resolve_expr(e, versions)
                else:
                    versions[out_col] = None
        elif isinstance(node, P.Split):
            era += 1
    st.final = versions
    return st


def verify_rewrite_pair(
    logical: Sequence[P.PlanNode],
    optimized: Sequence[P.PlanNode],
    final_schema: Sequence[str] = (),
) -> list[Diagnostic]:
    """Compare a logical frame plan against a claimed-equivalent rewrite."""
    diags: list[Diagnostic] = []
    lst = _walk(list(logical))
    ost = _walk(list(optimized))

    for i, node, miss in ost.undefined:
        diags.append(
            Diagnostic(
                "P010",
                f"optimized plan reads column(s) {miss} no prior node defines "
                "(rewrite broke column scoping)",
                provenance=(node_ref(i, node),),
            )
        )

    # Row-filter conjuncts per era: filters are idempotent and commute
    # within an era, so compare as sets of resolved signatures.
    eras = {e for e, _ in lst.conjuncts} | {e for e, _ in ost.conjuncts}
    for era in sorted(eras):
        lset = {s for e, s in lst.conjuncts if e == era}
        oset = {s for e, s in ost.conjuncts if e == era}
        if lset != oset:
            dropped = len(lset - oset)
            added = len(oset - lset)
            diags.append(
                Diagnostic(
                    "P012",
                    f"rewrite changed the row-filter set in plan era {era}: "
                    f"{dropped} conjunct(s) dropped, {added} added — rows "
                    "would survive differently",
                )
            )
    if lst.unverifiable != ost.unverifiable:
        diags.append(
            Diagnostic(
                "P012",
                f"rewrite changed the number of unverifiable conjuncts "
                f"({lst.unverifiable} -> {ost.unverifiable}); equivalence "
                "cannot be established for them",
                severity="warning",
            )
        )

    if [d[0] for d in lst.dedups] != [d[0] for d in ost.dedups]:
        diags.append(
            Diagnostic(
                "P015",
                f"rewrite changed the DropDuplicates sequence: "
                f"{[list(d[0]) for d in lst.dedups]} -> "
                f"{[list(d[0]) for d in ost.dedups]}",
            )
        )
    else:
        for (subset, lsigs), (_, osigs) in zip(lst.dedups, ost.dedups):
            if lsigs != osigs:
                diags.append(
                    Diagnostic(
                        "P015",
                        f"rewrite changed what DropDuplicates({list(subset)}) "
                        "keys on: the dedup key columns hold different values "
                        "at that point of the rewritten plan",
                    )
                )

    for c in final_schema:
        lv = lst.final.get(c, _MISSING)
        ov = ost.final.get(c, _MISSING)
        if lv is _MISSING:
            continue  # the logical plan never produced it (schema drift
            # upstream — infer_schema reports that as P006)
        if ov is _MISSING:
            diags.append(
                Diagnostic(
                    "P011",
                    f"rewrite lost final column {c!r}: the optimized plan "
                    "never produces it",
                )
            )
        elif lv is not None and ov is not None and lv != ov:
            diags.append(
                Diagnostic(
                    "P013",
                    f"rewrite changed the value lineage of final column "
                    f"{c!r}: it would hold different bytes after the "
                    "optimized plan",
                )
            )
    return diags


def verify_plan_rewrites(
    frame_nodes: Sequence[P.PlanNode], final_schema: Sequence[str] = ()
) -> list[Diagnostic]:
    """Optimize ``frame_nodes`` and statically verify the rewrite. A crash
    inside the verifier itself degrades to a warning diagnostic — the
    verifier must never be the thing that blocks a valid plan."""
    try:
        optimized = P.optimize_plan(list(frame_nodes), final_schema)
        return verify_rewrite_pair(frame_nodes, optimized, final_schema)
    except Exception as exc:  # noqa: BLE001 - degrade, never crash validate
        return [
            Diagnostic(
                "P011",
                f"rewrite verifier failed to analyze this plan: {exc!r}",
                severity="warning",
            )
        ]
