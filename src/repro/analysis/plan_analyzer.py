"""Plan analyzer: typed schema inference + streaming-shape checks.

This is the analyzed-logical-plan phase our Catalyst-style optimizer was
missing (Spark resolves and type-checks a plan before any physical
operator runs). :func:`infer_schema` walks the node list tracking each
column's type (``"str"`` text column, ``"tokens"`` int32 token output)
and read/write sets; :func:`check_streaming_plan` re-derives every shape
requirement of :func:`repro.core.plan.stream_batches` against the
*optimized* frame plan — the same plan the runtime checks — so
``Dataset.validate()`` rejects exactly the plans execution would, but
before a single shard reader, worker process, or remote coordinator
starts. :func:`analyze_plan` is the composite entry point
``Dataset.validate()`` calls.

Codes (``E0xx`` come from :mod:`repro.analysis.expr_check`, ``P010+``
from :mod:`repro.analysis.rewrites`):

* ``P001`` — streaming requires a ``SourceJsonDirs`` plan
* ``P002`` — ``Split`` cannot stream
* ``P003`` / ``P004`` — streaming missing ``Tokenize`` / ``Batch``
* ``P005`` — partial-subset dedup stacked with another dedup
* ``P006`` — node reads a column the schema does not hold
* ``P007`` — frame-level node after an array-level node
* ``P008`` — invalid Tokenize/Batch/Prefetch configuration
* ``P009`` — off-grid bucket widths
* ``P014`` — plan does not start with a source node
* ``P016`` — plan not row-program-eligible (cross-row / whole-frame steps,
  non-shard source, or missing ``Tokenize``) — see
  :func:`check_row_program_plan`
"""

from __future__ import annotations

from typing import Sequence

from ..core import plan as P
from .diagnostics import Diagnostic, node_ref
from .expr_check import check_predicate, check_transform


def _source_fields(node: P.PlanNode) -> tuple[str, ...] | None:
    if isinstance(node, P.SourceJsonDirs):
        return node.fields
    if isinstance(node, P.SourceFrame):
        return tuple(node.frame.field_names)
    return None


def _unknown(cols, schema: dict[str, str]) -> list[str]:
    return sorted(c for c in cols if c not in schema)


def infer_schema(
    nodes: Sequence[P.PlanNode],
) -> tuple[dict[str, str], list[Diagnostic]]:
    """Walk the plan inferring ``{column: "str" | "tokens"}``; collect
    every schema/shape/typing diagnostic along the way."""
    nodes = list(nodes)
    diags: list[Diagnostic] = []
    if not nodes or _source_fields(nodes[0]) is None:
        ref = (node_ref(0, nodes[0]),) if nodes else ()
        diags.append(
            Diagnostic(
                "P014",
                "plan must start with a source node (SourceJsonDirs or "
                "SourceFrame)",
                provenance=ref,
            )
        )
        return {}, diags

    columns: dict[str, str] = {f: "str" for f in _source_fields(nodes[0]) or ()}
    first_array: tuple[int, P.PlanNode] | None = None
    tok: P.Tokenize | None = None

    for i, node in enumerate(nodes[1:], start=1):
        ref = (node_ref(i, node),)
        if _source_fields(node) is not None:
            diags.append(
                Diagnostic(
                    "P014", "second source node mid-plan", provenance=ref
                )
            )
            continue
        if P.is_frame_node(node):
            if first_array is not None:
                fi, fn = first_array
                diags.append(
                    Diagnostic(
                        "P007",
                        f"frame-level {type(node).__name__} after array-level "
                        f"{type(fn).__name__}; frame verbs must come before "
                        "tokenize/batch/prefetch",
                        provenance=(node_ref(fi, fn), node_ref(i, node)),
                    )
                )
                continue  # don't cascade column checks against token schema
            if isinstance(node, P.Select):
                unknown = _unknown(node.fields, columns)
                if unknown:
                    diags.append(
                        Diagnostic(
                            "P006",
                            f"Select reads unknown column(s) {unknown}; "
                            f"columns here are {sorted(columns)}",
                            provenance=ref,
                        )
                    )
                columns = {c: columns[c] for c in node.fields if c in columns}
            elif isinstance(node, (P.DropNA, P.DropDuplicates)):
                unknown = _unknown(node.subset, columns)
                if unknown:
                    diags.append(
                        Diagnostic(
                            "P006",
                            f"{type(node).__name__} reads unknown column(s) "
                            f"{unknown}; columns here are {sorted(columns)}",
                            provenance=ref,
                        )
                    )
            elif isinstance(node, P.Project):
                for out_col, e in node.exprs:
                    diags += check_transform(out_col, e, columns, ref)
                    columns[out_col] = "str"
            elif isinstance(node, P.Filter):
                diags += check_predicate(node.pred, columns, ref)
            # Split: row partition, schema unchanged.
            continue

        # -- array-level suffix ------------------------------------------
        if first_array is None:
            first_array = (i, node)
        if isinstance(node, P.Tokenize):
            if tok is not None:
                diags.append(
                    Diagnostic(
                        "P008",
                        "second Tokenize node in the plan; one plan encodes "
                        "one token spec set",
                        provenance=ref,
                    )
                )
            for spec in node.specs:
                if columns.get(spec.column) != "str":
                    diags.append(
                        Diagnostic(
                            "P006",
                            f"tokenize spec {spec.name!r} reads "
                            f"{spec.column!r}, which is not a text column "
                            f"here; columns are {sorted(columns)}",
                            provenance=ref,
                        )
                    )
                if spec.max_len < 1:
                    diags.append(
                        Diagnostic(
                            "P008",
                            f"tokenize spec {spec.name!r} has max_len="
                            f"{spec.max_len}; must be >= 1",
                            provenance=ref,
                        )
                    )
            tok = node
            columns = {s.name: "tokens" for s in node.specs}
        elif isinstance(node, P.Batch):
            diags += _check_batch(node, tok, columns, ref)
        elif isinstance(node, P.Prefetch):
            if node.prefetch < 1:
                diags.append(
                    Diagnostic(
                        "P008",
                        f"Prefetch depth {node.prefetch}; must be >= 1",
                        provenance=ref,
                    )
                )
    return columns, diags


def _check_batch(
    node: P.Batch,
    tok: P.Tokenize | None,
    columns: dict[str, str],
    ref: tuple[str, ...],
) -> list[Diagnostic]:
    diags: list[Diagnostic] = []
    if node.batch_size < 1:
        diags.append(
            Diagnostic(
                "P008",
                f"batch_size={node.batch_size}; must be >= 1",
                provenance=ref,
            )
        )
    if tok is None:
        diags.append(
            Diagnostic(
                "P008",
                "Batch requires a Tokenize node earlier in the plan "
                "(batches are assembled from token arrays)",
                provenance=ref,
            )
        )
        return diags
    if node.bucket_by is None:
        return diags
    bcols = (
        (node.bucket_by,) if isinstance(node.bucket_by, str) else tuple(node.bucket_by)
    )
    specs_by_name = {s.name: s for s in tok.specs}
    for c in bcols:
        if columns.get(c) != "tokens":
            diags.append(
                Diagnostic(
                    "P008",
                    f"bucket_by={c!r} is not a token output; available: "
                    f"{sorted(specs_by_name)}",
                    provenance=ref,
                )
            )
    if not node.buckets:
        return diags
    widths_per_col: tuple = (
        (node.buckets,)
        if node.buckets and isinstance(node.buckets[0], int)
        else tuple(node.buckets)
    )
    if len(widths_per_col) != len(bcols):
        diags.append(
            Diagnostic(
                "P008",
                f"{len(widths_per_col)} bucket width list(s) for "
                f"{len(bcols)} bucket column(s)",
                provenance=ref,
            )
        )
        return diags
    for c, widths in zip(bcols, widths_per_col):
        spec = specs_by_name.get(c)
        ws = tuple(widths)
        if not ws:
            continue
        if list(ws) != sorted(set(ws)) or ws[0] < 1:
            diags.append(
                Diagnostic(
                    "P009",
                    f"bucket widths for {c!r} must be strictly increasing "
                    f"and >= 1, got {list(ws)}",
                    provenance=ref,
                )
            )
        elif spec is not None and ws[-1] < spec.max_len:
            diags.append(
                Diagnostic(
                    "P009",
                    f"top bucket width {ws[-1]} for {c!r} is below the "
                    f"spec's max_len={spec.max_len}; the longest rows would "
                    "not fit any bucket",
                    provenance=ref,
                )
            )
    return diags


def check_streaming_plan(
    nodes: Sequence[P.PlanNode],
    *,
    final_schema: Sequence[str] = (),
    optimize: bool = True,
    optimized_frame_nodes: Sequence[P.PlanNode] | None = None,
) -> list[Diagnostic]:
    """The shape requirements of :func:`repro.core.plan.stream_batches`,
    as diagnostics. Evaluated against the optimized frame plan (pass
    ``optimized_frame_nodes`` to reuse one already computed) because
    that is what streams — e.g. source narrowing can turn a
    partial-subset dedup into a full-subset one."""
    nodes = list(nodes)
    diags: list[Diagnostic] = []
    frame_nodes, array_nodes = P.split_plan(nodes)
    if optimized_frame_nodes is not None:
        frame_nodes = list(optimized_frame_nodes)
    elif optimize:
        try:
            frame_nodes = P.optimize_plan(frame_nodes, final_schema)
        except Exception:  # noqa: BLE001 - malformed plan: check unoptimized
            pass

    src = frame_nodes[0] if frame_nodes else None
    if not isinstance(src, P.SourceJsonDirs):
        ref = (node_ref(0, nodes[0]),) if nodes else ()
        diags.append(
            Diagnostic(
                "P001",
                "streaming execution requires a SourceJsonDirs plan "
                "(an in-memory frame has no shards to stream)",
                provenance=ref,
            )
        )
    splits = [(i, n) for i, n in enumerate(nodes) if isinstance(n, P.Split)]
    if splits:
        diags.append(
            Diagnostic(
                "P002",
                "Split is whole-frame only; drop .prefetch() or .split()",
                provenance=tuple(node_ref(i, n) for i, n in splits),
            )
        )
    tok = next((n for n in array_nodes if isinstance(n, P.Tokenize)), None)
    batch = next((n for n in array_nodes if isinstance(n, P.Batch)), None)
    # Provenance for a *missing* node points at what makes the plan stream:
    # the Prefetch node when there is one, else the source.
    stream_ref = next(
        (
            (node_ref(i, n),)
            for i, n in enumerate(nodes)
            if isinstance(n, P.Prefetch)
        ),
        (node_ref(0, nodes[0]),) if nodes else (),
    )
    if tok is None:
        diags.append(
            Diagnostic(
                "P003",
                "streaming needs .tokenize(...) in the plan (executors emit "
                "token buffers, not raw text)",
                provenance=stream_ref,
            )
        )
    if batch is None:
        diags.append(
            Diagnostic(
                "P004",
                "streaming needs .batch(...) in the plan",
                provenance=stream_ref,
            )
        )
    if isinstance(src, P.SourceJsonDirs):
        dedups = [n for n in frame_nodes[1:] if isinstance(n, P.DropDuplicates)]
        partial = [d for d in dedups if not set(d.subset) >= set(src.fields)]
        if partial and len(dedups) > 1:
            # Provenance names the stacked Dedup nodes at their *logical*
            # plan positions (the optimizer never adds or removes dedups).
            refs = tuple(
                node_ref(i, n)
                for i, n in enumerate(nodes)
                if isinstance(n, P.DropDuplicates)
            )
            diags.append(
                Diagnostic(
                    "P005",
                    f"streaming drop_duplicates({list(partial[0].subset)}) "
                    "with partial subsets cannot stack with another "
                    "drop_duplicates; drop .prefetch() for whole-frame "
                    "execution",
                    provenance=refs,
                )
            )
    return diags


def check_row_program_plan(nodes: Sequence[P.PlanNode]) -> list[Diagnostic]:
    """Row-program eligibility (``Dataset.row_program()``): every step must
    be executable on a single row in isolation.

    A served request is one row; anything that consults other rows
    (``drop_duplicates`` — cross-row keep-first state), partitions the
    whole frame (``split``), or changes batch assembly (``batch`` /
    ``prefetch`` are simply ignored — they shape training streams, not
    per-request encoding) cannot be part of the request path. The plan
    must also start from ``SourceJsonDirs`` (the shard-program compiler's
    contract — field names come from the source) and carry a ``Tokenize``
    node, because a row program's output is token arrays.
    """
    nodes = list(nodes)
    diags: list[Diagnostic] = []
    if not nodes or not isinstance(nodes[0], P.SourceJsonDirs):
        ref = (node_ref(0, nodes[0]),) if nodes else ()
        diags.append(
            Diagnostic(
                "P016",
                "row programs require a SourceJsonDirs plan (field names and "
                "the shard-program compiler both come from the source)",
                provenance=ref,
            )
        )
    for i, node in enumerate(nodes):
        ref = (node_ref(i, node),)
        if isinstance(node, P.DropDuplicates):
            diags.append(
                Diagnostic(
                    "P016",
                    "drop_duplicates holds cross-row keep-first state; a "
                    "single served request cannot evaluate it — drop it from "
                    "the serving chain",
                    provenance=ref,
                )
            )
        elif isinstance(node, P.Split):
            diags.append(
                Diagnostic(
                    "P016",
                    "split partitions the whole frame; not row-executable",
                    provenance=ref,
                )
            )
    if not any(isinstance(n, P.Tokenize) for n in nodes):
        ref = (node_ref(0, nodes[0]),) if nodes else ()
        diags.append(
            Diagnostic(
                "P016",
                "row programs encode requests to token arrays; add "
                ".tokenize(...) to the chain",
                provenance=ref,
            )
        )
    return diags


def analyze_plan(
    nodes: Sequence[P.PlanNode],
    *,
    final_schema: Sequence[str] = (),
    streaming: bool = False,
    optimize: bool = True,
) -> list[Diagnostic]:
    """Full static analysis of one plan: schema/type inference, streaming
    shape checks (when the plan would stream), and — on an otherwise clean
    plan — rewrite verification of the optimizer's output. Returns every
    diagnostic; callers decide whether warnings block."""
    from .rewrites import verify_plan_rewrites

    nodes = list(nodes)
    _, diags = infer_schema(nodes)
    if streaming:
        diags += check_streaming_plan(
            nodes, final_schema=final_schema, optimize=optimize
        )
    if optimize and not any(d.severity == "error" for d in diags) and nodes:
        frame_nodes, _ = P.split_plan(nodes)
        diags += verify_plan_rewrites(frame_nodes, final_schema)
    return diags
